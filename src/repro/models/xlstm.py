"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, recurrent), in the 7:1 arrangement of the xLSTM paper.

The mLSTM is executed in a chunked linear-attention form (O(S*Q) like the
Mamba2 SSD path) with exponential input gates and sigmoid forget gates; we
omit the paper's max-stabilizer in the chunked path (compute is fp32 and the
gates are bounded at init) — shapes and FLOPs match the stabilized version.
The sLSTM's recurrent gate connections make it inherently sequential; it runs
as a ``lax.scan`` over time (O(1) state => long_500k eligible).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import dense_init, embed, init_embed, init_mlp, mlp, rms_norm, shard, unembed


def mlstm_dims(cfg: ModelConfig) -> tuple:
    d_in = 2 * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    return d_in, H, P


def slstm_dims(cfg: ModelConfig) -> tuple:
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def ffn_dim(cfg: ModelConfig) -> int:
    # xLSTM uses a 4/3 projection-factor FFN after sLSTM blocks (d_ff=0 in the
    # assigned config means "use the family default").
    return int(math.ceil(4 * cfg.d_model / 3 / 128) * 128)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, P = mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    pdt = cfg.jparam_dtype
    return {
        "ln": jnp.ones((d,), pdt),
        "up": dense_init(ks[0], (d, 2 * d_in), pdt),          # x_in, z
        "wq": dense_init(ks[1], (d_in, d_in), pdt),
        "wk": dense_init(ks[2], (d_in, d_in), pdt),
        "wv": dense_init(ks[3], (d_in, d_in), pdt),
        "wif": dense_init(ks[4], (d_in, 2 * H), pdt),         # input/forget gates
        "down": dense_init(ks[5], (d_in, d), pdt),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int) -> jax.Array:
    """q,k,v: (B,S,H,P) fp32; li: log input gate, lf: log forget gate (B,S,H).
    Returns h (B,S,H,P)."""
    B, S, H, P = q.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    r = lambda a: a.reshape((B, nc, Q) + a.shape[2:])
    q, k, v, li, lf = map(r, (q, k, v, li, lf))
    scale = 1.0 / math.sqrt(P)

    A = jnp.cumsum(lf, axis=2)                                   # (B,nc,Q,H) inclusive
    # intra-chunk decay: D_ij = exp(A_i - A_j + li_j), j <= i
    diff = A[:, :, :, None, :] - A[:, :, None, :, :] + li[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    D = jnp.where(mask, jnp.exp(diff), 0.0)                      # (B,nc,Q,Q,H)
    qk = jnp.einsum("bcqhp,bckhp->bcqkh", q, k) * scale          # (B,nc,Q,Q,H)
    w = qk * D
    intra_h = jnp.einsum("bcqkh,bckhp->bcqhp", w, v)
    intra_n = w.sum(axis=3)                                      # (B,nc,Q,H) = q.n intra

    # inter-chunk state: C (B,H,P,P), n (B,H,P)
    dec_state = jnp.exp(A[:, :, -1:, :] - A + li)                # (B,nc,Q,H)
    new_C = jnp.einsum("bcqh,bcqhp,bcqhr->bchpr", dec_state, k, v)
    new_n = jnp.einsum("bcqh,bcqhp->bchp", dec_state, k)
    chunk_dec = jnp.exp(A[:, :, -1, :])                          # (B,nc,H)

    def step(carry, inp):
        C, n = carry
        nC, nn, cd = inp
        out = (C, n)
        C = C * cd[:, :, None, None] + nC
        n = n * cd[:, :, None] + nn
        return (C, n), out

    C0 = jnp.zeros((B, H, P, P), q.dtype)
    n0 = jnp.zeros((B, H, P), q.dtype)
    (_, _), (Cs, ns) = jax.lax.scan(
        step, (C0, n0),
        (new_C.transpose(1, 0, 2, 3, 4), new_n.transpose(1, 0, 2, 3),
         chunk_dec.transpose(1, 0, 2)))
    Cs = Cs.transpose(1, 0, 2, 3, 4)                             # (B,nc,H,P,P) pre-chunk states
    ns = ns.transpose(1, 0, 2, 3)

    inter_h = jnp.einsum("bcqh,bcqhp,bchpr->bcqhr", jnp.exp(A), q * scale, Cs)
    inter_n = jnp.einsum("bcqh,bcqhp,bchp->bcqh", jnp.exp(A), q * scale, ns)
    denom = jnp.maximum(jnp.abs(intra_n + inter_n), 1.0)
    h = (intra_h + inter_h) / denom[..., None]
    return h.reshape(B, S, H, P)


def mlstm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    d_in, H, P = mlstm_dims(cfg)
    dt = x.dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["up"].astype(dt))
    x_in, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ef->bsf", x_in, p["wq"].astype(dt)).reshape(B, S, H, P)
    k = jnp.einsum("bse,ef->bsf", x_in, p["wk"].astype(dt)).reshape(B, S, H, P)
    v = jnp.einsum("bse,ef->bsf", x_in, p["wv"].astype(dt)).reshape(B, S, H, P)
    gates = jnp.einsum("bse,eg->bsg", x_in, p["wif"].astype(dt)).astype(jnp.float32)
    gi, gf = jnp.split(gates, 2, axis=-1)                        # (B,S,H)
    li = -jax.nn.softplus(-gi)                                   # log sigmoid — bounded <= 0
    lf = -jax.nn.softplus(-gf)
    y = _mlstm_chunked(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), li, lf, cfg.xlstm_chunk)
    y = y.reshape(B, S, d_in).astype(dt) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["down"].astype(dt))
    return shard(out, "batch", "seq", "d_model")


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, P, P)
    n: jax.Array   # (B, H, P)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    d_in, H, P = mlstm_dims(cfg)
    return MLSTMState(C=jnp.zeros((batch, H, P, P), jnp.float32),
                      n=jnp.zeros((batch, H, P), jnp.float32))


def mlstm_decode_step(p: dict, x: jax.Array, state: MLSTMState, cfg: ModelConfig):
    B = x.shape[0]
    d_in, H, P = mlstm_dims(cfg)
    dt = x.dtype
    h = rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]
    up = jnp.einsum("bd,de->be", h, p["up"].astype(dt))
    x_in, z = jnp.split(up, 2, axis=-1)
    q = (x_in @ p["wq"].astype(dt)).reshape(B, H, P).astype(jnp.float32)
    k = (x_in @ p["wk"].astype(dt)).reshape(B, H, P).astype(jnp.float32)
    v = (x_in @ p["wv"].astype(dt)).reshape(B, H, P).astype(jnp.float32)
    gates = (x_in @ p["wif"].astype(dt)).astype(jnp.float32)
    gi, gf = jnp.split(gates, 2, axis=-1)
    fi = jnp.exp(-jax.nn.softplus(-gi))                          # sigmoid-style gates
    ff = jnp.exp(-jax.nn.softplus(-gf))
    C = state.C * ff[..., None, None] + fi[..., None, None] * jnp.einsum(
        "bhp,bhr->bhpr", k, v)
    n = state.n * ff[..., None] + fi[..., None] * k
    scale = 1.0 / math.sqrt(P)
    num = jnp.einsum("bhp,bhpr->bhr", q * scale, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q * scale, n)), 1.0)
    y = (num / den[..., None]).reshape(B, d_in).astype(dt) * jax.nn.silu(z)
    out = (y @ p["down"].astype(dt))[:, None]
    return out, MLSTMState(C=C, n=n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, dh = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    pdt = cfg.jparam_dtype
    return {
        "ln": jnp.ones((d,), pdt),
        "wx": dense_init(ks[0], (d, 4 * d), pdt),                # z,i,f,o from input
        "r": dense_init(ks[1], (H, dh, 4 * dh), pdt) * 0.1,      # recurrent, block-diag per head
        "ln2": jnp.ones((d,), pdt),
        "ffn": init_mlp(ks[2], cfg, d_ff=ffn_dim(cfg)),
        "out": dense_init(ks[3], (d, d), pdt),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, d)
    n: jax.Array
    m: jax.Array
    h: jax.Array


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=jnp.copy(z), m=jnp.full((batch, d), -1e30), h=jnp.copy(z))


def _slstm_cell(p, xt, state: SLSTMState, cfg: ModelConfig) -> SLSTMState:
    """One recurrent step.  xt: (B, d) fp32 pre-activation from W x."""
    B, d = state.h.shape
    H, dh = slstm_dims(cfg)
    hr = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hr, p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    zt, it, ft, ot = jnp.split(xt + rec, 4, axis=-1)
    m_new = jnp.maximum(ft + state.m, it)                        # log-space stabilizer
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + state.m - m_new)
    c = f_ * state.c + i_ * jnp.tanh(zt)
    n = jnp.maximum(f_ * state.n + i_, 1e-6)
    h = jax.nn.sigmoid(ot) * c / n
    return SLSTMState(c=c, n=n, m=m_new, h=h)


def slstm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    dt = x.dtype
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    xt = jnp.einsum("bsd,de->bse", h_in, p["wx"].astype(dt)).astype(jnp.float32)

    def step(state, x_t):
        new = _slstm_cell(p, x_t, state, cfg)
        return new, new.h

    s0 = init_slstm_state(cfg, B)
    _, hs = jax.lax.scan(step, s0, xt.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(dt)                         # (B,S,d)
    y = jnp.einsum("bsd,de->bse", y, p["out"].astype(dt))
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["ffn"], h2, cfg)


def slstm_decode_step(p: dict, x: jax.Array, state: SLSTMState, cfg: ModelConfig):
    dt = x.dtype
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]
    xt = (h_in @ p["wx"].astype(dt)).astype(jnp.float32)
    new = _slstm_cell(p, xt, state, cfg)
    y = (new.h.astype(dt) @ p["out"].astype(dt))[:, None]
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp(p["ffn"], h2, cfg), new


# ---------------------------------------------------------------------------
# Full model: groups of (slstm_every - 1) mLSTM + 1 sLSTM
# ---------------------------------------------------------------------------

def xlstm_group_shape(cfg: ModelConfig) -> tuple:
    k = cfg.slstm_every
    assert cfg.n_layers % k == 0, "n_layers must be divisible by slstm_every"
    return cfg.n_layers // k, k - 1          # (n_groups, mlstm per group)


def init_params(key, cfg: ModelConfig) -> dict:
    ng, nm = xlstm_group_shape(cfg)
    ke, km, ks = jax.random.split(key, 3)
    mkeys = jax.random.split(km, ng * nm)
    ml = jax.vmap(lambda k: init_mlstm(k, cfg))(mkeys)
    ml = jax.tree.map(lambda a: a.reshape((ng, nm) + a.shape[1:]), ml)
    skeys = jax.random.split(ks, ng)
    sl = jax.vmap(lambda k: init_slstm(k, cfg))(skeys)
    return {
        "embed": init_embed(ke, cfg),
        "mlstm": ml,              # (ng, nm, ...)
        "slstm": sl,              # (ng, ...)
        "ln_f": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
    }


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig) -> tuple:
    x = embed(params["embed"], tokens, cfg)

    def gbody(x, inp):
        mg, sg = inp

        def mbody(x, lp):
            return x + mlstm_forward(lp, x, cfg), None

        x, _ = jax.lax.scan(mbody, x, mg)
        x = slstm_forward(sg, x, cfg)
        return x, None

    if cfg.remat == "block":
        gbody = jax.checkpoint(gbody)
    x, _ = jax.lax.scan(gbody, x, (params["mlstm"], params["slstm"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


class XLSTMState(NamedTuple):
    ml: MLSTMState    # (ng, nm, ...)
    sl: SLSTMState    # (ng, ...)


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int = 0) -> XLSTMState:
    ng, nm = xlstm_group_shape(cfg)
    d_in, H, P = mlstm_dims(cfg)
    d = cfg.d_model
    ml = MLSTMState(
        C=jnp.zeros((ng, nm, batch, H, P, P), jnp.float32),
        n=jnp.zeros((ng, nm, batch, H, P), jnp.float32),
    )
    sl = SLSTMState(
        c=jnp.zeros((ng, batch, d), jnp.float32),
        n=jnp.zeros((ng, batch, d), jnp.float32),
        m=jnp.full((ng, batch, d), -1e30),
        h=jnp.zeros((ng, batch, d), jnp.float32),
    )
    return XLSTMState(ml, sl)


def decode_step(params: dict, state: XLSTMState, token: jax.Array, cfg: ModelConfig):
    x = embed(params["embed"], token, cfg)

    def gbody(x, inp):
        mg, sg, mstate, sstate = inp

        def mbody(x, linp):
            lp, ls = linp
            y, new = mlstm_decode_step(lp, x, ls, cfg)
            return x + y, new

        x, new_m = jax.lax.scan(mbody, x, (mg, mstate))
        x, new_s = slstm_decode_step(sg, x, sstate, cfg)
        return x, (new_m, new_s)

    x, (new_ml, new_sl) = jax.lax.scan(
        gbody, x, (params["mlstm"], params["slstm"], state.ml, state.sl))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, XLSTMState(new_ml, new_sl)
