"""Loss and train-step factory shared by all architectures."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..optim import (adamw_init, adamw_update, clip_by_global_norm,
                     linear_warmup_cosine)
from .common import ModelConfig
from .layers import shard


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  weights: jax.Array = None) -> jax.Array:
    """Mean token cross-entropy in fp32.  logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if weights is None:
        return nll.mean()
    w = weights.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def make_loss_fn(forward: Callable, cfg: ModelConfig, aux_weight: float = 0.01):
    """forward(params, batch, cfg) -> (logits, aux).  Returns loss_fn."""

    def loss_fn(params, batch):
        logits, aux = forward(params, batch, cfg)
        loss = cross_entropy(logits, batch["labels"], batch.get("weights"))
        return loss + aux_weight * aux, {"ce": loss, "aux": aux}

    return loss_fn


def make_train_step(forward: Callable, cfg: ModelConfig, *,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, clip: float = 1.0):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With cfg.accum_steps > 1 the global batch is split into that many
    microbatches processed sequentially under a lax.scan (gradient
    accumulation): peak activation memory scales with the microbatch, at the
    cost of re-running the forward/backward loop — the standard lever when a
    shape does not fit HBM."""
    loss_fn = make_loss_fn(forward, cfg)
    A = max(int(cfg.accum_steps), 1)

    def _grads(params, batch):
        if A == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        micro = {k: v.reshape((A, v.shape[0] // A) + v.shape[1:])
                 for k, v in batch.items()}
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            g_acc, loss_acc, aux_acc = acc
            (loss, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, loss_acc + loss, aux_acc + parts["aux"]), parts["ce"]

        (g_sum, loss_sum, aux_sum), ces = jax.lax.scan(
            body, (zero, jnp.zeros(()), jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g: g / A, g_sum)
        return (loss_sum / A, {"ce": ces.mean(), "aux": aux_sum / A}), grads

    def train_step(params, opt_state, batch):
        (loss, parts), grads = _grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        lr = linear_warmup_cosine(opt_state.step, base_lr=base_lr,
                                  warmup_steps=warmup, total_steps=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def init_optimizer(params):
    return adamw_init(params)
