"""Parameter / state / batch sharding-spec derivation.

``param_specs`` walks a parameter pytree and assigns a PartitionSpec per leaf
from its name, dimensionality, and the mesh — the tensor-parallel layout
(megatron-style: attention heads + FFN inner dim + vocab + experts over
'model'; everything replicated over 'data'/'pod' unless ZeRO is requested).

``zero1_specs`` additionally shards the largest replicated dim of each leaf
over the data axes (optimizer-state sharding, ZeRO-1): at 512 chips this cuts
AdamW moment memory by the data-axis size.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from .common import ModelConfig

ATTN_PARENTS = {"attn", "self_attn", "cross_attn", "shared_attn"}


def _path_names(path) -> list:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, GetAttrKey):
            out.append(k.name)
        elif isinstance(k, SequenceKey):
            out.append(str(k.idx))
    return out


def _shard_priority(names: list) -> tuple:
    """(base_ndim, priority list of base-dim indices to try for 'model').

    Base dims are counted from the END of the array shape (leading dims are
    layer stacks).  The first dim in priority order whose size divides the
    model-axis size gets the 'model' annotation."""
    name = names[-1]
    in_attn = any(n in ATTN_PARENTS for n in names[:-1])
    in_moe = "moe" in names[:-1]

    if name == "tok":
        return 2, [0, 1]
    if name == "unembed":
        return 2, [1, 0]
    if in_attn:
        if name in ("wq", "wk", "wv"):
            return 3, [1, 2, 0]      # heads, head_dim, d_model
        if name == "wo":
            return 3, [0, 1, 2]
        if name in ("bq", "bk", "bv"):
            return 2, [0, 1]
    if in_moe and name in ("wi", "wg"):
        return 3, [0, 2, 1]          # experts, ff, d_model
    if in_moe and name == "wo":
        return 3, [0, 1, 2]
    if name in ("wi", "wg"):
        return 2, [1, 0]
    if name in ("wo", "out", "down", "out_proj"):
        return 2, [0, 1]
    if name in ("in_proj", "up", "wx", "wq", "wk", "wv"):
        return 2, [1, 0]
    if name == "conv_w":
        return 2, [0]
    if name == "r":
        return 3, [1, 2]
    return 0, []


def param_specs(params, cfg: ModelConfig, mesh) -> dict:
    """Pytree of PartitionSpec matching ``params`` (tensor-parallel layout)."""
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def one(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        base_nd, prio = _shard_priority(names)
        entries = [None] * nd
        if msize > 1 and base_nd and nd >= base_nd:
            off = nd - base_nd
            for b in prio:
                i = off + b
                if leaf.shape[i] % msize == 0 and leaf.shape[i] >= msize:
                    entries[i] = "model"
                    break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, params)


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def zero1_specs(params, cfg: ModelConfig, mesh) -> dict:
    """Param specs with the largest remaining replicated dim additionally
    sharded over the data axes (for optimizer moments)."""
    base = param_specs(params, cfg, mesh)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1

    def one(spec, leaf):
        if dsize <= 1 or leaf.ndim == 0:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # choose the largest None dim divisible by the data size
        cand = [(leaf.shape[i], i) for i, e in enumerate(entries)
                if e is None and leaf.shape[i] % dsize == 0 and leaf.shape[i] >= dsize]
        if not cand:
            return spec
        _, i = max(cand)
        entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
        return P(*entries)

    return jax.tree.map(one, base, params)


def named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh, *, batch_dims: int = 1) -> P:
    """Shard the leading batch dim over all data axes."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    first = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    return P(first)


# ---------------------------------------------------------------------------
# Decode-state sharding (KV caches, SSM states, ...)
# ---------------------------------------------------------------------------

_CACHE_FIELDS = {"k", "v", "cross_k", "cross_v"}
_BATCHED_FIELDS = {"conv", "ssm", "C", "n", "c", "m", "h", "pos", "positions"}


def state_specs(state_sds, cfg: ModelConfig, mesh, batch: int) -> dict:
    """PartitionSpecs for a decode-state pytree (ShapeDtypeStructs).

    Rules: the batch dim shards over the data axes when divisible; for KV
    caches, if the batch cannot be sharded (B=1 long-context decode) the
    cache-length dim shards over 'data' instead (distributed flash-decoding);
    the kv-head dim (or failing divisibility, head_dim) shards over 'model'.
    Other state tensors shard their largest remaining divisible dim over
    'model'."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    data_entry = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape
        nd = len(shape)
        entries = [None] * nd
        # locate the batch dim: first dim whose size == batch
        bdim = next((i for i, s in enumerate(shape) if s == batch), None)
        batch_sharded = False
        if bdim is not None and dsize > 1 and batch % dsize == 0:
            entries[bdim] = data_entry
            batch_sharded = True
        if name in _CACHE_FIELDS and nd >= 4:
            # (..., B, C, K, hd)
            cdim, kdim, hdim = nd - 3, nd - 2, nd - 1
            if not batch_sharded and dsize > 1 and shape[cdim] % dsize == 0:
                entries[cdim] = data_entry
            if msize > 1 and shape[kdim] % msize == 0:
                entries[kdim] = "model"
            elif msize > 1 and shape[hdim] % msize == 0:
                entries[hdim] = "model"
        elif name == "positions" and nd >= 2:
            cdim = nd - 1
            if not batch_sharded and dsize > 1 and shape[cdim] % dsize == 0:
                entries[cdim] = data_entry
        elif name in _BATCHED_FIELDS and msize > 1:
            cand = [(shape[i], i) for i in range(nd)
                    if entries[i] is None and i != bdim
                    and shape[i] % msize == 0 and shape[i] >= msize]
            if cand:
                _, i = max(cand)
                entries[i] = "model"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, state_sds)
