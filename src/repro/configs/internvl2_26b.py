"""internvl2-26b [vlm]: InternViT (stub frontend) + InternLM2 backbone.
[arXiv:2404.16821; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553,
        n_vis_tokens=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-26b-smoke", family="vlm",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        n_vis_tokens=16,
    )
