"""Solver registry: every planning algorithm as a uniform, pluggable callable.

The paper contributes a *portfolio* — six polynomial heuristics (H1-H6), DP
baselines, and exact solvers — over the antagonist period/latency criteria.
This module makes that portfolio a first-class, extensible surface: each
algorithm is registered under a stable name with a :class:`SolverSpec`
describing its capabilities, and the planner (:mod:`repro.core.planner`)
selects applicable solvers per :class:`~repro.core.planner.PlanRequest`
instead of hardcoding the list.  Later criteria (energy, reliability),
replicated stages, or heterogeneous-comm solvers plug in with a decorator:

    @register_solver("my-solver", optimizes="period", description="...")
    def _solve_mine(workload, platform, objective):
        return mapping_or_None

A solver callable takes ``(workload, platform, objective)`` and returns
``None`` (no solution), a :class:`~repro.core.metrics.Mapping`, or a
:class:`Solution` (which may carry processor *groups* for replicated/deal
stages and pre-computed metrics).  ``objective.bound`` — when set — is the
constraint on the criterion the solver does *not* optimize.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

from .exact import (dp_homogeneous_period, dp_speed_ordered, exact_min_latency,
                    exact_min_period)
from .exact import brute_force as _brute_force
from .heuristics import (FIXED_LATENCY_HEURISTICS, FIXED_PERIOD_HEURISTICS,
                         NAMES, run_heuristic)
from .metrics import Mapping, evaluate, single_processor_mapping
from .platform import Platform
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Solution:
    """What a solver hands back: a mapping, optionally processor groups per
    interval (deal/replication extension) and pre-computed metrics.  Metrics
    left as None are filled in by the portfolio runner (vectorized)."""

    mapping: Mapping
    groups: Optional[tuple] = None       # tuple[tuple[int, ...], ...] or None
    period: Optional[float] = None
    latency: Optional[float] = None
    reliability: Optional[float] = None  # sequel's third criterion (replication)


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Capability metadata for a registered solver."""

    name: str
    fn: Callable
    optimizes: str = "both"              # "period" | "latency" | "both"
    needs_bound: bool = False            # meaningful only with objective.bound
    max_p: Optional[int] = None          # exponential solvers: processor ceiling
    supports_groups: bool = False        # may return grouped (deal) solutions
    auto: bool = True                    # part of the default portfolio
    predicate: Optional[Callable] = None  # extra (workload, platform) -> bool
    description: str = ""


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One row of a PlanReport's provenance table: what a solver produced for
    an objective, with metrics, feasibility, and wall time."""

    solver: str
    objective: "object"                  # the Objective this run targeted
    mapping: Optional[Mapping]
    period: float
    latency: float
    feasible: bool
    wall_time: float                     # seconds spent inside the solver
    groups: Optional[tuple] = None
    error: Optional[str] = None
    reliability: Optional[float] = None  # third criterion; None = not evaluated

    @property
    def point(self) -> tuple:
        return (self.period, self.latency)

    @property
    def point_tri(self) -> tuple:
        """(period, latency, reliability); an unevaluated reliability reads
        as 1.0 (no failure model = perfectly reliable)."""
        return (self.period, self.latency,
                self.reliability if self.reliability is not None else 1.0)


_REGISTRY: "dict[str, SolverSpec]" = {}


def register_solver(
    name: str,
    *,
    optimizes: str = "both",
    needs_bound: bool = False,
    max_p: Optional[int] = None,
    supports_groups: bool = False,
    auto: bool = True,
    predicate: Optional[Callable] = None,
    description: str = "",
) -> Callable:
    """Decorator: register ``fn`` as solver ``name`` with capability metadata.

    Registration order is preserved and is the deterministic tie-break order
    of the planner's selection policies."""
    if optimizes not in ("period", "latency", "both"):
        raise ValueError(f"optimizes must be period|latency|both, got {optimizes!r}")

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"solver {name!r} already registered")
        _REGISTRY[name] = SolverSpec(
            name=name, fn=fn, optimizes=optimizes, needs_bound=needs_bound,
            max_p=max_p, supports_groups=supports_groups, auto=auto,
            predicate=predicate, description=description,
        )
        return fn

    return deco


def get_solver(name: str) -> SolverSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown solver {name!r}; registered: {sorted(_REGISTRY)}") from None


def solver_names() -> list:
    return list(_REGISTRY)


def registered_solvers() -> tuple:
    """All SolverSpecs in registration order."""
    return tuple(_REGISTRY.values())


def applicable(
    spec: SolverSpec,
    workload: Workload,
    platform: Platform,
    objective,
    *,
    exact_max_p: Optional[int] = None,
    allow_groups: bool = False,
) -> bool:
    """Can ``spec`` serve ``objective`` on this instance within the size budget?"""
    if spec.optimizes not in ("both", objective.minimize):
        return False
    if spec.max_p is not None:
        cap = spec.max_p if exact_max_p is None else min(spec.max_p, exact_max_p)
        if platform.p > cap:
            return False
    if spec.supports_groups and not allow_groups:
        return False
    if spec.predicate is not None and not spec.predicate(workload, platform):
        return False
    return True


def _bound(objective) -> float:
    return objective.bound if objective.bound is not None else math.inf


def normalize_output(out) -> Optional[Solution]:
    """Coerce a solver's return value (None | Mapping | Solution) to Solution."""
    if out is None:
        return None
    if isinstance(out, Solution):
        return out
    if isinstance(out, Mapping):
        return Solution(mapping=out)
    raise TypeError(f"solver returned {type(out).__name__}, expected Mapping/Solution/None")


def solve(
    name: str,
    workload: Workload,
    platform: Platform,
    objective,
    *,
    exact_max_p: Optional[int] = None,
) -> Candidate:
    """Run one registered solver, timed, and return its provenance Candidate.

    Infeasibility (no mapping, a violated bound) or a solver exception is
    reported in the candidate rather than raised — portfolio runs must not die
    because one member did.
    """
    spec = get_solver(name)
    if not applicable(spec, workload, platform, objective,
                      exact_max_p=exact_max_p, allow_groups=True):
        return Candidate(name, objective, None, math.inf, math.inf, False, 0.0,
                         error="not applicable")
    t0 = time.perf_counter()
    try:
        sol = normalize_output(spec.fn(workload, platform, objective))
    except Exception as ex:  # noqa: BLE001 — portfolio members must not kill the run
        return Candidate(name, objective, None, math.inf, math.inf, False,
                         time.perf_counter() - t0, error=f"{type(ex).__name__}: {ex}")
    wall = time.perf_counter() - t0
    if sol is None:
        return Candidate(name, objective, None, math.inf, math.inf, False, wall)
    per, lat = sol.period, sol.latency
    if per is None or lat is None:
        per, lat = evaluate(workload, platform, sol.mapping)
    return Candidate(name, objective, sol.mapping, float(per), float(lat),
                     meets_bound(objective, float(per), float(lat)), wall,
                     groups=sol.groups, reliability=sol.reliability)


def meets_bound(objective, per: float, lat: float) -> bool:
    """The paper's feasibility rule: the non-minimized criterion must respect
    the bound (unbounded objectives are always feasible for finite metrics)."""
    if not (math.isfinite(per) and math.isfinite(lat)):
        return False
    if objective.bound is None:
        return True
    other = per if objective.minimize == "latency" else lat
    return other <= objective.bound + 1e-12


# ---------------------------------------------------------------------------
# Built-in solvers: the paper portfolio as registry entries
# ---------------------------------------------------------------------------

@register_solver("single", optimizes="both",
                 description="whole chain on the fastest processor (Lemma 1: latency-optimal)")
def _solve_single(workload, platform, objective):
    return single_processor_mapping(workload, platform.fastest())


def _heuristic_solver(code: str):
    def fn(workload, platform, objective):
        res = run_heuristic(code, workload, platform, _bound(objective))
        return res.mapping  # best-effort even when its own bound check failed
    fn.__name__ = f"_solve_{code.lower()}"
    return fn


for _code in ("H1", "H2", "H3", "H4"):
    register_solver(
        _code, optimizes="latency", needs_bound=True,
        description=f"paper heuristic {NAMES[_code]}: min latency s.t. period <= bound",
    )(_heuristic_solver(_code))

for _code in ("H5", "H6"):
    register_solver(
        _code, optimizes="period", needs_bound=True,
        description=f"paper heuristic {NAMES[_code]}: min period s.t. latency <= bound",
    )(_heuristic_solver(_code))


@register_solver("dp-speed-ordered", optimizes="period",
                 description="polynomial DP, exact under speed-ordered assignment")
def _solve_dp_speed_ordered(workload, platform, objective):
    return dp_speed_ordered(workload, platform, latency_cap=_bound(objective))


@register_solver("dp-homogeneous", optimizes="period", auto=False,
                 predicate=lambda wl, pf: bool((pf.s == pf.s[0]).all()),
                 description="exact O(n^2 p) DP for identical processor speeds")
def _solve_dp_homogeneous(workload, platform, objective):
    per, intervals = dp_homogeneous_period(workload, platform.p,
                                           float(platform.s[0]), platform.b)
    return Mapping(intervals, tuple(range(len(intervals))))


@register_solver("exact", optimizes="period", max_p=14,
                 description="exact min period (binary search + bitmask DP), exp. in p")
def _solve_exact(workload, platform, objective):
    return exact_min_period(workload, platform, latency_cap=_bound(objective))


@register_solver("exact-latency", optimizes="latency", max_p=14,
                 description="exact min latency s.t. period <= bound (bitmask DP), exp. in p")
def _solve_exact_latency(workload, platform, objective):
    if objective.bound is None:
        # Lemma 1: the unbounded optimum is the whole chain on the fastest
        # processor — skip the exponential DP.
        return single_processor_mapping(workload, platform.fastest())
    return exact_min_latency(workload, platform, period_cap=objective.bound)


@register_solver("brute-force", optimizes="both", max_p=6, auto=False,
                 predicate=lambda wl, pf: wl.n <= 10,
                 description="full enumeration ground truth (tiny instances only)")
def _solve_brute_force(workload, platform, objective):
    per_cap = _bound(objective) if objective.minimize == "latency" else math.inf
    lat_cap = _bound(objective) if objective.minimize == "period" else math.inf
    return _brute_force(workload, platform, period_cap=per_cap,
                        latency_cap=lat_cap, objective=objective.minimize)

# The deal/replication extension registers itself from repro.core.deal (it
# builds on the planner and would cycle if registered here).
