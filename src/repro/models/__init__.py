"""Model zoo: config-driven implementations of the ten assigned architectures."""

from .common import ModelConfig, ShapeSpec, SHAPES, param_count, active_param_count
from .registry import ModelAPI, get_model, lm_workload, layer_flops
from .train import make_train_step, make_loss_fn, cross_entropy, init_optimizer
from .sharding import param_specs, zero1_specs, batch_spec, named

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "param_count", "active_param_count",
           "ModelAPI", "get_model", "lm_workload", "layer_flops",
           "make_train_step", "make_loss_fn", "cross_entropy", "init_optimizer",
           "param_specs", "zero1_specs", "batch_spec", "named"]
