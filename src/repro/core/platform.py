"""Target platform description — Communication-Homogeneous platforms.

Different-speed processors ``s_u`` interconnected by links of identical
bandwidth ``b`` (paper Section 2).  The one-port linear cost model is captured
by the metric functions in :mod:`repro.core.metrics`; the platform itself only
stores speeds and bandwidth.

For the TPU adaptation a "processor" is a pod slice: its speed is
``chips * peak_flops * efficiency`` and can be degraded online to model
stragglers (see :mod:`repro.pipeline.replan`).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Platform:
    """p processors with speeds ``s`` and homogeneous link bandwidth ``b``."""

    s: np.ndarray          # shape (p,), processor speeds (flops / time-unit)
    b: float               # link bandwidth (bytes / time-unit), identical links
    name: str = "platform"

    def __post_init__(self):
        s = np.asarray(self.s, dtype=np.float64)
        object.__setattr__(self, "s", s)
        if s.ndim != 1 or len(s) == 0:
            raise ValueError("s must be a non-empty 1-D array")
        if (s <= 0).any():
            raise ValueError("processor speeds must be positive")
        if self.b <= 0:
            raise ValueError("bandwidth must be positive")

    @property
    def p(self) -> int:
        return int(len(self.s))

    def sorted_indices(self) -> np.ndarray:
        """Processor indices by non-increasing speed (ties broken by index,
        matching the paper's 'sort processors by non-increasing speed')."""
        return np.lexsort((np.arange(self.p), -self.s))

    def fastest(self) -> int:
        return int(self.sorted_indices()[0])

    def degrade(self, proc: int, factor: float) -> "Platform":
        """Return a platform where processor ``proc`` runs ``factor`` times slower.
        Used for straggler modeling."""
        if not (0 < factor):
            raise ValueError("factor must be positive")
        s = self.s.copy()
        s[proc] = s[proc] / factor
        return Platform(s, self.b, name=f"{self.name}-degraded")


def make_platform(s: Sequence[float], b: float, name: str = "platform") -> Platform:
    return Platform(np.asarray(s, dtype=np.float64), float(b), name)


def homogeneous_platform(p: int, s: float = 1.0, b: float = 10.0) -> Platform:
    return Platform(np.full(p, s), b, name=f"homog-{p}")


def tpu_pod_platform(
    pods: int,
    chips_per_pod: int = 256,
    peak_flops: float = 197e12,
    efficiency: float = 0.4,
    dcn_bandwidth: float = 25e9,
    degraded: dict | None = None,
) -> Platform:
    """A multi-pod TPU platform for the planner: one 'processor' per pod.

    ``degraded`` maps pod index -> slowdown factor (straggler modeling).
    """
    s = np.full(pods, chips_per_pod * peak_flops * efficiency)
    if degraded:
        for k, f in degraded.items():
            s[k] /= f
    return Platform(s, dcn_bandwidth, name=f"tpu-{pods}x{chips_per_pod}")
