"""Simulation-harness correctness + qualitative reproduction of paper claims."""

import numpy as np
import pytest

from repro.sim import EXPERIMENTS, failure_thresholds, gen_instance, run_experiment


def test_generator_ranges():
    for exp in EXPERIMENTS:
        wl, pf = gen_instance(exp, 20, 10, seed=0)
        assert wl.n == 20 and pf.p == 10
        assert pf.b == 10.0
        assert (1 <= pf.s).all() and (pf.s <= 20).all()
    wl, _ = gen_instance("E1", 10, 10, 0)
    assert (wl.delta == 10.0).all()
    wl, _ = gen_instance("E3", 10, 10, 0)
    assert wl.w.min() >= 10 and wl.w.max() <= 1000
    wl, _ = gen_instance("E4", 10, 10, 0)
    assert wl.w.max() <= 10.0


def test_generator_determinism():
    a = gen_instance("E2", 10, 10, seed=5)
    b = gen_instance("E2", 10, 10, seed=5)
    assert np.array_equal(a[0].w, b[0].w)
    assert np.array_equal(a[1].s, b[1].s)


def test_run_experiment_structure():
    res = run_experiment("E1", 10, 10, n_pairs=5, n_bounds=6)
    assert set(res.curves) == {"H1", "H2", "H3", "H4", "H5", "H6"}
    for c, (mp, ml, fr) in res.curves.items():
        assert len(mp) == 6
        assert (fr >= 0).all() and (fr <= 1).all()
    # H5/H6 share failure thresholds (paper Table 1 observation)
    assert res.thresholds["H5"] == pytest.approx(res.thresholds["H6"])


def test_failure_threshold_orderings():
    """Qualitative Table-1 claims: H1 has the smallest fixed-period failure
    threshold among H1-H3 (it is the least greedy consumer of processors);
    H5 == H6."""
    thr = failure_thresholds(exps=("E1",), ns=(10, 20), p=10, n_pairs=15)["E1"]
    for n in (10, 20):
        assert thr["H1"][n] <= thr["H2"][n] + 1e-9
        assert thr["H5"][n] == pytest.approx(thr["H6"][n])


def test_latency_period_tradeoff_direction():
    """Fixed-latency heuristics: as the latency budget grows, achieved period
    must not increase (more splitting allowed)."""
    res = run_experiment("E1", 20, 10, n_pairs=8, n_bounds=8)
    for code in ("H5", "H6"):
        mp, ml, fr = res.curves[code]
        ok = ~np.isnan(mp)
        mp = mp[ok]
        assert (np.diff(mp) <= 1e-6).all()
