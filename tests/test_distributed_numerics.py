"""Numerical equivalence of the distributed execution paths vs single-device
references, on 8 fake devices (subprocess).  These are the paths the dry-run
compiles but smoke tests (single device) never execute:

 - shard_map-local MoE dispatch  == per-token oracle
 - sequence-parallel attention   == plain attention
 - FSDP (2-D sharded) train step == unsharded train step
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.configs import get_smoke_config
    from repro.models.moe import moe_ffn, moe_ffn_tokens, init_moe
    from repro.models.attention import plain_attention, seq_parallel_attention

    rng = np.random.default_rng(0)
    mesh = make_mesh((2, 4), ("data", "model"))

    # ---- shard_map MoE vs per-token oracle --------------------------------
    cfg = get_smoke_config("mixtral-8x7b").replace(capacity_factor=16.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 32, cfg.d_model)) * 0.3, jnp.float32)
    with jax.set_mesh(mesh):
        y_dist = jax.jit(lambda p, x: moe_ffn(p, x, cfg)[0])(params, x)
    y_ref = jax.jit(lambda p, x: moe_ffn_tokens(p, x, cfg))(params, x)
    err = float(jnp.max(jnp.abs(np.asarray(y_dist) - np.asarray(y_ref))))
    assert err < 1e-4, f"moe dist err {err}"
    print("MOE_DIST_OK", err)

    # ---- grad check through the shard_map MoE ------------------------------
    with jax.set_mesh(mesh):
        g = jax.jit(jax.grad(lambda p, x: moe_ffn(p, x, cfg)[0].sum()))(params, x)
    g_ref = jax.jit(jax.grad(lambda p, x: moe_ffn_tokens(p, x, cfg).sum()))(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        gerr = float(jnp.max(jnp.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        assert gerr < 2e-3, f"moe grad err {gerr}"
    print("MOE_GRAD_OK")

    # ---- sequence-parallel attention vs plain ------------------------------
    # H=6 heads on a 4-way model axis (6 % 4 != 0 -> the seq-parallel path)
    B, S, H, K, hd = 2, 512, 6, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)) * 0.5, jnp.float32)
    with jax.set_mesh(mesh):
        a_sp = jax.jit(lambda q, k, v: seq_parallel_attention(
            q, k, v, causal=True, window=None, block_q=128, block_k=128))(q, k, v)
    a_ref = jax.jit(lambda q, k, v: plain_attention(
        q, k, v, causal=True, window=None))(q, k, v)
    aerr = float(jnp.max(jnp.abs(np.asarray(a_sp) - np.asarray(a_ref))))
    assert aerr < 1e-5, f"seq-parallel attention err {aerr}"
    print("SEQPAR_OK", aerr)

    # ---- windowed variant ---------------------------------------------------
    with jax.set_mesh(mesh):
        w_sp = jax.jit(lambda q, k, v: seq_parallel_attention(
            q, k, v, causal=True, window=200, block_q=128, block_k=128))(q, k, v)
    w_ref = jax.jit(lambda q, k, v: plain_attention(
        q, k, v, causal=True, window=200))(q, k, v)
    werr = float(jnp.max(jnp.abs(np.asarray(w_sp) - np.asarray(w_ref))))
    assert werr < 1e-5, f"seq-parallel SWA err {werr}"
    print("SEQPAR_SWA_OK", werr)

    # ---- FSDP-sharded train step == unsharded ------------------------------
    from repro.models import get_model, make_train_step, init_optimizer
    from repro.models.sharding import named, zero1_specs, param_specs
    from repro.optim.adamw import AdamWState

    cfg2 = get_smoke_config("qwen1.5-110b").replace(fsdp_params=True, accum_steps=2)
    api = get_model(cfg2)
    params2 = api.init(jax.random.PRNGKey(1))
    opt = init_optimizer(params2)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg2.vocab_size, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg2.vocab_size, (8, 64)), jnp.int32)}
    ts = make_train_step(api.forward, cfg2)
    p_ref, o_ref, m_ref = jax.jit(ts)(params2, opt, batch)   # single-device

    with jax.set_mesh(mesh):
        pn = named(zero1_specs(params2, cfg2, mesh), mesh)
        zn = named(zero1_specs(params2, cfg2, mesh), mesh)
        on = AdamWState(step=NamedSharding(mesh, P()), m=zn, v=zn)
        params_s = jax.device_put(params2, pn)
        opt_s = AdamWState(step=jax.device_put(opt.step, NamedSharding(mesh, P())),
                           m=jax.device_put(opt.m, zn), v=jax.device_put(opt.v, zn))
        batch_s = {kk: jax.device_put(vv, NamedSharding(mesh, P("data")))
                   for kk, vv in batch.items()}
        p_dist, o_dist, m_dist = jax.jit(
            ts, in_shardings=(pn, on, {kk: NamedSharding(mesh, P("data"))
                                       for kk in batch}),
            out_shardings=(pn, on, None))(params_s, opt_s, batch_s)
    dl = abs(float(m_dist["loss"]) - float(m_ref["loss"]))
    assert dl < 5e-3, f"fsdp loss mismatch {dl}"
    for a, b in zip(jax.tree.leaves(p_dist), jax.tree.leaves(p_ref)):
        perr = float(jnp.max(jnp.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
        assert perr < 5e-3, f"fsdp param err {perr}"
    print("FSDP_OK", dl)
""")


@pytest.mark.slow
def test_distributed_numerics_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _CODE], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    for marker in ("MOE_DIST_OK", "MOE_GRAD_OK", "SEQPAR_OK", "SEQPAR_SWA_OK",
                   "FSDP_OK"):
        assert marker in r.stdout, r.stdout[-2000:]
