"""Subprocess solve-worker entrypoint: ``python -m repro.fleet.worker_main``.

The process-isolated half of the controller/worker split
(:mod:`repro.fleet.supervision`).  The worker owns its whole execution
context — interpreter, numpy/engine state, memory — so a wedged, leaking, or
segfaulting solve takes down *this* process, never the controller; the
supervisor reaps it with SIGTERM→SIGKILL and spawns a replacement.

Protocol (:mod:`repro.fleet.transport`): length-prefixed CRC-framed records
over stdin/stdout.  stdout carries *only* frames — anything else (diagnostics,
engine warnings) must go to stderr or it would desynchronize the stream.
The main loop is strictly serial: read a frame, act, reply; a daemon thread
emits ``heartbeat`` frames every ``--heartbeat-interval`` seconds so the
controller can tell "alive but slow" from "gone" (heartbeats prove the
*process* lives, not that a solve progresses — reaping a wedged solve is the
controller-side timeout's job).

Flags used by the chaos harness and tests:

  ``--ignore-sigterm``  installs SIG_IGN for SIGTERM, modeling a worker too
                        wedged to honor graceful shutdown — only SIGKILL
                        reaps it, which is exactly what the supervisor's
                        escalation path must prove it does.
  ``--wedge-every K``   every K-th solve sleeps ``--wedge-seconds`` before
                        replying (a deterministic hung solve, no chaos rng).
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
import threading
import time

from .transport import (FrameError, FrameReader, decode_solve, encode_frame,
                        encode_results)

_READ_CHUNK = 1 << 16


def _write_all(fd: int, data: bytes) -> None:
    view = memoryview(data)
    while view:
        n = os.write(fd, view)
        view = view[n:]


class _Sender:
    """Serialized frame writes: the heartbeat thread and the main loop share
    stdout, and a frame larger than PIPE_BUF would interleave without the
    lock."""

    def __init__(self, fd: int):
        self.fd = fd
        self._lock = threading.Lock()

    def send(self, payload) -> None:
        data = encode_frame(payload)
        with self._lock:
            _write_all(self.fd, data)


def _heartbeat_loop(sender: _Sender, interval: float, stop: threading.Event,
                    state: dict) -> None:
    while not stop.wait(interval):
        try:
            sender.send(["heartbeat", {"pid": os.getpid(),
                                       "solves": state["solves"]}])
        except OSError:
            return   # controller is gone; the main loop will see EOF too


def serve(in_fd: int, out_fd: int, *, backend: str = "numpy",
          heartbeat_interval: float = 0.5, wedge_every: int = 0,
          wedge_seconds: float = 0.0) -> int:
    """Frame-serve until EOF or a ``bye`` frame.  Returns the exit code."""
    from ..core.batched import batched_min_period

    sender = _Sender(out_fd)
    state = {"solves": 0}
    stop = threading.Event()
    beat = threading.Thread(target=_heartbeat_loop,
                            args=(sender, heartbeat_interval, stop, state),
                            name="fleet-worker-heartbeat", daemon=True)
    beat.start()
    sender.send(["hello", {"pid": os.getpid(), "backend": backend}])
    reader = FrameReader()
    try:
        while True:
            try:
                payload = reader.next_frame()
            except FrameError as e:
                # The controller's request stream is corrupt: there is no
                # request id to attach an error to, and no way to resync.
                print(f"worker {os.getpid()}: poisoned request stream: {e}",
                      file=sys.stderr)
                return 2
            if payload is None:
                chunk = os.read(in_fd, _READ_CHUNK)
                if not chunk:
                    return 0   # controller closed the pipe: clean shutdown
                reader.feed(chunk)
                continue
            kind, body = payload
            if kind == "bye":
                return 0
            if kind == "wedge":
                # In-band injected hang: sleep as if the next solve wedged.
                time.sleep(float(body.get("seconds", 0.0)))
                continue
            if kind == "solve":
                rid = int(body["id"])
                if wedge_every and (state["solves"] + 1) % wedge_every == 0:
                    time.sleep(wedge_seconds)
                try:
                    results = batched_min_period(decode_solve(body), backend)
                except Exception as e:  # noqa: BLE001 — report, stay alive
                    sender.send(["error", {"id": rid,
                                           "kind": type(e).__name__,
                                           "message": str(e)}])
                    continue
                state["solves"] += 1
                sender.send(encode_results(rid, results))
                continue
            print(f"worker {os.getpid()}: ignoring unknown frame kind "
                  f"{kind!r}", file=sys.stderr)
    finally:
        stop.set()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.fleet.worker_main")
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--heartbeat-interval", type=float, default=0.5)
    ap.add_argument("--ignore-sigterm", action="store_true",
                    help="model a worker too wedged for graceful shutdown "
                         "(only SIGKILL reaps it)")
    ap.add_argument("--wedge-every", type=int, default=0,
                    help="every K-th solve sleeps --wedge-seconds (0 = off)")
    ap.add_argument("--wedge-seconds", type=float, default=0.0)
    args = ap.parse_args(argv)
    if args.ignore_sigterm:
        signal.signal(signal.SIGTERM, signal.SIG_IGN)
    return serve(sys.stdin.fileno(), sys.stdout.fileno(),
                 backend=args.backend,
                 heartbeat_interval=args.heartbeat_interval,
                 wedge_every=args.wedge_every,
                 wedge_seconds=args.wedge_seconds)


if __name__ == "__main__":
    sys.exit(main())
