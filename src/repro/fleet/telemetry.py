"""Drift telemetry: event types, synthetic burst traces, deterministic replay.

A fleet is a set of pipeline instances, many of them replicas of the same
(workload, platform) template — the situation that makes dedup worthwhile.
Drift arrives as a stream of per-instance events:

  - :class:`StageTimings`   — raw per-stage step times (what a live serving
    loop reports; feeds the instance's ``StragglerMonitor``)
  - :class:`StageDrift`     — a stage slowed down by a discrete factor (what
    the synthetic generator emits; expanded to timings in-service)
  - :class:`PodCountChange` — preemption / autoscale resize to a target count
  - :class:`PodFailure`     — a pod died (the sequel paper's failure events)

The burst-trace generator models *correlated* infrastructure events: on a
burst tick every replica of a hit group receives the identical event, and
drift factors come from a small discrete set — so degraded platforms collide
bit-wise across replicas and the service's signature dedup has real work to
do.  Background noise hits single instances and breaks some of that sharing,
which is what keeps the dedup hit-rate an honest measurement.

Everything is driven by one ``numpy`` Generator seed: generating a trace twice
with the same seed yields equal traces, and replaying a trace through the
service is deterministic (asserted in tests/test_fleet.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..sim.generators import gen_instance


# ---------------------------------------------------------------------------
# Event types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageTimings:
    """Measured per-stage step times for one instance (seconds per stage of
    the *current plan*, chain order)."""

    instance: int
    times: tuple


@dataclasses.dataclass(frozen=True)
class StageDrift:
    """Stage ``stage`` of ``instance``'s *current plan* runs ``factor`` times
    slower than predicted.  An out-of-range stage is a stale event from a
    pre-replan plan shape; the service drops it (like stale StageTimings)
    rather than remapping it onto an arbitrary stage."""

    instance: int
    stage: int
    factor: float


@dataclasses.dataclass(frozen=True)
class PodCountChange:
    """Autoscale / preemption: resize ``instance`` to ``num_pods`` pods."""

    instance: int
    num_pods: int


@dataclasses.dataclass(frozen=True)
class PodFailure:
    """Pod ``pod`` (mod the instance's current pod count) of ``instance``
    failed and is removed from the platform."""

    instance: int
    pod: int


@dataclasses.dataclass(frozen=True)
class Trace:
    """A replayable event stream: ``ticks[t]`` is the tuple of events that
    arrive during tick ``t``."""

    ticks: tuple
    seed: Optional[int] = None

    @property
    def num_ticks(self) -> int:
        return len(self.ticks)

    @property
    def num_events(self) -> int:
        return sum(len(t) for t in self.ticks)


# ---------------------------------------------------------------------------
# Wire codec (the write-ahead journal's record payload)
# ---------------------------------------------------------------------------

_EVENT_TYPES = {cls.__name__: cls
                for cls in (StageTimings, StageDrift, PodCountChange,
                            PodFailure)}


def event_to_wire(ev) -> list:
    """``[type_name, field_dict]`` with only JSON scalars: the journal's
    payload format.  Floats survive JSON exactly (shortest-repr round-trip),
    so a replayed event is bit-identical to the applied one."""
    cls = type(ev).__name__
    if isinstance(ev, StageTimings):
        return [cls, {"instance": int(ev.instance),
                      "times": [float(t) for t in ev.times]}]
    if isinstance(ev, StageDrift):
        return [cls, {"instance": int(ev.instance), "stage": int(ev.stage),
                      "factor": float(ev.factor)}]
    if isinstance(ev, PodCountChange):
        return [cls, {"instance": int(ev.instance),
                      "num_pods": int(ev.num_pods)}]
    if isinstance(ev, PodFailure):
        return [cls, {"instance": int(ev.instance), "pod": int(ev.pod)}]
    raise TypeError(f"unknown fleet event {cls}")


def event_from_wire(obj):
    """Inverse of :func:`event_to_wire`."""
    try:
        name, fields = obj
        cls = _EVENT_TYPES[name]
    except (ValueError, TypeError, KeyError):
        raise ValueError(f"malformed wire event {obj!r}") from None
    if cls is StageTimings:
        return StageTimings(int(fields["instance"]),
                            tuple(float(t) for t in fields["times"]))
    return cls(**fields)


# ---------------------------------------------------------------------------
# Fleet + trace synthesis
# ---------------------------------------------------------------------------

def make_fleet(n_groups: int, replicas: int, n: int, p: int,
               seed: int = 0, exp: str = "E2") -> tuple:
    """A fleet of ``n_groups * replicas`` instances: each group is one random
    (workload, platform) template from the Section-5 generators, shared
    verbatim by its replicas.  Returns (pairs, groups) where ``pairs`` is the
    flat [(workload, platform), ...] list (instance id = position) and
    ``groups`` the list of per-group instance-id lists."""
    pairs, groups = [], []
    for g in range(n_groups):
        wl, pf = gen_instance(exp, n, p, seed=seed + g)
        ids = []
        for _ in range(replicas):
            ids.append(len(pairs))
            pairs.append((wl, pf))
        groups.append(ids)
    return pairs, groups


def gen_burst_trace(
    groups: Sequence[Sequence[int]],
    num_ticks: int,
    seed: int = 0,
    *,
    n_stages: int = 8,
    initial_pods: int = 4,
    burst_prob: float = 0.5,
    noise_per_tick: int = 1,
    drift_factors: Sequence[float] = (1.5, 2.0, 3.0),
) -> Trace:
    """Synthesize a correlated burst trace over the given instance groups.

    Per tick, with probability ``burst_prob`` a *burst* hits a random subset
    of groups; every replica of a hit group receives the identical event
    (drift 70% / resize 20% / failure 10%, parameters drawn from discrete
    sets).  Independently, ``noise_per_tick`` uncorrelated single-instance
    drift events fire each tick.  Same seed, same trace.
    """
    rng = np.random.default_rng(seed)
    all_ids = [i for g in groups for i in g]
    factors = np.asarray(drift_factors, dtype=float)
    ticks = []
    for _ in range(num_ticks):
        events = []
        if rng.random() < burst_prob:
            n_hit = 1 + int(rng.integers(max(1, len(groups) // 2)))
            hit = rng.choice(len(groups), size=min(n_hit, len(groups)),
                             replace=False)
            for gi in hit:
                kind = rng.random()
                if kind < 0.7:
                    stage = int(rng.integers(n_stages))
                    factor = float(factors[rng.integers(len(factors))])
                    events += [StageDrift(i, stage, factor) for i in groups[gi]]
                elif kind < 0.9:
                    target = int(rng.integers(max(1, initial_pods // 2),
                                              initial_pods + 2))
                    events += [PodCountChange(i, target) for i in groups[gi]]
                else:
                    pod = int(rng.integers(initial_pods))
                    events += [PodFailure(i, pod) for i in groups[gi]]
        for _ in range(noise_per_tick):
            iid = int(all_ids[rng.integers(len(all_ids))])
            stage = int(rng.integers(n_stages))
            factor = float(factors[rng.integers(len(factors))])
            events.append(StageDrift(iid, stage, factor))
        ticks.append(tuple(events))
    return Trace(ticks=tuple(ticks), seed=seed)
