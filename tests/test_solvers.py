"""Solver registry + PlanRequest/PlanReport protocol.

Covers the API-redesign acceptance criteria:
 - registry round-trip: every registered solver runs and reports provenance;
 - back-compat: the plan() facade reproduces the seed implementation's
   mappings on fixed instances (table captured from the pre-registry code);
 - PlanReport.pareto is consistent with pareto_front;
 - plan(mode="exact") routes latency objectives to the exact latency search;
 - evaluate_batch matches the scalar evaluate.
"""

import itertools
import math

import numpy as np
import pytest

from repro.core import (Candidate, InfeasiblePlan, Mapping, Objective,
                        PlanRequest, all_interval_partitions, brute_force,
                        evaluate, evaluate_batch, latency, make_platform,
                        make_workload, optimal_latency, pareto_front, period,
                        plan, plan_pareto, plan_request, register_selection,
                        register_solver, registered_solvers,
                        single_processor_mapping, solve, solver_names)
from repro.core.planner import SELECTION_POLICIES


def _instance(seed: int, homogeneous: bool = False):
    rng = np.random.default_rng(seed)
    n, p = int(rng.integers(4, 10)), int(rng.integers(3, 6))
    w = rng.integers(1, 21, n).astype(float)
    delta = rng.integers(1, 51, n + 1).astype(float)
    s = np.full(p, 4.0) if homogeneous else rng.integers(1, 21, p).astype(float)
    return make_workload(w, delta), make_platform(s, 10.0)


# ---------------------------------------------------------------------------
# Registry round-trip
# ---------------------------------------------------------------------------

def test_every_registered_solver_runs_and_reports_provenance():
    """Each solver produces a timed Candidate on an instance it applies to."""
    het_wl, het_pf = _instance(0)
    hom_wl, hom_pf = _instance(1, homogeneous=True)
    for spec in registered_solvers():
        wl, pf = (hom_wl, hom_pf) if spec.name == "dp-homogeneous" else (het_wl, het_pf)
        minimize = "latency" if spec.optimizes == "latency" else "period"
        cand = solve(spec.name, wl, pf, Objective(minimize))
        assert isinstance(cand, Candidate)
        assert cand.solver == spec.name
        assert cand.error is None, cand.error
        assert cand.mapping is not None
        assert math.isfinite(cand.period) and math.isfinite(cand.latency)
        assert cand.feasible
        assert cand.wall_time >= 0.0
        cand.mapping.validate(wl.n, pf.p)
        if spec.supports_groups:
            assert cand.groups is not None


def test_registry_names_and_metadata():
    names = solver_names()
    for required in ("single", "H1", "H2", "H3", "H4", "H5", "H6",
                     "dp-speed-ordered", "dp-homogeneous", "exact",
                     "exact-latency", "brute-force", "deal"):
        assert required in names
    by_name = {s.name: s for s in registered_solvers()}
    assert by_name["H1"].optimizes == "latency" and by_name["H1"].needs_bound
    assert by_name["H5"].optimizes == "period" and by_name["H5"].needs_bound
    assert by_name["exact"].max_p is not None
    assert by_name["deal"].supports_groups


def test_plan_report_lists_every_applicable_solver():
    wl, pf = _instance(2)
    report = plan_request(PlanRequest(wl, pf, Objective("period")))
    ran = {c.solver for c in report.candidates}
    req = report.request
    expected = {s.name for s in req.solver_specs(req.objective)}
    assert ran == expected
    # the default min-period portfolio includes the paper's fixed-latency
    # heuristics, the DP baseline, and (small p) the exact solver
    assert {"single", "H5", "H6", "dp-speed-ordered", "exact"} <= ran
    for c in report.candidates:
        assert math.isfinite(c.period) == (c.mapping is not None)
        assert c.wall_time >= 0.0


def test_solver_filters_and_size_budget():
    wl, pf = _instance(2)
    rep = plan_request(PlanRequest(wl, pf, Objective("period"),
                                   exclude=("exact",)))
    assert "exact" not in {c.solver for c in rep.candidates}
    rep = plan_request(PlanRequest(wl, pf, Objective("period"),
                                   include=("single", "H5")))
    assert {c.solver for c in rep.candidates} == {"single", "H5"}
    # exact_max_p=0 prunes every exponential solver
    rep = plan_request(PlanRequest(wl, pf, Objective("period"), exact_max_p=0))
    assert not {"exact", "exact-latency", "brute-force"} & {c.solver for c in rep.candidates}


def test_plugin_solver_and_selection_policy():
    """The decorators accept new entries at runtime — the plugin path later
    PRs rely on."""
    wl, pf = _instance(3)

    @register_solver("test-last-proc", optimizes="both",
                     description="everything on processor p-1 (test plugin)")
    def _solve_last(workload, platform, objective):
        return single_processor_mapping(workload, platform.p - 1)

    @register_selection("test-first-feasible")
    def _select_first(candidates, request):
        for c in candidates:
            if c.mapping is not None and c.feasible:
                return c
        return None

    try:
        cand = solve("test-last-proc", wl, pf, Objective("period"))
        assert cand.mapping.alloc == (pf.p - 1,)
        rep = plan_request(PlanRequest(wl, pf, Objective("period"),
                                       selection="test-first-feasible"))
        assert rep.chosen is rep.candidates[0]
    finally:
        from repro.core import solvers as _solvers
        _solvers._REGISTRY.pop("test-last-proc")
        SELECTION_POLICIES.pop("test-first-feasible")


def test_solver_error_is_reported_not_raised():
    wl, pf = _instance(4)

    @register_solver("test-crash", optimizes="both")
    def _solve_crash(workload, platform, objective):
        raise RuntimeError("boom")

    try:
        rep = plan_request(PlanRequest(wl, pf, Objective("period"),
                                       include=("single", "test-crash")))
        crash = [c for c in rep.candidates if c.solver == "test-crash"]
        assert crash and not crash[0].feasible
        assert "boom" in crash[0].error
        assert rep.plan is not None          # portfolio survives the crash
    finally:
        from repro.core import solvers as _solvers
        _solvers._REGISTRY.pop("test-crash")


# ---------------------------------------------------------------------------
# Back-compat: plan() facade vs the seed implementation
# ---------------------------------------------------------------------------

# Captured from the pre-registry plan() on these exact instances (see the
# generator below): (seed, minimize, bound, intervals, alloc, planner).
SEED_PLANS = [
    (0, 'period', None, ((1, 1), (2, 2), (3, 4), (5, 11), (12, 14)), (0, 5, 1, 4, 2), 'auto(exact)'),
    (0, 'period', 22.916666666666668, ((1, 11), (12, 14)), (2, 4), 'auto(H5)'),
    (0, 'latency', None, ((1, 14),), (2,), 'auto(single)'),
    (0, 'latency', 7.638888888888889, None, None, 'InfeasiblePlan'),
    (1, 'period', None, ((1, 1), (2, 3), (4, 8), (9, 9)), (1, 2, 0, 3), 'auto(H6)'),
    (1, 'period', 19.25294117647059, ((1, 1), (2, 3), (4, 8), (9, 9)), (1, 2, 0, 3), 'auto(H6)'),
    (1, 'latency', None, ((1, 9),), (1,), 'auto(single)'),
    (1, 'latency', 6.41764705882353, ((1, 3), (4, 8), (9, 9)), (0, 1, 3), 'auto(H4)'),
    (2, 'period', None, ((1, 2), (3, 7), (8, 9), (10, 14)), (3, 1, 0, 2), 'auto(H5)'),
    (2, 'period', 16.575, ((1, 2), (3, 7), (8, 9), (10, 14)), (3, 1, 0, 2), 'auto(H5)'),
    (2, 'latency', None, ((1, 14),), (2,), 'auto(single)'),
    (2, 'latency', 5.5249999999999995, None, None, 'InfeasiblePlan'),
    (3, 'period', None, ((1, 4), (5, 10), (11, 13)), (1, 2, 0), 'auto(H5)'),
    (3, 'period', 10.65, ((1, 3), (4, 6), (7, 13)), (0, 1, 2), 'auto(exact)'),
    (3, 'latency', None, ((1, 13),), (2,), 'auto(single)'),
    (3, 'latency', 3.5500000000000003, None, None, 'InfeasiblePlan'),
    (4, 'period', None, ((1, 1), (2, 4), (5, 8), (9, 12)), (1, 6, 2, 3), 'auto(H5)'),
    (4, 'period', 17.325, ((1, 1), (2, 4), (5, 8), (9, 12)), (1, 6, 2, 3), 'auto(H5)'),
    (4, 'latency', None, ((1, 12),), (1,), 'auto(single)'),
    (4, 'latency', 5.7749999999999995, ((1, 1), (2, 4), (5, 8), (9, 12)), (1, 6, 2, 3), 'auto(H1)'),
    (5, 'period', None, ((1, 3), (4, 6), (7, 9), (10, 10), (11, 12)), (3, 2, 4, 6, 0), 'auto(H5)'),
    (5, 'period', 11.774999999999999, ((1, 2), (3, 6), (7, 9), (10, 12)), (4, 3, 2, 0), 'auto(exact)'),
    (5, 'latency', None, ((1, 12),), (0,), 'auto(single)'),
    (5, 'latency', 3.925, None, None, 'InfeasiblePlan'),
    (6, 'period', None, ((1, 1), (2, 4), (5, 8), (9, 9)), (5, 1, 2, 3), 'auto(dp-speed-ordered)'),
    (6, 'period', 16.95, ((1, 1), (2, 4), (5, 8), (9, 9)), (5, 1, 2, 3), 'auto(dp-speed-ordered)'),
    (6, 'latency', None, ((1, 9),), (5,), 'auto(single)'),
    (6, 'latency', 5.6499999999999995, None, None, 'InfeasiblePlan'),
    (7, 'period', None, ((1, 1), (2, 8), (9, 11), (12, 15)), (3, 1, 2, 0), 'auto(exact)'),
    (7, 'period', 22.275, ((1, 1), (2, 8), (9, 11), (12, 15)), (3, 1, 2, 0), 'auto(exact)'),
    (7, 'latency', None, ((1, 15),), (0,), 'auto(single)'),
    (7, 'latency', 7.425, ((1, 1), (2, 8), (9, 10), (11, 15)), (1, 2, 3, 0), 'auto(H4)'),
]


def _seed_cases():
    it = iter(SEED_PLANS)
    for seed in range(8):
        rng = np.random.default_rng(seed)
        n, p = int(rng.integers(4, 16)), int(rng.integers(3, 9))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        hi = period(wl, pf, single_processor_mapping(wl, pf.fastest()))
        lopt = optimal_latency(wl, pf)
        for obj in (Objective("period"), Objective("period", bound=lopt * 1.5),
                    Objective("latency"), Objective("latency", bound=hi * 0.5)):
            yield wl, pf, obj, next(it)


def test_plan_facade_reproduces_seed_mappings():
    for wl, pf, obj, exp in _seed_cases():
        _, _, _, intervals, alloc, planner = exp
        if planner == "InfeasiblePlan":
            with pytest.raises(InfeasiblePlan):
                plan(wl, pf, obj, mode="auto")
            continue
        sp = plan(wl, pf, obj, mode="auto")
        assert sp.mapping.intervals == intervals
        assert sp.mapping.alloc == alloc
        assert sp.planner == planner


# ---------------------------------------------------------------------------
# Pareto consistency + plan_pareto
# ---------------------------------------------------------------------------

def test_report_pareto_consistent_with_pareto_front():
    for seed in range(4):
        wl, pf = _instance(seed)
        rep = plan_request(PlanRequest(wl, pf, Objective("period")))
        pts = [c.point for c in rep.candidates if c.feasible]
        assert rep.pareto == tuple(pareto_front(pts))
        # every front point is achieved by some feasible candidate
        for pt in rep.pareto:
            assert any(np.allclose(pt, c.point) for c in rep.candidates if c.feasible)


def test_plan_pareto_front_and_selection():
    wl, pf = _instance(5)
    rep = plan_pareto(wl, pf, k=8)
    assert rep.plan is not None and len(rep.pareto) >= 1
    pers = [p for p, _ in rep.pareto]
    lats = [l for _, l in rep.pareto]
    assert pers == sorted(pers) and lats == sorted(lats, reverse=True)
    assert rep.chosen.point in rep.pareto or rep.chosen.feasible
    # selection policies are pluggable by name
    rep_lat = plan_pareto(wl, pf, k=8, selection="min-latency")
    assert rep_lat.plan.latency == pytest.approx(min(lats))
    assert rep_lat.plan.latency <= rep.plan.latency + 1e-12


def test_multi_objective_bounds_all_enforced():
    wl, pf = _instance(6)
    base = plan_request(PlanRequest(wl, pf, Objective("period"))).plan
    rep = plan_request(PlanRequest(
        wl, pf, (Objective("period"), Objective("latency", bound=base.latency))))
    assert rep.plan is not None
    assert rep.plan.latency <= base.latency + 1e-9


# ---------------------------------------------------------------------------
# Satellite: exact latency routing
# ---------------------------------------------------------------------------

def test_exact_mode_minimizes_latency_under_period_bound():
    """Seed bug: mode="exact" with a latency objective returned a min-PERIOD
    mapping.  It must minimize latency subject to the period bound."""
    rng = np.random.default_rng(11)
    hits = 0
    for _ in range(8):
        n, p = int(rng.integers(4, 8)), int(rng.integers(3, 5))
        wl = make_workload(rng.integers(1, 11, n).astype(float),
                           rng.integers(0, 21, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 11, p).astype(float), 5.0)
        min_per = period(wl, pf, plan(wl, pf, Objective("period"), mode="exact").mapping)
        cap = min_per * 1.4
        sp = plan(wl, pf, Objective("latency", bound=cap), mode="exact")
        assert sp.period <= cap + 1e-9
        truth = brute_force(wl, pf, period_cap=cap, objective="latency")
        assert sp.latency == pytest.approx(latency(wl, pf, truth), rel=1e-9)
        # count instances where the fix changes the answer vs min-period
        if sp.latency < latency(wl, pf, brute_force(wl, pf, period_cap=cap)) - 1e-9:
            hits += 1
    assert hits > 0, "test instances never exercised the latency/period divergence"


def test_exact_mode_unbounded_latency_is_lemma1():
    wl, pf = _instance(7)
    sp = plan(wl, pf, Objective("latency"), mode="exact")
    assert sp.latency == pytest.approx(optimal_latency(wl, pf), rel=1e-12)


# ---------------------------------------------------------------------------
# Vectorized evaluation
# ---------------------------------------------------------------------------

def test_evaluate_batch_matches_scalar():
    rng = np.random.default_rng(12)
    for _ in range(5):
        n, p = int(rng.integers(2, 8)), int(rng.integers(2, 5))
        wl = make_workload(rng.integers(1, 11, n).astype(float),
                           rng.integers(0, 21, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 11, p).astype(float), 5.0)
        mappings = [Mapping(iv, procs)
                    for m in range(1, min(n, p) + 1)
                    for iv in all_interval_partitions(n, m)
                    for procs in itertools.permutations(range(p), m)]
        batch = evaluate_batch(wl, pf, mappings)
        scalar = np.array([evaluate(wl, pf, mp) for mp in mappings])
        assert np.allclose(batch, scalar, rtol=1e-12, atol=0)


def test_grouped_plan_keeps_its_groups():
    """A deal candidate chosen by selection must carry its processor groups
    on the StagePlan (its metrics are only achievable with them)."""
    wl = make_workload([1.0, 1.0, 50.0, 1.0], [1.0] * 5)
    pf = make_platform([1.0] * 6, 10.0)
    rep = plan_request(PlanRequest(wl, pf, Objective("period"), allow_groups=True))
    if rep.chosen.solver == "deal":
        assert rep.plan.groups is not None
        assert len(rep.plan.groups) == rep.plan.num_stages
    ungrouped = plan_request(PlanRequest(wl, pf, Objective("period")))
    assert ungrouped.plan.groups is None
    assert rep.plan.period <= ungrouped.plan.period + 1e-12


def test_selection_policies_enforce_request_bounds():
    wl, pf = _instance(9)
    base = plan_request(PlanRequest(wl, pf, Objective("period"))).plan
    bound = base.latency * 0.99
    for policy in ("min-period", "min-latency", "knee"):
        rep = plan_request(PlanRequest(wl, pf, Objective("period", bound=bound),
                                       selection=policy))
        if rep.plan is not None:
            assert rep.plan.latency <= bound + 1e-12, policy


def test_time_budget_skips_are_recorded():
    wl, pf = _instance(8)
    rep = plan_request(PlanRequest(wl, pf, Objective("period"), time_budget=0.0))
    assert rep.plan is None
    assert all(c.error and "budget" in c.error for c in rep.candidates)
