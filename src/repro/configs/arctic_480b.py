"""arctic-480b [moe]: 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        n_experts=128, top_k=2, expert_d_ff=4864, dense_residual=True,
        param_dtype="bfloat16",        # 480B fp32 masters would not fit 16 GB/chip
        accum_steps=2,
        fsdp_params=True,              # 960 GB of bf16 experts never fit TP-only
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab_size=512,
        n_experts=4, top_k=2, expert_d_ff=128, dense_residual=True,
    )
