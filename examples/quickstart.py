"""Quickstart: the paper's bi-criteria pipeline mapping, end to end.

1. Build a pipeline workload (here: qwen3-4b's 36 transformer blocks at the
   train_4k shape) and a heterogeneous platform (4 pods, one degraded).
2. Run the paper's heuristics, then the solver-registry portfolio through
   the PlanRequest -> PlanReport protocol (full per-solver provenance).
3. Inspect the period/latency Pareto front and the resulting stage plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import (NAMES, Objective, PlanRequest, make_platform,
                        optimal_latency, plan_pareto, plan_request,
                        plan_with_deal, solve, solver_names)
from repro.models.common import SHAPES
from repro.models.registry import lm_workload


def main() -> None:
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    print(f"workload: {wl.n} stages, {wl.total_work/1e12:.1f} TFLOP per step")
    print(f"registered solvers: {', '.join(solver_names())}")

    # 4 pods at 25.2 PF/s effective each; pod 2 is thermally degraded 1.6x
    pf = make_platform([25.2e15, 25.2e15, 25.2e15 / 1.6, 25.2e15], b=25e9)

    print("\n--- paper heuristics, fixed period = 1.5x ideal ---")
    ideal = wl.total_work / pf.s.sum()
    for code in ("H1", "H2", "H3", "H4"):
        c = solve(code, wl, pf, Objective("latency", bound=ideal * 1.5))
        status = "ok " if c.feasible else "FAIL"
        print(f"{code} {NAMES[code]:14s} [{status}] period={c.period*1e3:7.2f}ms "
              f"latency={c.latency*1e3:7.2f}ms wall={c.wall_time*1e3:.1f}ms")

    print("\n--- fixed latency = 1.2x optimal ---")
    lopt = optimal_latency(wl, pf)
    for code in ("H5", "H6"):
        c = solve(code, wl, pf, Objective("period", bound=lopt * 1.2))
        print(f"{code} {NAMES[code]:14s} period={c.period*1e3:7.2f}ms "
              f"latency={c.latency*1e3:7.2f}ms")

    print("\n--- PlanRequest -> PlanReport (min period, full provenance) ---")
    report = plan_request(PlanRequest(wl, pf, Objective("period")))
    print(report.summary())
    p = report.plan
    print(f"\nplanner={p.planner} stages={p.stage_sizes} on pods {p.mapping.alloc}")
    print(f"period={p.period*1e3:.2f}ms latency={p.latency*1e3:.2f}ms "
          f"padding_overhead={p.padding_overhead:.1%}")
    print("note: the degraded pod receives the smallest interval")

    print("\n--- Pareto-first planning (knee selection) ---")
    pr = plan_pareto(wl, pf, k=8)
    for per, lat in pr.pareto:
        mark = " <== knee" if (per, lat) == pr.chosen.point else ""
        print(f"  period={per*1e3:7.2f}ms latency={lat*1e3:7.2f}ms{mark}")

    print("\n--- deal-skeleton extension (the paper's Section-7 future work) ---")
    # A compute-dominated chain (the paper's E3 regime) with one huge stage:
    # interval splitting is stuck (a stage is atomic), dealing replicates it.
    from repro.sim import gen_instance

    wl3, pf3 = gen_instance("E3", n=8, p=10, seed=7)
    base3 = plan_request(PlanRequest(wl3, pf3, Objective("period"))).plan
    dealt = plan_with_deal(wl3, pf3, Objective("period"))
    print(f"base:   m={base3.num_stages} stages, period={base3.period:.2f}")
    print(f"dealt:  groups={dealt.groups}")
    print(f"        period={dealt.period:.2f} "
          f"({(1 - dealt.period/base3.period):.1%} better)")


if __name__ == "__main__":
    main()
