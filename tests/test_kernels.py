"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=0.5):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _tol(dtype):
    return 0.08 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 256, 4, 2, 64),
    (2, 512, 8, 8, 32),
    (1, 384, 6, 3, 128),
    (2, 256, 4, 1, 64),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, causal, dtype):
    q, k, v = arr(B, S, H, hd, dtype=dtype), arr(B, S, K, hd, dtype=dtype), \
        arr(B, S, K, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("window", [64, 96, 256])
def test_flash_attention_sliding_window(window):
    q = arr(1, 256, 4, 64)
    k = arr(1, 256, 2, 64)
    v = arr(1, 256, 2, 64)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("B,C,H,K,hd,pos_frac,window", [
    (2, 256, 4, 2, 64, 0.5, None),
    (1, 512, 8, 8, 32, 0.9, None),
    (2, 512, 4, 4, 64, 0.7, 100),
    (1, 256, 8, 2, 128, 0.1, None),
])
def test_decode_attention_sweep(B, C, H, K, hd, pos_frac, window):
    q = arr(B, H, hd)
    k = arr(B, C, K, hd)
    v = arr(B, C, K, hd)
    positions = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None], (B, C))
    pos = jnp.full((B,), int(C * pos_frac), jnp.int32)
    out = ops.decode_attention(q, k, v, positions, pos, window=window, block_c=128)
    valid = (positions >= 0) & (positions <= pos[:, None])
    if window:
        valid &= positions > pos[:, None] - window
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("shape", [(4, 256), (2, 128, 256), (3, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = arr(*shape, dtype=dtype)
    sc = arr(shape[-1])
    out = ops.rmsnorm(x, sc)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=_tol(dtype))


def test_rmsnorm_residual():
    x = arr(4, 64, 256, dtype=jnp.bfloat16)
    r = arr(4, 64, 256, dtype=jnp.bfloat16)
    sc = arr(256)
    o1, r1 = ops.rmsnorm_residual(x, r, sc)
    o2, r2 = ref.rmsnorm_residual_ref(x, r, sc)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=0.08)
    np.testing.assert_allclose(np.asarray(r1, np.float32),
                               np.asarray(r2, np.float32), atol=0.08)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 64, 8, 16, 32, 16),
])
def test_ssd_sweep(B, S, H, P, N, chunk):
    x = arr(B, S, H, P)
    dt = jnp.abs(arr(B, S, H)) * 0.1
    A = -jnp.abs(arr(H)) * 0.5
    Bm, Cm = arr(B, S, N), arr(B, S, N)
    y, st = ops.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=2e-4)


def test_ssd_kernel_matches_model_path():
    """The Pallas SSD and the model's pure-jnp chunked SSD agree."""
    from repro.models.ssm import ssd_chunked as model_ssd

    B, S, H, P, N = 2, 128, 4, 32, 16
    x = arr(B, S, H, P)
    dt = jnp.abs(arr(B, S, H)) * 0.1
    A = -jnp.abs(arr(H)) * 0.5
    Bm, Cm = arr(B, S, N), arr(B, S, N)
    y1, s1 = ops.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = model_ssd(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


# ---------------------------------------------------------------------------
# Split-scoring kernels (repro.kernels.split_score): the heuristics' 2-way /
# 3-way candidate evaluation as pallas masked tiles, bit-identical to the
# shared numpy kernels on every live lane (float64, interpret mode).
# ---------------------------------------------------------------------------


def _split_inputs(rng, A, K):
    pre = np.sort(rng.uniform(0.0, 100.0, (A, K + 2)), axis=1)
    pre_d1, pre_C, pre_e = pre[:, :1], pre[:, 1:-1], pre[:, -1:]
    delta = rng.uniform(0.0, 50.0, (A, K + 2))
    del_d1, del_C, del_e = delta[:, :1], delta[:, 1:-1], delta[:, -1:]
    inv_j = rng.uniform(0.05, 2.0, (A, 1))
    inv_p = rng.uniform(0.05, 2.0, (A, 1))
    return pre_d1, pre_C, pre_e, del_d1, del_C, del_e, inv_j, inv_p


@pytest.mark.parametrize("A,K", [(5, 37), (8, 128), (17, 300), (1, 1)])
def test_split_score_2way_matches_numpy_on_live_lanes(A, K):
    from repro.core.heuristics import score_2way_kernel
    from repro.kernels import split_score

    rng = np.random.default_rng(11)
    ins = _split_inputs(rng, A, K)
    b = 10.0
    need = rng.integers(1, K + 1, A)
    want = score_2way_kernel(*ins[:6], b, *ins[6:], xp=np)
    got = split_score.score_2way_pallas(*ins[:6], b, *ins[6:], need=need)
    for g, w in zip(got, want):
        g = np.asarray(g)
        assert g.shape == w.shape
        # live lanes (cut offsets < need, in both placement-order halves)
        # are bit-identical; everything else is masked-tile zero padding
        # or computed-but-dead lanes the callers never select
        lanes = np.arange(K)[None, :] < need[:, None]
        live = np.concatenate([lanes, lanes], axis=1)
        assert np.array_equal(g[live], w[live])


@pytest.mark.parametrize("A,span", [(4, 5), (9, 12), (16, 20)])
def test_split_score_3way_matches_numpy_on_live_lanes(A, span):
    from repro.core.heuristics import _PERMS3, score_3way_kernel
    from repro.kernels import split_score

    rng = np.random.default_rng(13)
    o1, o2 = np.triu_indices(span - 1, k=1)
    K = o1.size
    dI = rng.uniform(0.0, 10.0, (A, 3, K))
    W = rng.uniform(0.1, 100.0, (A, 3, K))
    dO = rng.uniform(0.0, 10.0, (A, 3, K))
    inv = rng.uniform(0.05, 2.0, (A, 3))
    invp = inv[:, np.asarray(_PERMS3)][:, :, :, None]
    base = rng.uniform(1.0, 50.0, (A, 1, 1))
    spans = rng.integers(3, span + 1, A)
    need = split_score.pair_need(spans, span)
    want = score_3way_kernel(dI[:, None], W[:, None], dO[:, None], invp, base,
                             xp=np)
    got = split_score.score_3way_pallas(dI[:, None], W[:, None], dO[:, None],
                                        invp, base, need=need)
    # lane validity mirrors batched._choose_3way: pair (o1, o2) is live for
    # span s iff o2 <= s - 2; all live lanes sit below the pair_need bound
    live_l = o2[None, :] <= (spans - 2)[:, None]
    assert (np.nonzero(live_l)[1] < need[np.nonzero(live_l)[0]]).all()
    for g, w in zip(got, want):
        g = np.asarray(g)
        assert g.shape == w.shape
        live = np.broadcast_to(live_l[:, None, None, :], w.shape) \
            if w.ndim == 4 else np.broadcast_to(live_l[:, None, :], w.shape)
        assert np.array_equal(g[live], w[live])


def test_split_score_masked_tiles_zero_filled():
    """Tiles wholly past every row's live-lane bound skip compute via
    pl.when and are zero-filled."""
    from repro.kernels import split_score

    rng = np.random.default_rng(17)
    A, K = 8, 512
    ins = _split_inputs(rng, A, K)
    need = np.full(A, 3)                    # one live tile of 128 lanes
    cyc1, _, _ = split_score.score_2way_pallas(*ins[:6], 10.0, *ins[6:],
                                               need=need, block_k=128)
    cyc1 = np.asarray(cyc1)
    assert np.array_equal(cyc1[:, 128:K], np.zeros((A, K - 128)))
    assert not np.any(cyc1[:, :3] == 0.0)   # live lanes computed
