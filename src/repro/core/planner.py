"""High-level planner API: the paper's technique as a framework feature.

``plan()`` takes a workload (layers as pipeline stages) and a platform (pods
as processors) and returns a :class:`StagePlan` that the pipeline runtime
(:mod:`repro.pipeline.runtime`) executes.  The default "auto" mode runs the
paper's full heuristic portfolio plus the polynomial DP baselines and returns
the best feasible mapping — a beyond-paper ensemble that strictly dominates
any single heuristic.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .exact import dp_speed_ordered, exact_min_period
from .heuristics import (FIXED_LATENCY_HEURISTICS, FIXED_PERIOD_HEURISTICS,
                         HeuristicResult, run_heuristic)
from .metrics import Mapping, evaluate, optimal_latency, period, single_processor_mapping
from .platform import Platform
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Objective:
    """Bi-criteria objective: minimize ``minimize`` subject to the other
    criterion being <= ``bound`` (bound=None -> unconstrained)."""

    minimize: str                 # "latency" | "period"
    bound: Optional[float] = None

    def __post_init__(self):
        if self.minimize not in ("latency", "period"):
            raise ValueError(self.minimize)


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """A planned pipeline mapping, ready for the runtime."""

    mapping: Mapping
    period: float
    latency: float
    planner: str                  # which algorithm produced it
    # Runtime realization data:
    stage_sizes: tuple            # layers per stage, chain order
    max_stage_size: int           # padded stage depth for the stacked runtime
    padding_overhead: float       # wasted fraction of padded compute slots

    @property
    def num_stages(self) -> int:
        return len(self.stage_sizes)


def _realize(mapping: Mapping, per: float, lat: float, name: str) -> StagePlan:
    sizes = tuple(e - d + 1 for d, e in mapping.intervals)
    mx = max(sizes)
    total_slots = mx * len(sizes)
    pad = 1.0 - sum(sizes) / total_slots
    return StagePlan(mapping, per, lat, name, sizes, mx, pad)


def plan(
    workload: Workload,
    platform: Platform,
    objective: Objective,
    mode: str = "auto",
    exact_max_p: int = 12,
) -> StagePlan:
    """Compute a stage plan.

    mode:
      - one of "H1".."H6": the corresponding paper heuristic (bound required);
      - "auto": portfolio — all applicable heuristics + DP baselines (+ exact
        when p is small), best feasible result wins;
      - "exact": exact solver (exponential in p; raises if p > exact_max_p).
    """
    if mode in FIXED_PERIOD_HEURISTICS or mode in FIXED_LATENCY_HEURISTICS:
        if objective.bound is None:
            raise ValueError("paper heuristics need a bound")
        res = run_heuristic(mode, workload, platform, objective.bound)
        if not res.feasible or res.mapping is None:
            raise InfeasiblePlan(f"{mode} found no feasible mapping for {objective}")
        return _realize(res.mapping, res.period, res.latency, mode)

    if mode == "exact":
        if platform.p > exact_max_p:
            raise ValueError(f"exact solver limited to p <= {exact_max_p}")
        cap = objective.bound if objective.minimize == "period" else math.inf
        mp = exact_min_period(workload, platform, latency_cap=cap if cap is not None else math.inf)
        if mp is None:
            raise InfeasiblePlan("exact: infeasible")
        per, lat = evaluate(workload, platform, mp)
        return _realize(mp, per, lat, "exact")

    if mode != "auto":
        raise KeyError(mode)

    candidates: list = []

    def add(mp: Optional[Mapping], name: str):
        if mp is None:
            return
        per, lat = evaluate(workload, platform, mp)
        candidates.append((mp, per, lat, name))

    # Always valid fallback: everything on the fastest processor.
    add(single_processor_mapping(workload, platform.fastest()), "single")

    if objective.minimize == "latency":
        bound = objective.bound if objective.bound is not None else math.inf
        for code in FIXED_PERIOD_HEURISTICS:
            res = run_heuristic(code, workload, platform, bound)
            if res.feasible and res.mapping is not None:
                candidates.append((res.mapping, res.period, res.latency, code))
    else:
        bound = objective.bound if objective.bound is not None else math.inf
        for code in FIXED_LATENCY_HEURISTICS:
            res = run_heuristic(code, workload, platform, bound)
            if res.feasible and res.mapping is not None:
                candidates.append((res.mapping, res.period, res.latency, code))
        add(dp_speed_ordered(workload, platform, latency_cap=bound), "dp-speed-ordered")
        if platform.p <= exact_max_p:
            add(exact_min_period(workload, platform, latency_cap=bound), "exact")

    # Filter by constraint, sort by objective (tie-break on the other).
    feas = []
    for mp, per, lat, name in candidates:
        if objective.bound is not None:
            other = per if objective.minimize == "latency" else lat
            if other > objective.bound + 1e-12:
                continue
        key = (lat, per) if objective.minimize == "latency" else (per, lat)
        feas.append((key, mp, per, lat, name))
    if not feas:
        raise InfeasiblePlan(f"no planner produced a feasible mapping for {objective}")
    feas.sort(key=lambda t: t[0])
    _, mp, per, lat, name = feas[0]
    return _realize(mp, per, lat, f"auto({name})")


class InfeasiblePlan(RuntimeError):
    pass


def replan_for_straggler(
    workload: Workload,
    platform: Platform,
    current: StagePlan,
    observed_stage_times: np.ndarray,
    slowdown_threshold: float = 1.3,
) -> tuple:
    """Straggler mitigation: compare observed per-stage step times against the
    plan's predicted cycle times; degrade the effective speed of any processor
    running slower than ``slowdown_threshold`` x predicted; re-plan.

    Returns (new_plan, degraded_platform).  This is exactly the paper's
    heterogeneous-processor scenario arising *online* on homogeneous hardware.
    """
    from .metrics import interval_cycle_times

    predicted = interval_cycle_times(workload, platform, current.mapping)
    observed = np.asarray(observed_stage_times, dtype=float)
    if observed.shape != predicted.shape:
        raise ValueError("one observation per stage required")
    pf = platform
    for j, (obs, pred) in enumerate(zip(observed, predicted)):
        if pred > 0 and obs / pred > slowdown_threshold:
            pf = pf.degrade(current.mapping.alloc[j], obs / pred)
    new = plan(workload, pf, Objective("period", bound=None), mode="auto")
    return new, pf
