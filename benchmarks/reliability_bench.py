"""Tri-criteria planning benchmark: what replication buys on the R families.

For each reliability experiment family (R1 uniform, R2 bimodal, R3
speed-correlated, R4 compute-heavy bimodal) this script runs the tri-criteria
portfolio :func:`repro.core.plan_pareto_tri` on a few seeded instances and
records, as ``tri_criteria_*`` rows:

  - the 3-D Pareto front size (period x latency x reliability),
  - the reliability of the chosen plan vs the best *bi-criteria* plan on the
    same instance (the gain replication buys at the knee),
  - wall time per tri-criteria plan.

``bench_gate.py`` requires the rows and floors the reliability gain: the
tri-criteria knee must never choose a plan LESS reliable than the bi-criteria
portfolio's pick on the same instance (the degenerate singleton case is
bit-identical, so gain >= 0 is structural — a negative gain means the
consensus evaluation or the knee policy broke).

Rows MERGE into BENCH_planner.json (same contract as fleet_bench.py).

    PYTHONPATH=src python benchmarks/reliability_bench.py [--quick]
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core import (ReplicatedMapping, plan_pareto, plan_pareto_tri,  # noqa: E402
                        reliability)
from repro.sim import RELIABILITY_FAMILIES  # noqa: E402
from repro.sim.generators import gen_instance  # noqa: E402

from fleet_bench import merge_bench_json  # noqa: E402

STANDARD = dict(n=12, p=8, seeds=(0, 1, 2))
QUICK = dict(n=8, p=5, seeds=(0, 1))


def _plan_reliability(wl, pf, plan) -> float:
    if plan.groups is not None:
        return reliability(wl, pf, ReplicatedMapping(plan.mapping.intervals,
                                                     plan.groups))
    return reliability(wl, pf, plan.mapping)


def run(quick: bool = False) -> list:
    cfg = QUICK if quick else STANDARD
    rows = []
    for exp in RELIABILITY_FAMILIES:
        fronts, gains, rels, walls = [], [], [], []
        for seed in cfg["seeds"]:
            wl, pf = gen_instance(exp, cfg["n"], cfg["p"], seed=seed)
            t0 = time.perf_counter()
            tri = plan_pareto_tri(wl, pf)
            walls.append(time.perf_counter() - t0)
            bi = plan_pareto(wl, pf)
            tri_rel = _plan_reliability(wl, pf, tri.plan)
            bi_rel = _plan_reliability(wl, pf, bi.plan)
            fronts.append(len(tri.pareto))
            rels.append(tri_rel)
            gains.append(tri_rel - bi_rel)
        us = float(np.mean(walls)) * 1e6
        extra = {"front_size": float(np.mean(fronts)),
                 "reliability_gain": float(np.mean(gains)),
                 "min_reliability_gain": float(np.min(gains)),
                 "chosen_reliability": float(np.mean(rels)),
                 "n": cfg["n"], "p": cfg["p"], "seeds": len(cfg["seeds"])}
        rows.append((f"tri_criteria_{exp}", us,
                     f"front {np.mean(fronts):.1f} pts, chosen rel "
                     f"{np.mean(rels):.4f} (+{np.mean(gains):.4f} vs "
                     f"bi-criteria), {us:.0f}us/plan",
                     extra))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    for name, us, derived, _ in rows:
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}")
    merge_bench_json(rows, mode="quick" if args.quick else "full")
    print("# merged into BENCH_planner.json")


if __name__ == "__main__":
    main()
