"""Crash-safe fleet replanning: write-ahead journal, snapshot/restore, and
the supervised controller/worker split.

The acceptance contract: a controller killed at ANY tick of a seeded chaos
trace and restored from its journal finishes the trace with a
``fleet_digest()`` bit-identical to an uninterrupted run and zero invalid
published ticks.  Plus the unit surface underneath it — CRC'd record codec,
torn-tail recovery, snapshot cadence/compaction, supervisor retry/restart
semantics, and poison-problem quarantine.
"""

import time

import pytest

import repro.fleet.service as svc_mod
from repro.fleet import (ChaosSpec, InlineWorker, Journal, JournalError,
                         PodCountChange, ReplanService, SimulatedCrash,
                         StageDrift, SubprocessWorker, Supervisor,
                         ThreadWorker, TransportChaos, WorkerFailed,
                         WorkerTimeout, crash_restart_run, event_from_wire,
                         event_to_wire, gen_burst_trace, inject_chaos,
                         make_fleet, subprocess_supervisor)
from repro.fleet.journal import decode_record, encode_record


def _small_fleet(seed=11):
    pairs, groups = make_fleet(3, 3, n=8, p=4, seed=seed)
    trace = gen_burst_trace(groups, 10, seed=seed + 1, n_stages=8,
                            initial_pods=4, burst_prob=0.7)
    return pairs, inject_chaos(trace, groups, ChaosSpec(), seed=seed + 2)


def _journal(tmp_path, **kw):
    kw.setdefault("fsync", False)   # tmpfs + tests: skip the disk barrier
    return Journal(tmp_path / "journal", **kw)


# ---------------------------------------------------------------------------
# Record codec + WAL torn-tail recovery
# ---------------------------------------------------------------------------

def test_record_codec_round_trip():
    payload = {"tick": 3, "events": [["StageDrift",
                                      {"instance": 1, "stage": 2,
                                       "factor": 1.5}]]}
    assert decode_record(encode_record(payload)) == payload


@pytest.mark.parametrize("mangle", [
    lambda b: b[: len(b) // 2],                 # torn mid-record
    lambda b: b"deadbeef" + b[8:],              # CRC mismatch
    lambda b: b[:9] + b"not json\n",            # unparseable payload
    lambda b: b"xx\n",                          # too short to hold a CRC
])
def test_corrupt_records_are_detected(mangle):
    good = encode_record({"tick": 0, "events": []})
    with pytest.raises(JournalError):
        decode_record(mangle(good))


def test_wal_recovers_longest_good_prefix(tmp_path):
    j = _journal(tmp_path)
    for t in range(4):
        j.append(t, [StageDrift(0, 1, 2.0)])
    j.close()
    # Simulate a crash mid-append: tear the final record in half.
    data = j.wal_path.read_bytes()
    j.wal_path.write_bytes(data[: len(data) - 10])
    records, error = j.read_wal()
    assert [r["tick"] for r in records] == [0, 1, 2]
    assert error is not None and "record 3" in error
    with pytest.raises(JournalError):
        j.read_wal(strict=True)


def test_wal_survives_mid_log_corruption_to_prefix(tmp_path):
    j = _journal(tmp_path)
    for t in range(3):
        j.append(t, [])
    j.close()
    lines = j.wal_path.read_bytes().splitlines(keepends=True)
    lines[1] = b"00000000 {}\n"   # CRC of b"{}" is not 0: detected
    j.wal_path.write_bytes(b"".join(lines))
    records, error = j.read_wal()
    assert [r["tick"] for r in records] == [0]
    assert "record 1" in error


def test_event_wire_codec_round_trips_all_types():
    from repro.fleet import PodFailure, StageTimings
    events = [StageTimings(3, (0.5, 1.25, 2.0)), StageDrift(1, 4, 3.0),
              PodCountChange(2, 6), PodFailure(0, 1)]
    for ev in events:
        assert event_from_wire(event_to_wire(ev)) == ev
    with pytest.raises(ValueError):
        event_from_wire(["NoSuchEvent", {}])


# ---------------------------------------------------------------------------
# Snapshot cadence, compaction, restore
# ---------------------------------------------------------------------------

def test_snapshot_compacts_wal_and_prunes_old_snapshots(tmp_path):
    pairs, trace = _small_fleet()
    j = _journal(tmp_path, snapshot_every=4, keep_snapshots=2)
    svc = ReplanService(pairs, journal=j)
    svc.run_trace(trace)
    records, error = j.read_wal()
    assert error is None
    # WAL holds only the ticks the oldest RETAINED snapshot hasn't absorbed
    # (kept that far back so restore can fall back past a corrupt newest).
    snaps = j._snapshot_paths()
    assert len(snaps) <= 2
    oldest_tick = snaps[0][0]
    assert all(r["tick"] >= oldest_tick for r in records)
    assert len(records) <= j.snapshot_every * j.keep_snapshots


def test_restore_at_genesis_without_any_ticks(tmp_path):
    pairs, _ = _small_fleet()
    j = _journal(tmp_path)
    svc = ReplanService(pairs, journal=j)
    restored = ReplanService.restore(j)
    assert restored.tick_count == 0
    assert restored.fleet_digest() == svc.fleet_digest()


def test_restore_reproduces_state_and_continues_identically(tmp_path):
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)

    j = _journal(tmp_path, snapshot_every=3)
    svc = ReplanService(pairs, journal=j)
    for events in trace.ticks[:6]:
        svc.tick(events)
    svc.journal.close()

    restored = ReplanService.restore(j)
    assert restored.tick_count == 6
    assert restored.fleet_digest() == svc.fleet_digest()
    restored.resume_trace(trace)
    assert restored.fleet_digest() == ref.fleet_digest()
    assert restored.metrics.invalid_published == 0
    # Count-based metrics survive the snapshot + replay round trip exactly.
    for field in ("ticks", "requests", "solves", "warm_hits", "events",
                  "deferred", "fallback_solves", "dropped_events"):
        assert getattr(restored.metrics, field) == getattr(ref.metrics, field)


def test_restore_skips_corrupt_snapshot_in_favor_of_older(tmp_path):
    pairs, trace = _small_fleet()
    j = _journal(tmp_path, snapshot_every=3, keep_snapshots=3)
    svc = ReplanService(pairs, journal=j)
    for events in trace.ticks[:7]:
        svc.tick(events)
    svc.journal.close()
    snaps = sorted((tmp_path / "journal").glob("snapshot_*.json"))
    assert len(snaps) >= 2
    snaps[-1].write_bytes(b"00000000 torn\n")   # newest snapshot corrupted
    # Compaction keeps the WAL back to the oldest retained snapshot, so
    # recovery falls back to the older snapshot and replays forward to the
    # exact same state.
    restored = ReplanService.restore(j)
    assert restored.tick_count == svc.tick_count
    assert restored.fleet_digest() == svc.fleet_digest()


def test_restore_without_snapshot_raises(tmp_path):
    with pytest.raises(JournalError):
        ReplanService.restore(_journal(tmp_path))


def test_journaling_is_observation_only(tmp_path):
    """A journaled run publishes bit-identical plans to an unjournaled one."""
    pairs, trace = _small_fleet()
    plain = ReplanService(pairs)
    plain.run_trace(trace)
    journaled = ReplanService(pairs, journal=_journal(tmp_path))
    journaled.run_trace(trace)
    assert journaled.fleet_digest() == plain.fleet_digest()


# ---------------------------------------------------------------------------
# The tentpole property: crash anywhere, recover bit-identically
# ---------------------------------------------------------------------------

def test_crash_at_every_tick_recovers_bit_identically(tmp_path):
    """For EVERY tick of the seeded chaos trace: kill the controller
    mid-tick (events journaled, state untouched), restore from the journal,
    finish the trace — digest matches the uninterrupted run, zero invalid
    published ticks, and metrics agree tick-for-tick."""
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)
    for crash_tick in range(trace.num_ticks):
        d = tmp_path / f"crash_{crash_tick}"
        svc, restarts = crash_restart_run(
            pairs, trace, Journal(d, snapshot_every=4, fsync=False),
            crash_ticks=[crash_tick])
        assert len(restarts) == 1
        assert svc.fleet_digest() == ref.fleet_digest(), \
            f"digest diverged after crash at tick {crash_tick}"
        assert svc.metrics.ticks == ref.metrics.ticks
        assert svc.metrics.invalid_published == 0


def test_double_crash_including_crash_during_catchup(tmp_path):
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)
    svc, restarts = crash_restart_run(
        pairs, trace, Journal(tmp_path / "j", snapshot_every=4, fsync=False),
        crash_ticks=[3, 4])   # second kill lands right after the first restore
    assert len(restarts) == 2
    assert svc.fleet_digest() == ref.fleet_digest()


def test_crash_with_torn_wal_tail_still_recovers(tmp_path):
    """Crash plus a half-written final record (the real kill -9 shape): the
    torn record's tick is re-fetched from the trace by resume_trace, so the
    outcome is still bit-identical."""
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)
    j = Journal(tmp_path / "j", snapshot_every=4, fsync=False)
    svc = ReplanService(pairs, journal=j)
    for events in trace.ticks[:6]:
        svc.tick(events)
    svc.journal.close()
    data = j.wal_path.read_bytes()
    j.wal_path.write_bytes(data[: len(data) - 7])   # tear tick 5's record
    restored = ReplanService.restore(j)
    assert restored.tick_count == 5   # recovered to the last good record
    restored.resume_trace(trace)
    assert restored.fleet_digest() == ref.fleet_digest()


def test_simulated_crash_fires_before_state_mutation(tmp_path):
    pairs, trace = _small_fleet()
    j = Journal(tmp_path / "j", fsync=False)
    svc = ReplanService(pairs, journal=j)
    digest_before = svc.fleet_digest()

    def hook(tick):
        raise SimulatedCrash("boom")

    svc.crash_hook = hook
    with pytest.raises(SimulatedCrash):
        svc.tick(trace.ticks[0])
    assert svc.fleet_digest() == digest_before
    assert svc.tick_count == 0
    records, _ = j.read_wal()
    assert [r["tick"] for r in records] == [0]   # WAL wrote ahead of the crash


# ---------------------------------------------------------------------------
# Supervisor: retries, backoff, worker restarts, timeouts
# ---------------------------------------------------------------------------

def test_supervisor_retries_with_exponential_backoff_then_raises():
    calls, delays = [], []

    def flaky(batch):
        calls.append(batch)
        raise RuntimeError("transient")

    sup = Supervisor(flaky, max_attempts=4, backoff_base=0.01,
                     backoff_max=0.03, sleep=delays.append)
    with pytest.raises(WorkerFailed):
        sup.solve("pb")
    assert len(calls) == 4
    assert delays == [0.01, 0.02, 0.03]   # doubles, then clamps
    assert sup.stats.retries == 3 and sup.stats.failures == 4


def test_supervisor_recovers_when_a_retry_succeeds():
    attempts = []

    def flaky(batch):
        attempts.append(1)
        if len(attempts) < 2:
            raise RuntimeError("first attempt dies")
        return ["ok"]

    sup = Supervisor(flaky, max_attempts=3, backoff_base=0, sleep=lambda s: None)
    assert sup.solve("pb") == ["ok"]
    assert sup.stats.retries == 1 and sup.stats.dispatches == 2


def test_thread_worker_timeout_restarts_worker():
    import time as _time

    def hang(batch):
        _time.sleep(0.5)
        return ["late"]

    sup = Supervisor(hang, worker_cls=ThreadWorker, max_attempts=2,
                     timeout=0.05, backoff_base=0, sleep=lambda s: None)
    first_worker = sup.pool[0]
    with pytest.raises(WorkerFailed) as ei:
        sup.solve("pb")
    assert isinstance(ei.value.__cause__, WorkerTimeout)
    assert sup.stats.restarts >= 1
    assert sup.pool[0] is not first_worker
    sup.close()


def test_inline_worker_is_transparent():
    w = InlineWorker(lambda b: [b, b])
    assert w.solve("x") == ["x", "x"]
    assert w.solves == 1 and w.alive(0.0)


def test_service_results_identical_under_thread_workers():
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)
    svc = ReplanService(pairs)
    svc.supervisor = Supervisor(svc._solve_group, worker_cls=ThreadWorker,
                                workers=2, timeout=30.0)
    svc.run_trace(trace)
    assert svc.fleet_digest() == ref.fleet_digest()
    svc.supervisor.close()


def test_supervisor_timeout_with_inline_worker_is_rejected():
    """Deadline protection over a synchronous worker is fictional — the
    misconfiguration must fail at construction, not silently no-op."""
    import functools
    with pytest.raises(ValueError, match="preempt"):
        Supervisor(lambda b: b, timeout=1.0)
    with pytest.raises(ValueError, match="preempt"):
        Supervisor(lambda b: b, timeout=1.0,
                   worker_cls=functools.partial(InlineWorker))
    # No timeout, or a preemptable transport: fine.
    Supervisor(lambda b: b)
    Supervisor(lambda b: b, worker_cls=ThreadWorker, timeout=1.0).close()


def test_supervisor_counts_timeouts_separately_from_failures():
    def hang(batch):
        time.sleep(0.5)
        return ["late"]

    sup = Supervisor(hang, worker_cls=ThreadWorker, max_attempts=2,
                     timeout=0.05, backoff_base=0, sleep=lambda s: None)
    with pytest.raises(WorkerFailed):
        sup.solve("pb")
    assert sup.stats.timeouts == 2 and sup.stats.failures == 0
    # Abandoned (unkillable) threads are surfaced, not silently leaked.
    sup.close()
    assert sup.stats.leaked_threads == 2


def test_thread_worker_close_cancels_queued_work():
    ran = []

    def slow(batch):
        time.sleep(0.3)
        ran.append(batch)
        return [batch]

    w = ThreadWorker(slow)
    first = w._ex.submit(w._run, "running")
    queued = w._ex.submit(w._run, "queued")
    w.close()   # shutdown(cancel_futures=True): queued work must NOT run
    assert queued.cancelled()
    first.result(timeout=5)
    assert ran == ["running"]


# ---------------------------------------------------------------------------
# SubprocessWorker: real process isolation, kill-based preemption
# ---------------------------------------------------------------------------

def _batch(seed=0, rows=3, n=8, p=4):
    import numpy as np
    from repro.core.batched import ProblemBatch
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, size=(rows, n))
    delta = rng.uniform(0.1, 1.0, size=(rows, n + 1))
    s = np.sort(rng.uniform(0.5, 2.0, size=(rows, p)))[:, ::-1].copy()
    return ProblemBatch.from_arrays(w, delta, s, 10.0)


def _inline_reference(pb):
    from repro.core.batched import batched_min_period
    return batched_min_period(pb, "numpy")


@pytest.mark.slow
def test_subprocess_worker_is_bit_identical_to_inline():
    pb = _batch(seed=21)
    sup = subprocess_supervisor(workers=1, timeout=60.0)
    try:
        assert sup.solve(pb) == _inline_reference(pb)
    finally:
        sup.close()


@pytest.mark.slow
@pytest.mark.parametrize("fault,expect_timeout", [
    ({"kill_prob": 1.0}, False),        # SIGKILL after the request is sent
    ({"doa_prob": 1.0}, False),         # dead before the first heartbeat
    ({"corrupt_prob": 1.0}, False),     # reply frame fails CRC -> poisoned
    ({"truncate_prob": 1.0}, None),     # stalled/desynced reply
])
def test_subprocess_fault_matrix_recovers_with_one_restart(fault,
                                                           expect_timeout):
    """Each injected wire fault costs exactly one worker restart and the
    retried solve still matches the inline run bit-for-bit."""
    pb = _batch(seed=22)
    chaos = TransportChaos(max_faults=1, seed=13, **fault)
    sup = subprocess_supervisor(workers=1, timeout=2.0, chaos=chaos,
                                max_attempts=3, backoff_base=0.0,
                                term_grace=0.2)
    try:
        assert sup.solve(pb) == _inline_reference(pb)
        assert chaos.total_faults() == 1
        assert sup.stats.restarts == 1
        if expect_timeout is True:
            assert sup.stats.timeouts >= 1
        elif expect_timeout is False:
            assert sup.stats.failures >= 1
    finally:
        sup.close()


@pytest.mark.slow
def test_wedged_solve_is_reaped_by_sigkill_within_timeout():
    """The preemption guarantee: a wedged worker that IGNORES SIGTERM is
    killed by the kernel within timeout + term_grace, and the hang is
    accounted as a timeout (not a failure)."""
    pb = _batch(seed=23)
    chaos = TransportChaos(wedge_prob=1.0, wedge_seconds=30.0, max_faults=1,
                           seed=5)
    timeout, grace = 0.75, 0.2
    sup = subprocess_supervisor(workers=1, timeout=timeout, chaos=chaos,
                                max_attempts=1, term_grace=grace,
                                ignore_sigterm=True)
    wedged = sup.pool[0]
    t0 = time.perf_counter()
    with pytest.raises(WorkerFailed) as ei:
        sup.solve(pb)
    wall = time.perf_counter() - t0
    sup.close()
    assert isinstance(ei.value.__cause__, WorkerTimeout)
    assert wall < timeout + grace + 2.0   # reaped, not waited out (30s wedge)
    assert wedged._proc.returncode == -9  # SIGTERM ignored -> SIGKILL won
    assert wedged.sigkills == 1
    assert sup.stats.timeouts == 1 and sup.stats.failures == 0
    assert sup.stats.sigkills == 1


@pytest.mark.slow
def test_dead_worker_detected_by_alive_and_replaced():
    sup = subprocess_supervisor(workers=1, timeout=60.0)
    try:
        victim = sup.pool[0]
        victim._proc.kill()
        victim._proc.wait()
        assert not victim.alive(None)
        pb = _batch(seed=24)
        assert sup.solve(pb) == _inline_reference(pb)   # replaced pre-dispatch
        assert sup.stats.restarts == 1
        assert sup.pool[0] is not victim
    finally:
        sup.close()


@pytest.mark.slow
def test_service_digest_identical_under_subprocess_workers_with_kills():
    """The tentpole contract at service level: repeated SIGKILLs mid-solve
    leave the published fleet state bit-identical to the inline run, with
    zero invalid published ticks and every restart attributable to an
    injected fault."""
    pairs, trace = _small_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(trace)

    chaos = TransportChaos(kill_prob=0.5, max_faults=4, seed=1)
    svc = ReplanService(pairs)
    svc.supervisor = subprocess_supervisor(workers=2, timeout=60.0,
                                           chaos=chaos, max_attempts=3,
                                           backoff_base=0.0)
    svc._sync_acct_baselines()
    svc.run_trace(trace)
    svc.supervisor.close()

    assert svc.fleet_digest() == ref.fleet_digest()
    assert svc.metrics.invalid_published == 0
    assert chaos.counts.get("kill", 0) >= 1          # chaos actually fired
    assert 1 <= svc.metrics.worker_restarts <= chaos.total_faults()


# ---------------------------------------------------------------------------
# Poison quarantine
# ---------------------------------------------------------------------------

def test_poison_problem_is_quarantined_after_double_failures(monkeypatch):
    pairs, _ = _small_fleet()
    svc = ReplanService(pairs, quarantine_after=2)
    svc.supervisor.sleep = lambda s: None
    healthy_digest = svc.fleet_digest()

    def boom(*a, **k):
        raise RuntimeError("poisoned solve")

    monkeypatch.setattr(svc_mod, "batched_min_period", boom)
    monkeypatch.setattr(svc_mod, "min_period_exhaustive", boom)

    # Strike 1: batched AND scalar fail; the request defers (retry next tick).
    svc.tick([StageDrift(0, 0, 2.0)])
    assert svc.quarantine_strikes and not svc.quarantined
    assert svc._pending
    # Strike 2 (the deferred retry): quarantined, request pinned to the last
    # valid plan and NOT re-pended.
    svc.tick([])
    assert svc.quarantined and not svc._pending
    assert svc.metrics.quarantined_problems == 1
    assert svc.metrics.quarantined_requests >= 1
    # Quarantined ticks never solve, never wedge, never publish invalid.
    svc.tick([])
    assert svc.fleet_digest() == healthy_digest   # kept the last valid plans
    assert svc.metrics.invalid_published == 0
    assert not svc._pending

    # Drift that changes the signature re-enters the solve path: with the
    # solver healed, the instance replans out of quarantine.
    monkeypatch.undo()
    svc.tick([PodCountChange(0, 3)])
    assert svc.metrics.invalid_published == 0
    assert svc.states[0].plan.mapping.alloc is not None
    assert not svc._pending


def test_quarantine_state_survives_restore(tmp_path, monkeypatch):
    pairs, _ = _small_fleet()
    j = _journal(tmp_path, snapshot_every=1)
    svc = ReplanService(pairs, journal=j, quarantine_after=1)
    svc.supervisor.sleep = lambda s: None

    def boom(*a, **k):
        raise RuntimeError("poisoned solve")

    monkeypatch.setattr(svc_mod, "batched_min_period", boom)
    monkeypatch.setattr(svc_mod, "min_period_exhaustive", boom)
    svc.tick([StageDrift(0, 0, 2.0)])
    monkeypatch.undo()
    assert svc.quarantined
    svc.journal.close()
    # snapshot_every=1 put a post-tick snapshot on disk, so restore comes up
    # from state alone (no WAL replay) — the quarantine bookkeeping must
    # round-trip through the snapshot, not be re-derived by re-failing.
    restored = ReplanService.restore(j)
    assert restored.tick_count == 1 and restored.replayed_ticks == 0
    assert restored.quarantined == svc.quarantined
    assert restored.quarantine_strikes == svc.quarantine_strikes
    assert restored.metrics.quarantined_problems == 1
