"""CI perf-regression gate over BENCH_planner.json's structured fields.

``planner_bench.py`` writes every row with machine-readable fields (numeric
speedups, dispatch counts, cache deltas) next to the human ``derived``
string; this gate turns those into hard CI failures:

  1. **Row presence** — the campaign/fused/bucketed/h4scan/image/deal/
     split-score rows that later PRs are not allowed to silently drop.
  2. **Dispatch contract** — the fused H4 ``lax.scan`` bisection must report
     ``dispatches == 1`` (one dispatch per row-chunk for the WHOLE binary
     search; the row's B fits one chunk).
  3. **Within-run engine ordering** — the fused engine (warm) must beat the
     scalar reference, and the span-bucketed fused warm path must stay
     within a small factor of numpy-batched on every campaign row (the
     static-grid tax this PR removed would show up here as a multiple).
     The sharded SPMD rows must report bit-identical outputs, and the
     8-forced-host-device row must clear the scaling-efficiency floor.
  4. **Bucket-trace cap** — large-grid rows record their bucket-trace count;
     it must stay within the O(log n) budget they also record.
  5. **Fleet service floors** — the ``fleet_replan_*`` rows (burst-trace
     replay through the replanning service) must clear a dedup hit-rate
     floor and a replans/sec floor on the standard trace; the
     ``fleet_recovery_*`` rows must show bit-identical crash-restart
     recovery (digest match, zero invalid publishes, zero quarantines on a
     clean trace) with WAL replay bounded by the snapshot cadence; the
     ``fleet_remote_*`` rows must show subprocess workers digest-identical
     to inline under injected mid-solve SIGKILLs, restarts bounded by the
     injected-fault count, and a wedged SIGTERM-ignoring worker reaped by
     SIGKILL within the solve-timeout budget.
  6. **Cross-run regression** (optional ``--baseline``) — when a baseline
     BENCH_planner.json of the SAME ``_meta.mode`` is given, warm fused
     rows must not regress more than ``--tolerance`` (default 1.6x, absorbing
     runner noise).  Different modes (quick CI vs full local) skip this
     check — their row names collide but measure different workloads.

    PYTHONPATH=src python benchmarks/bench_gate.py [--baseline OLD.json]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

REQUIRED_PREFIXES = (
    "campaign_batched_",
    "campaign_fused_",
    "campaign_sharded_1dev_",
    "campaign_sharded_8dev_",
    "campaign_fused_h4scan_",
    "campaign_fused_bucketed_warm_",
    "campaign_fused_bucketed_cold_nocache_",
    "campaign_fused_bucketed_cold_cache_",
    "image_family_",
    "deal_enum_batched",
    "split_score_2way_pallas_",
    "split_score_3way_pallas_",
    "fleet_replan_throughput",
    "fleet_replan_latency",
    "fleet_replan_dedup",
    "fleet_replan_churn",
    "fleet_chaos_robustness",
    "fleet_chaos_recovery",
    "fleet_recovery_restart",
    "fleet_recovery_digest",
    "fleet_remote_throughput",
    "fleet_remote_restarts",
    "fleet_remote_digest",
    "tri_criteria_",
)

# warm span-bucketed fused may trail numpy-batched by at most this factor on
# CPU (measured ~1.0-1.3x either way; the pre-bucketing tax was 2.5-10x)
FUSED_VS_BATCHED_FLOOR = 0.4

# sharded SPMD engine: warm time through the shard_map engine at 8 forced
# host devices must reach >= this fraction of the fused single-program time
# (shards share the host's compute, so ideal scaling = fused time; measured
# ~0.78 at n=20 p=100 — the floor trips on SPMD overhead regressions, not
# runner noise).  On real multi-chip hardware efficiency e reads as e x D
# throughput scaling.
SHARDED_SCALING_FLOOR = 0.6

# fleet service floors on the standard/quick burst traces (measured 0.86 full
# / 0.68 quick hit-rate and ~6800/~3900 replans/s locally; the floors are set
# far below so they only trip on a broken dedup path or a collapsed batch
# engine, not on runner speed)
FLEET_DEDUP_FLOOR = 0.3
FLEET_REPLANS_PER_SEC_FLOOR = 200.0

# chaos-trace robustness bounds: invalid_published must be exactly zero (the
# keep-last-valid guarantee is a correctness contract, not a perf number);
# recovery from a reliability-floor dip is bounded well under the 30-tick
# standard trace (measured max 18 — recovery waits on flapped capacity
# returning, so the bound is about the repair pass firing, not its speed)
FLEET_MAX_RECOVERY_TICKS = 25

# crash-restart durability bounds: the restored digest must match the
# uninterrupted run exactly (bit-identical recovery is a correctness
# contract), a clean trace must quarantine nothing, the WAL replay length is
# capped by the snapshot cadence, and the total restore wall time gets a
# generous runner-independent ceiling (measured ~0.02s quick / ~0.1s full)
FLEET_MAX_RESTORE_SECONDS = 10.0

# tri-criteria knee: never choose a LESS reliable plan than the bi-criteria
# portfolio on the same instance (tiny negative tolerance for float noise)
TRI_CRITERIA_GAIN_FLOOR = -1e-9

# process-isolated workers: subprocess replans/sec floor.  Crossing the
# process boundary costs JSON framing + pipe hops + injected-kill restarts,
# so the floor is far below the in-process one (measured ~270 full / ~27
# quick replans/s locally — quick amortizes worker spawns over far fewer
# requests); it trips on a wedged transport, not on runner speed.
FLEET_REMOTE_REPLANS_PER_SEC_FLOOR = 5.0


def _fail(msgs: list, msg: str) -> None:
    msgs.append(msg)


def check(bench: dict, baseline: dict = None, tolerance: float = 1.6,
          required: tuple = None) -> list:
    """Return a list of failure strings (empty = gate passes).

    ``required`` overrides :data:`REQUIRED_PREFIXES` — partial bench runs
    (e.g. the multi-device CI job, which only re-runs planner_bench) pass
    the prefixes they DO produce via ``--require-prefix``; every
    value/floor check still applies to whatever rows are present."""
    fails: list = []
    rows = {k: v for k, v in bench.items() if not k.startswith("_")}

    # 1. row presence
    for prefix in (REQUIRED_PREFIXES if required is None else required):
        if not any(k.startswith(prefix) for k in rows):
            _fail(fails, f"missing benchmark row with prefix {prefix!r}")

    # 2. fused H4 bisection: one dispatch for the whole binary search
    for k, v in rows.items():
        if k.startswith("campaign_fused_h4scan_"):
            if v.get("dispatches") != 1:
                _fail(fails, f"{k}: dispatches={v.get('dispatches')!r}, "
                             "expected 1 (fused-bisection O(1) contract)")

    # 3. within-run engine ordering
    for k, v in rows.items():
        if (k.startswith(("campaign_fused_", "image_family_fused_"))
                and "speedup_vs_scalar" in v):
            if v["speedup_vs_scalar"] < 1.0:
                _fail(fails, f"{k}: fused warm slower than the scalar "
                             f"reference (speedup_vs_scalar="
                             f"{v['speedup_vs_scalar']:.2f})")
        if "vs_batched" in v and v["vs_batched"] < FUSED_VS_BATCHED_FLOOR:
            _fail(fails, f"{k}: fused warm is {1 / v['vs_batched']:.1f}x "
                         f"slower than numpy-batched (floor "
                         f"{FUSED_VS_BATCHED_FLOOR}x) — static-grid-tax "
                         "regression")

    # 3b. sharded SPMD engine: bit-identity is a correctness contract on
    # every sharded row; the 8-device row must clear the scaling floor
    for k, v in rows.items():
        if k.startswith("campaign_sharded_"):
            if v.get("identical_outputs") is not True:
                _fail(fails, f"{k}: identical_outputs="
                             f"{v.get('identical_outputs')!r} — sharded "
                             "engine output diverged from fused")
            if k.startswith("campaign_sharded_8dev_"):
                eff = v.get("scaling_efficiency")
                if eff is None or eff < SHARDED_SCALING_FLOOR:
                    _fail(fails, f"{k}: scaling_efficiency={eff!r} below "
                                 f"floor {SHARDED_SCALING_FLOOR} at "
                                 f"{v.get('devices')!r} devices — SPMD "
                                 "overhead regression")
                if v.get("devices", 0) < 8:
                    _fail(fails, f"{k}: devices={v.get('devices')!r} — the "
                                 "8-device row did not run on >= 8 devices")

    # 4. bucket-trace cap on rows that record it
    for k, v in rows.items():
        if "bucket_traces" in v and "bucket_trace_budget" in v:
            if v["bucket_traces"] > v["bucket_trace_budget"]:
                _fail(fails, f"{k}: bucket_traces={v['bucket_traces']} "
                             f"exceeds O(log n) budget "
                             f"{v['bucket_trace_budget']}")

    # 5. fleet service: dedup hit-rate and replans/sec floors
    for k, v in rows.items():
        if k.startswith("fleet_replan_dedup"):
            rate = v.get("dedup_hit_rate")
            if rate is None or rate < FLEET_DEDUP_FLOOR:
                _fail(fails, f"{k}: dedup_hit_rate={rate!r} below floor "
                             f"{FLEET_DEDUP_FLOOR} — signature dedup broken")
        if k.startswith("fleet_replan_throughput"):
            rps = v.get("replans_per_sec")
            if rps is None or rps < FLEET_REPLANS_PER_SEC_FLOOR:
                _fail(fails, f"{k}: replans_per_sec={rps!r} below floor "
                             f"{FLEET_REPLANS_PER_SEC_FLOOR}")
        if k.startswith("fleet_replan_latency"):
            n_lat = v.get("latency_samples")
            if not n_lat:
                _fail(fails, f"{k}: latency_samples={n_lat!r} — a run that "
                             "measured no per-request latencies cannot pass "
                             "as a fast one")
            elif v.get("p50_latency_us") is None or v.get("p99_latency_us") is None:
                _fail(fails, f"{k}: non-finite latency percentiles "
                             f"(p50={v.get('p50_latency_us')!r}, "
                             f"p99={v.get('p99_latency_us')!r}) over "
                             f"{n_lat} samples")

    # 5b. chaos-trace robustness: zero invalid publishes, bounded recovery
    for k, v in rows.items():
        if k.startswith("fleet_chaos_") and "invalid_published" in v:
            if v["invalid_published"] != 0:
                _fail(fails, f"{k}: invalid_published="
                             f"{v['invalid_published']} — an instance ended "
                             "a tick with a plan addressing dead pods "
                             "(keep-last-valid guarantee broken)")
        if k.startswith("fleet_chaos_recovery"):
            mrt = v.get("max_recovery_ticks")
            if mrt is None or mrt > FLEET_MAX_RECOVERY_TICKS:
                _fail(fails, f"{k}: max_recovery_ticks={mrt!r} exceeds bound "
                             f"{FLEET_MAX_RECOVERY_TICKS} — reliability-floor "
                             "repair not recovering")

    # 5d. crash-restart durability: bit-identical recovery, bounded replay
    for k, v in rows.items():
        if k.startswith("fleet_recovery_digest"):
            if not v.get("digest_match"):
                _fail(fails, f"{k}: restored fleet digest does not match the "
                             "uninterrupted run — journal replay is not "
                             "bit-identical")
            if v.get("invalid_published") != 0:
                _fail(fails, f"{k}: invalid_published="
                             f"{v.get('invalid_published')!r} across the "
                             "crash/restart run (must be 0)")
            if v.get("quarantined_problems") != 0:
                _fail(fails, f"{k}: quarantined_problems="
                             f"{v.get('quarantined_problems')!r} on a clean "
                             "trace (poison quarantine misfiring)")
        if k.startswith("fleet_recovery_restart"):
            replayed = v.get("max_replayed_ticks")
            cadence = v.get("snapshot_every")
            if replayed is None or cadence is None or replayed > cadence:
                _fail(fails, f"{k}: max_replayed_ticks={replayed!r} exceeds "
                             f"snapshot cadence {cadence!r} — WAL compaction "
                             "or snapshot cadence broken")
            wall = v.get("total_restore_wall_s")
            if wall is None or wall > FLEET_MAX_RESTORE_SECONDS:
                _fail(fails, f"{k}: total_restore_wall_s={wall!r} exceeds "
                             f"{FLEET_MAX_RESTORE_SECONDS}s bound")

    # 5e. process-isolated workers: kill-based preemption is a correctness
    # contract — subprocess digests bit-identical to inline, zero invalid
    # publishes, every restart attributable to an injected fault, and the
    # wedge probe reaped within its timeout budget
    for k, v in rows.items():
        if k.startswith("fleet_remote_digest"):
            if not v.get("digest_match"):
                _fail(fails, f"{k}: subprocess fleet digest does not match "
                             "the inline run — the wire codecs are not "
                             "bit-identical")
            if v.get("invalid_published") != 0:
                _fail(fails, f"{k}: invalid_published="
                             f"{v.get('invalid_published')!r} under injected "
                             "worker kills (must be 0)")
            if v.get("reaped_within_timeout") is not True:
                _fail(fails, f"{k}: reaped_within_timeout="
                             f"{v.get('reaped_within_timeout')!r} "
                             f"(wall {v.get('reap_wall_s')!r}s, budget "
                             f"{v.get('reap_budget_s')!r}s, rc "
                             f"{v.get('wedge_returncode')!r}) — a wedged "
                             "SIGTERM-ignoring worker was not SIGKILLed "
                             "within the solve timeout")
        if k.startswith("fleet_remote_restarts"):
            restarts, ceiling = v.get("worker_restarts"), v.get("restart_ceiling")
            if restarts is None or ceiling is None or restarts > ceiling:
                _fail(fails, f"{k}: worker_restarts={restarts!r} exceeds the "
                             f"injected-fault ceiling {ceiling!r} — restarts "
                             "not attributable to injected chaos")
            if not v.get("kills"):
                _fail(fails, f"{k}: kills={v.get('kills')!r} — the remote "
                             "run injected no mid-solve SIGKILLs, so the "
                             "preemption contract went unexercised")
            if restarts is not None and not restarts:
                _fail(fails, f"{k}: worker_restarts=0 with injected kills — "
                             "dead workers were never detected/replaced")
        if k.startswith("fleet_remote_throughput"):
            rps = v.get("replans_per_sec")
            if rps is None or rps < FLEET_REMOTE_REPLANS_PER_SEC_FLOOR:
                _fail(fails, f"{k}: replans_per_sec={rps!r} below floor "
                             f"{FLEET_REMOTE_REPLANS_PER_SEC_FLOOR} — "
                             "subprocess transport wedged")

    # 5c. tri-criteria knee must not lose reliability vs the bi-criteria pick
    for k, v in rows.items():
        if k.startswith("tri_criteria_") and "min_reliability_gain" in v:
            if v["min_reliability_gain"] < TRI_CRITERIA_GAIN_FLOOR:
                _fail(fails, f"{k}: min_reliability_gain="
                             f"{v['min_reliability_gain']:.2e} < 0 — the "
                             "tri-criteria knee chose a less reliable plan "
                             "than the bi-criteria portfolio")

    # 6. cross-run regression vs a same-mode baseline
    if baseline is not None:
        mode = bench.get("_meta", {}).get("mode")
        base_mode = baseline.get("_meta", {}).get("mode")
        if mode != base_mode:
            print(f"bench_gate: baseline mode {base_mode!r} != current "
                  f"{mode!r}; skipping cross-run comparison")
        else:
            for k, v in rows.items():
                if not (k.startswith("campaign_fused_")
                        or k.startswith("image_family_fused_")):
                    continue
                if "cold" in k or k not in baseline:
                    continue
                old, new = baseline[k].get("us_per_call"), v.get("us_per_call")
                if old and new and new > old * tolerance:
                    _fail(fails, f"{k}: warm {new / 1e6:.2f}s vs baseline "
                                 f"{old / 1e6:.2f}s (> {tolerance}x)")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=str(REPO_ROOT / "BENCH_planner.json"))
    ap.add_argument("--baseline", default=None,
                    help="previous BENCH_planner.json to gate warm fused "
                         "rows against (same _meta.mode only)")
    ap.add_argument("--tolerance", type=float, default=1.6)
    ap.add_argument("--require-prefix", action="append", default=None,
                    metavar="PREFIX",
                    help="replace the built-in required-row prefixes "
                         "(repeatable; for partial bench runs)")
    args = ap.parse_args()
    bench = json.loads(pathlib.Path(args.bench).read_text())
    baseline = (json.loads(pathlib.Path(args.baseline).read_text())
                if args.baseline else None)
    fails = check(bench, baseline, args.tolerance,
                  required=(tuple(args.require_prefix)
                            if args.require_prefix else None))
    for k in sorted(bench):
        if k.startswith("_"):
            continue
        v = bench[k]
        extras = {f: v[f] for f in ("speedup_vs_scalar", "vs_batched",
                                    "dispatches", "bucket_traces",
                                    "cache_speedup", "vs_numpy",
                                    "dedup_hit_rate", "replans_per_sec",
                                    "latency_samples",
                                    "invalid_published", "max_recovery_ticks",
                                    "digest_match", "max_replayed_ticks",
                                    "quarantined_problems",
                                    "min_reliability_gain",
                                    "devices", "scaling_efficiency",
                                    "vs_fused",
                                    "worker_restarts", "restart_ceiling",
                                    "kills", "reaped_within_timeout")
                  if f in v}
        if extras:
            print(f"  {k}: {extras}")
    if fails:
        print("\nbench_gate FAILURES:")
        for f in fails:
            print(f"  - {f}")
        return 1
    print("\nbench_gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
