"""The fleet controller loop: collect → dedup → warm-start → batch → publish.

Per tick the service applies every arriving drift event to its instance's
state (EWMA straggler monitor, platform degradation, elastic resize, pod
removal), collects the *dirty* instances — those whose effective platform
changed — and answers all of their replan requests together:

  1. each dirty instance's problem is canonicalized and signed
     (:mod:`repro.fleet.signatures`); instances that are the same problem up
     to processor relabeling share one signature,
  2. signatures already in the cross-tick plan cache are warm-start hits:
     the previous solve is reused byte-for-byte (exact-bytes signatures mean
     a hit can never change a result, only skip work),
  3. the remaining distinct problems are grouped by (n, p, b) shape, stacked
     with :meth:`ProblemBatch.from_arrays`, and solved in two lockstep runs
     per group via :func:`repro.core.batched.batched_min_period` —
     thousands of requests become a handful of engine programs,
  4. every dirty instance receives its plan by remapping the canonical
     allocation through its own speed-sort permutation and is republished as
     a :class:`StagePlan`; its straggler monitor resets to the new stage
     count.

The published plans are bit-identical to running the scalar portfolio
``min_period_exhaustive(workload, platform)`` per instance (relabeling
theorem + the batched engine's equivalence contract; asserted in
tests/test_fleet.py).

Graceful degradation (the chaos-harness contract, tests/test_fleet.py +
``fleet_bench.py --chaos``):

  - ``solve_deadline`` — a per-tick solve budget in seconds.  Groups past
    the budget are NOT solved this tick: their instances keep their last
    valid plan and are retried next tick.  Instances whose current plan is
    *invalid* (it addresses pods that no longer exist) are never deferred —
    their groups solve regardless of the budget, which is what guarantees
    zero ticks ending with an invalid published plan.
  - supervised workers — each solve group is dispatched to a worker actor
    (:mod:`repro.fleet.supervision`): per-group timeout, exponential-backoff
    retries, heartbeat-based worker restarts.  A group the workers cannot
    solve is re-solved per member with the scalar reference portfolio
    (bit-identical by the equivalence contract), so one poisoned batch
    degrades throughput, not correctness.
  - poison quarantine — a canonical problem that fails the batched solve
    *and* the scalar fallback ``quarantine_after`` times is quarantined: its
    subscribers keep their last valid plan (counted per tick in
    ``FleetMetrics.quarantined_requests``) and the problem is never retried
    until drift changes its signature — a poison problem costs a metric, not
    a wedged tick loop.
  - ``reliability_floor`` — when platforms carry failure probabilities, any
    instance whose plan's reliability drops below the floor gets a greedy
    replication pass (:func:`repro.core.replication.replicate_stage_plan`);
    time spent below the floor and recovery latency are counted in
    :class:`FleetMetrics` and floor-gated in ``bench_gate.py``.

Durability (the crash-safety contract, tests/test_fleet_recovery.py +
``fleet_bench.py --recovery``): pass ``journal=`` (a directory or a
:class:`repro.fleet.journal.Journal`) and the service write-ahead-logs every
tick's events *before* mutating state and snapshots its full state (the
instances with their effective platforms, plans, monitors, the plan cache in
LRU order, ``_pending``, ``_below_since``, quarantine state, and metrics —
RNG-free by construction) every ``Journal.snapshot_every`` ticks with
CRC-checked, atomic-rename writes.  :meth:`ReplanService.restore` rebuilds
the controller from the newest snapshot and replays the WAL tail through the
ordinary ``tick()`` path; determinism of replay makes the restored
``fleet_digest()`` bit-identical to an uninterrupted run.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import pathlib
import time
from typing import Callable, Optional, Sequence

import numpy as np

from ..core import (Mapping, Platform, ReplicatedMapping, StagePlan,
                    interval_cycle_times, min_period_exhaustive, reliability)
from ..core.batched import ProblemBatch, batched_min_period
from ..core.planner import _realize
from ..core.replication import replicate_stage_plan
from ..pipeline.replan import StragglerMonitor, elastic_platform
from .journal import (Journal, JournalError, decode_monitor, decode_plan,
                      decode_platform, decode_result, decode_workload,
                      encode_monitor, encode_plan, encode_platform,
                      encode_result, encode_workload)
from .metrics import FleetMetrics
from .signatures import canonicalize, remap_alloc, signature
from .supervision import Supervisor
from .telemetry import (PodCountChange, PodFailure, StageDrift, StageTimings,
                        Trace, event_from_wire)

#: Engines ``batched_min_period`` accepts; validated up front so a typo fails
#: at construction, not deep inside the first tick's solve.
KNOWN_BACKENDS = ("numpy", "jax", "pallas", "fused", "sharded")

#: Default LRU bound on the cross-tick plan cache.  Far above the distinct
#: canonical problems of the standard traces (so the default-config hit-rate
#: is unchanged — asserted in tests), but a hard ceiling on controller
#: memory over unbounded uptime.
DEFAULT_PLAN_CACHE_CAP = 4096


@dataclasses.dataclass
class InstanceState:
    """One pipeline instance as the service sees it: the workload, the
    *effective* platform (with every observed degradation folded in), the
    current published plan, and the straggler monitor for that plan."""

    workload: object
    platform: Platform
    plan: Optional[StagePlan] = None
    monitor: Optional[StragglerMonitor] = None


class _PlanCache:
    """Bounded LRU over canonical digest → ``HeuristicResult``.

    Eviction can never change a result — signatures are exact bytes, so a
    re-solve after eviction is bit-identical to the evicted entry; the cap
    only trades memory for occasional re-solves (``evictions`` counts them,
    surfaced as ``FleetMetrics.cache_evictions``)."""

    def __init__(self, cap: Optional[int]):
        self.cap = cap
        self.evictions = 0
        self._d: collections.OrderedDict = collections.OrderedDict()

    def __contains__(self, digest) -> bool:
        return digest in self._d

    def __len__(self) -> int:
        return len(self._d)

    def lookup(self, digest):
        """Get-and-touch: a hit refreshes recency."""
        if digest not in self._d:
            return None
        self._d.move_to_end(digest)
        return self._d[digest]

    def put(self, digest, res) -> None:
        self._d[digest] = res
        self._d.move_to_end(digest)
        while self.cap is not None and len(self._d) > self.cap:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._d.clear()

    def items(self):
        """(digest, result) pairs oldest-first — serialized in this order so
        a restored cache carries the exact LRU recency order."""
        return self._d.items()


class ReplanService:
    """Telemetry-driven, dedup-batched replanning over a fleet of instances.

    ``instances`` is a sequence of (workload, platform) pairs; instance ids
    are positions.  ``backend`` is the lockstep engine backend ("numpy" is
    the bit-exact reference; "fused" runs each solve group as one jitted
    device program).  ``warm_start=False`` drops the cross-tick plan cache
    at every tick (same-tick dedup always applies) — it exists to *prove*
    warm-starting never changes results, not to be used.

    ``solve_deadline`` (seconds per tick) and ``reliability_floor`` (minimum
    plan reliability, needs platforms with failure probabilities) enable the
    graceful-degradation behaviors documented in the module docstring; both
    default to off, keeping the clean path byte-identical.

    ``plan_cache_cap`` bounds the cross-tick plan cache (LRU; ``None`` means
    unbounded).  ``journal`` (a directory path or :class:`Journal`) enables
    the write-ahead log + snapshot durability layer.  ``supervisor``
    overrides the default in-process supervised worker pool (e.g. to use
    :class:`~repro.fleet.supervision.ThreadWorker` actors with a solve
    timeout); ``quarantine_after`` is the strike count at which a poison
    problem is quarantined.
    """

    def __init__(self, instances: Sequence, backend: str = "numpy",
                 warm_start: bool = True,
                 solve_deadline: Optional[float] = None,
                 reliability_floor: Optional[float] = None,
                 plan_cache_cap: Optional[int] = DEFAULT_PLAN_CACHE_CAP,
                 journal=None,
                 supervisor: Optional[Supervisor] = None,
                 quarantine_after: int = 2):
        # Fail fast: every knob is validated here, with the error naming the
        # knob — not three frames deep inside the first group solve.
        if backend not in KNOWN_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; known engines: "
                             f"{', '.join(KNOWN_BACKENDS)}")
        if solve_deadline is not None and solve_deadline < 0:
            raise ValueError(f"solve_deadline must be >= 0 seconds, got "
                             f"{solve_deadline}")
        if reliability_floor is not None and \
                not (0.0 <= reliability_floor <= 1.0):
            raise ValueError(f"reliability_floor must be in [0, 1], got "
                             f"{reliability_floor}")
        if plan_cache_cap is not None and plan_cache_cap < 1:
            raise ValueError(f"plan_cache_cap must be >= 1 or None, got "
                             f"{plan_cache_cap}")
        if quarantine_after < 1:
            raise ValueError(f"quarantine_after must be >= 1, got "
                             f"{quarantine_after}")
        self.backend = backend
        self.warm_start = warm_start
        self.solve_deadline = solve_deadline
        self.reliability_floor = reliability_floor
        self.plan_cache_cap = plan_cache_cap
        self.quarantine_after = int(quarantine_after)
        self.states = [InstanceState(wl, pf) for wl, pf in instances]
        self._init_runtime(journal=journal, supervisor=supervisor)
        # Initial fleet-wide planning runs through the same dedup+batch path
        # but is not a *re*plan: it stays out of the metrics.  (No plan
        # exists yet, so nothing is deferrable: a deadline cannot leave an
        # instance unplanned.)
        self._replan(range(len(self.states)))
        self._repair_reliability(dict.fromkeys(range(len(self.states))))
        self._sync_acct_baselines()
        if self.journal is not None:
            # Genesis snapshot: restore() is self-contained from the journal
            # directory alone, even before the first cadence snapshot.
            self._maybe_snapshot(force=True)

    def _init_runtime(self, journal=None,
                      supervisor: Optional[Supervisor] = None) -> None:
        """Runtime state shared by ``__init__`` and snapshot restore."""
        self.metrics = FleetMetrics()
        self.plan_cache = _PlanCache(self.plan_cache_cap)
        self.tick_count = 0
        self._pending: dict = {}     # deadline-deferred ids, retried next tick
        self._dropped = 0            # stale events discarded this tick
        self._below_since: dict = {} # iid -> tick it dipped below the floor
        self.quarantine_strikes: dict = {}   # digest -> failed-round count
        self.quarantined: set = set()        # digests pinned to last valid plan
        self.journal = (Journal(journal) if isinstance(journal,
                                                       (str, pathlib.Path))
                        else journal)
        self.supervisor = supervisor if supervisor is not None else \
            Supervisor(self._solve_group, max_attempts=2)
        self.crash_hook: Optional[Callable] = None  # fault injection point
        self.replayed_ticks = 0      # WAL records re-applied by restore()
        self._replaying = False
        self._last_tick_stats = (0, 0, 0, [], 0, 0, 0)
        self._sync_acct_baselines()

    def _sync_acct_baselines(self) -> None:
        """Supervisor/cache counters are cumulative on their objects; the
        per-tick metrics record deltas against these baselines."""
        self._seen_retries = self.supervisor.stats.retries
        self._seen_restarts = self.supervisor.stats.restarts
        self._seen_timeouts = self.supervisor.stats.timeouts
        self._seen_evictions = self.plan_cache.evictions

    def _solve_group(self, pb: ProblemBatch) -> list:
        # Late-bound module global so test fault injection (monkeypatching
        # ``service.batched_min_period``) reaches the workers too.
        return batched_min_period(pb, self.backend)

    # -- event application ----------------------------------------------------

    def _observe(self, st: InstanceState, observed: np.ndarray) -> bool:
        """Feed one timing observation; degrade the platform if the EWMA
        flags stragglers (the ``replan_for_straggler`` recipe).  Returns
        whether the platform changed."""
        if not _plan_valid(st) or len(observed) != st.plan.num_stages:
            self._dropped += 1
            return False   # stale report from a pre-replan plan shape
        st.monitor.observe(observed)
        predicted = interval_cycle_times(st.workload, st.platform,
                                         st.plan.mapping)
        bad = st.monitor.stragglers(predicted)
        if not bad:
            return False
        pf = st.platform
        for j in bad:
            pf = pf.degrade(st.plan.mapping.alloc[j],
                            float(st.monitor.ewma[j] / predicted[j]))
        st.platform = pf
        return True

    def _apply(self, ev) -> bool:
        """Apply one event; returns True when the instance needs a replan."""
        st = self.states[ev.instance]
        if isinstance(ev, StageTimings):
            return self._observe(st, np.asarray(ev.times, dtype=float))
        if isinstance(ev, StageDrift):
            if not _plan_valid(st):
                return False   # platform already changed this tick
            if not (0 <= ev.stage < st.plan.num_stages):
                # stale event addressed at a pre-replan plan shape: drop it,
                # like stale StageTimings — remapping it (the old
                # ``stage % num_stages``) would slow an arbitrary stage
                self._dropped += 1
                return False
            predicted = interval_cycle_times(st.workload, st.platform,
                                             st.plan.mapping)
            observed = predicted.copy()
            observed[ev.stage] *= ev.factor
            return self._observe(st, observed)
        if isinstance(ev, PodCountChange):
            target = max(1, int(ev.num_pods))
            if target == st.platform.p:
                return False
            st.platform = elastic_platform(st.platform, target)
            return True
        if isinstance(ev, PodFailure):
            if st.platform.p <= 1:
                return False   # last pod: nothing to fail over to
            pod = int(ev.pod) % st.platform.p
            # Platform.without appends "-failed" at most once (names stay
            # bounded over long traces) and drops the pod's failure
            # probability alongside its speed.
            st.platform = st.platform.without(pod)
            return True
        raise TypeError(f"unknown fleet event {type(ev).__name__}")

    # -- solve + publish ------------------------------------------------------

    def _strike(self, digest: str) -> None:
        """One failed batched+scalar round for this canonical problem; at
        ``quarantine_after`` strikes the problem is quarantined."""
        n = self.quarantine_strikes.get(digest, 0) + 1
        self.quarantine_strikes[digest] = n
        self._tick_strikes += 1
        if n >= self.quarantine_after and digest not in self.quarantined:
            self.quarantined.add(digest)
            self._tick_quarantined += 1

    def _replan(self, ids) -> dict:
        """Dedup, batch-solve, and publish new plans for the given instance
        ids.  Returns {iid: StagePlan}; sets ``self._last_tick_stats``.

        With a ``solve_deadline``, canonical problems are solved group by
        group until the budget runs out; later groups are deferred — their
        subscribers keep their last valid plan and are retried next tick —
        EXCEPT problems with a subscriber whose plan is invalid or missing,
        which always solve (keep-last-VALID-plan, never keep-broken-plan).
        Group solves go through the supervised worker pool; a group the
        workers give up on falls back to per-member scalar solves of the
        same canonical problems (bit-identical results), and a member whose
        scalar solve *also* raises is struck toward quarantine."""
        ids = list(ids)
        t0 = time.perf_counter()
        deadline = (None if self.solve_deadline is None
                    else t0 + self.solve_deadline)
        self._tick_strikes = 0
        self._tick_quarantined = 0
        sig_of = {i: signature(self.states[i].workload,
                               self.states[i].platform) for i in ids}
        warm_hits = sum(sig_of[i].digest in self.plan_cache for i in ids)
        need: dict = {}
        for i in ids:
            sig = sig_of[i]
            if (sig.digest not in self.plan_cache
                    and sig.digest not in need
                    and sig.digest not in self.quarantined):
                need[sig.digest] = (sig, self.states[i])
        must = {sig_of[i].digest for i in ids
                if self.states[i].plan is None
                or not _plan_valid(self.states[i])}
        by_shape: dict = {}
        for digest, (sig, st) in need.items():
            by_shape.setdefault(sig.shape, []).append((digest, st))
        fallback_solves = 0
        solved = 0
        # Tick-local results: publishing reads from here first, so LRU
        # eviction pressure can only cost cross-tick re-solves — it can never
        # evict a result between its solve and its publish in the same tick.
        fresh: dict = {}
        for (n, p, b), entries in by_shape.items():
            if deadline is not None and time.perf_counter() > deadline:
                entries = [e for e in entries if e[0] in must]
            if not entries:
                continue
            pb = ProblemBatch.from_arrays(
                np.stack([st.workload.w for _, st in entries]),
                np.stack([st.workload.delta for _, st in entries]),
                np.stack([st.platform.s[st.platform.sorted_indices()]
                          for _, st in entries]),
                b)
            try:
                results = list(self.supervisor.solve(pb))
            except Exception:  # noqa: BLE001 — degrade, don't die mid-tick
                for digest, st in entries:
                    try:
                        res = min_period_exhaustive(
                            st.workload, canonicalize(st.platform)[0])
                    except Exception:  # noqa: BLE001 — poison problem
                        self._strike(digest)
                        continue
                    fresh[digest] = res
                    self.plan_cache.put(digest, res)
                    fallback_solves += 1
                    solved += 1
                continue
            for (digest, _), res in zip(entries, results):
                fresh[digest] = res
                self.plan_cache.put(digest, res)
            solved += len(entries)
        published, churns, deferred = {}, [], []
        quarantined_requests = 0
        for i in ids:
            st = self.states[i]
            res = self.plan_cache.lookup(sig_of[i].digest)
            if res is None:
                res = fresh.get(sig_of[i].digest)
            if res is None:
                if sig_of[i].digest in self.quarantined:
                    # Pinned to the last valid plan; NOT retried — the
                    # problem re-enters the solve path only when drift
                    # changes its signature.
                    quarantined_requests += 1
                else:
                    deferred.append(i)   # keep last valid plan, retry next tick
                continue
            _, perm = canonicalize(st.platform)
            mapping = Mapping(res.mapping.intervals,
                              remap_alloc(res.mapping.alloc, perm))
            plan = _realize(mapping, res.period, res.latency, res.name)
            if st.plan is not None:
                churns.append(_plan_churn(st.plan, plan, st.workload.n))
            st.plan = plan
            st.monitor = StragglerMonitor(plan.num_stages)
            published[i] = plan
        self._pending.update(dict.fromkeys(deferred))
        self._last_tick_stats = (len(ids), solved, warm_hits, churns,
                                 len(deferred), fallback_solves,
                                 quarantined_requests)
        return published

    def _plan_reliability(self, st: InstanceState) -> float:
        """Reliability of the instance's published plan (consensus model when
        the plan carries replication groups)."""
        if st.plan.groups is not None:
            rm = ReplicatedMapping(st.plan.mapping.intervals, st.plan.groups)
            return reliability(st.workload, st.platform, rm)
        return reliability(st.workload, st.platform, st.plan.mapping)

    def _repair_reliability(self, published: dict) -> tuple:
        """Reliability-floor pass: re-replicate any instance whose plan sits
        below the floor, republishing into ``published`` when the plan
        actually changed.  Returns (instance-ticks below the floor, list of
        recovery latencies closed this tick)."""
        floor = self.reliability_floor
        if floor is None:
            return 0, []
        below, recoveries = 0, []
        for i, st in enumerate(self.states):
            if st.platform.fail is None or not _plan_valid(st):
                continue
            rel = self._plan_reliability(st)
            if rel < floor - _FLOOR_EPS:
                new = replicate_stage_plan(st.workload, st.platform, st.plan,
                                           target=floor)
                if (new is not st.plan
                        and (new.groups != st.plan.groups
                             or new.mapping != st.plan.mapping)):
                    st.plan = new
                    st.monitor = StragglerMonitor(new.num_stages)
                    published[i] = new
                rel = self._plan_reliability(st)
            if rel < floor - _FLOOR_EPS:
                below += 1
                self._below_since.setdefault(i, self.tick_count)
            elif i in self._below_since:
                recoveries.append(self.tick_count - self._below_since.pop(i))
        return below, recoveries

    def tick(self, events: Sequence) -> dict:
        """Process one tick's events; returns the republished plans."""
        events = tuple(events)
        if self.journal is not None and not self._replaying:
            # Write-ahead: the tick's events hit stable storage before any
            # state mutates, so a controller killed anywhere inside this
            # method replays the tick from disk on restore.
            self.journal.append(self.tick_count, events)
            if self.crash_hook is not None:
                self.crash_hook(self.tick_count)
        t0 = time.perf_counter()
        if not self.warm_start:
            self.plan_cache.clear()
        self._dropped = 0
        # Deadline-deferred instances retry before this tick's events touch
        # anything; new dirtiness merges in behind them.
        dirty: dict = dict.fromkeys(self._pending)
        self._pending = {}
        for ev in events:
            if self._apply(ev):
                dirty[ev.instance] = None
        published = self._replan(dirty.keys())
        below, recoveries = self._repair_reliability(published)
        (requests, solves, warm_hits, churns, deferred,
         fallback_solves, quarantined_requests) = self._last_tick_stats
        invalid = sum(not _plan_valid(st) for st in self.states)
        retries = self.supervisor.stats.retries - self._seen_retries
        restarts = self.supervisor.stats.restarts - self._seen_restarts
        timeouts = self.supervisor.stats.timeouts - self._seen_timeouts
        evictions = self.plan_cache.evictions - self._seen_evictions
        self.metrics.record_tick(requests=requests, solves=solves,
                                 warm_hits=warm_hits, events=len(events),
                                 wall=time.perf_counter() - t0, churns=churns,
                                 deferred=deferred,
                                 fallback_solves=fallback_solves,
                                 dropped_events=self._dropped,
                                 below_floor=below, recoveries=recoveries,
                                 invalid_published=invalid,
                                 quarantined_requests=quarantined_requests,
                                 quarantine_strikes=self._tick_strikes,
                                 quarantined_problems=self._tick_quarantined,
                                 solve_retries=retries,
                                 worker_restarts=restarts,
                                 worker_timeouts=timeouts,
                                 cache_evictions=evictions)
        self._sync_acct_baselines()
        self.tick_count += 1
        self._maybe_snapshot()
        return published

    def run_trace(self, trace: Trace) -> FleetMetrics:
        """Replay a telemetry trace tick by tick.  Deterministic: the same
        trace over the same fleet yields the same plans and counters."""
        for events in trace.ticks:
            self.tick(events)
        return self.metrics

    def resume_trace(self, trace: Trace) -> FleetMetrics:
        """Continue a (restored) service through the tail of ``trace``: the
        ticks it has not yet processed, ``trace.ticks[self.tick_count:]``.
        Valid when this service has been driven by exactly this trace from
        tick 0 — the crash/restart replay contract."""
        for events in trace.ticks[self.tick_count:]:
            self.tick(events)
        return self.metrics

    # -- durability -----------------------------------------------------------

    def _maybe_snapshot(self, force: bool = False) -> None:
        if self.journal is None:
            return
        if force or self.tick_count % self.journal.snapshot_every == 0:
            self.journal.write_snapshot(self.tick_count, self._state_dict())

    def _state_dict(self) -> dict:
        """Full service state as JSON scalars — everything a future tick's
        behavior depends on (the service is RNG-free, so this is exhaustive).
        Exact float round-trip makes restore bit-identical."""
        return {
            "config": {
                "backend": self.backend,
                "warm_start": self.warm_start,
                "solve_deadline": self.solve_deadline,
                "reliability_floor": self.reliability_floor,
                "plan_cache_cap": self.plan_cache_cap,
                "quarantine_after": self.quarantine_after,
                "snapshot_every": (None if self.journal is None
                                   else self.journal.snapshot_every),
            },
            "tick_count": self.tick_count,
            "instances": [{"workload": encode_workload(st.workload),
                           "platform": encode_platform(st.platform),
                           "plan": encode_plan(st.plan),
                           "monitor": encode_monitor(st.monitor)}
                          for st in self.states],
            "plan_cache": [[digest, encode_result(res)]
                           for digest, res in self.plan_cache.items()],
            "cache_evictions": self.plan_cache.evictions,
            "pending": list(self._pending),
            "below_since": [[int(i), int(t)]
                            for i, t in self._below_since.items()],
            "quarantine_strikes": [[d, int(n)] for d, n
                                   in self.quarantine_strikes.items()],
            "quarantined": sorted(self.quarantined),
            "metrics": dataclasses.asdict(self.metrics),
        }

    @classmethod
    def _from_state(cls, state: dict, journal: Optional[Journal],
                    supervisor: Optional[Supervisor]) -> "ReplanService":
        cfg = state["config"]
        svc = object.__new__(cls)
        svc.backend = cfg["backend"]
        svc.warm_start = cfg["warm_start"]
        svc.solve_deadline = cfg["solve_deadline"]
        svc.reliability_floor = cfg["reliability_floor"]
        svc.plan_cache_cap = cfg["plan_cache_cap"]
        svc.quarantine_after = cfg["quarantine_after"]
        svc.states = [InstanceState(decode_workload(d["workload"]),
                                    decode_platform(d["platform"]),
                                    decode_plan(d["plan"]),
                                    decode_monitor(d["monitor"]))
                      for d in state["instances"]]
        svc._init_runtime(journal=journal, supervisor=supervisor)
        for digest, res in state["plan_cache"]:
            svc.plan_cache.put(digest, decode_result(res))
        svc.plan_cache.evictions = int(state["cache_evictions"])
        svc.tick_count = int(state["tick_count"])
        svc._pending = dict.fromkeys(int(i) for i in state["pending"])
        svc._below_since = {int(i): int(t) for i, t in state["below_since"]}
        svc.quarantine_strikes = {d: int(n)
                                  for d, n in state["quarantine_strikes"]}
        svc.quarantined = set(state["quarantined"])
        svc.metrics = FleetMetrics(**state["metrics"])
        svc._sync_acct_baselines()
        return svc

    @classmethod
    def restore(cls, journal_or_dir, *, supervisor: Optional[Supervisor] = None,
                strict: bool = False) -> "ReplanService":
        """Rebuild a crashed controller from its journal directory.

        Loads the newest CRC-valid snapshot, then re-applies the WAL tail
        through the ordinary ``tick()`` path (suppressing re-journaling).
        The restored service's ``fleet_digest()`` is bit-identical to an
        uninterrupted run over the same ticks, it keeps journaling into the
        same directory, and ``resume_trace`` continues exactly where the
        crashed controller left off.  ``strict=True`` turns a torn WAL tail
        (normal after a crash mid-append) into a :class:`JournalError`
        instead of recovering to the last good record.
        """
        journal = (journal_or_dir if isinstance(journal_or_dir, Journal)
                   else Journal(journal_or_dir))
        snap = journal.latest_snapshot()
        if snap is None:
            raise JournalError(f"no valid snapshot in {journal.dir} — "
                               "cannot restore")
        snap_tick, state = snap
        every = state["config"].get("snapshot_every")
        if every:
            journal.snapshot_every = int(every)
        svc = cls._from_state(state, journal, supervisor)
        records, _ = journal.read_wal(strict=strict)
        expect = svc.tick_count
        svc._replaying = True
        try:
            for rec in records:
                if rec["tick"] < expect:
                    continue   # pre-snapshot record not yet compacted away
                if rec["tick"] != expect:
                    raise JournalError(
                        f"WAL gap: expected tick {expect}, found record for "
                        f"tick {rec['tick']}")
                svc.tick([event_from_wire(e) for e in rec["events"]])
                expect += 1
        finally:
            svc._replaying = False
        svc.replayed_ticks = expect - snap_tick
        return svc

    # -- introspection --------------------------------------------------------

    @property
    def plans(self) -> list:
        return [st.plan for st in self.states]

    def fleet_digest(self) -> str:
        """Hash of every instance's current plan — determinism fingerprint."""
        h = hashlib.blake2b(digest_size=16)
        for st in self.states:
            h.update(repr((st.plan.mapping.intervals, st.plan.mapping.alloc,
                           st.plan.period, st.plan.latency,
                           st.plan.groups)).encode())
        return h.hexdigest()


_FLOOR_EPS = 1e-12   # matches the greedy replicator's target tolerance


def _plan_valid(st: InstanceState) -> bool:
    """Whether the published plan still addresses the current platform — a
    same-tick pod removal/resize invalidates the plan's allocation until the
    end-of-tick replan; timing reports against it are meaningless."""
    if st.plan is None:
        return False
    if max(st.plan.mapping.alloc) >= st.platform.p:
        return False
    if st.plan.groups is not None:
        return max(u for g in st.plan.groups for u in g) < st.platform.p
    return True


def _plan_churn(old: StagePlan, new: StagePlan, n: int) -> float:
    """Fraction of the n layers whose pod assignment changed."""
    old_alloc = np.repeat(np.asarray(old.mapping.alloc), old.stage_sizes)
    new_alloc = np.repeat(np.asarray(new.mapping.alloc), new.stage_sizes)
    return float(np.mean(old_alloc != new_alloc))
