"""Deal-skeleton extension (the paper's Section-7 'natural extension').

When a stage interval is both the period bottleneck and splitting is stuck
(single stage, or no improving cut), the paper suggests nesting a *deal*
(farm) skeleton: round-robin the tasks of that interval over a GROUP of
processors.  With a group U processing every |U|-th task, the interval's
cycle time becomes

    cycle_deal = delta_in/b + w_I / sum_{u in U} s_u + delta_out/b

under perfect dealing (each task goes to a processor proportionally often to
its speed; the aggregate rate is the sum of speeds), while its LATENCY
contribution uses the slowest group member (a task may land on it):

    lat_deal = delta_in/b + w_I / min_{u in U} s_u

``plan_with_deal`` runs the base planner, then greedily assigns remaining
unused processors as replicas of the current bottleneck interval while the
period improves.  In the TPU mapping this is data parallelism *within* a
stage group — which the runtime already executes (DP inside a pod) — so the
extension closes the loop between the paper's future work and what modern
pipelines actually do.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .metrics import Mapping
from .planner import Objective, StagePlan, auto_request, plan, plan_request
from .platform import Platform
from .solvers import Solution, register_solver
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class DealPlan:
    """A stage plan where each interval may own a GROUP of processors."""

    base: StagePlan
    groups: tuple              # tuple[tuple[int, ...]] — processors per interval
    period: float
    latency: float

    @property
    def num_stages(self) -> int:
        return self.base.num_stages


def _deal_metrics(workload: Workload, platform: Platform, mapping: Mapping,
                  groups) -> tuple:
    w, delta, b, s = workload.w, workload.delta, platform.b, platform.s
    per = 0.0
    lat = 0.0
    for (d, e), grp in zip(mapping.intervals, groups):
        wsum = w[d - 1: e].sum()
        rate = sum(s[u] for u in grp)
        cyc = delta[d - 1] / b + wsum / rate + delta[e] / b
        per = max(per, cyc)
        lat += delta[d - 1] / b + wsum / min(s[u] for u in grp)
    lat += delta[workload.n] / b
    return float(per), float(lat)


def plan_with_deal(workload: Workload, platform: Platform,
                   objective: Optional[Objective] = None,
                   mode: str = "auto") -> DealPlan:
    """Base interval plan + greedy deal-replication of the bottleneck stage.

    Back-compat facade: the base plan goes through the PlanRequest portfolio
    (explicit heuristic/exact modes fall back to the ``plan()`` facade)."""
    objective = objective or Objective("period")
    if mode == "auto":
        from .planner import InfeasiblePlan

        report = plan_request(auto_request(workload, platform, objective))
        if report.plan is None:
            raise InfeasiblePlan(
                f"no planner produced a feasible mapping for {objective}")
        base = dataclasses.replace(report.plan,
                                   planner=f"auto({report.chosen.solver})")
    else:
        base = plan(workload, platform, objective, mode=mode)
    used = set(base.mapping.alloc)
    free = [int(u) for u in platform.sorted_indices() if int(u) not in used]
    groups = [[u] for u in base.mapping.alloc]

    per, lat = _deal_metrics(workload, platform, base.mapping, groups)
    while free:
        # find the bottleneck interval
        cycles = []
        for (d, e), grp in zip(base.mapping.intervals, groups):
            wsum = workload.w[d - 1: e].sum()
            rate = sum(platform.s[u] for u in grp)
            cycles.append(workload.delta[d - 1] / platform.b + wsum / rate
                          + workload.delta[e] / platform.b)
        j = int(np.argmax(cycles))
        cand = free[0]
        trial = [list(g) for g in groups]
        trial[j].append(cand)
        new_per, new_lat = _deal_metrics(workload, platform, base.mapping, trial)
        if new_per >= per - 1e-12:
            break                      # bottleneck is communication-bound
        if objective.minimize == "period" and objective.bound is not None \
                and new_lat > objective.bound + 1e-12:
            break
        groups = trial
        per, lat = new_per, new_lat
        free.pop(0)
    return DealPlan(base=base, groups=tuple(tuple(g) for g in groups),
                    period=per, latency=lat)


@register_solver("deal", optimizes="period", supports_groups=True,
                 description="interval plan + greedy deal-replication of the "
                             "bottleneck stage over unused processors")
def _solve_deal(workload, platform, objective):
    """Registry entry for the deal extension: only selected by requests with
    ``allow_groups=True`` (or an explicit include)."""
    dp = plan_with_deal(workload, platform, objective)
    return Solution(mapping=dp.base.mapping, groups=dp.groups,
                    period=dp.period, latency=dp.latency)
