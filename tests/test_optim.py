"""Optimizer, schedules, clipping, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         ef_compress_update, ef_init, global_norm,
                         int8_compress, int8_decompress, linear_warmup_cosine,
                         topk_compress, topk_decompress)


def test_adamw_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, lr=0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.full((4,), 10.0)}
    state = adamw_init(params)
    zeros = {"w": jnp.zeros(4)}
    for _ in range(50):
        params, state = adamw_update(params, zeros, state, lr=0.1,
                                     weight_decay=0.5)
    assert float(jnp.abs(params["w"]).max()) < 10.0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((3,), 100.0), "b": jnp.full((2,), -100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(float(global_norm(tree)))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((3,), 1e-3)}
    c2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), np.asarray(small["a"]))


def test_warmup_cosine_shape():
    lrs = [float(linear_warmup_cosine(jnp.asarray(s), base_lr=1.0,
                                      warmup_steps=10, total_steps=100))
           for s in range(100)]
    assert lrs[0] == pytest.approx(0.0)
    assert lrs[10] == pytest.approx(1.0, rel=0.1)
    assert lrs[99] < 0.2
    assert max(lrs) <= 1.0 + 1e-6


def test_topk_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)))
    vals, idx, shape = topk_compress(x, frac=0.25)
    dense = topk_decompress(vals, idx, shape)
    # kept entries are the largest-magnitude quarter
    kept = np.count_nonzero(np.asarray(dense))
    assert kept == 32 * 16 // 4
    mask = np.asarray(dense) != 0
    thresh = np.quantile(np.abs(np.asarray(x)), 0.75)
    assert np.abs(np.asarray(x)[mask]).min() >= thresh * 0.9


def test_int8_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64,)) * 3)
    q, scale = int8_compress(x)
    back = int8_decompress(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.51 + 1e-6


def test_error_feedback_accumulates_everything():
    """Over many rounds, EF top-k transmits (in total) everything: the sum of
    decompressed messages converges to the sum of gradients."""
    rng = np.random.default_rng(2)
    g_total = np.zeros((50,))
    sent_total = np.zeros((50,))
    grads = {"g": jnp.zeros(50)}
    state = ef_init(grads)
    for _ in range(60):
        g = rng.normal(size=(50,))
        g_total += g
        comp, state = ef_compress_update({"g": jnp.asarray(g)}, state, frac=0.1)
        vals, idx, shape = comp["g"]
        sent_total += np.asarray(topk_decompress(vals, idx, shape))
    residual = np.asarray(state.residual["g"])
    np.testing.assert_allclose(sent_total + residual, g_total, atol=1e-4)
    # residual stays bounded (does not blow up)
    assert np.abs(residual).max() < np.abs(g_total).max() + 10
