"""Benchmark driver: one section per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV rows and writes the planner rows to
``BENCH_planner.json`` at the repo root (perf trajectory across PRs)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import kernel_bench, paper_sim, planner_bench, roofline

    print("# paper_sim: Section 5 simulation study (Figures 2-7 + Table 1)")
    out = paper_sim.run(full="--full" in sys.argv)
    for c in out["claims"]:
        print(f"paper_claim,,{c}")

    print("# planner_bench: heuristic timing + campaign speedup + optimality gaps")
    full = "--full" in sys.argv
    planner_rows = planner_bench.run(quick=not full)
    for row in planner_rows:
        print(planner_bench.format_row(*row))
    planner_bench.write_bench_json(planner_rows, mode="full" if full else "quick")
    print(f"# wrote {planner_bench.BENCH_JSON}")

    print("# kernel_bench: kernel reference timings + schedule density")
    for name, us, derived in kernel_bench.run():
        print(f"{name},{us:.1f},{derived}")

    print("# roofline: per-cell terms from the dry-run (results/roofline.csv)")
    try:
        for name, us, derived in roofline.run():
            print(f"{name},{us:.1f},{derived}")
    except Exception:
        print("roofline,0.0,SKIPPED (run repro.launch.dryrun --all first)")
        traceback.print_exc()


if __name__ == "__main__":
    main()
