"""The fleet controller loop: collect → dedup → warm-start → batch → publish.

Per tick the service applies every arriving drift event to its instance's
state (EWMA straggler monitor, platform degradation, elastic resize, pod
removal), collects the *dirty* instances — those whose effective platform
changed — and answers all of their replan requests together:

  1. each dirty instance's problem is canonicalized and signed
     (:mod:`repro.fleet.signatures`); instances that are the same problem up
     to processor relabeling share one signature,
  2. signatures already in the cross-tick plan cache are warm-start hits:
     the previous solve is reused byte-for-byte (exact-bytes signatures mean
     a hit can never change a result, only skip work),
  3. the remaining distinct problems are grouped by (n, p, b) shape, stacked
     with :meth:`ProblemBatch.from_arrays`, and solved in two lockstep runs
     per group via :func:`repro.core.batched.batched_min_period` —
     thousands of requests become a handful of engine programs,
  4. every dirty instance receives its plan by remapping the canonical
     allocation through its own speed-sort permutation and is republished as
     a :class:`StagePlan`; its straggler monitor resets to the new stage
     count.

The published plans are bit-identical to running the scalar portfolio
``min_period_exhaustive(workload, platform)`` per instance (relabeling
theorem + the batched engine's equivalence contract; asserted in
tests/test_fleet.py).
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Optional, Sequence

import numpy as np

from ..core import Mapping, Platform, StagePlan, interval_cycle_times
from ..core.batched import ProblemBatch, batched_min_period
from ..core.planner import _realize
from ..pipeline.replan import StragglerMonitor, elastic_platform
from .metrics import FleetMetrics
from .signatures import canonicalize, remap_alloc, signature
from .telemetry import (PodCountChange, PodFailure, StageDrift, StageTimings,
                        Trace)


@dataclasses.dataclass
class InstanceState:
    """One pipeline instance as the service sees it: the workload, the
    *effective* platform (with every observed degradation folded in), the
    current published plan, and the straggler monitor for that plan."""

    workload: object
    platform: Platform
    plan: Optional[StagePlan] = None
    monitor: Optional[StragglerMonitor] = None


class ReplanService:
    """Telemetry-driven, dedup-batched replanning over a fleet of instances.

    ``instances`` is a sequence of (workload, platform) pairs; instance ids
    are positions.  ``backend`` is the lockstep engine backend ("numpy" is
    the bit-exact reference; "fused" runs each solve group as one jitted
    device program).  ``warm_start=False`` drops the cross-tick plan cache
    at every tick (same-tick dedup always applies) — it exists to *prove*
    warm-starting never changes results, not to be used.
    """

    def __init__(self, instances: Sequence, backend: str = "numpy",
                 warm_start: bool = True):
        self.backend = backend
        self.warm_start = warm_start
        self.metrics = FleetMetrics()
        self.states = [InstanceState(wl, pf) for wl, pf in instances]
        self.plan_cache: dict = {}   # digest -> canonical HeuristicResult
        self.tick_count = 0
        # Initial fleet-wide planning runs through the same dedup+batch path
        # but is not a *re*plan: it stays out of the metrics.
        self._replan(range(len(self.states)))

    # -- event application ----------------------------------------------------

    def _observe(self, st: InstanceState, observed: np.ndarray) -> bool:
        """Feed one timing observation; degrade the platform if the EWMA
        flags stragglers (the ``replan_for_straggler`` recipe).  Returns
        whether the platform changed."""
        if len(observed) != st.plan.num_stages or not _plan_valid(st):
            return False   # stale report from a pre-replan plan shape
        st.monitor.observe(observed)
        predicted = interval_cycle_times(st.workload, st.platform,
                                         st.plan.mapping)
        bad = st.monitor.stragglers(predicted)
        if not bad:
            return False
        pf = st.platform
        for j in bad:
            pf = pf.degrade(st.plan.mapping.alloc[j],
                            float(st.monitor.ewma[j] / predicted[j]))
        st.platform = pf
        return True

    def _apply(self, ev) -> bool:
        """Apply one event; returns True when the instance needs a replan."""
        st = self.states[ev.instance]
        if isinstance(ev, StageTimings):
            return self._observe(st, np.asarray(ev.times, dtype=float))
        if isinstance(ev, StageDrift):
            if not _plan_valid(st):
                return False   # platform already changed this tick
            predicted = interval_cycle_times(st.workload, st.platform,
                                             st.plan.mapping)
            observed = predicted.copy()
            observed[ev.stage % st.plan.num_stages] *= ev.factor
            return self._observe(st, observed)
        if isinstance(ev, PodCountChange):
            target = max(1, int(ev.num_pods))
            if target == st.platform.p:
                return False
            st.platform = elastic_platform(st.platform, target)
            return True
        if isinstance(ev, PodFailure):
            if st.platform.p <= 1:
                return False   # last pod: nothing to fail over to
            pod = int(ev.pod) % st.platform.p
            st.platform = Platform(np.delete(st.platform.s, pod),
                                   st.platform.b,
                                   name=f"{st.platform.name}-failed")
            return True
        raise TypeError(f"unknown fleet event {type(ev).__name__}")

    # -- solve + publish ------------------------------------------------------

    def _replan(self, ids) -> dict:
        """Dedup, batch-solve, and publish new plans for the given instance
        ids.  Returns {iid: StagePlan}; sets ``self._last_tick_stats``."""
        ids = list(ids)
        sig_of = {i: signature(self.states[i].workload,
                               self.states[i].platform) for i in ids}
        warm_hits = sum(sig_of[i].digest in self.plan_cache for i in ids)
        need: dict = {}
        for i in ids:
            sig = sig_of[i]
            if sig.digest not in self.plan_cache and sig.digest not in need:
                need[sig.digest] = (sig, self.states[i])
        by_shape: dict = {}
        for digest, (sig, st) in need.items():
            by_shape.setdefault(sig.shape, []).append((digest, st))
        for (n, p, b), entries in by_shape.items():
            pb = ProblemBatch.from_arrays(
                np.stack([st.workload.w for _, st in entries]),
                np.stack([st.workload.delta for _, st in entries]),
                np.stack([st.platform.s[st.platform.sorted_indices()]
                          for _, st in entries]),
                b)
            for (digest, _), res in zip(entries,
                                        batched_min_period(pb, self.backend)):
                self.plan_cache[digest] = res
        published, churns = {}, []
        for i in ids:
            st = self.states[i]
            res = self.plan_cache[sig_of[i].digest]
            _, perm = canonicalize(st.platform)
            mapping = Mapping(res.mapping.intervals,
                              remap_alloc(res.mapping.alloc, perm))
            plan = _realize(mapping, res.period, res.latency, res.name)
            if st.plan is not None:
                churns.append(_plan_churn(st.plan, plan, st.workload.n))
            st.plan = plan
            st.monitor = StragglerMonitor(plan.num_stages)
            published[i] = plan
        self._last_tick_stats = (len(ids), len(need), warm_hits, churns)
        return published

    def tick(self, events: Sequence) -> dict:
        """Process one tick's events; returns the republished plans."""
        t0 = time.perf_counter()
        if not self.warm_start:
            self.plan_cache.clear()
        dirty: dict = {}   # insertion-ordered unique dirty ids
        for ev in events:
            if self._apply(ev):
                dirty[ev.instance] = None
        published = self._replan(dirty.keys())
        requests, solves, warm_hits, churns = self._last_tick_stats
        self.metrics.record_tick(requests=requests, solves=solves,
                                 warm_hits=warm_hits, events=len(events),
                                 wall=time.perf_counter() - t0, churns=churns)
        self.tick_count += 1
        return published

    def run_trace(self, trace: Trace) -> FleetMetrics:
        """Replay a telemetry trace tick by tick.  Deterministic: the same
        trace over the same fleet yields the same plans and counters."""
        for events in trace.ticks:
            self.tick(events)
        return self.metrics

    # -- introspection --------------------------------------------------------

    @property
    def plans(self) -> list:
        return [st.plan for st in self.states]

    def fleet_digest(self) -> str:
        """Hash of every instance's current plan — determinism fingerprint."""
        h = hashlib.blake2b(digest_size=16)
        for st in self.states:
            h.update(repr((st.plan.mapping.intervals, st.plan.mapping.alloc,
                           st.plan.period, st.plan.latency)).encode())
        return h.hexdigest()


def _plan_valid(st: InstanceState) -> bool:
    """Whether the published plan still addresses the current platform — a
    same-tick pod removal/resize invalidates the plan's allocation until the
    end-of-tick replan; timing reports against it are meaningless."""
    return max(st.plan.mapping.alloc) < st.platform.p


def _plan_churn(old: StagePlan, new: StagePlan, n: int) -> float:
    """Fraction of the n layers whose pod assignment changed."""
    old_alloc = np.repeat(np.asarray(old.mapping.alloc), old.stage_sizes)
    new_alloc = np.repeat(np.asarray(new.mapping.alloc), new.stage_sizes)
    return float(np.mean(old_alloc != new_alloc))
