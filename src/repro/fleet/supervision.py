"""Supervised solve workers: the fleet controller/worker split.

The controller (:class:`~repro.fleet.service.ReplanService`) no longer calls
the batched engine inline; each deduped solve group is dispatched to a
**worker actor** through a :class:`Supervisor`.  The worker API is shaped for
multi-host deployment — a worker owns its execution context, exposes a
heartbeat, and can be killed and replaced without touching controller state —
while the default implementation stays in-process and deterministic:

  - :class:`InlineWorker` — synchronous in-process execution, the default.
    No threads, no timeouts, bit-identical to calling the engine directly.
  - :class:`ThreadWorker` — runs each solve on a dedicated worker thread so
    the supervisor can enforce a per-group ``timeout`` (a hung solve raises
    :class:`WorkerTimeout` on the controller side while the worker is
    replaced underneath it).

The supervisor dispatches round-robin over its pool, retries a failed group
with **exponential backoff** (``backoff_base`` doubling up to
``backoff_max``), and **restarts** workers that time out or whose heartbeat
has gone stale.  After ``max_attempts`` failures it raises
:class:`WorkerFailed` — at which point the service falls back to per-member
scalar solves, and problems that fail *that* too are quarantined (see
``ReplanService``).  On the clean path none of this machinery fires, so
published plans remain bit-identical to the pre-supervision service
(asserted in tests/test_fleet.py).
"""

from __future__ import annotations

import concurrent.futures
import time
from typing import Callable, Optional


class WorkerFailed(RuntimeError):
    """A solve group failed on every attempt; the last cause is chained."""


class WorkerTimeout(RuntimeError):
    """A worker exceeded the per-group solve timeout (hung or wedged)."""


class InlineWorker:
    """Synchronous in-process worker — deterministic, zero overhead.

    ``timeout`` cannot preempt a synchronous call, so it is ignored here;
    use :class:`ThreadWorker` when a hung solve must not wedge the
    controller.
    """

    def __init__(self, solve_fn: Callable, worker_id: int = 0):
        self.solve_fn = solve_fn
        self.worker_id = worker_id
        self.solves = 0
        self.heartbeat = time.monotonic()

    def solve(self, batch, timeout: Optional[float] = None):
        self.heartbeat = time.monotonic()
        out = self.solve_fn(batch)
        self.heartbeat = time.monotonic()
        self.solves += 1
        return out

    def alive(self, heartbeat_timeout: Optional[float]) -> bool:
        # A synchronous worker cannot be secretly wedged: if control returned
        # to the supervisor, the worker is idle.
        return True

    def close(self) -> None:
        pass


class ThreadWorker:
    """Worker actor on its own thread: per-group timeout + heartbeat.

    The multi-host-shaped executor — ``solve`` submits to the worker's
    single-thread executor and bounds the wait.  On timeout the controller
    raises :class:`WorkerTimeout` and the supervisor replaces the worker;
    the abandoned thread finishes (or leaks) in the background, which is the
    in-process analogue of declaring a remote actor dead.
    """

    def __init__(self, solve_fn: Callable, worker_id: int = 0):
        self.solve_fn = solve_fn
        self.worker_id = worker_id
        self.solves = 0
        self.heartbeat = time.monotonic()
        self._ex = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"fleet-worker-{worker_id}")

    def _run(self, batch):
        out = self.solve_fn(batch)
        self.heartbeat = time.monotonic()
        self.solves += 1
        return out

    def solve(self, batch, timeout: Optional[float] = None):
        self.heartbeat = time.monotonic()
        fut = self._ex.submit(self._run, batch)
        try:
            return fut.result(timeout)
        except concurrent.futures.TimeoutError:
            fut.cancel()
            raise WorkerTimeout(
                f"worker {self.worker_id} exceeded {timeout}s solve "
                "timeout") from None

    def alive(self, heartbeat_timeout: Optional[float]) -> bool:
        if heartbeat_timeout is None:
            return True
        return time.monotonic() - self.heartbeat <= heartbeat_timeout

    def close(self) -> None:
        self._ex.shutdown(wait=False)


class SupervisorStats:
    """Lifetime counters the service folds into :class:`FleetMetrics`."""

    def __init__(self):
        self.dispatches = 0
        self.failures = 0
        self.retries = 0
        self.restarts = 0

    def as_dict(self) -> dict:
        return {"dispatches": self.dispatches, "failures": self.failures,
                "retries": self.retries, "restarts": self.restarts}


class Supervisor:
    """Dispatch solve groups to a supervised worker pool.

    ``solve_fn`` is the actual group solver (the service binds it to
    ``batched_min_period`` on its backend).  ``worker_cls`` picks the actor
    flavor; ``workers`` the pool width (all workers run the same pure
    function, so width only affects liveness, never results).  A failed
    dispatch is retried up to ``max_attempts`` total attempts with
    exponential backoff; timed-out or heartbeat-stale workers are closed and
    replaced (counted in ``stats.restarts``).  ``sleep`` is injectable so
    tests can assert the backoff schedule without waiting it out.
    """

    def __init__(self, solve_fn: Callable, *, workers: int = 1,
                 worker_cls=InlineWorker, max_attempts: int = 2,
                 timeout: Optional[float] = None,
                 backoff_base: float = 0.01, backoff_max: float = 1.0,
                 heartbeat_timeout: Optional[float] = None,
                 sleep: Callable = time.sleep):
        if workers < 1:
            raise ValueError(f"need at least one worker, got {workers}")
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        self.solve_fn = solve_fn
        self.worker_cls = worker_cls
        self.max_attempts = int(max_attempts)
        self.timeout = timeout
        self.backoff_base = float(backoff_base)
        self.backoff_max = float(backoff_max)
        self.heartbeat_timeout = heartbeat_timeout
        self.sleep = sleep
        self.stats = SupervisorStats()
        self._next_id = 0
        self.pool = [self._spawn() for _ in range(workers)]
        self._rr = 0

    def _spawn(self):
        w = self.worker_cls(self.solve_fn, worker_id=self._next_id)
        self._next_id += 1
        return w

    def _restart(self, idx: int) -> None:
        self.pool[idx].close()
        self.pool[idx] = self._spawn()
        self.stats.restarts += 1

    def solve(self, batch):
        """Solve one group, supervising the worker.  Returns the worker's
        result list; raises :class:`WorkerFailed` after ``max_attempts``
        failed attempts (the service then degrades to scalar fallback)."""
        delay = self.backoff_base
        last: Optional[BaseException] = None
        for attempt in range(self.max_attempts):
            idx = self._rr % len(self.pool)
            self._rr += 1
            worker = self.pool[idx]
            if not worker.alive(self.heartbeat_timeout):
                self._restart(idx)
                worker = self.pool[idx]
            self.stats.dispatches += 1
            try:
                return worker.solve(batch, timeout=self.timeout)
            except Exception as e:  # noqa: BLE001 — supervise, don't die
                self.stats.failures += 1
                last = e
                if isinstance(e, WorkerTimeout) or \
                        not worker.alive(self.heartbeat_timeout):
                    self._restart(idx)
                if attempt + 1 < self.max_attempts:
                    self.stats.retries += 1
                    if delay > 0:
                        self.sleep(delay)
                    delay = min(delay * 2 if delay > 0 else delay,
                                self.backoff_max)
        raise WorkerFailed(
            f"solve group failed after {self.max_attempts} attempts") from last

    def close(self) -> None:
        for w in self.pool:
            w.close()
