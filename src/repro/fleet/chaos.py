"""Fault injection for telemetry traces: storms, flapping, delivery faults.

The burst-trace generator (:mod:`repro.fleet.telemetry`) models *planned*
drift.  This module composes the unplanned kind on top of any ``Trace``:

  - **pod-failure storms** — on a storm tick, several groups lose several
    pods at once, every replica of a hit group identically (correlated
    infrastructure failure: a rack, a power domain);
  - **flapping pods** — a group loses a pod and gets its capacity restored a
    few ticks later (``PodCountChange`` back to the nominal count), the
    oscillation that defeats naive keep-last-plan caching;
  - **delivery faults** — each event is independently dropped or duplicated,
    and a tick's event order may be shuffled, modeling an at-least-once
    telemetry bus with no ordering guarantee;
  - **controller crashes** — :func:`crash_restart_run` kills the controller
    mid-tick (after the write-ahead append, before any state mutates) at
    chosen ticks and restarts it from its journal, asserting the
    crash-safety contract end to end: the survivor finishes the trace with
    a ``fleet_digest()`` bit-identical to an uninterrupted run and zero
    invalid published ticks;
  - **worker/transport faults** — :class:`TransportChaos` (defined in
    :mod:`repro.fleet.transport`, re-exported here) attacks the subprocess
    worker plane: dead-on-arrival spawns, SIGKILL mid-solve, in-band wedges,
    and drop/corrupt/truncate/delay on the reply wire.  Telemetry chaos asks
    "do bad *inputs* break the plan?"; transport chaos asks "do bad
    *executors* break the controller?".

Everything is driven by one seeded ``numpy`` Generator: ``inject_chaos`` is a
pure function of (trace, groups, spec, seed), so a chaos trace replays
bit-identically (asserted in tests/test_fleet.py) and every run is
debuggable.  With :class:`ChaosSpec` probabilities at zero the input trace
comes back unchanged — chaos-disabled paths are byte-identical.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from .telemetry import PodCountChange, PodFailure, Trace
from .transport import TransportChaos  # noqa: F401  (re-exported)


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Fault-injection intensities.  All probabilities are per tick except
    ``drop_prob``/``dup_prob`` which are per event."""

    storm_prob: float = 0.15      # correlated multi-group pod-failure storm
    storm_groups: int = 4         # groups hit per storm
    storm_failures: int = 2       # pods killed per hit instance
    flap_prob: float = 0.15       # one group's pod flaps (fail now, restore later)
    flap_ticks: int = 3           # restore capacity this many ticks later
    drop_prob: float = 0.05       # event silently lost
    dup_prob: float = 0.05        # event delivered twice
    reorder_prob: float = 0.25    # tick's delivery order shuffled

    def __post_init__(self):
        for f in ("storm_prob", "flap_prob", "drop_prob", "dup_prob",
                  "reorder_prob"):
            v = getattr(self, f)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{f} must be a probability, got {v}")
        if self.flap_ticks < 1:
            raise ValueError("flap_ticks must be >= 1")


def inject_chaos(
    trace: Trace,
    groups: Sequence[Sequence[int]],
    spec: ChaosSpec = ChaosSpec(),
    *,
    seed: int = 0,
    initial_pods: int = 4,
) -> Trace:
    """Compose chaos onto ``trace`` and return the new (replayable) Trace.

    Per tick, in order: storm failures and flap failures are appended after
    the tick's planned events (flap restores land ``flap_ticks`` later as
    ``PodCountChange`` back to ``initial_pods``); then the delivery layer
    applies per-event drop/duplication and an optional within-tick shuffle —
    restores travel through the same lossy layer, so a dropped restore
    leaves the group degraded, exactly the pathology the service must absorb.
    """
    rng = np.random.default_rng(seed)
    ticks = [list(t) for t in trace.ticks]
    n_groups = len(groups)
    for t in range(len(ticks)):
        extra = []
        if n_groups and rng.random() < spec.storm_prob:
            hit = rng.choice(n_groups, size=min(spec.storm_groups, n_groups),
                             replace=False)
            for gi in hit:
                pods = rng.integers(0, max(1, initial_pods),
                                    size=spec.storm_failures)
                for pod in pods:
                    extra += [PodFailure(i, int(pod)) for i in groups[int(gi)]]
        if n_groups and rng.random() < spec.flap_prob:
            gi = int(rng.integers(n_groups))
            pod = int(rng.integers(max(1, initial_pods)))
            extra += [PodFailure(i, pod) for i in groups[gi]]
            restore = t + spec.flap_ticks
            if restore < len(ticks):
                ticks[restore].extend(
                    PodCountChange(i, initial_pods) for i in groups[gi])
        delivered = []
        for ev in ticks[t] + extra:
            if rng.random() < spec.drop_prob:
                continue
            delivered.append(ev)
            if rng.random() < spec.dup_prob:
                delivered.append(ev)
        if len(delivered) > 1 and rng.random() < spec.reorder_prob:
            order = rng.permutation(len(delivered))
            delivered = [delivered[int(k)] for k in order]
        ticks[t] = delivered
    return Trace(ticks=tuple(tuple(t) for t in ticks), seed=trace.seed)


class SimulatedCrash(RuntimeError):
    """The injected kill signal: raised from the controller's crash hook at
    the worst possible moment — the tick's events are on disk but no state
    has mutated (the write-ahead window a real ``kill -9`` would hit)."""


def crash_restart_run(instances, trace: Trace, journal_dir, *,
                      crash_ticks: Sequence[int] = (),
                      restore_supervisor=None, **service_kwargs):
    """Run ``trace`` over a journaled service, killing and restarting the
    controller at each tick in ``crash_ticks``.

    The crash fires via ``ReplanService.crash_hook`` right after the tick's
    write-ahead append; the replacement controller is built with
    :meth:`ReplanService.restore` from the same journal directory and
    resumes the trace where the corpse left off.  Events are neither lost
    nor double-applied: the crashed tick's events are already in the WAL, so
    replay applies them exactly once.

    Returns ``(service, restarts)`` — the surviving service (which has
    processed the full trace) and one dict per injected crash with the
    restart tick, the number of WAL ticks replayed, and the restore wall
    time.  ``service_kwargs`` are forwarded to the initial
    :class:`ReplanService`; ``restore_supervisor`` (optional) is forwarded
    to each ``restore`` call.
    """
    from .service import ReplanService

    remaining = sorted({int(t) for t in crash_ticks})
    svc = ReplanService(instances, journal=journal_dir, **service_kwargs)

    def arm(s):
        def hook(tick):
            if remaining and tick >= remaining[0]:
                remaining.pop(0)
                raise SimulatedCrash(f"injected crash at tick {tick}")
        s.crash_hook = hook

    arm(svc)
    restarts = []
    while True:
        try:
            svc.resume_trace(trace)
            return svc, restarts
        except SimulatedCrash:
            # The corpse's state is garbage by construction; everything the
            # survivor needs is on disk.
            svc.journal.close()
            t0 = time.perf_counter()
            svc = ReplanService.restore(journal_dir,
                                        supervisor=restore_supervisor)
            arm(svc)
            restarts.append({"tick": svc.tick_count,
                             "replayed_ticks": svc.replayed_ticks,
                             "restore_wall": time.perf_counter() - t0})
