"""Online re-planning: straggler mitigation and elastic scaling.

This is the paper's heterogeneous-processor scenario arising *online*:
observed per-stage step times turn a homogeneous pod platform into an
effectively heterogeneous one, and the paper's heuristics re-balance the
layer intervals.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import (InfeasiblePlan, Objective, Platform, StagePlan, Workload,
                    auto_request, interval_cycle_times, plan_request,
                    replan_for_straggler)


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA of per-stage step times; flags stages slower than predicted."""

    num_stages: int
    alpha: float = 0.2
    threshold: float = 1.3
    ewma: Optional[np.ndarray] = None

    def observe(self, stage_times) -> np.ndarray:
        t = np.asarray(stage_times, dtype=float)
        if self.ewma is None:
            self.ewma = t.copy()
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * t
        return self.ewma

    def stragglers(self, predicted) -> list:
        """Stage indices whose EWMA exceeds threshold x predicted cycle time."""
        if self.ewma is None:
            return []
        pred = np.asarray(predicted, dtype=float)
        return [int(j) for j in range(len(pred))
                if pred[j] > 0 and self.ewma[j] / pred[j] > self.threshold]


def replan_stages(workload: Workload, platform: Platform, current: StagePlan,
                  monitor: StragglerMonitor) -> tuple:
    """If stragglers are detected, degrade the platform and re-plan.
    Returns (new_plan_or_None, degraded_platform)."""
    predicted = interval_cycle_times(workload, platform, current.mapping)
    bad = monitor.stragglers(predicted)
    if not bad:
        return None, platform
    new_plan, degraded = replan_for_straggler(
        workload, platform, current, monitor.ewma,
        slowdown_threshold=monitor.threshold)
    return new_plan, degraded


def elastic_platform(old_platform: Platform, new_num_pods: int,
                     surviving=None) -> Platform:
    """The resized platform after a preemption / capacity change.

    Surviving pods keep their *observed* speeds (losing them would throw away
    exactly the heterogeneity the straggler monitor measured); only newly
    added pods get the median surviving speed as prior.  ``surviving`` names
    the pods that remain (default: the first ``min(p, new_num_pods)``).
    """
    if new_num_pods < 1:
        raise ValueError("need at least one pod")
    if surviving is None:
        surviving = np.arange(min(old_platform.p, new_num_pods))
    else:
        surviving = np.asarray(surviving, dtype=np.int64)[:new_num_pods]
    kept = old_platform.s[surviving]
    fill = np.full(new_num_pods - len(kept), float(np.median(kept)))
    if old_platform.fail is None:
        fail = None
    else:
        kept_f = old_platform.fail[surviving]
        fail = np.concatenate(
            [kept_f, np.full(new_num_pods - len(kept_f), float(np.median(kept_f)))])
    return Platform(np.concatenate([kept, fill]), old_platform.b,
                    name=f"elastic-{new_num_pods}", fail=fail)


def elastic_replan(workload: Workload, old_platform: Platform,
                   new_num_pods: int) -> StagePlan:
    """Elastic scaling: the pod count changed (preemption / capacity add);
    re-run the planner portfolio on the resized platform, preserving the
    surviving pods' observed speeds."""
    pf = elastic_platform(old_platform, new_num_pods)
    report = plan_request(auto_request(workload, pf, Objective("period")))
    if report.plan is None:
        raise InfeasiblePlan(f"elastic replan found no feasible mapping "
                             f"for {new_num_pods} pods")
    return report.plan
