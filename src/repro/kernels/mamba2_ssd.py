"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

For each (batch, chunk, head) grid cell the kernel computes, entirely in VMEM:
  - the intra-chunk output  y[i] = sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
  - the chunk's state contribution  S_c = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
  - the chunk decay  exp(cum_Q)
The O(S)-sequential inter-chunk recurrence stays outside (a cheap
``lax.scan`` over nc chunk states in the wrapper — it is O(nc) tiny matmuls).

Block shapes: a full (Q, P) x-tile and (Q, N) B/C tiles per head; Q (chunk)
is a multiple of 128 in production configs, P/N are 64-128 — MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, dec_ref):
    # shapes: x (1,1,Q,1,P); dt (1,1,Q,1); a (1,); b/c (1,1,Q,N)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)         # (Q,)
    A = a_ref[0].astype(jnp.float32)                    # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)                # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                # (Q, N)
    Q = x.shape[0]

    dA = dt * A                                         # (Q,) log-decay
    cum = jnp.cumsum(dA)                                # inclusive
    # L[i,j] = exp(cum_i - cum_j) for j <= i else 0
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    w = scores * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))          # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    dec_state = jnp.exp(cum[-1] - cum) * dt                          # (Q,)
    st = jax.lax.dot_general(Bm * dec_state[:, None], x,
                             (((0,), (0,)), ((), ())))               # (N, P)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)
    dec_ref[0, 0, 0] = jnp.exp(cum[-1]).astype(dec_ref.dtype)


def ssd_intra_chunk(x, dt, A, Bmat, Cmat, *, interpret: bool = False):
    """x: (B,nc,Q,H,P); dt: (B,nc,Q,H); A: (H,); Bmat/Cmat: (B,nc,Q,N).

    Returns (y_intra (B,nc,Q,H,P), chunk_state (B,nc,H,N,P), chunk_decay (B,nc,H)).
    """
    Bb, nc, Q, H, P = x.shape
    N = Bmat.shape[-1]
    grid = (Bb, nc, H)
    y, st, dec = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, c, h: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c, h: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat)
    return y, st, dec


def ssd_chunked_kernel(x, dt, A, Bmat, Cmat, chunk: int, *,
                       interpret: bool = False):
    """Full SSD using the Pallas intra-chunk kernel + jnp inter-chunk scan.
    Same contract as repro.models.ssm.ssd_chunked (x: (B,S,H,P) fp32)."""
    Bb, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = Bmat.reshape(Bb, nc, Q, N)
    Cc = Cmat.reshape(Bb, nc, Q, N)

    y_intra, chunk_state, chunk_decay = ssd_intra_chunk(
        xc, dtc, A, Bc, Cc, interpret=interpret)

    def step(state, inp):                                # state: (B,H,N,P)
        c_state, c_decay = inp
        new = state * c_decay[..., None, None] + c_state
        return new, state

    init = jnp.zeros((Bb, H, N, P), jnp.float32)
    final_state, prev = jax.lax.scan(
        step, init, (chunk_state.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,N,P)

    cum = jnp.cumsum(dtc * A, axis=2)                    # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, jnp.exp(cum), prev)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final_state.transpose(0, 1, 3, 2)          # state as (B,H,P,N)
