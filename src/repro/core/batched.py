"""Batched campaign engine: the paper's heuristics over stacked instances.

The Section-5 simulation study evaluates six heuristics on hundreds of random
(workload, platform) pairs.  The scalar path (:mod:`repro.core.heuristics`)
runs each pair through a Python while-loop, so a campaign is dominated by
interpreter overhead.  This module runs B homogeneously-shaped problems in
*lockstep* with structure-of-arrays state — one numpy (or JAX) call evaluates
a whole batch of worst-interval selections, split scorings, and state updates
per iteration, with per-problem masks tracking convergence.

Equivalence contract: with the numpy backend every float this engine produces
is **bit-for-bit identical** to the per-instance path (asserted by
tests/test_batched.py).  That holds because both paths evaluate candidates
through the shared kernels ``score_2way_kernel``/``score_3way_kernel`` of
:mod:`repro.core.heuristics` and apply state updates with the same elementwise
expressions in the same order.

Public surface:

  - :func:`stack_instances` / :class:`ProblemBatch` — SoA instance stacking
  - :func:`batched_trajectories` — H1-H4 exhaustion trajectories (the sweep
    primitive of ``repro.sim.experiments``)
  - :func:`batched_fixed_latency` — H5/H6 over a per-problem bound grid in
    one lockstep pass
  - :func:`batched_sp_bi_p` — H4 whose binary search probes all B problems
    per bisection step

Backends: ``backend="numpy"`` (default, bit-exact), ``backend="jax"``
(scoring kernels under ``jax.jit`` with x64 enabled), ``backend="pallas"``
(scoring through the masked-tile ``pl.pallas_call`` kernels of
:mod:`repro.kernels.split_score` — interpret mode on CPU, compiled on
TPU/GPU), ``backend="fused"`` (the ENTIRE lockstep loop as one jitted
``lax.while_loop`` — :mod:`repro.core.fused` — with span-bucketed candidate
grids and O(1) host dispatches per heuristic arity), or ``backend="sharded"``
(the fused loop as one ``shard_map`` SPMD program with the instance axis
sharded across every device — :mod:`repro.core.sharded`).  All jit backends carry
the kernels' runtime-zero FMA guard, so their split trajectories AND floats
match the numpy reference exactly on all tested instances; numpy remains the
contractual bit-exact reference.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .heuristics import (_EPS, _PERMS3, HeuristicResult, _pick_bi, _pick_mono,
                         _three_way_candidates, score_2way_kernel,
                         score_3way_kernel, score_kernels)
from .metrics import Mapping

__all__ = [
    "ProblemBatch", "stack_instances", "batched_trajectories",
    "batched_trajectory_sets", "batched_fixed_latency", "batched_min_period",
    "batched_sp_bi_p", "h4_search_bounds",
]


# ---------------------------------------------------------------------------
# Problem stacking
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProblemBatch:
    """B equally-shaped problems as stacked arrays (one row per problem).

    ``prefix`` is the stage-work prefix sum (``Workload.prefix_w`` per row)
    and ``order`` the speed-sorted processor indices
    (``Platform.sorted_indices`` per row) — precomputed once per campaign.
    """

    w: np.ndarray        # (B, n)
    delta: np.ndarray    # (B, n+1)
    s: np.ndarray        # (B, p)
    b: float
    prefix: np.ndarray   # (B, n+1)
    order: np.ndarray    # (B, p) int

    @property
    def B(self) -> int:
        return self.w.shape[0]

    @property
    def n(self) -> int:
        return self.w.shape[1]

    @property
    def p(self) -> int:
        return self.s.shape[1]

    def take(self, rows) -> "ProblemBatch":
        """Sub-batch of the given rows (with repetition allowed — used to tile
        instances across a bound grid)."""
        rows = np.asarray(rows)
        return ProblemBatch(self.w[rows], self.delta[rows], self.s[rows],
                            self.b, self.prefix[rows], self.order[rows])

    def packed(self) -> np.ndarray:
        """[delta | prefix | s] concatenated per row (cached): lets the hot
        loops fetch several per-interval quantities in one fancy-index."""
        cached = getattr(self, "_packed", None)
        if cached is None:
            cached = np.concatenate([self.delta, self.prefix, self.s], axis=1)
            object.__setattr__(self, "_packed", cached)
        return cached

    @classmethod
    def from_arrays(cls, w, delta, s, b: float) -> "ProblemBatch":
        """Build a batch straight from stacked arrays — the entry point for
        callers that already hold heterogeneous platform rows (e.g. the fleet
        service's observed per-pod speeds) and should not have to materialize
        B Workload/Platform objects just to stack them again.  ``prefix`` and
        ``order`` are derived exactly like ``Workload.prefix_w`` /
        ``Platform.sorted_indices`` so downstream results stay bit-identical
        to the object path."""
        w = np.asarray(w, dtype=np.float64)
        delta = np.asarray(delta, dtype=np.float64)
        s = np.asarray(s, dtype=np.float64)
        if w.ndim != 2 or s.ndim != 2 or s.shape[0] != w.shape[0]:
            raise ValueError(f"need 2-D stacked rows, got w{w.shape} s{s.shape}")
        if delta.shape != (w.shape[0], w.shape[1] + 1):
            raise ValueError(f"need delta shape (B, n+1), got {delta.shape}")
        B = w.shape[0]
        prefix = np.concatenate([np.zeros((B, 1)), np.cumsum(w, axis=1)], axis=1)
        order = np.lexsort((np.broadcast_to(np.arange(s.shape[1]), s.shape), -s),
                           axis=-1)
        return cls(w=w, delta=delta, s=s, b=float(b), prefix=prefix, order=order)

    @classmethod
    def concat(cls, batches: Sequence) -> "ProblemBatch":
        """Stack several same-shape batches (ProblemBatch or any batch-like
        with the same array attributes) row-wise into one ProblemBatch."""
        pbs = [_as_problem_batch(b) for b in batches]
        if not pbs:
            raise ValueError("empty batch list")
        if len(pbs) == 1:
            return pbs[0]
        first = pbs[0]
        for pb in pbs[1:]:
            if pb.n != first.n or pb.p != first.p or pb.b != first.b:
                raise ValueError("all batches must share n, p, and b")
        return cls(
            w=np.concatenate([pb.w for pb in pbs]),
            delta=np.concatenate([pb.delta for pb in pbs]),
            s=np.concatenate([pb.s for pb in pbs]),
            b=first.b,
            prefix=np.concatenate([pb.prefix for pb in pbs]),
            order=np.concatenate([pb.order for pb in pbs]),
        )


def stack_instances(pairs: Sequence) -> ProblemBatch:
    """Stack (Workload, Platform) pairs of identical shape into a ProblemBatch."""
    if not len(pairs):
        raise ValueError("empty batch")
    n = pairs[0][0].n
    p = pairs[0][1].p
    b = float(pairs[0][1].b)
    for wl, pf in pairs:
        if wl.n != n or pf.p != p or float(pf.b) != b:
            raise ValueError("all instances in a batch must share n, p, and b")
    return ProblemBatch(
        w=np.stack([wl.w for wl, _ in pairs]),
        delta=np.stack([wl.delta for wl, _ in pairs]),
        s=np.stack([pf.s for _, pf in pairs]),
        b=b,
        prefix=np.stack([wl.prefix_w() for wl, _ in pairs]),
        order=np.stack([pf.sorted_indices() for _, pf in pairs]),
    )


def _as_problem_batch(batch) -> ProblemBatch:
    if isinstance(batch, ProblemBatch):
        return batch
    if hasattr(batch, "w") and hasattr(batch, "prefix") and hasattr(batch, "order"):
        return ProblemBatch(batch.w, batch.delta, batch.s, float(batch.b),
                            batch.prefix, batch.order)
    return stack_instances(list(batch))


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class _Backend:
    """Kernel-implementation backend for the lockstep loop: resolves the
    shared scoring kernels through ``heuristics.score_kernels`` ("numpy",
    "jax", or "pallas" — the Pallas kernels are span-aware: the hot loops
    hand them each row's live-lane bound so masked tiles skip compute)."""

    def __init__(self, name: str):
        self.name = name
        if name not in ("numpy", "jax", "pallas"):
            raise ValueError(f"unknown backend {name!r}; use 'numpy', 'jax', "
                             "'pallas', 'fused', or 'sharded'")
        self.score2, self.score3 = score_kernels(name)
        self.span_aware = name == "pallas"


_BACKENDS: dict = {}


def _get_backend(name: str) -> _Backend:
    if name not in _BACKENDS:
        _BACKENDS[name] = _Backend(name)
    return _BACKENDS[name]


# ---------------------------------------------------------------------------
# Lockstep splitting state
# ---------------------------------------------------------------------------

class _BatchState:
    """SoA mirror of ``heuristics._State`` across B problems.

    Items (1-indexed intervals + processor) live in chain order in a padded
    (B, n, 5) float array ``arr`` together with each item's cycle time and
    latency term (padding cycle -inf); ``m`` counts valid items per row.
    The metric fields are maintained incrementally exactly like the scalar
    state's ``_cycles``/``_lat_terms``; d/e/proc are small integers, exactly
    represented in float64.
    """

    # arr field layout: 0=d, 1=e, 2=proc (exactly-represented ints), 3=cycle,
    # 4=latency term.  ``packed`` concatenates [delta | prefix | s] per row so
    # the hot paths fetch several per-interval quantities in ONE fancy-index.
    F_D, F_E, F_U, F_CYC, F_TERM = range(5)

    def __init__(self, pb: ProblemBatch, active: Optional[np.ndarray] = None):
        B, n = pb.B, pb.n
        self.pb = pb
        self.packed = pb.packed()
        self.off_pre = n + 1
        self.off_s = 2 * (n + 1)
        rows = np.arange(B)
        fastest = pb.order[:, 0]
        self.arr = np.zeros((B, n, 5))
        self.arr[:, :, self.F_CYC] = -np.inf
        term0 = pb.delta[:, 0] / pb.b + (pb.prefix[:, n] - pb.prefix[:, 0]) / pb.s[rows, fastest]
        self.tail = pb.delta[:, n] / pb.b
        self.arr[:, 0, self.F_D] = 1
        self.arr[:, 0, self.F_E] = n
        self.arr[:, 0, self.F_U] = fastest
        self.arr[:, 0, self.F_CYC] = term0 + self.tail
        self.arr[:, 0, self.F_TERM] = term0
        self.m = np.ones(B, dtype=np.int64)
        self.next_idx = np.ones(B, dtype=np.int64)
        self.lat_sum = term0.copy()
        self.active = np.ones(B, dtype=bool) if active is None else active.copy()
        self.splits = np.zeros(B, dtype=np.int64)

    def period(self) -> np.ndarray:
        return self.arr[:, :, self.F_CYC].max(axis=1)

    def latency(self) -> np.ndarray:
        return self.lat_sum + self.tail

    def items_int(self, i: int) -> np.ndarray:
        """(m, 3) int items of row i: (d, e, proc) in chain order."""
        return self.arr[i, : int(self.m[i]), :3].astype(np.int64)

    def mapping(self, i: int) -> Mapping:
        items = self.items_int(i)
        return Mapping(intervals=tuple((int(d), int(e)) for d, e, _ in items),
                       alloc=tuple(int(u) for _, _, u in items))


def _mapping_from_rows(items_row, m: int) -> Mapping:
    return Mapping(intervals=tuple((int(items_row[t, 0]), int(items_row[t, 1]))
                                   for t in range(m)),
                   alloc=tuple(int(items_row[t, 2]) for t in range(m)))


# ---------------------------------------------------------------------------
# Batched candidate selection
# ---------------------------------------------------------------------------

def _lex_argmin(keys: Sequence[np.ndarray], mask: np.ndarray):
    """Per-row index of the lexicographically smallest key tuple among masked
    candidates — the batched equivalent of the scalar paths'
    ``lexsort(keys[::-1])[0]``.  Returns (choice_index (A,), has_any (A,))."""
    has = mask.any(axis=1)
    n_has = np.count_nonzero(has)
    m = mask.copy()
    for i, key in enumerate(keys):
        key = np.broadcast_to(key, m.shape)
        kmin = np.where(m, key, np.inf).min(axis=1)
        m &= key == kmin[:, None]
        # later keys only break ties; stop once every row is decided (each
        # has-row keeps >= 1 candidate, so total count == n_has means unique)
        if i + 1 < len(keys) and np.count_nonzero(m) == n_has:
            break
    return np.argmax(m, axis=1), has


def _split_by_span(spans: np.ndarray) -> Optional[np.ndarray]:
    """When one row's interval is much wider than the median, lane-compacted
    scoring wastes (max_span - span) lanes on every other row.  Returns a
    boolean 'small rows' partition mask (process the two groups separately),
    or None when partitioning isn't worth the extra call."""
    if spans.size < 16:
        return None
    med = int(np.median(spans))
    if int(spans.max()) < 2 * med:
        return None
    small = spans <= med
    if not small.any() or small.all():
        return None
    return small


def _merge_choices(small, outs_small, outs_large):
    merged = []
    for a, b in zip(outs_small, outs_large):
        full_shape = (small.size,) + a.shape[1:]
        m = np.empty(full_shape, dtype=a.dtype)
        m[small] = a
        m[~small] = b
        merged.append(m)
    return tuple(merged)


def _choose_2way(state, rows, d, e, j, jp, bi_mode, old_cycle, cur_lat, lat_lim, be):
    """Best 2-way split per row, or none.  Mirrors ``_best_split_2way_fast``.

    Cut lanes are compacted to the current *maximum* interval span across the
    rows (cut c = d + offset): spans shrink geometrically as splitting
    proceeds, so later lockstep iterations touch far fewer lanes than a
    global 1..n-1 grid would.  Invalid lanes are masked; key values use the
    absolute cut position so selection is identical to the scalar path.
    (Unlike the 3-way pair grid, lane count is only linear in the span here,
    so span-skew partitioning would cost more in extra calls than it saves.)
    """
    pb = state.pb
    n = pb.n
    A = rows.size
    rowc = rows[:, None]
    K = int((e - d).max())                       # lanes: cuts d .. d+K-1
    c_abs = d[:, None] + np.arange(K)[None, :]
    valid = c_abs < e[:, None]
    c_idx = np.minimum(c_abs, n - 1)             # in-range gather for masked lanes
    # interval-end quantities via ONE packed gather
    gidx = np.empty((A, 6), dtype=np.int64)
    gidx[:, 0] = state.off_pre + (d - 1)         # prefix[d-1]
    gidx[:, 1] = state.off_pre + e               # prefix[e]
    gidx[:, 2] = d - 1                           # delta[d-1]
    gidx[:, 3] = e                               # delta[e]
    gidx[:, 4] = state.off_s + j                 # s[j]
    gidx[:, 5] = state.off_s + jp                # s[jp]
    g = state.packed[rowc, gidx]
    cidx2 = np.empty((A, 2, K), dtype=np.int64)
    cidx2[:, 0] = state.off_pre + c_idx          # prefix[c]
    cidx2[:, 1] = c_idx                          # delta[c]
    gc = state.packed[rows[:, None, None], cidx2]
    # span-aware kernels (pallas) take each row's live-cut count so tiles
    # beyond every row's span skip compute
    kw = {"need": e - d} if be.span_aware else {}
    cyc1, cyc2, dlat = be.score2(
        g[:, 0][:, None], gc[:, 0], g[:, 1][:, None],
        g[:, 2][:, None], gc[:, 1], g[:, 3][:, None],
        pb.b, (1.0 / g[:, 4])[:, None], (1.0 / g[:, 5])[:, None], **kw)
    if be.name != "numpy":
        cyc1, cyc2, dlat = np.asarray(cyc1), np.asarray(cyc2), np.asarray(dlat)
    mx = np.maximum(cyc1, cyc2)
    okay = (mx < old_cycle[:, None] - _EPS)
    okay &= (cur_lat[:, None] + dlat <= lat_lim[:, None] + _EPS)
    okay &= np.concatenate([valid, valid], axis=1)
    # (cut, placement-order) tie-break as ONE exactly-represented integer key
    cutorder = np.concatenate([c_abs * 2, c_abs * 2 + 1], axis=1).astype(float)
    any_bi = bool(bi_mode.any())
    if not any_bi:
        keys = [mx, dlat, cutorder]
    else:
        den1 = np.maximum(old_cycle[:, None] - cyc1, _EPS)
        den2 = np.maximum(old_cycle[:, None] - cyc2, _EPS)
        ratio = np.maximum(dlat / den1, dlat / den2)
        if bi_mode.all():
            keys = [ratio, mx, cutorder]
        else:
            # mixed batch: per-row key columns (each row sees exactly the
            # key tuple its own mode would use)
            bc = bi_mode[:, None]
            keys = [np.where(bc, ratio, mx), np.where(bc, mx, dlat), cutorder]
    q, has = _lex_argmin(keys, okay)
    c = d + (q % K)
    swapped = q >= K
    pa = np.where(swapped, jp, j)
    pb2 = np.where(swapped, j, jp)
    return has, c, pa, pb2


@functools.lru_cache(maxsize=None)
def _offset_pair_grid(span: int):
    """All cut-offset pairs 0 <= o1 < o2 <= span-2 as flat (K,) int arrays
    (cut c_i = d + o_i for an interval of ``span`` stages starting at d)."""
    i, jj = np.triu_indices(span - 1, k=1)
    return i, jj


_PERM_ARR = np.array(_PERMS3)          # (6, 3)


def _choose_3way(state, rows, d, e, j, jp, jpp, bi_mode, old_cycle, cur_lat, lat_lim, be):
    """Best 3-way split per row (all >= 3-stage worst intervals).  Mirrors
    ``_best_split_3way_fast``: per-perm scoring via the shared kernel, global
    lexmin over (keys..., perm index).  Like ``_choose_2way``, cut-pair lanes
    are compacted to the rows' maximum interval span and span-skewed batches
    are partitioned (the pair grid grows quadratically in the span)."""
    small = _split_by_span(e - d + 1)
    if small is not None:
        lg = ~small
        return _merge_choices(
            small,
            _choose_3way(state, rows[small], d[small], e[small], j[small],
                         jp[small], jpp[small], bi_mode[small],
                         old_cycle[small], cur_lat[small], lat_lim[small], be),
            _choose_3way(state, rows[lg], d[lg], e[lg], j[lg], jp[lg], jpp[lg],
                         bi_mode[lg], old_cycle[lg], cur_lat[lg],
                         lat_lim[lg], be))
    A = rows.size
    span_max = int((e - d + 1).max())
    K_est = (span_max - 1) * (span_max - 2) // 2
    # The scoring arrays are (rows, 6 perms, 3 parts, K pairs): chunk rows so
    # the working set stays cache-sized — on wide intervals the batch would
    # otherwise lose to memory bandwidth what it wins in call overhead.
    if A > 16 and A * K_est > 30_000:
        step = max(16, 30_000 // max(K_est, 1))
        outs = [_choose_3way(state, rows[i:i + step], d[i:i + step],
                             e[i:i + step], j[i:i + step], jp[i:i + step],
                             jpp[i:i + step], bi_mode[i:i + step],
                             old_cycle[i:i + step], cur_lat[i:i + step],
                             lat_lim[i:i + step], be)
                for i in range(0, A, step)]
        return tuple(np.concatenate([o[f] for o in outs]) for f in range(4))
    pb = state.pb
    n = pb.n
    o1g, o2g = _offset_pair_grid(span_max)
    K = o1g.size
    c1 = d[:, None] + o1g[None, :]
    c2 = d[:, None] + o2g[None, :]
    valid = c2 <= (e - 1)[:, None]
    c1i = np.minimum(c1, n - 1)
    c2i = np.minimum(c2, n - 1)
    gidx = np.empty((A, 7), dtype=np.int64)
    gidx[:, 0] = state.off_pre + (d - 1)         # prefix[d-1]
    gidx[:, 1] = state.off_pre + e               # prefix[e]
    gidx[:, 2] = d - 1                           # delta[d-1]
    gidx[:, 3] = e                               # delta[e]
    gidx[:, 4] = state.off_s + j                 # s[j]
    gidx[:, 5] = state.off_s + jp                # s[jp]
    gidx[:, 6] = state.off_s + jpp               # s[jpp]
    g = state.packed[rows[:, None], gidx]
    cidx = np.empty((A, 4, K), dtype=np.int64)
    cidx[:, 0] = state.off_pre + c1i             # prefix[c1]
    cidx[:, 1] = state.off_pre + c2i             # prefix[c2]
    cidx[:, 2] = c1i                             # delta[c1]
    cidx[:, 3] = c2i                             # delta[c2]
    gc = state.packed[rows[:, None, None], cidx]
    pre_d1 = g[:, 0][:, None]
    pre_e = g[:, 1][:, None]
    pre_c1, pre_c2, delta_c1, delta_c2 = gc[:, 0], gc[:, 1], gc[:, 2], gc[:, 3]
    W = np.stack([pre_c1 - pre_d1, pre_c2 - pre_c1, pre_e - pre_c2], axis=1)   # (A, 3, K)
    dI = np.stack([np.broadcast_to(g[:, 2][:, None], (A, K)), delta_c1, delta_c2], axis=1) / pb.b
    dO = np.stack([delta_c1, delta_c2, np.broadcast_to(g[:, 3][:, None], (A, K))], axis=1) / pb.b
    procs = np.stack([j, jp, jpp], axis=1)                                     # (A, 3)
    inv = 1.0 / g[:, 4:7]
    base_term = (g[:, 2] / pb.b + (g[:, 1] - g[:, 0]) / g[:, 4])[:, None, None]
    # all 6 permutations in one kernel call: perm axis 1, parts axis 2
    invp = inv[:, _PERM_ARR][:, :, :, None]                                    # (A, 6, 3, 1)
    if be.span_aware:
        from ..kernels.split_score import pair_need

        # per-row last-valid-lane bound of the r1-major pair layout, so the
        # pallas kernel's out-of-band tiles skip compute
        kw = {"need": pair_need(e - d + 1, span_max)}
    else:
        kw = {}
    cyc, dlat, mx = be.score3(dI[:, None], W[:, None], dO[:, None], invp,
                              base_term, **kw)
    if be.name != "numpy":
        cyc, dlat, mx = np.asarray(cyc), np.asarray(dlat), np.asarray(mx)
    any_bi = bool(bi_mode.any())
    ratio_all = None
    if any_bi:
        ratio_all = (dlat[:, :, None, :]
                     / np.maximum(old_cycle[:, None, None, None] - cyc, _EPS)).max(axis=2)
    mx_f = mx.reshape(A, 6 * K)
    dlat_f = dlat.reshape(A, 6 * K)
    okay = mx_f < old_cycle[:, None] - _EPS
    okay &= cur_lat[:, None] + dlat_f <= lat_lim[:, None] + _EPS
    okay &= np.broadcast_to(valid[:, None, :], (A, 6, K)).reshape(A, 6 * K)
    # (c1, c2, perm index) tie-break as ONE exactly-represented integer key,
    # matching the scalar path's per-perm (.., c1, c2) lexsort + cross-perm
    # (keys..., pi) comparison.
    ccp = ((c1 * (n + 1) + c2)[:, None, :] * 6
           + np.arange(6)[None, :, None]).astype(float).reshape(A, 6 * K)
    if not any_bi:
        keys = [mx_f, dlat_f, ccp]
    elif bi_mode.all():
        keys = [ratio_all.reshape(A, 6 * K), mx_f, ccp]
    else:
        bc = bi_mode[:, None]
        ratio_f = ratio_all.reshape(A, 6 * K)
        keys = [np.where(bc, ratio_f, mx_f), np.where(bc, mx_f, dlat_f), ccp]
    q, has = _lex_argmin(keys, okay)
    pi = q // K
    kk = q % K
    c1b = d + o1g[kk]
    c2b = d + o2g[kk]
    u_parts = np.take_along_axis(procs, _PERM_ARR[pi], axis=1)                 # (A, 3)
    return has, c1b, c2b, u_parts


class _RowView:
    """Minimal scalar-state shim over one batch row, so the 2-stage 3-way
    fallback reuses ``_three_way_candidates``/``_pick_*`` verbatim."""

    __slots__ = ("pre", "delta", "s", "b", "items")

    def __init__(self, pre, delta, s, b, d, e, j):
        self.pre, self.delta, self.s, self.b = pre, delta, s, b
        self.items = [[d, e, j]]

    def cycle(self, d, e, u):
        return self.delta[d - 1] / self.b + (self.pre[e] - self.pre[d - 1]) / self.s[u] + self.delta[e] / self.b

    def latency_term(self, d, e, u):
        return self.delta[d - 1] / self.b + (self.pre[e] - self.pre[d - 1]) / self.s[u]


# ---------------------------------------------------------------------------
# Lockstep loop
# ---------------------------------------------------------------------------

def _apply_splits(state: _BatchState, rows, idx, pd, pe, pu, nparts, consumed):
    """Replace item ``idx`` of each row with its 2 or 3 parts: shift the item
    arrays, scatter the parts, and update cycle/term/lat_sum incrementally
    with the same division-based expressions as the scalar ``replace``."""
    pb = state.pb
    n = pb.n
    R = rows.size
    arR = np.arange(R)
    rowc = rows[:, None]
    # per-part latency terms and cycles via ONE packed gather (lane 2 is
    # garbage for 2-part rows — indices are in-range and never scattered)
    gidx = np.empty((R, 3, 5), dtype=np.int64)
    gidx[:, :, 0] = pd - 1                       # delta[pd-1]
    gidx[:, :, 1] = state.off_pre + pe           # prefix[pe]
    gidx[:, :, 2] = state.off_pre + (pd - 1)     # prefix[pd-1]
    gidx[:, :, 3] = state.off_s + pu             # s[pu]
    gidx[:, :, 4] = pe                           # delta[pe]
    g = state.packed[rows[:, None, None], gidx]
    t_parts = g[:, :, 0] / pb.b + (g[:, :, 1] - g[:, :, 2]) / g[:, :, 3]
    c_parts = t_parts + g[:, :, 4] / pb.b
    old_term = state.arr[rows, idx, state.F_TERM]
    add = t_parts[:, 0] + t_parts[:, 1]
    three = nparts == 3
    add = np.where(three, add + t_parts[:, 2], add)
    new_lat = (state.lat_sum[rows] - old_term) + add
    sh = (nparts - 1)[:, None]
    # the shift only touches the first max(m)+2 item columns — the rest is
    # padding on every row and stays put
    mm = min(n, int(state.m[rows].max()) + 2)
    col = np.arange(mm)[None, :]
    idxc = idx[:, None]
    src = np.where(col <= idxc, col, np.where(col <= idxc + sh, idxc, col - sh))
    parts = np.empty((R, 3, 5))
    parts[:, :, state.F_D] = pd
    parts[:, :, state.F_E] = pe
    parts[:, :, state.F_U] = pu
    parts[:, :, state.F_CYC] = c_parts
    parts[:, :, state.F_TERM] = t_parts
    sub = state.arr[rowc, src]
    sub[arR, idx] = parts[:, 0]
    sub[arR, idx + 1] = parts[:, 1]
    if three.any():
        sub[arR[three], idx[three] + 2] = parts[three, 2]
    state.arr[rowc, col] = sub
    state.m[rows] += nparts - 1
    state.next_idx[rows] += consumed
    state.splits[rows] += 1
    state.lat_sum[rows] = new_lat


def _run_loop(state: _BatchState, k: int, bi_mode: np.ndarray, stop: np.ndarray,
              lat_limit: np.ndarray, backend: str = "numpy",
              record: Optional[Callable] = None) -> None:
    """The paper's splitting loop in lockstep: mirrors ``_splitting_loop``
    per row (stop-bound check, worst interval, candidate choice, update),
    deactivating rows as they converge.  ``bi_mode`` selects each row's
    candidate-choice rule (False = mono-criterion, True = bi-criteria), so
    heuristics sharing a split arity run together in one pass.
    ``record(rows, periods, latencies)`` is invoked after each lockstep apply
    with the rows that accepted a split.

    ``backend="fused"`` hands the whole loop to the device-resident traced
    engine (:mod:`repro.core.fused`): one jitted ``lax.while_loop`` executes
    every iteration on-device and this function returns after a single
    dispatch per row-chunk, instead of O(iterations) host round-trips.
    ``backend="sharded"`` runs the same traced loop as one ``shard_map``
    SPMD program with the row axis sharded across every device
    (:mod:`repro.core.sharded`).
    """
    if backend == "fused":
        from . import fused

        fused.run_fused(state, k, np.asarray(bi_mode, dtype=bool),
                        np.asarray(stop, dtype=float),
                        np.asarray(lat_limit, dtype=float), record)
        return
    if backend == "sharded":
        from . import sharded

        sharded.run_sharded(state, k, np.asarray(bi_mode, dtype=bool),
                            np.asarray(stop, dtype=float),
                            np.asarray(lat_limit, dtype=float), record)
        return
    pb = state.pb
    be = _get_backend(backend)
    rows = np.nonzero(state.active)[0]
    while rows.size:
        # 1. natural stop: period bound already satisfied.  Only the first
        # max(m) item columns are live (cycle padding is -inf beyond).
        mm = int(state.m[rows].max())
        cyc_sub = state.arr[rows, :mm, state.F_CYC]
        per = cyc_sub.max(axis=1)
        keep = per > stop[rows] + _EPS
        if not keep.all():
            state.active[rows[~keep]] = False
            rows = rows[keep]
            cyc_sub = cyc_sub[keep]
            if rows.size == 0:
                break
        # 2./3. worst interval must be splittable and processors available
        widx = np.argmax(cyc_sub, axis=1)
        worst = state.arr[rows, widx, :3].astype(np.int64)   # (R, 3): d, e, proc
        d, e, j = worst[:, 0], worst[:, 1], worst[:, 2]
        ok = (e > d) & (state.next_idx[rows] + k <= pb.p)
        if not ok.all():
            state.active[rows[~ok]] = False
            sel = np.nonzero(ok)[0]
            rows, widx, d, e, j = rows[sel], widx[sel], d[sel], e[sel], j[sel]
            cyc_sub = cyc_sub[sel]
            if rows.size == 0:
                continue
        old_cycle = cyc_sub[np.arange(rows.size), widx]
        cur_lat = state.lat_sum[rows] + state.tail[rows]
        lat_lim = lat_limit[rows]
        jp = pb.order[rows, state.next_idx[rows]]
        R = rows.size
        # all three part lanes are written (or the row is filtered by `has`)
        # before any use, so uninitialized memory is fine here
        pd = np.empty((R, 3), dtype=np.int64)
        pe = np.empty((R, 3), dtype=np.int64)
        pu = np.empty((R, 3), dtype=np.int64)
        nparts = np.full(R, 2, dtype=np.int64)
        consumed = np.ones(R, dtype=np.int64)
        if k == 1:
            has, c, pa, pb2 = _choose_2way(state, rows, d, e, j, jp,
                                           bi_mode[rows], old_cycle, cur_lat,
                                           lat_lim, be)
            pd[:, 0], pe[:, 0], pu[:, 0] = d, c, pa
            pd[:, 1], pe[:, 1], pu[:, 1] = c + 1, e, pb2
            pd[:, 2], pe[:, 2], pu[:, 2] = c + 1, e, pb2       # in-range filler
        else:
            jpp = pb.order[rows, state.next_idx[rows] + 1]
            has = np.zeros(R, dtype=bool)
            big = e - d + 1 >= 3
            if big.any():
                bi = np.nonzero(big)[0]
                hb, c1, c2, u_parts = _choose_3way(
                    state, rows[bi], d[bi], e[bi], j[bi], jp[bi], jpp[bi],
                    bi_mode[rows[bi]], old_cycle[bi], cur_lat[bi],
                    lat_lim[bi], be)
                has[bi] = hb
                pd[bi, 0], pe[bi, 0] = d[bi], c1
                pd[bi, 1], pe[bi, 1] = c1 + 1, c2
                pd[bi, 2], pe[bi, 2] = c2 + 1, e[bi]
                pu[bi] = u_parts
                nparts[bi] = 3
                consumed[bi] = 2
            # 2-stage worst interval: the scalar fast path falls back to the
            # readable generator; do exactly that, row by row (rare + tiny).
            for t in np.nonzero(~big)[0]:
                i = rows[t]
                view = _RowView(pb.prefix[i], pb.delta[i], pb.s[i], pb.b,
                                int(d[t]), int(e[t]), int(j[t]))
                pick = _pick_bi if bi_mode[i] else _pick_mono
                choice = pick(_three_way_candidates(view, 0, int(jp[t]), int(jpp[t])),
                              float(old_cycle[t]), float(lat_lim[t]), float(cur_lat[t]))
                if choice is None:
                    continue
                parts, _, _ = choice
                has[t] = True
                for q, (pd_, pe_, pu_) in enumerate(parts):
                    pd[t, q], pe[t, q], pu[t, q] = pd_, pe_, pu_
                pd[t, 2], pe[t, 2], pu[t, 2] = pd[t, 1], pe[t, 1], pu[t, 1]
                nparts[t] = len(parts)
                used = {pu_ for _, _, pu_ in parts} - {int(j[t])}
                consumed[t] = k if len(used) == k else len(used)
        # 4. rows with no improving candidate are done
        if not has.all():
            state.active[rows[~has]] = False
            sel = np.nonzero(has)[0]
            rows, widx = rows[sel], widx[sel]
            pd, pe, pu = pd[sel], pe[sel], pu[sel]
            nparts, consumed = nparts[sel], consumed[sel]
            if rows.size == 0:
                continue
        # 5. apply accepted splits
        _apply_splits(state, rows, widx, pd, pe, pu, nparts, consumed)
        if record is not None:
            record(rows, state.arr[rows, :int(state.m[rows].max()), state.F_CYC].max(axis=1),
                   state.lat_sum[rows] + state.tail[rows])


# ---------------------------------------------------------------------------
# Public engine API
# ---------------------------------------------------------------------------

_TRAJ_CONFIG = {"H1": ("mono", 1), "H2": ("mono", 2), "H3": ("bi", 2), "H4": ("bi", 1)}


def batched_trajectories(code: str, batch, backend: str = "numpy") -> list:
    """Per-problem (period, latency) exhaustion trajectories — the batched
    ``split_trajectory`` (see its docstring for why one run covers every
    period bound).  Returns a list of B trajectories."""
    if code not in _TRAJ_CONFIG:
        raise KeyError(f"trajectories are for fixed-period heuristics, not {code}")
    return batched_trajectory_sets([code], batch, backend)[code]


def batched_trajectory_sets(codes, batch, backend: str = "numpy") -> dict:
    """Trajectories for several heuristic codes in as few lockstep runs as
    possible: codes sharing a split arity (H1+H4 2-way, H2+H3 3-way) run
    TOGETHER as extra batch rows distinguished only by their per-row choice
    mode.  Returns {code: [trajectory per problem]}."""
    pb = _as_problem_batch(batch)
    B = pb.B
    out = {}
    by_k: dict = {}
    for code in codes:
        mode, k = _TRAJ_CONFIG[code]
        by_k.setdefault(k, []).append((code, mode))
    for k, group in by_k.items():
        tiled = pb if len(group) == 1 else pb.take(np.tile(np.arange(B), len(group)))
        bi_mode = np.concatenate([np.full(B, mode == "bi") for _, mode in group])
        st = _BatchState(tiled)
        trajs = [[(float(p), float(l))] for p, l in zip(st.period(), st.latency())]

        def rec(rows, pers, lats):
            for i, p, l in zip(rows, pers, lats):
                trajs[i].append((float(p), float(l)))

        _run_loop(st, k, bi_mode, np.full(tiled.B, -np.inf),
                  np.full(tiled.B, np.inf), backend, record=rec)
        for gi, (code, _) in enumerate(group):
            out[code] = trajs[gi * B:(gi + 1) * B]
    return out


_FIXED_LAT = {"H5": ("mono", "Sp mono L"), "H6": ("bi", "Sp bi L")}


def _fixed_latency_state(code: str, pb: ProblemBatch, bounds: np.ndarray,
                         backend: str):
    """Run the H5/H6 splitting loop; returns (state, initially_failed mask)."""
    bi_mode = np.full(pb.B, _FIXED_LAT[code][0] == "bi")
    st = _BatchState(pb)
    failed = st.latency() > bounds + _EPS
    st.active[failed] = False
    _run_loop(st, 1, bi_mode, np.full(pb.B, -np.inf), bounds, backend)
    return st, failed


def batched_fixed_latency(code: str, batch, bounds, backend: str = "numpy") -> list:
    """H5/H6 (min period s.t. latency <= bound) for B problems at once, each
    with its own bound — a whole (instance x bound-grid) campaign in one
    lockstep pass.  Returns per-problem HeuristicResults identical to
    ``sp_mono_l``/``sp_bi_l``."""
    pb = _as_problem_batch(batch)
    bounds = np.asarray(bounds, dtype=float)
    name = _FIXED_LAT[code][1]
    st, failed = _fixed_latency_state(code, pb, bounds, backend)
    per, lat = st.period(), st.latency()
    return [HeuristicResult.failure(name) if failed[i]
            else HeuristicResult(st.mapping(i), float(per[i]), float(lat[i]),
                                 True, int(st.splits[i]), name)
            for i in range(pb.B)]


# Strategy order mirrors heuristics.min_period_exhaustive: (name, arity, bi)
_MIN_PERIOD_STRATEGIES = (
    ("Sp mono L", 1, False),
    ("Sp bi L", 1, True),
    ("3-Explo mono", 2, False),
    ("3-Explo bi", 2, True),
)


def batched_min_period(batch, backend: str = "numpy") -> list:
    """Unbounded min-period portfolio for B problems at once — the batched
    ``heuristics.min_period_exhaustive``.

    Two lockstep runs cover all four exhaustion strategies: each run tiles the
    batch x2 with per-row choice mode (mono rows then bi rows), one run per
    split arity.  The per-problem winner is the lexicographically smallest
    (period, latency, strategy order), with the same strict float comparisons
    as the scalar tuple-min — so every returned float and mapping is
    bit-identical to the scalar portfolio (asserted in tests/test_fleet.py).
    This is the fleet replanning service's solve primitive.
    """
    pb = _as_problem_batch(batch)
    B = pb.B
    rows2 = np.tile(np.arange(B), 2)
    bi_mode = np.concatenate([np.zeros(B, dtype=bool), np.ones(B, dtype=bool)])
    states = []
    for k in (1, 2):
        st = _BatchState(pb.take(rows2))
        _run_loop(st, k, bi_mode, np.full(2 * B, -np.inf),
                  np.full(2 * B, np.inf), backend)
        states.append(st)
    st1, st2 = states
    per1, lat1 = st1.period(), st1.latency()
    per2, lat2 = st2.period(), st2.latency()
    per = np.stack([per1[:B], per1[B:], per2[:B], per2[B:]])   # (4, B)
    lat = np.stack([lat1[:B], lat1[B:], lat2[:B], lat2[B:]])
    strat = np.broadcast_to(np.arange(4)[:, None], per.shape)
    win = np.lexsort((strat, lat, per), axis=0)[0]
    out = []
    for i in range(B):
        wi = int(win[i])
        st = st1 if wi < 2 else st2
        row = i + (wi % 2) * B
        out.append(HeuristicResult(st.mapping(row), float(per[wi, i]),
                                   float(lat[wi, i]), True,
                                   int(st.splits[row]),
                                   _MIN_PERIOD_STRATEGIES[wi][0]))
    return out


def evaluate_state_rows(workloads, platforms, state: "_BatchState",
                        skip=None) -> np.ndarray:
    """(period, latency) of each row's final mapping through the *metrics*
    layer — bit-identical to ``metrics.evaluate(wl, pf, mapping)`` per row
    (same per-interval expressions, including the ``w[d-1:e].sum()`` reduction
    evaluate uses), but without materializing Mapping objects, computing each
    interval's work sum once instead of twice, and reusing the previous row's
    result when it holds the same instance and final mapping (bound grids
    produce long runs of identical outcomes).  Rows with ``skip`` set are
    left as NaN.  Returns (B, 2)."""
    B = state.pb.B
    out = np.full((B, 2), np.nan)
    prev = -1
    for i in range(B):
        if skip is not None and skip[i]:
            continue
        m = int(state.m[i])
        if (prev >= 0 and workloads[i] is workloads[prev]
                and platforms[i] is platforms[prev]
                and int(state.m[prev]) == m
                and np.array_equal(state.arr[i, :m, :3], state.arr[prev, :m, :3])):
            out[i] = out[prev]
            prev = i
            continue
        items = state.items_int(i)
        wl, pf = workloads[i], platforms[i]
        w, delta, b, s = wl.w, wl.delta, pf.b, pf.s
        per = -math.inf
        tot = 0.0
        for t in range(m):
            d, e, a = items[t]
            lat_term = delta[d - 1] / b + w[d - 1:e].sum() / s[a]
            cyc = lat_term + delta[e] / b
            if cyc > per:
                per = cyc
            tot += lat_term
        out[i, 0] = per
        out[i, 1] = tot + delta[wl.n] / b
        prev = i
    return out


def h4_search_bounds(pb: ProblemBatch, groups=None) -> tuple:
    """Initial (lo, hi) authorized-latency bounds of the H4 binary search:
    lo = the optimal latency (all-on-fastest), hi = every stage its own
    interval on the slowest processor — the exact per-row mirror of
    ``sp_bi_p``'s scalar formulas.  Rows sharing a ``groups`` key (same
    instance tiled across a bound grid) compute the bound once.  Shared by
    every bisection flavor (host probe loops, the fused scan, benchmarks),
    so they all provably search the same interval."""
    B = pb.B
    lat_opt = _BatchState(pb).latency()
    if groups is None:
        groups = np.arange(B)
    groups = np.asarray(groups)
    lat_ub = np.empty(B)
    seen: dict = {}
    for i in range(B):            # scalar formulas per row (once per instance)
        gkey = int(groups[i])
        if gkey in seen:
            lat_ub[i] = lat_ub[seen[gkey]]
            continue
        seen[gkey] = i
        s_min = float(pb.s[i].min())
        lat_ub[i] = float(pb.delta[i, :-1].sum() / pb.b
                          + pb.w[i].sum() / s_min
                          + pb.delta[i, -1] / pb.b)
    return lat_opt, np.maximum(lat_ub, lat_opt)


def batched_sp_bi_p(batch, bounds, iters: int = 40, backend: str = "numpy",
                    with_mappings: bool = True, groups=None) -> list:
    """H4 'Sp bi P' for B problems at once: ONE binary search whose every
    bisection step probes all still-searching problems in lockstep, instead
    of B independent searches.  Identical results to ``sp_bi_p``.
    ``with_mappings=False`` skips Mapping materialization (metrics-only
    campaigns).  ``groups`` (optional, metrics-only) marks rows that share an
    instance — probe runs are then deduplicated across each instance's period
    bounds (see ``_sp_bi_p_grouped``)."""
    pb = _as_problem_batch(batch)
    p_fix = np.asarray(bounds, dtype=float)
    B = pb.B
    if groups is None:
        groups = np.arange(B)
    groups = np.asarray(groups)
    lo, hi = h4_search_bounds(pb, groups)
    if backend in ("fused", "sharded") and min(pb.n - 1, pb.p - 1) > 0:
        # the bisection itself is fused (one probe0 + lax.scan program per
        # row-chunk — sharded over the device mesh for backend="sharded");
        # probe-run dedup is pointless when probes are free, so `groups`
        # is ignored — results are identical either way.
        return _sp_bi_p_fused(pb, p_fix, iters, lo, hi, with_mappings,
                              backend)
    if not with_mappings:
        return _sp_bi_p_grouped(pb, p_fix, groups, iters, backend, lo, hi)
    return _sp_bi_p_rowwise(pb, p_fix, iters, backend, lo, hi, with_mappings)


def _sp_bi_p_fused(pb, p_fix, iters, lo, hi, with_mappings,
                   backend: str = "fused"):
    """H4 with the binary search fused into one jitted program per row-chunk
    (:func:`repro.core.fused.run_fused_bisection`, or its ``shard_map`` SPMD
    twin :func:`repro.core.sharded.run_sharded_bisection`): O(1) host
    dispatches per campaign instead of ~iters+1, outputs identical to the
    host-driven probe-loop paths (asserted by
    tests/test_engine_equivalence.py)."""
    if backend == "sharded":
        from . import sharded

        r = sharded.run_sharded_bisection(pb, p_fix, lo, hi, iters)
    else:
        from . import fused

        r = fused.run_fused_bisection(pb, p_fix, lo, hi, iters)
    out = []
    for i in range(pb.B):
        if not r["feas0"][i]:
            mp = (_mapping_from_rows(r["items0"][i], int(r["m0"][i]))
                  if with_mappings else None)
            out.append(HeuristicResult(mp, float(r["per0"][i]),
                                       float(r["lat0"][i]), False,
                                       int(r["sp0"][i]), "Sp bi P"))
        else:
            mp = (_mapping_from_rows(r["items"][i], int(r["m"][i]))
                  if with_mappings else None)
            out.append(HeuristicResult(mp, float(r["per"][i]),
                                       float(r["lat"][i]), True,
                                       int(r["sp"][i]), "Sp bi P"))
    return out


def _sp_bi_p_rowwise(pb, p_fix, iters, backend, lo, hi, with_mappings):
    """One lockstep probe row per (problem): keeps full state for mappings."""
    B = pb.B

    all_bi = np.ones(B, dtype=bool)

    def probe(limits, act):
        st = _BatchState(pb, active=act)
        _run_loop(st, 1, all_bi, p_fix, limits, backend)
        per, lat = st.period(), st.latency()
        feas = (per <= p_fix + _EPS) & (lat <= limits + _EPS)
        return st, per, lat, feas

    # Ensure feasibility at the upper end first.
    st0, per0, lat0, feas0 = probe(hi, np.ones(B, dtype=bool))
    fail_maps = [st0.mapping(i) if with_mappings and not feas0[i] else None
                 for i in range(B)]
    fail_per, fail_lat, fail_splits = per0.copy(), lat0.copy(), st0.splits.copy()
    best_items = st0.arr[:, :, :3].copy()
    best_m, best_splits = st0.m.copy(), st0.splits.copy()
    best_per, best_lat = per0.copy(), lat0.copy()
    alive = feas0.copy()
    for _ in range(iters):
        if not alive.any():
            break
        mid = 0.5 * (lo + hi)
        st, per, lat, feas = probe(mid, alive)
        good = alive & feas
        hi = np.where(good, mid, hi)
        lo = np.where(alive & ~feas, mid, lo)
        better = good & ((lat < best_lat - _EPS) |
                         ((np.abs(lat - best_lat) <= _EPS) & (per < best_per)))
        if better.any():
            best_items[better] = st.arr[better, :, :3]
            best_m[better] = st.m[better]
            best_splits[better] = st.splits[better]
            best_per[better] = per[better]
            best_lat[better] = lat[better]
    out = []
    for i in range(B):
        if not feas0[i]:
            out.append(HeuristicResult(fail_maps[i], float(fail_per[i]),
                                       float(fail_lat[i]), False,
                                       int(fail_splits[i]), "Sp bi P"))
        else:
            mp = (_mapping_from_rows(best_items[i], int(best_m[i]))
                  if with_mappings else None)
            out.append(HeuristicResult(mp, float(best_per[i]), float(best_lat[i]),
                                       True, int(best_splits[i]), "Sp bi P"))
    return out


def _sp_bi_p_grouped(pb, p_fix, groups, iters, backend, lo, hi):
    """Metrics-only H4 with probe-run deduplication.

    A probe's split *choices* never depend on its period stop-bound — only
    the stopping point does (the ``split_trajectory`` argument, applied to
    the latency-limited loop).  So per bisection step, ONE latency-limited
    exhaustion run per unique (instance, latency-limit) pair is recorded as a
    (period, latency)-per-split trajectory, and every period bound sharing
    that pair reads its probe result off the shared trajectory: the first
    state with ``period <= bound + eps`` (or the final state).  Rows of the
    same instance share limits until their feasibility histories diverge, so
    this collapses each instance's whole bound grid into a handful of runs.
    """
    B = pb.B

    def probe(limits, act):
        alive_rows = np.nonzero(act)[0]
        key_arr = np.empty((alive_rows.size, 2), dtype=np.int64)
        key_arr[:, 0] = groups[alive_rows]
        key_arr[:, 1] = limits[alive_rows].view(np.int64)
        uniq, inv = np.unique(key_arr, axis=0, return_inverse=True)
        inv = inv.ravel()
        R = len(uniq)
        exemplar = np.empty(R, dtype=np.int64)
        exemplar[inv[::-1]] = alive_rows[::-1]      # first occurrence wins
        sub = pb.take(exemplar)
        st = _BatchState(sub)
        init_per, init_lat = st.period(), st.latency()
        recs = []
        _run_loop(st, 1, np.ones(R, dtype=bool), np.full(R, -np.inf),
                  limits[exemplar], backend,
                  record=lambda rows, pers, lats: recs.append((rows, pers, lats)))
        # assemble per-run trajectories; step index == split count because an
        # active row accepts a split at every lockstep iteration
        T = len(recs) + 1
        per_tr = np.full((R, T), np.inf)            # +inf padding: never a stop
        lat_tr = np.zeros((R, T))
        lengths = np.ones(R, dtype=np.int64)
        per_tr[:, 0] = init_per
        lat_tr[:, 0] = init_lat
        for s, (rws, pers, lats) in enumerate(recs, start=1):
            per_tr[rws, s] = pers
            lat_tr[rws, s] = lats
            lengths[rws] = s + 1
        # vectorized scan over all dependent rows
        bnd = p_fix[alive_rows] + _EPS
        hit = per_tr[inv] <= bnd[:, None]
        has_hit = hit.any(axis=1)
        t_idx = np.where(has_hit, np.argmax(hit, axis=1), lengths[inv] - 1)
        per = np.empty(B)
        lat = np.empty(B)
        sp = np.zeros(B, dtype=np.int64)
        feas = np.zeros(B, dtype=bool)
        per[alive_rows] = per_tr[inv, t_idx]
        lat[alive_rows] = lat_tr[inv, t_idx]
        sp[alive_rows] = t_idx
        feas[alive_rows] = ((per[alive_rows] <= p_fix[alive_rows] + _EPS)
                            & (lat[alive_rows] <= limits[alive_rows] + _EPS))
        return per, lat, sp, feas

    per0, lat0, sp0, feas0 = probe(hi, np.ones(B, dtype=bool))
    best_per, best_lat, best_sp = per0.copy(), lat0.copy(), sp0.copy()
    alive = feas0.copy()
    for _ in range(iters):
        if not alive.any():
            break
        mid = 0.5 * (lo + hi)
        per, lat, sp, feas = probe(mid, alive)
        good = alive & feas
        hi = np.where(good, mid, hi)
        lo = np.where(alive & ~feas, mid, lo)
        better = good & ((lat < best_lat - _EPS) |
                         ((np.abs(lat - best_lat) <= _EPS) & (per < best_per)))
        best_per[better] = per[better]
        best_lat[better] = lat[better]
        best_sp[better] = sp[better]
    return [HeuristicResult(None, float(per0[i]), float(lat0[i]), False,
                            int(sp0[i]), "Sp bi P") if not feas0[i]
            else HeuristicResult(None, float(best_per[i]), float(best_lat[i]),
                                 True, int(best_sp[i]), "Sp bi P")
            for i in range(B)]
