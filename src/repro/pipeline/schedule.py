"""Pipeline schedules.

The runtime executes a synchronous GPipe-style schedule: with S stages and M
microbatches, tick t has stage s working on microbatch (t - s); total ticks
M + S - 1; bubble fraction (S-1)/(M+S-1).  The paper's period/latency map
directly: steady-state period = max stage cycle time (Eq. 1), fill latency =
sum of stage times along the chain (Eq. 2).
"""

from __future__ import annotations


def gpipe_ticks(num_stages: int, num_microbatches: int) -> int:
    return num_microbatches + num_stages - 1


def stage_microbatch(tick: int, stage: int) -> int:
    """Microbatch index stage ``stage`` works on at ``tick`` (may be out of range)."""
    return tick - stage


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / gpipe_ticks(num_stages, num_microbatches)


def predicted_step_time(plan_period: float, plan_latency: float,
                        num_microbatches: int) -> float:
    """Paper metrics -> pipeline step time: fill (latency) + (M-1) periods."""
    return plan_latency + (num_microbatches - 1) * plan_period
