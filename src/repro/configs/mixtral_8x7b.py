"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        n_experts=8, top_k=2, expert_d_ff=14336,
        sliding_window=4096,
        accum_steps=2,        # fits the 16 GB/chip HBM budget at train_4k
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x7b-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        n_experts=4, top_k=2, expert_d_ff=256,
        sliding_window=32,
    )
