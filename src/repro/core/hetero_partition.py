"""Hetero-1D-Partition (the paper's Definition 1) and the NMWTS reduction.

HETERO-1D-PARTITION: partition n elements a_1..a_n into p intervals and find a
permutation sigma such that max_k sum(I_k)/s_sigma(k) <= K.

This module provides:
 - the problem as a (Workload, Platform) pair with zero communication,
 - the Theorem-1 reduction from Numerical Matching With Target Sums, used by
   the tests to machine-check both directions of the proof construction,
 - a direct checker.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .metrics import Mapping, period
from .platform import Platform
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Hetero1DInstance:
    a: np.ndarray  # element weights
    s: np.ndarray  # prescribed values (processor speeds)
    K: float       # bound

    def as_mapping_problem(self) -> tuple:
        """Theorem 2's conversion: stages w_i = a_i, all delta = 0, b = 1."""
        wl = Workload(np.asarray(self.a, float), np.zeros(len(self.a) + 1), name="hetero1d")
        pf = Platform(np.asarray(self.s, float), 1.0, name="hetero1d")
        return wl, pf

    def check(self, intervals: Sequence, sigma: Sequence[int]) -> bool:
        """Does (intervals, sigma) witness the bound K?  intervals are 1-indexed
        [d,e] pairs covering [1..n]; sigma[k] = processor for interval k."""
        wl, pf = self.as_mapping_problem()
        mp = Mapping(tuple(intervals), tuple(sigma))
        mp.validate(wl.n, pf.p)
        if len(mp.intervals) != pf.p:
            return False  # Definition 1 uses exactly p intervals
        return period(wl, pf, mp) <= self.K + 1e-9


@dataclasses.dataclass(frozen=True)
class NMWTSInstance:
    """Numerical Matching With Target Sums: do permutations sigma1, sigma2 exist
    with x_i + y_sigma1(i) = z_sigma2(i) for all i?"""

    x: np.ndarray
    y: np.ndarray
    z: np.ndarray

    @property
    def m(self) -> int:
        return len(self.x)

    def solve_small(self) -> Optional[tuple]:
        """Brute-force solver for tests (m <= 7). Returns (sigma1, sigma2) or None."""
        import itertools

        m = self.m
        if self.x.sum() + self.y.sum() != self.z.sum():
            return None
        zs = list(self.z)
        for s1 in itertools.permutations(range(m)):
            targets = [self.x[i] + self.y[s1[i]] for i in range(m)]
            # match targets to z values (multiset equality -> greedy by sorting)
            avail = sorted(range(m), key=lambda j: zs[j])
            order = sorted(range(m), key=lambda i: targets[i])
            s2 = [0] * m
            good = True
            for i, j in zip(order, avail):
                if targets[i] != zs[j]:
                    good = False
                    break
                s2[i] = j
            if good:
                return tuple(s1), tuple(s2)
        return None


def reduce_nmwts(inst: NMWTSInstance) -> Hetero1DInstance:
    """The Theorem-1 construction: 3m numbers -> (M+3)m tasks, 3m speeds, K=1."""
    x, y, z = inst.x, inst.y, inst.z
    m = inst.m
    M = int(max(x.max(), y.max(), z.max()))
    B, C, D = 2 * M, 5 * M, 7 * M
    tasks = []
    for i in range(m):
        tasks.append(B + int(x[i]))       # A_i
        tasks.extend([1] * M)             # M unit tasks
        tasks.append(C)
        tasks.append(D)
    speeds = (
        [B + int(z[i]) for i in range(m)]
        + [C + M - int(y[i]) for i in range(m)]
        + [D] * m
    )
    return Hetero1DInstance(np.asarray(tasks, float), np.asarray(speeds, float), K=1.0)


def witness_from_nmwts_solution(
    inst: NMWTSInstance, sigma1: Sequence[int], sigma2: Sequence[int]
) -> tuple:
    """Build the interval mapping used in the 'only if' direction of the proof:
    for each i, A_i plus y_sigma1(i) units -> P_sigma2(i); the remaining
    M - y_sigma1(i) units plus C -> P_{m+sigma1(i)}; D -> P_{2m+i}."""
    m = inst.m
    M = int(max(inst.x.max(), inst.y.max(), inst.z.max()))
    N = M + 3
    intervals = []
    procs = []
    for i in range(m):
        base = i * N  # 0-indexed start of block i
        yv = int(inst.y[sigma1[i]])
        intervals.append((base + 1, base + 1 + yv))            # A_i + yv units
        procs.append(sigma2[i])
        intervals.append((base + 2 + yv, base + N - 1))        # rest units + C
        procs.append(m + sigma1[i])
        intervals.append((base + N, base + N))                 # D
        procs.append(2 * m + i)
    return tuple(intervals), tuple(procs)


def extract_nmwts_solution(inst: NMWTSInstance, hinst: Hetero1DInstance,
                           intervals: Sequence, procs: Sequence[int]) -> Optional[tuple]:
    """The 'if' direction of the proof: given a K=1 witness for the reduced
    instance, recover (sigma1, sigma2).  Returns None if the witness does not
    have the structure forced by the proof (it always should)."""
    m = inst.m
    M = int(max(inst.x.max(), inst.y.max(), inst.z.max()))
    N = M + 3
    sigma1 = [-1] * m
    sigma2 = [-1] * m
    for (d, e), u in zip(intervals, procs):
        # Which block does this interval start in, and what does it contain?
        blk = (d - 1) // N
        start_in_blk = (d - 1) % N
        if start_in_blk == 0:
            # starts with A_blk: must be on some P_sigma2, h units follow
            if u >= m:
                return None
            h = e - d  # number of unit tasks
            sigma2[blk] = u
            # y_sigma1 for this block equals h (proof: y_{sigma1(i)} = h_i)
        elif (e - 1) % N == N - 2:
            # ends with C: processor must be some P_{m+j}
            if not (m <= u < 2 * m):
                return None
            sigma1[blk] = u - m
        elif start_in_blk == N - 1 and d == e:
            if not (2 * m <= u < 3 * m):
                return None
        else:
            return None
    if -1 in sigma1 or -1 in sigma2:
        return None
    return tuple(sigma1), tuple(sigma2)
