from .checkpointer import (Checkpointer, CheckpointManager,
                           atomic_write_bytes, atomic_write_json)

__all__ = ["Checkpointer", "CheckpointManager",
           "atomic_write_bytes", "atomic_write_json"]
