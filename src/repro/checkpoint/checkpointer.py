"""Fault-tolerant checkpointing: sharded npz save/restore with async writes.

Layout per step:
    <dir>/step_000123/
        manifest.json         # pytree structure, shapes, dtypes, step, extras
        shard_00000.npz       # flat leaves (single-host: one shard)
        _COMMITTED            # written LAST — torn checkpoints are ignored

Restart semantics: ``CheckpointManager.restore_latest`` returns the newest
*committed* step; partially-written checkpoints (simulated crash mid-save)
are skipped — this is what the fault-tolerance tests exercise.  Async mode
runs the serialization + write on a background thread so the train loop only
blocks on the previous save (one outstanding write, Orbax-style).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import numpy as np

# jax is imported lazily inside the pytree save/restore paths: the atomic
# write helpers above them are also the commit primitive for the (jax-free)
# fleet journal, which must stay importable without pulling in jax.


def atomic_write_bytes(path, data: bytes, fsync: bool = True) -> None:
    """Crash-safe file write: write to a same-directory temp file, fsync it,
    then atomically rename over the destination — a reader never observes a
    torn file, only the old bytes or the new bytes.  This is the commit
    primitive under both the training checkpoints here and the fleet
    replanning service's snapshots (:mod:`repro.fleet.journal`)."""
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as fh:
        fh.write(data)
        if fsync:
            fh.flush()
            os.fsync(fh.fileno())
    os.replace(tmp, path)


def atomic_write_json(path, obj, fsync: bool = True) -> None:
    """``atomic_write_bytes`` for a JSON-serializable object."""
    atomic_write_bytes(path, json.dumps(obj).encode(), fsync=fsync)


def _flatten(tree) -> tuple:
    import jax

    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    """Low-level save/restore of one pytree."""

    def save(self, path: pathlib.Path, tree: Any, step: int,
             extras: Optional[dict] = None) -> None:
        path = pathlib.Path(path)
        tmp = path.with_name(path.name + ".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten(tree)
        arrays = [np.asarray(l) for l in leaves]
        # numpy cannot serialize ml_dtypes (bfloat16 etc.) natively: store a
        # byte view and record the logical dtype in the manifest.
        stored = []
        for a in arrays:
            if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
                stored.append(a.view(np.uint8))
            else:
                stored.append(a)
        np.savez(tmp / "shard_00000.npz",
                 **{f"leaf_{i}": a for i, a in enumerate(stored)})
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(arrays),
            "shapes": [list(a.shape) for a in arrays],
            "dtypes": [str(a.dtype) for a in arrays],
            "extras": extras or {},
        }
        atomic_write_json(tmp / "manifest.json", manifest)
        atomic_write_bytes(tmp / "_COMMITTED", b"ok")
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)

    def restore(self, path: pathlib.Path, like: Any) -> tuple:
        """Restore into the structure of ``like``.  Returns (tree, manifest)."""
        path = pathlib.Path(path)
        if not (path / "_COMMITTED").exists():
            raise FileNotFoundError(f"checkpoint at {path} is not committed")
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "shard_00000.npz")
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        like_leaves, treedef = _flatten(like)
        if len(like_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, expected {len(like_leaves)}")
        import jax

        restored = []
        for i, (got, want) in enumerate(zip(leaves, like_leaves)):
            arr = np.asarray(got)
            dtype_str = manifest["dtypes"][i]
            shape = tuple(manifest["shapes"][i])
            if arr.dtype == np.uint8 and dtype_str not in ("uint8",):
                # byte view of an ml_dtypes array: view it back
                import ml_dtypes

                dt = np.dtype(getattr(ml_dtypes, dtype_str))
                arr = arr.view(dt).reshape(shape)
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(f"shape mismatch {arr.shape} vs {want.shape}")
            restored.append(arr.astype(want.dtype) if hasattr(want, "dtype") else arr)
        return jax.tree.unflatten(treedef, restored), manifest


class CheckpointManager:
    """Step-indexed checkpoint directory with retention + async save."""

    def __init__(self, directory, max_to_keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._ckpt = Checkpointer()
        self._pending: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:08d}"

    def steps(self) -> list:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "_COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree: Any, extras: Optional[dict] = None) -> None:
        import jax

        self.wait()  # at most one outstanding async write
        # Materialize device arrays on the calling thread (cheap: host copies)
        host_tree = jax.tree.map(np.asarray, tree)

        def do():
            self._ckpt.save(self._step_dir(step), host_tree, step, extras)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=do, daemon=True)
            self._pending.start()
        else:
            do()

    def restore_latest(self, like: Any) -> Optional[tuple]:
        """(tree, manifest) of the newest committed step, or None."""
        steps = self.steps()
        if not steps:
            return None
        return self._ckpt.restore(self._step_dir(steps[-1]), like)

    def restore(self, step: int, like: Any) -> tuple:
        return self._ckpt.restore(self._step_dir(step), like)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
