"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS *before* calling it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests, elastic re-meshing)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axis_size(mesh) -> int:
    out = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            out *= mesh.shape[a]
    return out


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
