"""The paper's six polynomial bi-criteria heuristics (Section 4).

All heuristics sort processors by non-increasing speed and start from the
optimal-latency solution: every stage on the fastest processor.  They then
repeatedly *split* the interval of the used processor with the largest cycle
time, enrolling the next fastest unused processor(s).

Fixed-period family (minimize latency under ``period <= P_fix``):
  - ``sp_mono_p``  (H1)  greedy split, mono-criterion choice
  - ``explo3_mono`` (H2) 3-way split, mono-criterion choice
  - ``explo3_bi``  (H3)  3-way split, bi-criteria (min max dLat/dPer) choice
  - ``sp_bi_p``    (H4)  binary search on authorized latency + bi-criteria split

Fixed-latency family (minimize period under ``latency <= L_fix``):
  - ``sp_mono_l``  (H5)  greedy split, mono-criterion choice
  - ``sp_bi_l``    (H6)  bi-criteria choice

Numbering follows the paper's Table 1 (H5/H6 share failure thresholds because
both fail exactly when ``L_fix`` is below the optimal latency).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from .metrics import Mapping, latency, period
from .platform import Platform
from .workload import Workload

_EPS = 1e-12


@dataclasses.dataclass
class HeuristicResult:
    """Outcome of one heuristic run."""

    mapping: Optional[Mapping]
    period: float
    latency: float
    feasible: bool          # constraint satisfied?
    splits: int             # number of accepted splits
    name: str

    @classmethod
    def failure(cls, name: str) -> "HeuristicResult":
        return cls(None, math.inf, math.inf, False, 0, name)


class _State:
    """Mutable interval mapping state shared by all heuristics."""

    force_reference = False  # class-wide switch: use generator candidate paths

    def __init__(self, workload: Workload, platform: Platform):
        self.wl = workload
        self.pf = platform
        self.order = platform.sorted_indices()   # processors, fastest first
        self.next_idx = 1                        # next unused processor in `order`
        fastest = int(self.order[0])
        # items: list of [d, e, proc], 1-indexed inclusive intervals, chain order.
        self.items: list = [[1, workload.n, fastest]]
        self._prefix = workload.prefix_w()
        # Incrementally-maintained metrics: one cycle time and one latency term
        # per item, plus the running latency sum.  ``replace`` keeps these in
        # sync (O(parts) per accepted split) so the splitting loop never
        # recomputes cycles()/latency() over all intervals per iteration.
        t0 = self.latency_term(1, workload.n, fastest)
        self._cycles: list = [self.cycle(1, workload.n, fastest)]
        self._lat_terms: list = [t0]
        self._lat_sum = t0
        self._tail = workload.delta[workload.n] / platform.b

    # -- elementary quantities ------------------------------------------------
    def interval_w(self, d: int, e: int) -> float:
        return self._prefix[e] - self._prefix[d - 1]

    def cycle(self, d: int, e: int, proc: int) -> float:
        wl, pf = self.wl, self.pf
        return wl.delta[d - 1] / pf.b + self.interval_w(d, e) / pf.s[proc] + wl.delta[e] / pf.b

    def cycles(self) -> np.ndarray:
        return np.asarray(self._cycles)

    def period(self) -> float:
        return float(max(self._cycles))

    def latency(self) -> float:
        return float(self._lat_sum + self._tail)

    def latency_term(self, d: int, e: int, proc: int) -> float:
        """This interval's contribution to Eq. (2) (input comm + compute)."""
        return self.wl.delta[d - 1] / self.pf.b + self.interval_w(d, e) / self.pf.s[proc]

    def worst_index(self) -> int:
        return self._cycles.index(max(self._cycles))

    def peek_procs(self, k: int) -> Optional[list]:
        """The next k fastest unused processors, or None if fewer remain."""
        if self.next_idx + k > len(self.order):
            return None
        return [int(self.order[self.next_idx + i]) for i in range(k)]

    def consume_procs(self, k: int) -> None:
        self.next_idx += k

    def replace(self, idx: int, parts: list) -> None:
        self.items[idx : idx + 1] = [list(p) for p in parts]
        new_terms = [self.latency_term(d, e, u) for d, e, u in parts]
        new_cycles = [self.cycle(d, e, u) for d, e, u in parts]
        add = 0.0
        for t in new_terms:
            add += t
        self._lat_sum = self._lat_sum - self._lat_terms[idx] + add
        self._lat_terms[idx : idx + 1] = new_terms
        self._cycles[idx : idx + 1] = new_cycles

    def mapping(self) -> Mapping:
        return Mapping(
            intervals=tuple((d, e) for d, e, _ in self.items),
            alloc=tuple(u for _, _, u in self.items),
        )

    def result(self, name: str, feasible: bool, splits: int) -> HeuristicResult:
        return HeuristicResult(self.mapping(), self.period(), self.latency(), feasible, splits, name)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def _two_way_candidates(st: _State, idx: int, jp: int):
    """All 2-way splits of item idx using new processor jp.

    Yields (parts, new_cycles, d_latency): parts = [(d,c,pa),(c+1,e,pb)] for
    every cut c and both placements, new_cycles their cycle times, d_latency
    the global latency delta of applying the split.
    """
    d, e, j = st.items[idx]
    base_lat_term = st.latency_term(d, e, j)
    for c in range(d, e):
        for pa, pb in ((j, jp), (jp, j)):
            parts = [(d, c, pa), (c + 1, e, pb)]
            cyc = [st.cycle(*p) for p in parts]
            dlat = sum(st.latency_term(*p) for p in parts) - base_lat_term
            yield parts, cyc, dlat


def _three_way_candidates(st: _State, idx: int, jp: int, jpp: int):
    """All 3-way splits of item idx over processors {j, jp, jpp} (all 6 perms).

    Falls back to 2-way splits over the same processor choices when the
    interval has only 2 stages (a 3-way split needs >= 3 stages).
    """
    import itertools

    d, e, j = st.items[idx]
    base_lat_term = st.latency_term(d, e, j)
    if e - d + 1 >= 3:
        for c1 in range(d, e - 1):
            for c2 in range(c1 + 1, e):
                spans = [(d, c1), (c1 + 1, c2), (c2 + 1, e)]
                for perm in itertools.permutations((j, jp, jpp)):
                    parts = [(s0, s1, u) for (s0, s1), u in zip(spans, perm)]
                    cyc = [st.cycle(*p) for p in parts]
                    dlat = sum(st.latency_term(*p) for p in parts) - base_lat_term
                    yield parts, cyc, dlat
    elif e - d + 1 == 2:
        spans = [(d, d), (d + 1, e)]
        for pa, pb in itertools.permutations((j, jp, jpp), 2):
            parts = [(spans[0][0], spans[0][1], pa), (spans[1][0], spans[1][1], pb)]
            cyc = [st.cycle(*p) for p in parts]
            dlat = sum(st.latency_term(*p) for p in parts) - base_lat_term
            yield parts, cyc, dlat


def _pick_mono(candidates, old_cycle: float, lat_limit: float, cur_lat: float):
    """Mono-criterion choice: min over candidates of max(new cycles), only among
    strictly improving candidates (max new cycle < old cycle) whose resulting
    latency respects lat_limit.  Ties broken by latency delta, then shape."""
    best = None
    best_key = None
    for parts, cyc, dlat in candidates:
        mx = max(cyc)
        if mx >= old_cycle - _EPS:
            continue
        if cur_lat + dlat > lat_limit + _EPS:
            continue
        key = (mx, dlat, parts[0][1])
        if best_key is None or key < best_key:
            best, best_key = (parts, cyc, dlat), key
    return best


def _pick_bi(candidates, old_cycle: float, lat_limit: float, cur_lat: float):
    """Bi-criteria choice: min over candidates of max_i dLatency/dPeriod(i)
    (paper's ratio), among improving candidates respecting lat_limit."""
    best = None
    best_key = None
    for parts, cyc, dlat in candidates:
        mx = max(cyc)
        if mx >= old_cycle - _EPS:
            continue
        if cur_lat + dlat > lat_limit + _EPS:
            continue
        # dPeriod(i) = old worst cycle - new cycle of processor i; all > 0 here.
        ratio = max(dlat / max(old_cycle - c, _EPS) for c in cyc)
        key = (ratio, mx, parts[0][1])
        if best_key is None or key < best_key:
            best, best_key = (parts, cyc, dlat), key
    return best


# ---------------------------------------------------------------------------
# Shared scoring kernels — the arithmetic core of the fast paths, written
# shape-agnostically (leading batch dimensions broadcast) so the scalar path
# below and the batched campaign engine (:mod:`repro.core.batched`) evaluate
# candidates through the *same* code and cannot drift.  Pure elementwise array
# math + concatenate/sum/max, hence jax.jit-able with ``xp=jax.numpy``.
# ---------------------------------------------------------------------------

def score_2way_kernel(pre_d1, pre_C, pre_e, delta_d1, delta_C, delta_e, b,
                      inv_j, inv_p, xp=np, zero=0.0):
    """Cycle times and latency delta of every 2-way split of interval [d, e].

    ``pre_C``/``delta_C`` hold the prefix-sum and delta values at the cut
    points along the last axis; scalars (or per-row columns, batched) for the
    interval ends.  Returns ``(cyc1, cyc2, dlat)`` with the two placement
    orders concatenated along the last axis: first all cuts with the original
    processor ``j`` on the first part, then all cuts with ``j`` and the new
    processor ``jp`` swapped.

    ``zero`` exists for the traced backends: every product feeding an add is
    written ``(a * b + zero)`` so that when XLA contracts it to an FMA the
    contraction is ``fma(a, b, 0) == round(a * b)`` — the separately-rounded
    product numpy computes — instead of a single-rounded ``fma(a, b, c)``
    that would drift from the numpy reference by an ulp.  Callers under jit
    pass a *runtime* zero scalar (a traced argument cannot be folded away);
    for numpy ``x + 0.0`` is exact, so the default changes nothing.
    """
    W1 = pre_C - pre_d1
    W2 = pre_e - pre_C
    dIn = delta_d1 / b
    dMid = delta_C / b
    dOut = delta_e / b
    # order A: first part on j, second on jp; order B: swapped.
    cyc1 = xp.concatenate([dIn + (W1 * inv_j + zero) + dMid,
                           dIn + (W1 * inv_p + zero) + dMid], axis=-1)
    cyc2 = xp.concatenate([dMid + (W2 * inv_p + zero) + dOut,
                           dMid + (W2 * inv_j + zero) + dOut], axis=-1)
    dlat = xp.concatenate([dMid + (W2 * (inv_p - inv_j) + zero),
                           dMid + (W1 * (inv_p - inv_j) + zero)], axis=-1)
    return cyc1, cyc2, dlat


def score_kernels(impl: str = "numpy"):
    """Shared selection interface for the split-scoring kernels: returns
    ``(score2, score3)`` callables with the ``score_2way_kernel`` /
    ``score_3way_kernel`` calling convention for the named implementation.

      - ``"numpy"`` — the bit-exact reference (plain elementwise numpy).
      - ``"jax"``   — the same kernels under ``jax.jit`` (x64, runtime-zero
        FMA guard), bit-identical to numpy.
      - ``"pallas"`` — real ``pl.pallas_call`` kernels with BlockSpec tiling
        and ``pl.when`` masked tiles (:mod:`repro.kernels.split_score`);
        interpret-mode on CPU (bit-identical on live lanes), compiled on
        TPU/GPU.  Accepts an extra per-row ``need`` kwarg (live-lane bound)
        so out-of-band tiles skip compute.

    Every engine backend (``repro.core.batched._Backend``) resolves its
    kernels through this function, so scalar/numpy/jax/fused/pallas cannot
    drift apart at the arithmetic core.
    """
    import functools

    if impl == "numpy":
        return (functools.partial(score_2way_kernel, xp=np),
                functools.partial(score_3way_kernel, xp=np))
    if impl == "jax":
        import jax

        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp

        # zero is passed as a *runtime* scalar so the kernels' FMA guard
        # survives XLA constant folding (see score_2way_kernel docstring)
        j2 = jax.jit(functools.partial(score_2way_kernel, xp=jnp))
        j3 = jax.jit(functools.partial(score_3way_kernel, xp=jnp))
        zero = np.float64(0.0)
        return (lambda *a, **k: j2(*a, zero=zero, **k),
                lambda *a, **k: j3(*a, zero=zero, **k))
    if impl == "pallas":
        import jax

        jax.config.update("jax_enable_x64", True)
        from ..kernels.split_score import score_2way_pallas, score_3way_pallas

        zero = np.float64(0.0)
        return (functools.partial(score_2way_pallas, zero=zero),
                functools.partial(score_3way_pallas, zero=zero))
    raise ValueError(f"unknown kernel implementation {impl!r}; "
                     "use 'numpy', 'jax', or 'pallas'")


def score_3way_kernel(dI, W, dO, invp, base_term, xp=np, zero=0.0):
    """Cycle times, latency delta, and max cycle of 3-way splits for ONE
    processor permutation.  ``dI``/``W``/``dO``/``invp`` carry the three parts
    on axis -2 and the (c1, c2) cut pairs on axis -1; ``base_term`` is the
    replaced interval's latency term.  Returns ``(cyc, dlat, mx)``.

    ``zero`` is the traced-backend FMA guard (see ``score_2way_kernel``);
    the part sum is spelled as left-associated adds so traced reductions
    keep numpy's element order (numpy sums 3 elements as ``(c0 + c1) + c2``).
    """
    comp = dI + (W * invp + zero)
    cyc = comp + dO
    dlat = (comp[..., 0, :] + comp[..., 1, :] + comp[..., 2, :]) - base_term
    mx = cyc.max(axis=-2)
    return cyc, dlat, mx


# ---------------------------------------------------------------------------
# Vectorized fast paths (numpy) — bit-identical to the generator versions,
# asserted by tests/test_heuristics.py::test_fast_paths_match_reference.
# ---------------------------------------------------------------------------

def _best_split_2way_fast(st: _State, idx: int, jp: int, mode: str,
                          old_cycle: float, lat_limit: float, cur_lat: float):
    d, e, j = st.items[idx]
    if e == d:
        return None
    pre, delta, b, s = st._prefix, st.wl.delta, st.pf.b, st.pf.s
    C = np.arange(d, e)                       # cut points
    cyc1, cyc2, dlat = score_2way_kernel(
        pre[d - 1], pre[C], pre[e], delta[d - 1], delta[C], delta[e], b,
        1.0 / s[j], 1.0 / s[jp])
    cuts = np.concatenate([C, C])
    order = np.concatenate([np.zeros(len(C)), np.ones(len(C))])
    mx = np.maximum(cyc1, cyc2)
    okay = (mx < old_cycle - _EPS) & (cur_lat + dlat <= lat_limit + _EPS)
    if not okay.any():
        return None
    idxs = np.nonzero(okay)[0]
    if mode == "mono":
        keys = (mx[idxs], dlat[idxs], cuts[idxs], order[idxs])
    else:
        den1 = np.maximum(old_cycle - cyc1[idxs], _EPS)
        den2 = np.maximum(old_cycle - cyc2[idxs], _EPS)
        ratio = np.maximum(dlat[idxs] / den1, dlat[idxs] / den2)
        keys = (ratio, mx[idxs], cuts[idxs], order[idxs])
    best = idxs[np.lexsort(keys[::-1])[0]]
    c = int(cuts[best])
    if order[best] == 0:
        parts = [(d, c, j), (c + 1, e, jp)]
    else:
        parts = [(d, c, jp), (c + 1, e, j)]
    return parts, [float(cyc1[best]), float(cyc2[best])], float(dlat[best])


_PERMS3 = [(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1), (2, 1, 0)]


def _best_split_3way_fast(st: _State, idx: int, jp: int, jpp: int, mode: str,
                          old_cycle: float, lat_limit: float, cur_lat: float):
    d, e, j = st.items[idx]
    if e - d + 1 < 3:
        # fall back to the generator for the 2-stage case (cheap)
        cands = _three_way_candidates(st, idx, jp, jpp)
        pick = _pick_mono if mode == "mono" else _pick_bi
        return pick(cands, old_cycle, lat_limit, cur_lat)
    pre, delta, b, s = st._prefix, st.wl.delta, st.pf.b, st.pf.s
    procs = np.array([j, jp, jpp])
    inv = 1.0 / s[procs]
    c1, c2 = np.meshgrid(np.arange(d, e - 1), np.arange(d + 1, e), indexing="ij")
    valid = c2 > c1
    c1, c2 = c1[valid], c2[valid]
    W = np.stack([pre[c1] - pre[d - 1], pre[c2] - pre[c1], pre[e] - pre[c2]])   # (3, K)
    dI = np.stack([np.full_like(c1, delta[d - 1], dtype=float), delta[c1], delta[c2]]) / b
    dO = np.stack([delta[c1], delta[c2], np.full_like(c1, delta[e], dtype=float)]) / b
    base_term = delta[d - 1] / b + (pre[e] - pre[d - 1]) / s[j]
    best_choice, best_key = None, None
    for pi, perm in enumerate(_PERMS3):
        invp = inv[list(perm)][:, None]                                          # (3, 1)
        cyc, dlat, mx = score_3way_kernel(dI, W, dO, invp, base_term)           # (3, K)
        okay = (mx < old_cycle - _EPS) & (cur_lat + dlat <= lat_limit + _EPS)
        if not okay.any():
            continue
        ix = np.nonzero(okay)[0]
        if mode == "mono":
            keys = (mx[ix], dlat[ix], c1[ix].astype(float), c2[ix].astype(float))
        else:
            ratio = (dlat[ix] / np.maximum(old_cycle - cyc[:, ix], _EPS)).max(axis=0)
            keys = (ratio, mx[ix], c1[ix].astype(float), c2[ix].astype(float))
        o = ix[np.lexsort(keys[::-1])[0]]
        key = tuple(float(k[np.lexsort(keys[::-1])[0]]) for k in keys) + (pi,)
        if best_key is None or key < best_key:
            u = [procs[q] for q in perm]
            spans = [(d, int(c1[o])), (int(c1[o]) + 1, int(c2[o])), (int(c2[o]) + 1, e)]
            parts = [(s0, s1, int(uu)) for (s0, s1), uu in zip(spans, u)]
            cycv = [float(v) for v in cyc[:, o]]
            best_choice, best_key = (parts, cycv, float(dlat[o])), key
    return best_choice


# ---------------------------------------------------------------------------
# Generic splitting loop
# ---------------------------------------------------------------------------

def _splitting_loop(
    st: _State,
    *,
    n_new_procs: int,
    gen_candidates: Callable,
    pick: Callable,
    stop_when_period_leq: float = -math.inf,
    lat_limit: float = math.inf,
    on_split: Optional[Callable] = None,
) -> int:
    """Run the paper's splitting loop on state ``st``.

    Repeatedly: if the current period already satisfies ``stop_when_period_leq``
    stop; otherwise split the worst interval using the next ``n_new_procs``
    fastest unused processors, choosing the candidate with ``pick``.  Stops
    when stuck (no improving candidate / no processors / single-stage worst
    interval).  Returns the number of accepted splits.

    ``pick``/``gen_candidates`` identify the strategy; the loop dispatches to
    the vectorized fast paths (identical results, see tests) unless
    ``st.force_reference`` is set.  ``on_split(st)``, when given, is invoked
    after every accepted split (trajectory recording).
    """
    mode = "mono" if pick is _pick_mono else "bi"
    fast = not getattr(st, "force_reference", False)
    splits = 0
    while True:
        if st.period() <= stop_when_period_leq + _EPS:
            break
        idx = st.worst_index()
        d, e, j = st.items[idx]
        if e == d:  # single stage: cannot split
            break
        new_procs = st.peek_procs(n_new_procs)
        if new_procs is None:
            break
        old_cycle = st.cycle(d, e, j)
        cur_lat = st.latency()
        if fast and n_new_procs == 1:
            choice = _best_split_2way_fast(st, idx, new_procs[0], mode, old_cycle, lat_limit, cur_lat)
        elif fast and n_new_procs == 2:
            choice = _best_split_3way_fast(st, idx, new_procs[0], new_procs[1], mode,
                                           old_cycle, lat_limit, cur_lat)
        else:
            choice = pick(gen_candidates(st, idx, *new_procs), old_cycle, lat_limit, cur_lat)
        if choice is None:
            break
        parts, _, _ = choice
        st.replace(idx, parts)
        # Only consume the processors actually enrolled (a 3-way fallback on a
        # 2-stage interval may use just one of the pair).
        used = {u for _, _, u in parts} - {j}
        st.consume_procs(n_new_procs if len(used) == n_new_procs else len(used))
        splits += 1
        if on_split is not None:
            on_split(st)
    return splits


# ---------------------------------------------------------------------------
# Fixed-period heuristics (minimize latency s.t. period <= P_fix)
# ---------------------------------------------------------------------------

def sp_mono_p(workload: Workload, platform: Platform, p_fix: float) -> HeuristicResult:
    """H1 'Sp mono P': greedy mono-criterion splitting until period <= p_fix."""
    st = _State(workload, platform)
    splits = _splitting_loop(
        st, n_new_procs=1, gen_candidates=_two_way_candidates, pick=_pick_mono,
        stop_when_period_leq=p_fix,
    )
    return st.result("Sp mono P", st.period() <= p_fix + _EPS, splits)


def explo3_mono(workload: Workload, platform: Platform, p_fix: float) -> HeuristicResult:
    """H2 '3-Explo mono': 3-way exploration, mono-criterion choice."""
    st = _State(workload, platform)
    splits = _splitting_loop(
        st, n_new_procs=2, gen_candidates=_three_way_candidates, pick=_pick_mono,
        stop_when_period_leq=p_fix,
    )
    return st.result("3-Explo mono", st.period() <= p_fix + _EPS, splits)


def explo3_bi(workload: Workload, platform: Platform, p_fix: float) -> HeuristicResult:
    """H3 '3-Explo bi': 3-way exploration, bi-criteria (dLat/dPer) choice."""
    st = _State(workload, platform)
    splits = _splitting_loop(
        st, n_new_procs=2, gen_candidates=_three_way_candidates, pick=_pick_bi,
        stop_when_period_leq=p_fix,
    )
    return st.result("3-Explo bi", st.period() <= p_fix + _EPS, splits)


def _bi_split_under_latency(workload: Workload, platform: Platform, p_fix: float,
                            lat_limit: float) -> HeuristicResult:
    st = _State(workload, platform)
    splits = _splitting_loop(
        st, n_new_procs=1, gen_candidates=_two_way_candidates, pick=_pick_bi,
        stop_when_period_leq=p_fix, lat_limit=lat_limit,
    )
    feasible = st.period() <= p_fix + _EPS and st.latency() <= lat_limit + _EPS
    return st.result("Sp bi P(inner)", feasible, splits)


def sp_bi_p(workload: Workload, platform: Platform, p_fix: float,
            iters: int = 40) -> HeuristicResult:
    """H4 'Sp bi P': binary search over the authorized latency increase; at each
    probe, bi-criteria splitting constrained to the authorized latency; keep the
    smallest authorized latency that still yields ``period <= p_fix``."""
    lat_opt = _State(workload, platform).latency()
    # Upper bound: every stage its own interval on the slowest processor.
    s_min = float(platform.s.min())
    lat_ub = float(
        workload.delta[:-1].sum() / platform.b
        + workload.total_work / s_min
        + workload.delta[-1] / platform.b
    )
    lo, hi = lat_opt, max(lat_ub, lat_opt)
    best: Optional[HeuristicResult] = None
    # Ensure feasibility at the upper end first.
    probe = _bi_split_under_latency(workload, platform, p_fix, hi)
    if probe.feasible:
        best = probe
    else:
        return HeuristicResult(probe.mapping, probe.period, probe.latency, False, probe.splits, "Sp bi P")
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        probe = _bi_split_under_latency(workload, platform, p_fix, mid)
        if probe.feasible:
            hi = mid
            if probe.latency < best.latency - _EPS or (
                abs(probe.latency - best.latency) <= _EPS and probe.period < best.period
            ):
                best = probe
        else:
            lo = mid
    return HeuristicResult(best.mapping, best.period, best.latency, True, best.splits, "Sp bi P")


# ---------------------------------------------------------------------------
# Fixed-latency heuristics (minimize period s.t. latency <= L_fix)
# ---------------------------------------------------------------------------

def sp_mono_l(workload: Workload, platform: Platform, l_fix: float) -> HeuristicResult:
    """H5 'Sp mono L': greedy mono-criterion splitting while latency <= l_fix."""
    st = _State(workload, platform)
    if st.latency() > l_fix + _EPS:
        return HeuristicResult.failure("Sp mono L")
    splits = _splitting_loop(
        st, n_new_procs=1, gen_candidates=_two_way_candidates, pick=_pick_mono,
        lat_limit=l_fix,
    )
    return st.result("Sp mono L", True, splits)


def sp_bi_l(workload: Workload, platform: Platform, l_fix: float) -> HeuristicResult:
    """H6 'Sp bi L': bi-criteria splitting while latency <= l_fix."""
    st = _State(workload, platform)
    if st.latency() > l_fix + _EPS:
        return HeuristicResult.failure("Sp bi L")
    splits = _splitting_loop(
        st, n_new_procs=1, gen_candidates=_two_way_candidates, pick=_pick_bi,
        lat_limit=l_fix,
    )
    return st.result("Sp bi L", True, splits)


def min_period_exhaustive(workload: Workload, platform: Platform) -> HeuristicResult:
    """Unbounded min-period portfolio: every splitting strategy run to
    exhaustion, best result wins.

    With no latency constraint the paper's six heuristics collapse to four
    distinct exhaustion runs: H1 and H5 are the same 2-way/mono loop once the
    period stop-bound is unreachable and the latency limit is infinite, H6
    and H4's inner splitter (at unbounded authorized latency) are the 2-way/bi
    loop, and H2/H3 are the 3-way runs.  The winner is the lexicographically
    best (period, latency), ties broken by strategy order below — the scalar
    reference for the fleet replanning service's batched solves
    (:func:`repro.core.batched.batched_min_period` is bit-identical)."""
    runs = (
        sp_mono_l(workload, platform, math.inf),      # 2-way mono (H1/H5)
        sp_bi_l(workload, platform, math.inf),        # 2-way bi   (H4/H6)
        explo3_mono(workload, platform, -math.inf),   # 3-way mono (H2)
        explo3_bi(workload, platform, -math.inf),     # 3-way bi   (H3)
    )
    best = min(range(len(runs)),
               key=lambda i: (runs[i].period, runs[i].latency, i))
    r = runs[best]
    # exhaustion runs carry the stop-bound's feasibility flag; the unbounded
    # objective is always satisfied
    return HeuristicResult(r.mapping, r.period, r.latency, True, r.splits, r.name)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

FIXED_PERIOD_HEURISTICS = {
    "H1": sp_mono_p,
    "H2": explo3_mono,
    "H3": explo3_bi,
    "H4": sp_bi_p,
}

FIXED_LATENCY_HEURISTICS = {
    "H5": sp_mono_l,
    "H6": sp_bi_l,
}

NAMES = {
    "H1": "Sp mono P",
    "H2": "3-Explo mono",
    "H3": "3-Explo bi",
    "H4": "Sp bi P",
    "H5": "Sp mono L",
    "H6": "Sp bi L",
}


def split_trajectory(code: str, workload: Workload, platform: Platform) -> list:
    """Run a fixed-period heuristic to exhaustion (bound -inf) and return the
    (period, latency) trajectory: the state after 0, 1, 2, ... accepted splits.

    Because the split choices of H1/H2/H3 do not depend on the period bound
    (only the stopping point does), the result of the heuristic for ANY bound
    P_fix is the first trajectory state with period <= P_fix.  For H4 the
    trajectory of its inner bi-criteria splitter (whose top-of-binary-search
    probe is latency-unconstrained) characterizes feasibility the same way.
    This turns an O(bounds) family of runs into one run — used by the
    simulation harness and the failure-threshold computation.
    """
    st = _State(workload, platform)
    traj = [(st.period(), st.latency())]
    if code == "H1":
        gen, pick, k = _two_way_candidates, _pick_mono, 1
    elif code == "H2":
        gen, pick, k = _three_way_candidates, _pick_mono, 2
    elif code == "H3":
        gen, pick, k = _three_way_candidates, _pick_bi, 2
    elif code == "H4":
        gen, pick, k = _two_way_candidates, _pick_bi, 1
    else:
        raise KeyError(f"trajectories are for fixed-period heuristics, not {code}")
    _splitting_loop(
        st, n_new_procs=k, gen_candidates=gen, pick=pick,
        on_split=lambda s: traj.append((s.period(), s.latency())),
    )
    return traj


import contextlib


@contextlib.contextmanager
def reference_mode():
    """Force the readable generator-based candidate paths (for tests that
    check the vectorized fast paths are behavior-identical)."""
    old = _State.force_reference
    _State.force_reference = True
    try:
        yield
    finally:
        _State.force_reference = old


def run_heuristic(code: str, workload: Workload, platform: Platform, bound: float) -> HeuristicResult:
    if code in FIXED_PERIOD_HEURISTICS:
        return FIXED_PERIOD_HEURISTICS[code](workload, platform, bound)
    if code in FIXED_LATENCY_HEURISTICS:
        return FIXED_LATENCY_HEURISTICS[code](workload, platform, bound)
    raise KeyError(f"unknown heuristic {code!r}")
