"""Interval mappings and the paper's two metrics (Eq. 1 and Eq. 2).

A mapping is a partition of stages [1..n] into m <= p intervals
I_j = [d_j, e_j] (1-indexed, consecutive, covering) together with an
allocation of each interval to a *distinct* processor.

    T_period  = max_j ( delta[d_j-1]/b + sum(w[d_j..e_j])/s_alloc(j) + delta[e_j]/b )
    T_latency = sum_j ( delta[d_j-1]/b + sum(w[d_j..e_j])/s_alloc(j) ) + delta[n]/b

Note the asymmetry, faithful to the paper: the period charges *both* the input
and the output communication of every interval (one-port: each processor both
receives and sends every period), while the latency charges each inter-processor
hand-off once, plus the final output.

The sequel paper (arXiv 0711.1231) adds a third criterion, reliability:
processors fail independently with probability ``Platform.fail[u]``, and an
interval replicated on a *set* of processors survives unless all replicas
fail.  :class:`ReplicatedMapping` models that allocation (disjoint replica
sets), with period/latency charged at the SLOWEST replica of each interval —
the sequel's consensus model, where every replica processes every data set
(contrast the deal/farm extension in :mod:`repro.core.deal`, which
round-robins tasks so a group's aggregate *rate* is the sum of speeds).  The
third metric is

    reliability = prod_j ( 1 − prod_{u ∈ alloc_j} fail_u )

and a single-replica ``ReplicatedMapping`` is bit-identical to the plain
``Mapping`` on period/latency (asserted by tests/test_engine_properties.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .platform import Platform
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Mapping:
    """Interval mapping: intervals[j] = (d_j, e_j) 1-indexed, alloc[j] = processor id."""

    intervals: tuple  # tuple[tuple[int, int], ...]
    alloc: tuple      # tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "intervals", tuple((int(d), int(e)) for d, e in self.intervals))
        object.__setattr__(self, "alloc", tuple(int(a) for a in self.alloc))
        if len(self.intervals) != len(self.alloc):
            raise ValueError("one processor per interval")

    @property
    def m(self) -> int:
        return len(self.intervals)

    def validate(self, n: int, p: int) -> None:
        """Check the partition conditions of the paper (d_1=1, d_{j+1}=e_j+1, e_m=n)
        and that allocated processors are distinct and in range."""
        if self.m == 0:
            raise ValueError("empty mapping")
        if self.m > p:
            raise ValueError(f"more intervals ({self.m}) than processors ({p})")
        d0, _ = self.intervals[0]
        if d0 != 1:
            raise ValueError("first interval must start at stage 1")
        prev_e = 0
        for (d, e) in self.intervals:
            if d != prev_e + 1:
                raise ValueError(f"interval [{d},{e}] does not follow previous end {prev_e}")
            if e < d:
                raise ValueError(f"empty interval [{d},{e}]")
            prev_e = e
        if prev_e != n:
            raise ValueError(f"last interval ends at {prev_e}, expected n={n}")
        if len(set(self.alloc)) != len(self.alloc):
            raise ValueError("processors must be distinct")
        for a in self.alloc:
            if not (0 <= a < p):
                raise ValueError(f"processor {a} out of range")


@dataclasses.dataclass(frozen=True)
class ReplicatedMapping:
    """Interval mapping where interval j runs replicated on the processor SET
    ``groups[j]`` (sets disjoint across intervals).  Consensus model of the
    sequel paper: every replica processes every data set, so the interval's
    compute speed is its slowest replica's, and the interval fails only if
    ALL replicas fail.  ``groups[j][0]`` is the interval's leader (the base
    mapping's processor in the greedy replication solvers)."""

    intervals: tuple  # tuple[tuple[int, int], ...], 1-indexed as in Mapping
    groups: tuple     # tuple[tuple[int, ...], ...] — replica set per interval

    def __post_init__(self):
        object.__setattr__(self, "intervals", tuple((int(d), int(e)) for d, e in self.intervals))
        object.__setattr__(self, "groups", tuple(tuple(int(u) for u in g) for g in self.groups))
        if len(self.intervals) != len(self.groups):
            raise ValueError("one replica set per interval")
        if any(len(g) == 0 for g in self.groups):
            raise ValueError("empty replica set")

    @property
    def m(self) -> int:
        return len(self.intervals)

    @property
    def alloc(self) -> tuple:
        """Leader processor per interval (first replica)."""
        return tuple(g[0] for g in self.groups)

    def leader_mapping(self) -> Mapping:
        """The plain (non-replicated) mapping of the group leaders."""
        return Mapping(intervals=self.intervals, alloc=self.alloc)

    def validate(self, n: int, p: int) -> None:
        """Partition conditions of Mapping.validate plus global disjointness
        of the replica sets."""
        self.leader_mapping().validate(n, p)
        flat = [u for g in self.groups for u in g]
        if len(set(flat)) != len(flat):
            raise ValueError("replica sets must be disjoint")
        for u in flat:
            if not (0 <= u < p):
                raise ValueError(f"processor {u} out of range")


def _interval_speeds(platform: Platform, mapping) -> np.ndarray:
    """Per-interval effective compute speed: the allocated processor's speed
    for a Mapping; the slowest replica's (consensus model) for a
    ReplicatedMapping.  A singleton replica set yields exactly the leader's
    speed, keeping the degenerate case bit-identical to the plain path."""
    if isinstance(mapping, ReplicatedMapping):
        s = platform.s
        return np.array([s[list(g)].min() for g in mapping.groups])
    return platform.s[np.asarray(mapping.alloc, dtype=np.int64)]


def interval_cycle_times(workload: Workload, platform: Platform, mapping) -> np.ndarray:
    """Per-interval cycle time: in-comm + compute + out-comm (the max of these is the period)."""
    w, delta, b = workload.w, workload.delta, platform.b
    sp = _interval_speeds(platform, mapping)
    out = np.empty(mapping.m)
    for j, (d, e) in enumerate(mapping.intervals):
        out[j] = delta[d - 1] / b + w[d - 1 : e].sum() / sp[j] + delta[e] / b
    return out


def period(workload: Workload, platform: Platform, mapping) -> float:
    """Eq. (1)."""
    return float(interval_cycle_times(workload, platform, mapping).max())


def latency(workload: Workload, platform: Platform, mapping) -> float:
    """Eq. (2)."""
    w, delta, b = workload.w, workload.delta, platform.b
    sp = _interval_speeds(platform, mapping)
    tot = 0.0
    for j, (d, e) in enumerate(mapping.intervals):
        tot += delta[d - 1] / b + w[d - 1 : e].sum() / sp[j]
    return float(tot + delta[workload.n] / b)


def reliability(workload: Workload, platform: Platform, mapping) -> float:
    """Sequel metric: R = prod_j (1 − prod_{u ∈ alloc_j} f_u).

    Accepts both Mapping (each interval a single processor) and
    ReplicatedMapping.  With ``platform.fail`` unset every processor is
    perfectly reliable and R == 1.0 exactly."""
    if platform.fail is None:
        return 1.0
    f = platform.fail
    groups = (mapping.groups if isinstance(mapping, ReplicatedMapping)
              else tuple((a,) for a in mapping.alloc))
    r = 1.0
    for g in groups:
        miss = 1.0
        for u in g:
            miss *= float(f[u])
        r *= 1.0 - miss
    return float(r)


def evaluate(workload: Workload, platform: Platform, mapping) -> tuple:
    """(period, latency) for a mapping."""
    return (period(workload, platform, mapping), latency(workload, platform, mapping))


def evaluate_tri(workload: Workload, platform: Platform, mapping) -> tuple:
    """(period, latency, reliability) for a Mapping or ReplicatedMapping."""
    return (period(workload, platform, mapping),
            latency(workload, platform, mapping),
            reliability(workload, platform, mapping))


def evaluate_batch(workload: Workload, platform: Platform,
                   mappings: Sequence[Mapping], *,
                   with_reliability: bool = False) -> np.ndarray:
    """Vectorized ``evaluate`` over a batch of mappings.

    Returns an array of shape (len(mappings), 2): column 0 the period (Eq. 1),
    column 1 the latency (Eq. 2).  With ``with_reliability=True`` a third
    column carries the sequel's reliability metric, so the tri-criteria
    Pareto machinery sees all three criteria in one stacked evaluation.
    Mappings are stacked into (B, m) index arrays per interval count so the
    cycle and latency terms of the whole batch are computed with numpy
    instead of per-mapping Python loops — this is what makes portfolio and
    sweep evaluation cheap.  ReplicatedMapping entries are allowed (their
    compute speed is the group minimum, reliability the survival product).
    """
    out = np.empty((len(mappings), 3 if with_reliability else 2))
    if not len(mappings):
        return out
    pre = workload.prefix_w()
    delta, b, s = workload.delta, platform.b, platform.s
    fail = platform.fail
    tail = delta[workload.n] / b
    by_m: dict = {}
    for i, mp in enumerate(mappings):
        by_m.setdefault(mp.m, []).append(i)
    for idxs in by_m.values():
        iv = np.array([mappings[i].intervals for i in idxs])   # (B, m, 2)
        D, E = iv[:, :, 0], iv[:, :, 1]
        plain = all(not isinstance(mappings[i], ReplicatedMapping) for i in idxs)
        if plain:
            al = np.array([mappings[i].alloc for i in idxs])   # (B, m)
            sp = s[al]
        else:
            sp = np.array([[s[list(g)].min() for g in
                            (mappings[i].groups if isinstance(mappings[i], ReplicatedMapping)
                             else tuple((a,) for a in mappings[i].alloc))]
                           for i in idxs])
        lat_terms = delta[D - 1] / b + (pre[E] - pre[D - 1]) / sp
        cyc = lat_terms + delta[E] / b
        ix = np.asarray(idxs)
        out[ix, 0] = cyc.max(axis=1)
        out[ix, 1] = lat_terms.sum(axis=1) + tail
        if with_reliability:
            if fail is None:
                out[ix, 2] = 1.0
            elif plain:
                out[ix, 2] = np.prod(1.0 - fail[al], axis=1)
            else:
                out[ix, 2] = [reliability(workload, platform, mappings[i])
                              for i in idxs]
    return out


def single_processor_mapping(workload: Workload, proc: int) -> Mapping:
    return Mapping(intervals=((1, workload.n),), alloc=(proc,))


def optimal_latency(workload: Workload, platform: Platform) -> float:
    """Lemma 1: minimum latency = whole chain on the fastest processor."""
    m = single_processor_mapping(workload, platform.fastest())
    return latency(workload, platform, m)


def intervals_from_cuts(n: int, cuts: Sequence[int]) -> tuple:
    """cuts = sorted interior cut points; cut c means a boundary between stage c and c+1.
    Returns the interval tuple for Mapping."""
    prev = 1
    out = []
    for c in cuts:
        out.append((prev, c))
        prev = c + 1
    out.append((prev, n))
    return tuple(out)


def all_interval_partitions(n: int, m: int) -> Iterable[tuple]:
    """Yield every partition of [1..n] into exactly m intervals (as interval tuples)."""
    import itertools

    for cuts in itertools.combinations(range(1, n), m - 1):
        yield intervals_from_cuts(n, cuts)
