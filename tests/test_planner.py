"""Planner API, straggler replanning, elastic scaling."""

import numpy as np
import pytest

from repro.core import (InfeasiblePlan, Objective, Platform, make_platform,
                        make_workload, period, plan, replan_for_straggler,
                        run_heuristic, interval_cycle_times)
from repro.models.common import SHAPES
from repro.models.registry import lm_workload
from repro.configs import get_config
from repro.pipeline.replan import StragglerMonitor, elastic_replan, replan_stages


def test_auto_dominates_single_heuristics():
    rng = np.random.default_rng(0)
    for _ in range(10):
        n, p = int(rng.integers(4, 16)), int(rng.integers(3, 8))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        auto = plan(wl, pf, Objective("period"), mode="auto")
        for code in ("H5", "H6"):
            r = run_heuristic(code, wl, pf, float("inf"))
            if r.feasible:
                assert auto.period <= r.period + 1e-9


def test_infeasible_raises():
    wl = make_workload([10.0], [0, 0])
    pf = make_platform([1.0], 1.0)
    with pytest.raises(InfeasiblePlan):
        plan(wl, pf, Objective("latency", bound=0.001), mode="auto")


def test_arch_workload_plan():
    """Planner runs on a real architecture workload (qwen3-4b, train_4k)."""
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    assert wl.n == cfg.n_layers
    pf = make_platform([1e15, 1e15, 0.5e15, 1e15], b=25e9)   # one slow pod
    p = plan(wl, pf, Objective("period"), mode="auto")
    # the slow pod must get fewer layers than the fastest pods
    sizes_by_proc = dict(zip(p.mapping.alloc, p.stage_sizes))
    if 2 in sizes_by_proc and 0 in sizes_by_proc:
        assert sizes_by_proc[2] <= sizes_by_proc[0]


def test_straggler_replan_improves_period():
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    pf = make_platform([1e15] * 4, b=25e9)
    p0 = plan(wl, pf, Objective("period"), mode="auto")
    # pod serving stage 1 degrades 2x: observed times double there
    predicted = interval_cycle_times(wl, pf, p0.mapping)
    observed = predicted.copy()
    observed[1] *= 2.0
    new_plan, degraded = replan_for_straggler(wl, pf, p0, observed)
    new_pred = interval_cycle_times(wl, degraded, new_plan.mapping)
    old_pred_degraded = interval_cycle_times(wl, degraded, p0.mapping)
    assert new_pred.max() <= old_pred_degraded.max() + 1e-6


def test_straggler_monitor_flags():
    mon = StragglerMonitor(num_stages=3, alpha=1.0, threshold=1.3)
    mon.observe([1.0, 2.9, 1.0])
    assert mon.stragglers([1.0, 2.0, 1.0]) == [1]
    assert mon.stragglers([1.0, 3.0, 1.0]) == []


def test_replan_stages_no_straggler_is_noop():
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    pf = make_platform([1e15] * 4, b=25e9)
    p0 = plan(wl, pf, Objective("period"), mode="auto")
    mon = StragglerMonitor(num_stages=p0.num_stages, alpha=1.0)
    mon.observe(interval_cycle_times(wl, pf, p0.mapping))
    new_plan, _ = replan_stages(wl, pf, p0, mon)
    assert new_plan is None


def test_elastic_replan_changes_pod_count():
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    pf = make_platform([1e15] * 4, b=25e9)
    p8 = elastic_replan(wl, pf, 8)
    assert p8.num_stages <= 8
    p2 = elastic_replan(wl, pf, 2)
    assert p2.num_stages <= 2
