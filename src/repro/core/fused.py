"""Fused device-resident campaign engine: the whole lockstep loop under jit.

The batched engine (:mod:`repro.core.batched`) runs B problems in lockstep,
but only the inner scoring kernels run under ``jax.jit`` — every iteration
still round-trips through Python for worst-interval selection, candidate-grid
construction, and state updates, so a campaign issues O(iterations) host
dispatches and cannot live on an accelerator.  This module traces the ENTIRE
splitting loop — stop checks, worst-interval argmax, masked candidate scoring
through the shared ``score_2way_kernel``/``score_3way_kernel``, exact
lexicographic tie-breaks, and structure-of-arrays state updates — into one
``jax.jit``-compiled ``lax.while_loop``, so a whole campaign run is O(1)
host dispatches per (shape, heuristic-arity) pair.

Design differences from the numpy lockstep loop (same *choices*, fixed shape):

  - Candidate grids are SPAN-BUCKETED: instead of one static worst-case grid
    (all cuts ``1..n-1`` / all pairs ``c1 < c2`` — the "static-grid tax" that
    made every iteration pay O(n) / O(n^2) lanes even for a 2-stage worst
    interval), each lockstep iteration routes to the smallest geometric
    (power-of-two) bucket covering the live rows' worst-interval span, via a
    ``lax.switch`` over per-bucket scoring branches (:func:`bucket_sizes`).
    Cut lanes are interval-relative (cut ``c = d + offset``) with validity
    masks and clamped gathers, exactly like the numpy engine's span
    compaction; tie-break keys use absolute positions, so selection is
    identical lane-layout notwithstanding.  Evaluated lanes shrink from
    O(n * S) toward the live span while the branch count — and therefore the
    per-program bucket-trace count (:func:`bucket_trace_count`) — stays
    O(log n) per arity (:func:`trace_budget`, asserted by the tests).
  - The 2-stage 3-way fallback (scalar generator in the numpy engine) is six
    extra static lanes with the scalar path's enumeration-order tie-break,
    shared across buckets.
  - Convergence is a per-row mask; the loop exits when every row is done,
    recording per-iteration (period, latency, accepted) into fixed (T, S)
    buffers (T = max possible splits) for trajectory assembly on the host.
  - Batches are padded to a fixed chunk size S per (n, arity), so EVERY call
    of a campaign — trajectories, H4 bisection probes on shrinking subsets,
    H5/H6 bound-grid runs — reuses one trace per arity.  The carried SoA
    state buffers (items array, item counts, latency sums, split counts) are
    donated to the jitted program, so XLA reuses their device buffers for
    the outputs instead of allocating fresh ones per call.

Equivalence contract: split trajectories — the accepted splits AND their
(period, latency) floats — are identical to the numpy engine on all tested
instances (asserted by tests/test_engine_equivalence.py).  This requires
defeating two XLA rewrites that would drift by an ulp and flip exact ties:
FMA contraction of ``a * b + c`` chains (neutralized by the kernels' runtime-
``zero`` guard: ``fma(a, b, 0) == round(a * b)``) and reduction reordering
(the kernels sum the 3-part axis with explicit left-associated adds; max/min
reductions are order-exact).  The numpy engine remains the contractual
bit-exact reference; the fused engine is validated against it per test grid.

Cold starts amortize across processes through JAX's persistent compilation
cache (:func:`enable_persistent_cache` — benchmarks enable it by default).

Use via ``backend="fused"`` on any :mod:`repro.core.batched` entry point (the
lockstep runner dispatches here), or ``engine="fused"`` in
``repro.sim.experiments`` / ``benchmarks/paper_sim.py``.
"""

from __future__ import annotations

import functools
import os
import pathlib
from typing import Callable, Optional

import numpy as np

from .heuristics import _EPS, score_2way_kernel, score_3way_kernel

__all__ = ["fused_available", "run_fused", "run_fused_bisection",
           "trace_count", "reset_trace_count",
           "dispatch_count", "reset_dispatch_count",
           "bucket_trace_count", "reset_bucket_trace_count",
           "bucket_sizes", "bucket_index", "trace_budget",
           "enable_persistent_cache"]

# number of traced (compiled) variants of the fused programs since the last
# reset; incremented from inside the traced wrappers, which Python-execute
# only while jax is tracing — so this counts actual traces, not dispatches.
_TRACES = [0]
# number of traced bucket BRANCHES since the last reset: each program trace
# traces every bucket of its arity exactly once (lax.switch compiles all
# branches), so this counter realizes the O(log n)-buckets-per-arity cap.
_BUCKET_TRACES = [0]
# number of jitted-program dispatches (host -> device calls) since the last
# reset: one per row-chunk for the lockstep loop, one per row-chunk for the
# WHOLE H4 bisection (probe-at-hi + the lax.scan over probe iterations).
_DISPATCHES = [0]

# lane budget per jitted call: rows_per_chunk * candidate_lanes is held under
# this so the 3-way pair grid of large n stays cache-/memory-sized.  Sized
# against the TOP bucket (the full grid) — smaller buckets only use less.
_LANE_BUDGET = 4_000_000
_MAX_CHUNK = 128

_PERMS3 = np.array([(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1),
                    (2, 1, 0)])
# the scalar 2-stage fallback's candidate order: permutations((j,jp,jpp), 2)
_FB_A = np.array([0, 0, 1, 1, 2, 2])
_FB_B = np.array([1, 2, 0, 2, 0, 1])


def fused_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return False
    return True


def trace_count() -> int:
    """Traces of the fused programs since the last :func:`reset_trace_count`."""
    return _TRACES[0]


def reset_trace_count() -> None:
    _TRACES[0] = 0


def bucket_trace_count() -> int:
    """Bucket-branch traces since :func:`reset_bucket_trace_count` — the
    O(log n)-buckets-per-arity cap is asserted on this counter (each program
    trace traces every bucket of its arity once; see :func:`trace_budget`)."""
    return _BUCKET_TRACES[0]


def reset_bucket_trace_count() -> None:
    _BUCKET_TRACES[0] = 0


def dispatch_count() -> int:
    """Jitted-program dispatches since :func:`reset_dispatch_count` — the
    O(1)-dispatch contract is asserted on this counter by the tests."""
    return _DISPATCHES[0]


def reset_dispatch_count() -> None:
    _DISPATCHES[0] = 0


@functools.lru_cache(maxsize=None)
def bucket_sizes(n: int, k: int) -> tuple:
    """Geometric (power-of-two) candidate-grid buckets for stage count ``n``.

    For arity ``k == 1`` the sizes count candidate CUTS of the worst interval
    (``1 <= e - d <= n - 1``); for ``k == 2`` they count its SPAN
    (``3 <= e - d + 1 <= n`` — 2-stage intervals score through the static
    fallback lanes instead, shared across buckets).  Sizes double from a
    small floor and the top bucket is clamped to the exact maximum, so there
    are at most ``ceil(log2(n)) + 1`` buckets; each is traced once per fused
    program, which is the O(log n)-traces-per-arity cap asserted in tests.
    """
    if k == 1:
        lo, hi = 2, n - 1
    else:
        if n < 3:
            return ()
        lo, hi = 4, n
    if hi <= 0:
        return ()
    sizes = []
    s = lo
    while s < hi:
        sizes.append(s)
        s *= 2
    sizes.append(hi)
    return tuple(sizes)


def bucket_index(need: int, sizes) -> int:
    """Index of the smallest bucket in ``sizes`` covering ``need`` lanes.
    The traced loop evaluates the same expression on-device per iteration
    (``sum(need > sizes[:-1])``), so this host mirror is what the
    bucket-routing property test pins down."""
    sizes = np.asarray(sizes)
    return int(np.sum(np.asarray(need) > sizes[:-1]))


def trace_budget(n: int) -> int:
    """Upper bound on bucket-branch traces for one campaign at stage count
    ``n``: one bucket set per traced k=1 program (the lockstep loop AND the
    bisection's inlined loop) plus one per traced k=2 program."""
    return 2 * len(bucket_sizes(n, 1)) + len(bucket_sizes(n, 2))


def enable_persistent_cache(path: Optional[str] = None) -> str:
    """Point JAX at an on-disk compilation cache so fused-program cold starts
    are paid once per machine, not once per process.  Idempotent; returns the
    cache directory.  Benchmarks call this by default (``JAX_COMPILATION_
    CACHE_DIR`` overrides the location)."""
    import jax

    path = str(path or os.environ.get("JAX_COMPILATION_CACHE_DIR")
               or pathlib.Path.home() / ".cache" / "repro-jax-cache")
    pathlib.Path(path).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # persist only compiles that meaningfully cost (the fused programs take
    # seconds); trivial sub-second compiles would otherwise accumulate in an
    # uneviected cache directory forever
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    return path


def chunk_rows(n: int, k: int) -> int:
    """Fixed rows-per-call for shape (n, arity k) — deterministic so every
    call of a campaign pads to the same chunk shape and shares one trace.
    Sized against the TOP span bucket (the worst-case grid)."""
    if k == 1:
        lanes = max(2 * (n - 1), 1)
    else:
        lanes = 18 * ((n - 1) * (n - 2) // 2) + 6
    return int(max(1, min(_MAX_CHUNK, _LANE_BUDGET // max(lanes, 1))))


def _lex_argmin_traced(xp, keys, mask):
    """Traced mirror of ``batched._lex_argmin``: per-row first index of the
    lexicographically smallest key tuple among masked lanes (no early exit —
    extra key passes only re-filter ties, so the winner is identical)."""
    has = mask.any(axis=1)
    m = mask
    for key in keys:
        kmin = xp.where(m, key, xp.inf).min(axis=1)
        m = m & (key == kmin[:, None])
    return xp.argmax(m, axis=1), has


def _build_loop(n: int, p: int, k: int, T: int, S: int) -> tuple:
    """Build the UNJITTED fused loop for static shape (n, p, k).

    Returns ``(init_state, loop)``:

        init_state(delta, s, b, prefix, order) -> (arr, m, nx, lat, sp)
        loop(delta, s, b, zero, prefix, order, bi_mode, stop, lat_limit,
             active0, arr0, m0, nx0, lat0, sp0)
          -> (arr, m, next_idx, lat_sum, splits, per_rec, lat_rec, acc_rec, t)

    with ``arr`` (S, n, 5) in the ``_BatchState`` field layout and the records
    (T, S) per lockstep iteration.  Callers jit the loop with the SoA state
    arguments donated (:func:`_get_loop`) or inline it into a larger traced
    program (:func:`_get_bisect`).  Candidate scoring runs through a
    ``lax.switch`` over the geometric span buckets of :func:`bucket_sizes`.
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from jax import lax

    col = jnp.arange(n)[None, :]
    sizes = bucket_sizes(n, k)
    thresholds = np.asarray(sizes[:-1], dtype=np.int64)
    fb_key = np.arange(6, dtype=float)[None, :]

    def take1(A, idx):
        return jnp.take_along_axis(A, idx[:, None], axis=1)[:, 0]

    def make_choose_2way(L: int) -> Callable:
        """Scoring/selection branch over the L-cut bucket: interval-relative
        cut lanes ``c = d + offset`` (same compaction as the numpy engine's
        ``_choose_2way``), absolute-position tie-break keys."""
        off = np.arange(L)

        def choose(ops):
            _BUCKET_TRACES[0] += 1  # Python-executes once per branch trace
            (prefix, delta, b, zero, d, e, j, jp_, bi, old_cycle, cur_lat,
             lat_lim, live, pre_d1, pre_e, del_d1, del_e, inv_j, inv_p) = ops
            c = d[:, None] + off[None, :]
            valid = c < e[:, None]
            ci = jnp.minimum(c, n - 1)           # in-range gather, masked lanes
            pre_C = jnp.take_along_axis(prefix, ci, axis=1)
            del_C = jnp.take_along_axis(delta, ci, axis=1)
            cyc1, cyc2, dlat = score_2way_kernel(
                pre_d1[:, None], pre_C, pre_e[:, None],
                del_d1[:, None], del_C, del_e[:, None], b,
                inv_j[:, None], inv_p[:, None], xp=jnp, zero=zero)
            mx = jnp.maximum(cyc1, cyc2)
            okay = (mx < old_cycle[:, None] - _EPS)
            okay &= cur_lat[:, None] + dlat <= lat_lim[:, None] + _EPS
            okay &= jnp.concatenate([valid, valid], axis=1)
            okay &= live[:, None]
            ratio = jnp.maximum(
                dlat / jnp.maximum(old_cycle[:, None] - cyc1, _EPS),
                dlat / jnp.maximum(old_cycle[:, None] - cyc2, _EPS))
            cf = c.astype(jnp.float64)
            cutorder = jnp.concatenate([cf * 2.0, cf * 2.0 + 1.0], axis=1)
            bc = bi[:, None]
            keys = [jnp.where(bc, ratio, mx), jnp.where(bc, mx, dlat),
                    cutorder]
            q, has = _lex_argmin_traced(jnp, keys, okay)
            cw = d + (q % L)
            swapped = q >= L
            pa = jnp.where(swapped, jp_, j)
            pb2 = jnp.where(swapped, j, jp_)
            pd = jnp.stack([d, cw + 1, cw + 1], axis=1)
            pe = jnp.stack([cw, e, e], axis=1)
            pu = jnp.stack([pa, pb2, pb2], axis=1)
            nparts = jnp.full((S,), 2, dtype=jnp.int64)
            consumed = jnp.ones((S,), dtype=jnp.int64)
            return has, pd, pe, pu, nparts, consumed

        return choose

    def make_choose_3way(L: Optional[int]) -> Callable:
        """Scoring/selection branch over the L-span bucket: all relative cut
        pairs ``0 <= r1 < r2 <= L-2`` (``c_i = d + r_i``) x 6 permutations,
        concatenated with the shared 2-stage fallback lanes for the joint
        exact lex tie-break.  ``L=None`` (n < 3) keeps fallback lanes only."""
        if L is not None:
            r1, r2 = np.triu_indices(L - 1, k=1)
            K = int(r1.size)
        else:
            r1 = r2 = None
            K = 0

        def choose(ops):
            _BUCKET_TRACES[0] += 1  # Python-executes once per branch trace
            (prefix, delta, b, zero, d, e, bi, old_cycle, cur_lat, lat_lim,
             live, span2, pre_d1, pre_e, del_d1, del_e, invp, base_term,
             procs3, mx_fb, dlat_fb, ratio_fb, okay_fb) = ops
            bc = bi[:, None]
            if K:
                c1 = d[:, None] + r1[None, :]
                c2 = d[:, None] + r2[None, :]
                valid = c2 <= (e - 1)[:, None]
                c1i = jnp.minimum(c1, n - 1)
                c2i = jnp.minimum(c2, n - 1)
                pre_c1 = jnp.take_along_axis(prefix, c1i, axis=1)
                pre_c2 = jnp.take_along_axis(prefix, c2i, axis=1)
                del_c1 = jnp.take_along_axis(delta, c1i, axis=1)
                del_c2 = jnp.take_along_axis(delta, c2i, axis=1)
                W = jnp.stack([pre_c1 - pre_d1[:, None], pre_c2 - pre_c1,
                               pre_e[:, None] - pre_c2], axis=1)  # (S, 3, K)
                dI = jnp.stack([jnp.broadcast_to(del_d1[:, None], (S, K)),
                                del_c1, del_c2], axis=1) / b
                dO = jnp.stack([del_c1, del_c2,
                                jnp.broadcast_to(del_e[:, None], (S, K))],
                               axis=1) / b
                cyc, dlat, mx = score_3way_kernel(
                    dI[:, None], W[:, None], dO[:, None], invp,
                    base_term[:, None, None], xp=jnp, zero=zero)
                ratio = (dlat[:, :, None, :]
                         / jnp.maximum(old_cycle[:, None, None, None] - cyc,
                                       _EPS)).max(axis=2)
                mx_f = mx.reshape(S, 6 * K)
                dlat_f = dlat.reshape(S, 6 * K)
                ratio_f = ratio.reshape(S, 6 * K)
                okay3 = mx_f < old_cycle[:, None] - _EPS
                okay3 &= cur_lat[:, None] + dlat_f <= lat_lim[:, None] + _EPS
                okay3 &= jnp.broadcast_to(valid[:, None, :],
                                          (S, 6, K)).reshape(S, 6 * K)
                okay3 &= (live & ~span2)[:, None]
                # (c1, c2, perm) tie-break as ONE exactly-represented integer
                # key — absolute positions, so bucket layout cannot matter
                ccp = ((c1 * (n + 1) + c2)[:, None, :] * 6
                       + np.arange(6)[None, :, None]
                       ).astype(jnp.float64).reshape(S, 6 * K)
                key1 = jnp.concatenate(
                    [jnp.where(bc, ratio_f, mx_f),
                     jnp.where(bc, ratio_fb, mx_fb)], axis=1)
                key2 = jnp.concatenate(
                    [jnp.where(bc, mx_f, dlat_f),
                     jnp.where(bc, mx_fb, dlat_fb)], axis=1)
                key3 = jnp.concatenate(
                    [ccp, jnp.broadcast_to(fb_key, (S, 6))], axis=1)
                okay = jnp.concatenate([okay3, okay_fb], axis=1)
            else:
                key1 = jnp.where(bc, ratio_fb, mx_fb)
                key2 = jnp.where(bc, mx_fb, dlat_fb)
                key3 = jnp.broadcast_to(fb_key, (S, 6))
                okay = okay_fb
            q, has = _lex_argmin_traced(jnp, [key1, key2, key3], okay)

            fb = q >= 6 * K
            # grid winner
            pi = jnp.minimum(q // max(K, 1), 5)
            kk = q % max(K, 1)
            if K:
                c1b = d + jnp.take(jnp.asarray(r1), kk, mode="clip")
                c2b = d + jnp.take(jnp.asarray(r2), kk, mode="clip")
            else:
                c1b = c2b = d
            perm = jnp.asarray(_PERMS3)[pi]                          # (S, 3)
            u_grid = jnp.take_along_axis(procs3, perm, axis=1)
            pd_g = jnp.stack([d, c1b + 1, c2b + 1], axis=1)
            pe_g = jnp.stack([c1b, c2b, e], axis=1)
            # fallback winner
            qf = jnp.where(fb, q - 6 * K, 0)
            ia = jnp.asarray(_FB_A)[qf]
            ib = jnp.asarray(_FB_B)[qf]
            pu0 = jnp.take_along_axis(procs3, ia[:, None], axis=1)[:, 0]
            pu1 = jnp.take_along_axis(procs3, ib[:, None], axis=1)[:, 0]
            pd_f = jnp.stack([d, d + 1, d + 1], axis=1)
            pe_f = jnp.stack([d, e, e], axis=1)
            pu_f = jnp.stack([pu0, pu1, pu1], axis=1)
            cons_f = jnp.where((ia != 0) & (ib != 0), 2, 1).astype(jnp.int64)

            fbc = fb[:, None]
            pd = jnp.where(fbc, pd_f, pd_g)
            pe = jnp.where(fbc, pe_f, pe_g)
            pu = jnp.where(fbc, pu_f, u_grid)
            nparts = jnp.where(fb, 2, 3).astype(jnp.int64)
            consumed = jnp.where(fb, cons_f, 2).astype(jnp.int64)
            return has, pd, pe, pu, nparts, consumed

        return choose

    if k == 1:
        branches = [make_choose_2way(L) for L in sizes]
    else:
        branches = ([make_choose_3way(L) for L in sizes]
                    if sizes else [make_choose_3way(None)])

    def init_state(delta, s, b, prefix, order):
        """The optimal-latency starting state (all stages on the fastest
        processor) — same expressions as ``batched._BatchState.__init__``."""
        fastest = order[:, 0]
        term0 = delta[:, 0] / b + (prefix[:, n] - prefix[:, 0]) / take1(s, fastest)
        tail = delta[:, n] / b
        arr = jnp.full((S, n, 5), 0.0).at[:, :, 3].set(-jnp.inf)
        arr = arr.at[:, 0, 0].set(1.0)
        arr = arr.at[:, 0, 1].set(float(n))
        arr = arr.at[:, 0, 2].set(fastest.astype(jnp.float64))
        arr = arr.at[:, 0, 3].set(term0 + tail)
        arr = arr.at[:, 0, 4].set(term0)
        m0 = jnp.ones(S, dtype=jnp.int64)
        nx0 = jnp.ones(S, dtype=jnp.int64)
        sp0 = jnp.zeros(S, dtype=jnp.int64)
        return arr, m0, nx0, term0, sp0

    def loop(delta, s, b, zero, prefix, order, bi_mode, stop, lat_limit,
             active0, arr0, m0, nx0, lat0, sp0):
        tail = delta[:, n] / b
        per_rec = jnp.zeros((T, S))
        lat_rec = jnp.zeros((T, S))
        acc_rec = jnp.zeros((T, S), dtype=bool)

        def cond(carry):
            t, active = carry[0], carry[5]
            return (t < T) & active.any()

        def body(carry):
            (t, arr, m, next_idx, lat_sum, active,
             per_rec, lat_rec, acc_rec) = carry[:9]
            splits = carry[9]
            cyc = arr[:, :, 3]
            per = cyc.max(axis=1)
            live = active & (per > stop + _EPS)
            widx = jnp.argmax(cyc, axis=1)
            item = jnp.take_along_axis(arr, widx[:, None, None], axis=1)[:, 0, :]
            d = jnp.clip(item[:, 0].astype(jnp.int64), 1, n)
            e = jnp.clip(item[:, 1].astype(jnp.int64), 1, n)
            j = jnp.clip(item[:, 2].astype(jnp.int64), 0, p - 1)
            live &= (item[:, 1] > item[:, 0]) & (next_idx + k <= p)
            old_cycle = item[:, 3]
            old_term = item[:, 4]
            cur_lat = lat_sum + tail
            jp_ = take1(order, jnp.clip(next_idx, 0, p - 1))

            # shared per-row interval-end quantities (bucket-independent)
            pre_d1 = take1(prefix, d - 1)
            pre_e = take1(prefix, e)
            del_d1 = take1(delta, d - 1)
            del_e = take1(delta, e)

            if k == 1:
                inv_j = 1.0 / take1(s, j)
                inv_p = 1.0 / take1(s, jp_)
                need = e - d                      # candidate cuts per row
                cur = jnp.max(jnp.where(live, need, 0))
                ops = (prefix, delta, b, zero, d, e, j, jp_, bi_mode,
                       old_cycle, cur_lat, lat_limit, live,
                       pre_d1, pre_e, del_d1, del_e, inv_j, inv_p)
                if len(branches) > 1:
                    bidx = jnp.sum(cur > jnp.asarray(thresholds))
                    (has, pd, pe, pu,
                     nparts, consumed) = lax.switch(bidx, branches, ops)
                else:
                    has, pd, pe, pu, nparts, consumed = branches[0](ops)
            else:
                jpp = take1(order, jnp.clip(next_idx + 1, 0, p - 1))
                sj = take1(s, j)
                s3 = jnp.stack([sj, take1(s, jp_), take1(s, jpp)], axis=1)
                invp = (1.0 / s3)[:, _PERMS3][:, :, :, None]         # (S,6,3,1)
                base_term = del_d1 / b + (pre_e - pre_d1) / sj
                procs3 = jnp.stack([j, jp_, jpp], axis=1)            # (S, 3)
                span2 = (e - d + 1) == 2

                # 2-stage fallback lanes (division-based like the scalar
                # generator): span-independent, computed once outside the
                # bucket switch and fed to every branch's joint tie-break.
                pre_dd = take1(prefix, jnp.minimum(d, n))
                del_dd = take1(delta, jnp.minimum(d, n))
                W1 = (pre_dd - pre_d1)[:, None]
                W2 = (pre_e - pre_dd)[:, None]
                spa = s3[:, _FB_A]
                spb = s3[:, _FB_B]
                t1 = del_d1[:, None] / b + W1 / spa
                cyc1_fb = t1 + del_dd[:, None] / b
                t2 = del_dd[:, None] / b + W2 / spb
                cyc2_fb = t2 + del_e[:, None] / b
                dlat_fb = (t1 + t2) - base_term[:, None]
                mx_fb = jnp.maximum(cyc1_fb, cyc2_fb)
                okay_fb = mx_fb < old_cycle[:, None] - _EPS
                okay_fb &= (cur_lat[:, None] + dlat_fb
                            <= lat_limit[:, None] + _EPS)
                okay_fb &= (live & span2)[:, None]
                ratio_fb = jnp.maximum(
                    dlat_fb / jnp.maximum(old_cycle[:, None] - cyc1_fb, _EPS),
                    dlat_fb / jnp.maximum(old_cycle[:, None] - cyc2_fb, _EPS))

                span = e - d + 1
                cur = jnp.max(jnp.where(live & ~span2, span, 0))
                ops = (prefix, delta, b, zero, d, e, bi_mode, old_cycle,
                       cur_lat, lat_limit, live, span2, pre_d1, pre_e,
                       del_d1, del_e, invp, base_term, procs3,
                       mx_fb, dlat_fb, ratio_fb, okay_fb)
                if len(branches) > 1:
                    bidx = jnp.sum(cur > jnp.asarray(thresholds))
                    (has, pd, pe, pu,
                     nparts, consumed) = lax.switch(bidx, branches, ops)
                else:
                    has, pd, pe, pu, nparts, consumed = branches[0](ops)
            accept = live & has

            # apply splits (same division-based expressions as _apply_splits)
            pdc = jnp.clip(pd, 1, n)
            pec = jnp.clip(pe, 1, n)
            puc = jnp.clip(pu, 0, p - 1)
            del_pd1 = jnp.take_along_axis(delta, pdc - 1, axis=1)
            pre_pe = jnp.take_along_axis(prefix, pec, axis=1)
            pre_pd1 = jnp.take_along_axis(prefix, pdc - 1, axis=1)
            s_pu = jnp.take_along_axis(s, puc, axis=1)
            del_pe = jnp.take_along_axis(delta, pec, axis=1)
            t_parts = del_pd1 / b + (pre_pe - pre_pd1) / s_pu
            c_parts = t_parts + del_pe / b
            add = t_parts[:, 0] + t_parts[:, 1]
            add = jnp.where(nparts == 3, add + t_parts[:, 2], add)
            new_lat = (lat_sum - old_term) + add
            sh = (nparts - 1)[:, None]
            idxc = widx[:, None]
            src = jnp.where(col <= idxc, col,
                            jnp.where(col <= idxc + sh, idxc, col - sh))
            new_arr = jnp.take_along_axis(arr, src[:, :, None], axis=1)
            parts5 = jnp.stack([pdc.astype(jnp.float64),
                                pec.astype(jnp.float64),
                                puc.astype(jnp.float64), c_parts, t_parts],
                               axis=2)                               # (S, 3, 5)
            m0_ = (col == idxc)[:, :, None]
            m1_ = (col == idxc + 1)[:, :, None]
            m2_ = ((col == idxc + 2) & (nparts == 3)[:, None])[:, :, None]
            new_arr = jnp.where(m0_, parts5[:, 0][:, None, :], new_arr)
            new_arr = jnp.where(m1_, parts5[:, 1][:, None, :], new_arr)
            new_arr = jnp.where(m2_, parts5[:, 2][:, None, :], new_arr)

            acc3 = accept[:, None, None]
            arr = jnp.where(acc3, new_arr, arr)
            m = m + jnp.where(accept, nparts - 1, 0)
            next_idx = next_idx + jnp.where(accept, consumed, 0)
            lat_sum = jnp.where(accept, new_lat, lat_sum)
            splits = splits + accept.astype(jnp.int64)

            per_rec = per_rec.at[t].set(arr[:, :, 3].max(axis=1))
            lat_rec = lat_rec.at[t].set(lat_sum + tail)
            acc_rec = acc_rec.at[t].set(accept)
            return (t + 1, arr, m, next_idx, lat_sum, accept,
                    per_rec, lat_rec, acc_rec, splits)

        init = (jnp.int64(0), arr0, m0, nx0, lat0, active0,
                per_rec, lat_rec, acc_rec, sp0)
        (t, arr, m, next_idx, lat_sum, active,
         per_rec, lat_rec, acc_rec, splits) = lax.while_loop(cond, body, init)
        return arr, m, next_idx, lat_sum, splits, per_rec, lat_rec, acc_rec, t

    return init_state, loop


@functools.lru_cache(maxsize=None)
def _get_loop(n: int, p: int, k: int, T: int, S: int) -> Callable:
    """The jitted fused loop for static shape (n, p, k), cached per shape.
    The five carried SoA state buffers (arr, m, next_idx, lat_sum, splits)
    are donated: XLA reuses their device buffers for the outputs."""
    import jax

    _init_state, loop = _build_loop(n, p, k, T, S)

    def counted(*args):
        _TRACES[0] += 1  # Python-executes only while tracing
        return loop(*args)

    return jax.jit(counted, donate_argnums=(10, 11, 12, 13, 14))


def _build_bisect(n: int, p: int, T: int, S: int, iters: int) -> Callable:
    """Build the UNJITTED fused H4 bisection for static shape (n, p): the
    probe at the upper latency bound plus a ``lax.scan`` over ``iters`` probe
    iterations — each probe an inline :func:`_build_loop` run — carrying the
    per-row (lo, hi) bound state and the best-so-far probe outcome.  One
    dispatch replaces the ~iters+1 per-probe dispatches of the host-driven
    binary search, with bit-identical updates: ``mid = 0.5 * (lo + hi)``,
    feasibility ``(period <= p_fix + eps) & (latency <= mid + eps)``, and the
    (latency, then period) best-probe tie-break all mirror
    ``batched._sp_bi_p_rowwise`` expression for expression.

    Returned callable (jitted by :func:`_get_bisect`, or sharded over the
    row axis by ``repro.core.sharded``):
        fn(delta, s, b, zero, prefix, order, p_fix, lo0, hi0, active0)
        -> (items0, m0, sp0, per0, lat0, feas0,
            best_items, best_m, best_sp, best_per, best_lat)
    with items* (S, n, 3) in the ``_BatchState`` (d, e, proc) layout.
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from jax import lax

    init_state, loop = _build_loop(n, p, 1, T, S)

    def fn(delta, s, b, zero, prefix, order, p_fix, lo0, hi0, active0):
        all_bi = jnp.ones(S, dtype=bool)
        tail = delta[:, n] / b

        def probe(limits, act):
            st0 = init_state(delta, s, b, prefix, order)
            arr, m, _nx, lat_sum, splits, *_rest = loop(
                delta, s, b, zero, prefix, order, all_bi, p_fix, limits,
                act, *st0)
            per = arr[:, :, 3].max(axis=1)
            lat = lat_sum + tail
            feas = (per <= p_fix + _EPS) & (lat <= limits + _EPS)
            return arr, m, splits, per, lat, feas

        # Ensure feasibility at the upper end first (the rowwise path's
        # probe0); its state seeds both the failure outputs and `best`.
        arr0, m0, sp0, per0, lat0, feas0 = probe(hi0, active0)
        alive = feas0 & active0

        def body(carry, _):
            lo, hi, b_it, b_m, b_sp, b_per, b_lat = carry
            mid = 0.5 * (lo + hi)
            arr, m, sp, per, lat, feas = probe(mid, alive)
            good = alive & feas
            hi = jnp.where(good, mid, hi)
            lo = jnp.where(alive & ~feas, mid, lo)
            better = good & ((lat < b_lat - _EPS)
                             | ((jnp.abs(lat - b_lat) <= _EPS)
                                & (per < b_per)))
            bc = better[:, None, None]
            return (lo, hi, jnp.where(bc, arr[:, :, :3], b_it),
                    jnp.where(better, m, b_m), jnp.where(better, sp, b_sp),
                    jnp.where(better, per, b_per),
                    jnp.where(better, lat, b_lat)), None

        init = (lo0, hi0, arr0[:, :, :3], m0, sp0, per0, lat0)
        (_lo, _hi, b_it, b_m, b_sp, b_per, b_lat), _ = lax.scan(
            body, init, None, length=iters)
        return (arr0[:, :, :3], m0, sp0, per0, lat0, feas0,
                b_it, b_m, b_sp, b_per, b_lat)

    return fn


@functools.lru_cache(maxsize=None)
def _get_bisect(n: int, p: int, T: int, S: int, iters: int) -> Callable:
    """The jitted fused H4 bisection, cached per shape (see
    :func:`_build_bisect` for the program's contract)."""
    import jax

    fn = _build_bisect(n, p, T, S, iters)

    def counted(*args):
        _TRACES[0] += 1  # Python-executes only while tracing
        return fn(*args)

    return jax.jit(counted)


def run_fused(state, k: int, bi_mode: np.ndarray, stop: np.ndarray,
              lat_limit: np.ndarray, record: Optional[Callable] = None) -> None:
    """Run the fused loop over ``state`` (a ``batched._BatchState``), writing
    final arrays back and replaying per-iteration ``record`` callbacks — a
    drop-in replacement for the numpy ``_run_loop`` body with O(1) dispatches.
    """
    pb = state.pb
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0 or not state.active.any():
        state.active[:] = False
        return
    S = chunk_rows(n, k)
    fn = _get_loop(n, p, k, T, S)
    b = np.float64(pb.b)
    bi_mode = np.asarray(bi_mode, dtype=bool)
    stop = np.asarray(stop, dtype=np.float64)
    lat_limit = np.asarray(lat_limit, dtype=np.float64)
    chunks = []  # (rows, per_rec, lat_rec, acc_rec, t_used)
    for lo in range(0, B, S):
        rows = np.arange(lo, min(lo + S, B))
        pad = S - rows.size
        sel = np.concatenate([rows, np.zeros(pad, dtype=np.int64)]) if pad else rows
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = state.active[rows]
        _DISPATCHES[0] += 1
        # the SoA state slices are fresh fancy-index copies, safe to donate
        out = fn(pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), bi_mode[sel],
                 stop[sel], lat_limit[sel], act,
                 state.arr[sel], state.m[sel], state.next_idx[sel],
                 state.lat_sum[sel], state.splits[sel])
        (arr, m, next_idx, lat_sum, splits,
         per_rec, lat_rec, acc_rec, t_used) = (np.asarray(o) for o in out)
        r = rows.size
        state.arr[rows] = arr[:r]
        state.m[rows] = m[:r]
        state.next_idx[rows] = next_idx[:r]
        state.lat_sum[rows] = lat_sum[:r]
        state.splits[rows] = splits[:r]
        state.active[rows] = False
        if record is not None:
            chunks.append((rows, per_rec[:, :r], lat_rec[:, :r],
                           acc_rec[:, :r], int(t_used)))
    if record is None:
        return
    # Replay records in global lockstep order: a row's s-th accepted split
    # always lands at iteration s regardless of which rows share its chunk,
    # so merging chunk records per iteration reproduces the numpy engine's
    # record sequence exactly.
    t_max = max((t for *_, t in chunks), default=0)
    for t in range(t_max):
        rsel, pers, lats = [], [], []
        for rows, per_rec, lat_rec, acc_rec, t_used in chunks:
            if t >= t_used:
                continue
            a = acc_rec[t]
            if a.any():
                rsel.append(rows[a])
                pers.append(per_rec[t][a])
                lats.append(lat_rec[t][a])
        if rsel:
            record(np.concatenate(rsel), np.concatenate(pers),
                   np.concatenate(lats))


def run_fused_bisection(pb, p_fix: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                        iters: int) -> dict:
    """Run the ENTIRE H4 binary search device-resident: one jitted
    probe0 + ``lax.scan`` program per row-chunk (O(1) host dispatches per
    campaign instead of ~iters+1), bit-identical to the host-driven search.

    ``pb`` is a ``batched.ProblemBatch``; returns per-row numpy arrays:
    ``items0/m0/sp0/per0/lat0/feas0`` (the probe-at-``hi`` state — the
    failure outputs) and ``items/m/sp/per/lat`` (the best feasible probe).
    The caller (``batched._sp_bi_p_fused``) assembles HeuristicResults.
    """
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0:
        raise ValueError("unsplittable shape: caller should use the host path")
    S = chunk_rows(n, 1)
    fn = _get_bisect(n, p, T, S, int(iters))
    b = np.float64(pb.b)
    p_fix = np.asarray(p_fix, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out = {
        "items0": np.zeros((B, n, 3)), "m0": np.zeros(B, dtype=np.int64),
        "sp0": np.zeros(B, dtype=np.int64), "per0": np.zeros(B),
        "lat0": np.zeros(B), "feas0": np.zeros(B, dtype=bool),
        "items": np.zeros((B, n, 3)), "m": np.zeros(B, dtype=np.int64),
        "sp": np.zeros(B, dtype=np.int64), "per": np.zeros(B),
        "lat": np.zeros(B),
    }
    names = ("items0", "m0", "sp0", "per0", "lat0", "feas0",
             "items", "m", "sp", "per", "lat")
    for lo_i in range(0, B, S):
        rows = np.arange(lo_i, min(lo_i + S, B))
        pad = S - rows.size
        sel = (np.concatenate([rows, np.zeros(pad, dtype=np.int64)])
               if pad else rows)
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = True
        _DISPATCHES[0] += 1
        res = fn(pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), p_fix[sel],
                 lo[sel], hi[sel], act)
        for name, val in zip(names, res):
            out[name][rows] = np.asarray(val)[:rows.size]
    return out
