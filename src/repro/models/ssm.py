"""Mamba2 (state-space duality) blocks, chunked-scan formulation.

The SSD forward runs in chunks of ``cfg.ssm_chunk``: within-chunk terms are
quadratic in the chunk (MXU-friendly batched matmuls), the inter-chunk state
(B, H, P, N) is carried by a ``lax.scan`` — O(S * Q) compute, O(1)-in-S decode
state, which is what makes the SSM archs eligible for the ``long_500k`` cell.

Shapes follow the Mamba2 paper: d_inner = expand * d_model, H = d_inner / P
heads of head-dim P, single B/C group (G=1), state size N = cfg.ssm_state.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .layers import dense_init, rms_norm, shard


def ssm_dims(cfg: ModelConfig) -> tuple:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, P, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * N                       # x, B, C go through the conv
    ks = jax.random.split(key, 4)
    pdt = cfg.jparam_dtype
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * N + H), pdt, fan_in=d),
        "conv_w": dense_init(ks[1], (conv_dim, cfg.ssm_conv), pdt, fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "A_log": jnp.zeros((H,), pdt),            # A = -exp(A_log) = -1 at init
        "D": jnp.ones((H,), pdt),
        "dt_bias": jnp.zeros((H,), pdt),
        "norm": jnp.ones((d_in,), pdt),
        "out_proj": dense_init(ks[2], (d_in, d), pdt, fan_in=d_in),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x: (B, S, C); w: (C, K)."""
    B, S, C = x.shape
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # depthwise: gather K shifted copies — cheap, fusible, no conv primitive needed
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i : i + S, :] * w[:, i]
    return out + b


def _segsum_chunk(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) per-step log-decay.  Returns (..., Q, Q) matrix
    M[i,j] = sum_{t=j+1..i} dA_t  for j <= i, -inf above diagonal."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]      # cs_i - cs_j
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int) -> tuple:
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,); Bmat/Cmat: (B, S, N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = Bmat.reshape(Bb, nc, Q, N)
    Cc = Cmat.reshape(Bb, nc, Q, N)
    dA = dtc * A                                     # (B,nc,Q,H) log-decay per step
    cs = jnp.cumsum(dA, axis=2)                      # within-chunk cumulative

    # Intra-chunk (quadratic in Q): y_i += C_i . sum_{j<=i} exp(cs_i-cs_j) dt_j B_j x_j
    L = _segsum_chunk(dA.transpose(0, 1, 3, 2))      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)   # (B,nc,Q,Q)
    gated = scores[:, :, None] * jnp.exp(L)          # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", gated, dtc, xc)

    # Inter-chunk state recurrence over chunks.
    decay_out = jnp.exp(cs)                                        # (B,nc,Q,H)
    decay_state = jnp.exp(cs[:, :, -1:, :] - cs)                   # exp(cs_Q - cs_j)
    chunk_state = jnp.einsum("bcqh,bcqh,bcqhp,bcqn->bchpn",
                             decay_state, dtc, xc, Bc)             # per-chunk new-state term
    chunk_decay = jnp.exp(cs[:, :, -1, :])                         # (B,nc,H)

    def step(state, inp):
        c_state, c_decay = inp                                     # (B,H,P,N), (B,H)
        new = state * c_decay[..., None, None] + c_state
        return new, state                                          # emit state BEFORE chunk

    init = jnp.zeros((Bb, H, P, N), x.dtype)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_out, prev_states)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final_state


def mamba2_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence Mamba2 mixer.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    d_in, H, P, N = ssm_dims(cfg)
    dt = x.dtype
    z_x_bc_dt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))
    z, xbc, dtv = jnp.split(z_x_bc_dt, [d_in, 2 * d_in + 2 * N], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(dt), p["conv_b"].astype(dt)))
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs.astype(jnp.float32), dtv, A,
                       Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                       cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_in).astype(dt)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))
    return shard(out, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# Decode (O(1) state)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jax.Array    # (B, conv_dim, K-1) last inputs
    ssm: jax.Array     # (B, H, P, N)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    d_in, H, P, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * N
    return MambaState(
        conv=jnp.zeros((batch, conv_dim, cfg.ssm_conv - 1), jnp.float32),
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def mamba2_decode_step(p: dict, x: jax.Array, state: MambaState,
                       cfg: ModelConfig) -> tuple:
    """x: (B, 1, d) -> (y (B,1,d), new_state)."""
    B = x.shape[0]
    d_in, H, P, N = ssm_dims(cfg)
    dt = x.dtype
    z_x_bc_dt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt))[:, 0]
    z, xbc, dtv = jnp.split(z_x_bc_dt, [d_in, 2 * d_in + 2 * N], axis=-1)
    # conv over the stored window + current input
    hist = jnp.concatenate([state.conv, xbc.astype(jnp.float32)[:, :, None]], axis=-1)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = (hist * w[None]).sum(-1) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)
    new_conv = hist[:, :, 1:]
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, H, P)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * A)                               # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtv, xs, Bmat)
    ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cmat, ssm)
    y = y + xs * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, d_in).astype(dt)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(dt))[:, None]
    return out, MambaState(conv=new_conv, ssm=ssm)
