"""Decoder-only transformer LM covering the dense, MoE and VLM families.

One scanned block structure; config switches select GQA shape, qk-norm, QKV
bias, sliding-window attention, and MoE vs dense FFN.  The VLM family is the
same LM consuming a prefix of precomputed patch embeddings (the assignment
specifies the vision frontend as a stub).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .attention import (KVCache, attention, cache_from_prefill,
                        decode_attention_step, init_attention, init_cache,
                        _project_qkv)
from .common import ModelConfig
from .layers import embed, init_embed, init_mlp, mlp, rms_norm, shard, unembed
from .moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
        "attn": init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k3, cfg)
    return p


def block_forward(p: dict, x: jax.Array, cfg: ModelConfig, positions) -> tuple:
    h = rms_norm(x, p["ln1"], cfg.norm_eps, cfg.use_pallas)
    h = attention(p["attn"], h, cfg, positions=positions, causal=True,
                  window=cfg.sliding_window)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps, cfg.use_pallas)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        h, aux = moe_ffn(p["moe"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg)
    x = x + h
    return shard(x, "batch", "seq_sp", "d_model"), aux


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> dict:
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    return {
        "embed": init_embed(ke, cfg),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
    }


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "block":
        return jax.checkpoint(fn)
    return fn


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: Optional[jax.Array] = None) -> tuple:
    """Returns (logits, aux_loss).  tokens: (B, S_text); prefix_embeds (VLM):
    (B, S_vis, d) prepended before the text tokens."""
    x = embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        x, aux = carry
        x, a = block_forward(lp, x, cfg, positions)
        return (x, aux + a), None

    body = _maybe_remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            (x, aux), _ = body((x, aux), lp)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps, cfg.use_pallas)
    logits = unembed(params["embed"], x, cfg)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: KVCache      # stacked over layers: fields (L, B, C, K, hd)


def _block_prefill(p, x, cfg: ModelConfig, positions):
    """Like block_forward but also returns this layer's (k, v) for the cache."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps, cfg.use_pallas)
    B, S, _ = h.shape
    q, k, v = _project_qkv(p["attn"], h, h, cfg, positions, positions)
    from .attention import blocked_attention, plain_attention

    if S <= 2048 or S % 512:
        out = plain_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        out = blocked_attention(q, k, v, causal=True, window=cfg.sliding_window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(h.dtype))
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps, cfg.use_pallas)
    if cfg.family == "moe":
        h, _ = moe_ffn(p["moe"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg)
    return x + h, (k, v)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: Optional[jax.Array] = None) -> tuple:
    """Forward pass that also builds the per-layer KV caches.
    Returns (last_logits, DecodeState)."""
    x = embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        x, kv = _block_prefill(lp, x, cfg, positions)
        return x, kv

    body = _maybe_remat(body, cfg)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps, cfg.use_pallas)
    logits = unembed(params["embed"], x[:, -1:], cfg)
    caches = jax.vmap(lambda k, v: cache_from_prefill(cfg, k, v, cfg.sliding_window))(ks, vs)
    return logits, DecodeState(caches)


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int) -> DecodeState:
    """Fresh decode state with given cache capacity (= seq_len, or window for SWA)."""
    cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
    L = cfg.n_layers
    caches = KVCache(
        k=jnp.zeros((L, batch, cap, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        v=jnp.zeros((L, batch, cap, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        pos=jnp.zeros((L, batch), jnp.int32),
        positions=jnp.full((L, batch, cap), -1, jnp.int32),
    )
    return DecodeState(caches)


def decode_step(params: dict, state: DecodeState, token: jax.Array,
                cfg: ModelConfig) -> tuple:
    """One decoding step: token (B, 1) -> (logits (B,1,V), new state)."""
    x = embed(params["embed"], token, cfg)
    # Boost MoE capacity for tiny decode batches so routing rarely drops.
    dcfg = cfg.replace(capacity_factor=max(cfg.capacity_factor, 8.0)) \
        if cfg.family == "moe" else cfg

    def body(x, inp):
        lp, cache = inp
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, new_cache = decode_attention_step(lp["attn"], h, cache, cfg,
                                             window=cfg.sliding_window)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, _ = moe_ffn(lp["moe"], h, dcfg)
        else:
            h = mlp(lp["mlp"], h, cfg)
        return x + h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], state.caches))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, DecodeState(new_caches)
