"""Simulation-harness correctness + qualitative reproduction of paper claims,
and the scenario-family registry (samplers, registration, family sets)."""

import numpy as np
import pytest

from repro.sim import (EXPERIMENTS, FAMILY_SETS, IMAGE_FAMILIES,
                       PAPER_FAMILIES, ExperimentSpec, failure_thresholds,
                       gen_instance, register_experiment, run_experiment)
from repro.sim.generators import (JPEG_COMP, JPEG_OUT, bimodal_comp,
                                  correlated_comm, uniform_comp)


def test_generator_ranges():
    for exp in EXPERIMENTS:
        wl, pf = gen_instance(exp, 20, 10, seed=0)
        assert wl.n == 20 and pf.p == 10
        assert pf.b == 10.0
        assert (1 <= pf.s).all() and (pf.s <= 20).all()
        assert (wl.w > 0).all() and (wl.delta >= 0).all()
    wl, _ = gen_instance("E1", 10, 10, 0)
    assert (wl.delta == 10.0).all()
    wl, _ = gen_instance("E3", 10, 10, 0)
    assert wl.w.min() >= 10 and wl.w.max() <= 1000
    wl, _ = gen_instance("E4", 10, 10, 0)
    assert wl.w.max() <= 10.0


def test_family_sets_cover_registry():
    assert set(PAPER_FAMILIES) == {"E1", "E2", "E3", "E4"}
    assert set(IMAGE_FAMILIES) == {"I1", "I2", "I3", "I4"}
    assert set(FAMILY_SETS["all"]) <= set(EXPERIMENTS)
    for exp in PAPER_FAMILIES:
        assert EXPERIMENTS[exp].family == "paper"
    for exp in IMAGE_FAMILIES:
        assert EXPERIMENTS[exp].family == "image"


def test_image_family_structure():
    """I1 tiles the JPEG profile (jitter <= 20%); I3 correlates comm with the
    adjacent stages' work."""
    wl, _ = gen_instance("I1", 21, 10, seed=3)
    base = JPEG_COMP[np.arange(21) % len(JPEG_COMP)]
    assert (np.abs(wl.w / base - 1.0) <= 0.2 + 1e-12).all()
    out = JPEG_OUT[np.arange(21) % len(JPEG_OUT)]
    assert (np.abs(wl.delta[1:] / out - 1.0) <= 0.2 + 1e-12).all()
    wl, _ = gen_instance("I3", 30, 10, seed=3)
    wpad = np.concatenate([wl.w[:1], wl.w, wl.w[-1:]])
    adj = 0.5 * (wpad[:-1] + wpad[1:])
    ratio = wl.delta / adj
    assert (ratio >= 0.5 - 1e-12).all() and (ratio <= 1.5 + 1e-12).all()


def test_register_experiment_flows_through():
    """A custom family registered at runtime generates instances and runs
    through the campaign harness like a built-in one."""
    name = "XTEST"
    register_experiment(ExperimentSpec(
        name, "custom bursty family",
        comp=bimodal_comp(light=(1, 2), heavy=(20, 40), heavy_frac=0.5),
        comm=correlated_comm(rho=0.5), family="custom"))
    try:
        wl, pf = gen_instance(name, 8, 6, seed=1)
        assert wl.n == 8 and pf.p == 6
        res = run_experiment(name, 6, 6, n_pairs=2, n_bounds=3)
        assert set(res.curves) == {"H1", "H2", "H3", "H4", "H5", "H6"}
        # duplicate names are rejected (the built-ins' random streams are
        # part of the seed contract) unless explicitly overridden
        with pytest.raises(ValueError):
            register_experiment(EXPERIMENTS[name])
        register_experiment(EXPERIMENTS[name], override=True)
    finally:
        del EXPERIMENTS[name]


def test_bad_sampler_shape_raises():
    name = "XBAD"
    register_experiment(ExperimentSpec(
        name, "wrong comm shape",
        comp=uniform_comp(1, 5), comm=lambda rng, n, w: np.ones(n)))
    try:
        with pytest.raises(ValueError):
            gen_instance(name, 5, 4, seed=0)
    finally:
        del EXPERIMENTS[name]


def test_generator_determinism():
    a = gen_instance("E2", 10, 10, seed=5)
    b = gen_instance("E2", 10, 10, seed=5)
    assert np.array_equal(a[0].w, b[0].w)
    assert np.array_equal(a[1].s, b[1].s)


def test_run_experiment_structure():
    res = run_experiment("E1", 10, 10, n_pairs=5, n_bounds=6)
    assert set(res.curves) == {"H1", "H2", "H3", "H4", "H5", "H6"}
    for c, (mp, ml, fr) in res.curves.items():
        assert len(mp) == 6
        assert (fr >= 0).all() and (fr <= 1).all()
    # H5/H6 share failure thresholds (paper Table 1 observation)
    assert res.thresholds["H5"] == pytest.approx(res.thresholds["H6"])


def test_failure_threshold_orderings():
    """Qualitative Table-1 claims: H1 has the smallest fixed-period failure
    threshold among H1-H3 (it is the least greedy consumer of processors);
    H5 == H6."""
    thr = failure_thresholds(exps=("E1",), ns=(10, 20), p=10, n_pairs=15)["E1"]
    for n in (10, 20):
        assert thr["H1"][n] <= thr["H2"][n] + 1e-9
        assert thr["H5"][n] == pytest.approx(thr["H6"][n])


def test_latency_period_tradeoff_direction():
    """Fixed-latency heuristics: as the latency budget grows, achieved period
    must not increase (more splitting allowed)."""
    res = run_experiment("E1", 20, 10, n_pairs=8, n_bounds=8)
    for code in ("H5", "H6"):
        mp, ml, fr = res.curves[code]
        ok = ~np.isnan(mp)
        mp = mp[ok]
        assert (np.diff(mp) <= 1e-6).all()
