"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the mathematical definition, written with materialized
intermediates — slow and memory-hungry, but obviously correct.  The kernel
tests sweep shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,T,K,hd).  Materialized softmax attention."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    kf = jnp.repeat(k, G, axis=2)                        # (B,T,H,hd)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / math.sqrt(hd)
    pq = jnp.arange(S)[:, None]
    pk = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= pq >= pk
    if window is not None:
        mask &= (pq - pk) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, mask) -> jax.Array:
    """q: (B,H,hd); k,v: (B,C,K,hd); mask: (B,C)."""
    B, H, hd = q.shape
    C, K = k.shape[1], k.shape[2]
    G = H // K
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bchd->bhc", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhc,bchd->bhd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_residual_ref(x, residual, scale, *, eps: float = 1e-5) -> tuple:
    r = (x.astype(jnp.float32) + residual.astype(jnp.float32))
    return rmsnorm_ref(r.astype(x.dtype), scale, eps=eps), r.astype(x.dtype)


def ssd_ref(x, dt, A, Bmat, Cmat) -> tuple:
    """Sequential (step-by-step) SSD reference.

    x: (B,S,H,P) fp32; dt: (B,S,H); A: (H,); Bmat/Cmat: (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bmat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                            # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A)                         # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    final, ys = jax.lax.scan(
        step, s0, (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                   Bmat.transpose(1, 0, 2), Cmat.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), final
