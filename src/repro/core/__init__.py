"""Core paper library: Benoit/Rehn-Sonigo/Robert 2007, bi-criteria pipeline mapping.

The planning surface is the solver registry (:mod:`repro.core.solvers`) plus
the request/report protocol (:mod:`repro.core.planner`):

    req = PlanRequest(workload, platform, Objective("period"))
    report = plan_request(req)        # -> PlanReport with provenance + Pareto
    front = plan_pareto(workload, platform)   # Pareto-first planning

``plan()`` / ``plan_with_deal()`` remain as thin back-compat facades.  New
algorithms plug in via ``@register_solver`` without touching any consumer.
"""

from .workload import Workload, make_workload, uniform_workload
from .platform import (Platform, make_platform, homogeneous_platform,
                       sample_failures, tpu_pod_platform)
from .metrics import (Mapping, ReplicatedMapping, period, latency, reliability,
                      evaluate, evaluate_batch, evaluate_tri,
                      interval_cycle_times, optimal_latency,
                      single_processor_mapping, intervals_from_cuts,
                      all_interval_partitions)
from .heuristics import (HeuristicResult, run_heuristic, NAMES,
                         FIXED_PERIOD_HEURISTICS, FIXED_LATENCY_HEURISTICS,
                         min_period_exhaustive,
                         sp_mono_p, explo3_mono, explo3_bi, sp_bi_p, sp_mono_l, sp_bi_l)
from .batched import (ProblemBatch, batched_fixed_latency, batched_min_period,
                      batched_sp_bi_p, batched_trajectories, stack_instances)
from .exact import (brute_force, exact_min_period, exact_min_latency,
                    dp_homogeneous_period, dp_speed_ordered, pareto_exact)
from .pareto import (pareto_front, pareto_front_tri, tradeoff_curves,
                     sweep_heuristic, sweep_solver)
from .solvers import (Candidate, Solution, SolverSpec, applicable, get_solver,
                      register_solver, registered_solvers, solve, solver_names)
from .planner import (AUTO_PORTFOLIO, InfeasiblePlan, Objective, PlanReport,
                      PlanRequest, SELECTION_POLICIES, StagePlan, auto_request,
                      plan, plan_pareto, plan_request, register_selection,
                      replan_for_straggler)
from .deal import DealPlan, plan_with_deal
from .replication import (plan_pareto_tri, replicate_greedy,
                          replicate_stage_plan)

__all__ = [
    "Workload", "make_workload", "uniform_workload",
    "Platform", "make_platform", "homogeneous_platform", "sample_failures",
    "tpu_pod_platform",
    "Mapping", "ReplicatedMapping", "period", "latency", "reliability",
    "evaluate", "evaluate_batch", "evaluate_tri",
    "interval_cycle_times", "optimal_latency", "single_processor_mapping",
    "intervals_from_cuts", "all_interval_partitions",
    "HeuristicResult", "run_heuristic", "NAMES",
    "FIXED_PERIOD_HEURISTICS", "FIXED_LATENCY_HEURISTICS",
    "min_period_exhaustive",
    "sp_mono_p", "explo3_mono", "explo3_bi", "sp_bi_p", "sp_mono_l", "sp_bi_l",
    "ProblemBatch", "batched_fixed_latency", "batched_min_period",
    "batched_sp_bi_p", "batched_trajectories", "stack_instances",
    "brute_force", "exact_min_period", "exact_min_latency",
    "dp_homogeneous_period", "dp_speed_ordered", "pareto_exact",
    "pareto_front", "pareto_front_tri", "tradeoff_curves", "sweep_heuristic",
    "sweep_solver",
    "Candidate", "Solution", "SolverSpec", "applicable", "get_solver",
    "register_solver", "registered_solvers", "solve", "solver_names",
    "AUTO_PORTFOLIO", "InfeasiblePlan", "Objective", "PlanReport", "PlanRequest",
    "SELECTION_POLICIES", "StagePlan", "auto_request", "plan", "plan_pareto",
    "plan_request", "register_selection", "replan_for_straggler",
    "DealPlan", "plan_with_deal",
    "plan_pareto_tri", "replicate_greedy", "replicate_stage_plan",
]
