"""Wire protocol for process-isolated fleet workers: CRC-framed stdio pipes.

The controller and a :mod:`repro.fleet.worker_main` subprocess speak a
length-prefixed, CRC-checked frame stream over the child's stdin/stdout:

    frame := magic b"RW" | payload length (u32 LE) | crc32(payload) (u32 LE)
             | payload (canonical JSON, sorted keys, no whitespace)

A frame's payload is ``[kind, body]`` — the same shape as the journal's
event wire records.  The kinds:

  controller -> worker
    ``["solve", {"id", "w", "delta", "s", "b"}]``  — one stacked solve group
    ``["wedge", {"seconds"}]``                     — chaos: sleep before the
                                                     next frame (a wedged
                                                     solve, injected in-band)
    ``["bye", {}]``                                — clean shutdown

  worker -> controller
    ``["hello", {"pid", "backend"}]``              — post-import readiness
    ``["heartbeat", {"pid", "solves"}]``           — periodic liveness beat
    ``["result", {"id", "results"}]``              — the solved group
    ``["error", {"id", "kind", "message"}]``       — the solve raised (the
                                                     worker itself is fine)

Bit-identity is the load-bearing property: solve groups ship as exact-float
JSON (``.tolist()`` + shortest-repr round-trip, the same codec contract as
:mod:`repro.fleet.journal`) and are rebuilt with
:meth:`repro.core.batched.ProblemBatch.from_arrays`, which re-derives
``prefix``/``order`` exactly as the controller would have; results travel
through the journal's :func:`~repro.fleet.journal.encode_result` /
:func:`~repro.fleet.journal.decode_result`.  So a subprocess solve returns
byte-for-byte what an :class:`~repro.fleet.supervision.InlineWorker` would
have produced, and ``fleet_digest()`` cannot tell the transports apart
(asserted in tests/test_fleet_recovery.py and gated as ``fleet_remote_*``
rows).

Corruption anywhere in a frame — magic, length, CRC field, payload — is
*detected*, never silently absorbed: the reader raises :class:`FrameError`
and the supervisor declares the worker's stream poisoned, kills the process,
and replaces it.  A dropped or truncated frame stalls the reply and is reaped
by the controller's solve timeout.  :class:`TransportChaos` injects exactly
these faults at the transport boundary so the recovery paths are exercised,
counted, and gated rather than theoretical.
"""

from __future__ import annotations

import json
import struct
import time
import zlib
from typing import Optional

import numpy as np

from .journal import decode_result, encode_result  # noqa: F401  (re-exported)

MAGIC = b"RW"
_HEADER = struct.Struct("<2sII")   # magic, payload length, crc32(payload)
HEADER_BYTES = _HEADER.size

#: Hard ceiling on a single frame's payload.  Far above any real solve group
#: (the standard trace's groups are a few KB) but small enough that a
#: corrupted length field fails fast instead of waiting on gigabytes that
#: will never arrive.
MAX_FRAME_BYTES = 64 << 20


class FrameError(RuntimeError):
    """A frame failed its magic/length/CRC/parse check — the stream is
    desynchronized or corrupt and cannot be trusted past this point."""


def encode_frame(payload) -> bytes:
    """One wire frame: header (magic, length, CRC) + canonical JSON payload."""
    data = json.dumps(payload, separators=(",", ":"), sort_keys=True).encode()
    if len(data) > MAX_FRAME_BYTES:
        raise FrameError(f"payload of {len(data)} bytes exceeds the "
                         f"{MAX_FRAME_BYTES}-byte frame ceiling")
    return _HEADER.pack(MAGIC, len(data), zlib.crc32(data)) + data


class FrameReader:
    """Incremental frame parser over an arbitrary byte-chunk stream.

    ``feed()`` bytes as they arrive (pipes deliver whatever chunk sizes they
    like), then drain complete frames with ``next_frame()`` — ``None`` means
    the buffered prefix is still incomplete.  Any integrity failure raises
    :class:`FrameError`; there is deliberately NO resynchronization — a
    poisoned stream means a poisoned worker, and the supervisor's job is to
    replace it, not to guess where the next frame starts.
    """

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf += data

    @property
    def buffered(self) -> int:
        return len(self._buf)

    def next_frame(self):
        if len(self._buf) < HEADER_BYTES:
            return None
        magic, length, want = _HEADER.unpack_from(self._buf)
        if magic != MAGIC:
            raise FrameError(f"bad frame magic {bytes(magic)!r} — stream "
                             "desynchronized")
        if length > MAX_FRAME_BYTES:
            raise FrameError(f"frame length {length} exceeds the "
                             f"{MAX_FRAME_BYTES}-byte ceiling (corrupt "
                             "length field)")
        if len(self._buf) < HEADER_BYTES + length:
            return None
        data = bytes(self._buf[HEADER_BYTES:HEADER_BYTES + length])
        del self._buf[:HEADER_BYTES + length]
        got = zlib.crc32(data)
        if got != want:
            raise FrameError(f"frame CRC mismatch: header says {want:08x}, "
                             f"payload hashes to {got:08x}")
        try:
            payload = json.loads(data.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise FrameError(f"unparseable frame payload: {e}") from None
        if not (isinstance(payload, list) and len(payload) == 2
                and isinstance(payload[0], str)):
            raise FrameError(f"frame payload is not [kind, body]: "
                             f"{payload!r}")
        return payload


# ---------------------------------------------------------------------------
# Solve-group / result codecs (exact floats, like the journal's)
# ---------------------------------------------------------------------------

def encode_solve(request_id: int, batch) -> list:
    """``["solve", ...]`` payload for one stacked solve group.  Ships the raw
    (w, delta, s, b) arrays; ``prefix``/``order`` are re-derived on the
    worker side by ``ProblemBatch.from_arrays`` — bit-identically, because
    derivation is deterministic and the floats round-trip JSON exactly."""
    return ["solve", {"id": int(request_id),
                      "w": np.asarray(batch.w).tolist(),
                      "delta": np.asarray(batch.delta).tolist(),
                      "s": np.asarray(batch.s).tolist(),
                      "b": float(batch.b)}]


def decode_solve(body: dict):
    """Rebuild the :class:`~repro.core.batched.ProblemBatch` on the worker."""
    from ..core.batched import ProblemBatch

    return ProblemBatch.from_arrays(body["w"], body["delta"], body["s"],
                                    body["b"])


def encode_results(request_id: int, results) -> list:
    """``["result", ...]`` payload: the journal's exact-float result codec,
    one entry per batch row."""
    return ["result", {"id": int(request_id),
                       "results": [encode_result(r) for r in results]}]


def decode_results(body: dict) -> list:
    return [decode_result(d) for d in body["results"]]


# ---------------------------------------------------------------------------
# Wire-level fault injection
# ---------------------------------------------------------------------------

class TransportChaos:
    """Seeded fault injection at the subprocess transport boundary.

    The storm/flap/delivery chaos of :mod:`repro.fleet.chaos` attacks the
    *telemetry* plane; this attacks the *worker* plane — the fault classes a
    real remote host exhibits:

      - ``doa_prob``       (per spawn)    worker dead on arrival (killed
                                          before its first heartbeat)
      - ``kill_prob``      (per dispatch) SIGKILL mid-solve, after the
                                          request is on the wire
      - ``wedge_prob``     (per dispatch) in-band ``wedge`` frame: the worker
                                          sleeps ``wedge_seconds`` — a hung
                                          solve the timeout must reap
      - ``drop_prob``      (per chunk)    inbound reply bytes silently lost
      - ``corrupt_prob``   (per chunk)    one inbound byte flipped (CRC or
                                          magic check trips)
      - ``truncate_prob``  (per chunk)    inbound chunk cut short (stalls or
                                          desyncs the stream)
      - ``delay_prob``     (per chunk)    inbound delivery delayed
                                          ``delay_seconds``

    Drop/truncate leave the controller waiting on a reply that never
    completes, so those faults are only recoverable with a solve ``timeout``
    configured — which is the point: the harness proves the timeout path.

    ``max_faults`` caps the total number of injections (deterministic tests,
    bounded bench restarts); ``counts`` records what actually fired, which
    the bench turns into the gated restart ceiling — every worker restart
    must be attributable to an injected fault.
    """

    _PROBS = ("doa_prob", "kill_prob", "wedge_prob", "drop_prob",
              "corrupt_prob", "truncate_prob", "delay_prob")

    def __init__(self, *, doa_prob: float = 0.0, kill_prob: float = 0.0,
                 wedge_prob: float = 0.0, wedge_seconds: float = 30.0,
                 drop_prob: float = 0.0, corrupt_prob: float = 0.0,
                 truncate_prob: float = 0.0, delay_prob: float = 0.0,
                 delay_seconds: float = 0.02,
                 max_faults: Optional[int] = None, seed: int = 0):
        for name, v in [("doa_prob", doa_prob), ("kill_prob", kill_prob),
                        ("wedge_prob", wedge_prob), ("drop_prob", drop_prob),
                        ("corrupt_prob", corrupt_prob),
                        ("truncate_prob", truncate_prob),
                        ("delay_prob", delay_prob)]:
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must be a probability, got {v}")
        if wedge_seconds < 0 or delay_seconds < 0:
            raise ValueError("wedge_seconds/delay_seconds must be >= 0")
        if max_faults is not None and max_faults < 0:
            raise ValueError(f"max_faults must be >= 0, got {max_faults}")
        self.doa_prob = doa_prob
        self.kill_prob = kill_prob
        self.wedge_prob = wedge_prob
        self.wedge_seconds = wedge_seconds
        self.drop_prob = drop_prob
        self.corrupt_prob = corrupt_prob
        self.truncate_prob = truncate_prob
        self.delay_prob = delay_prob
        self.delay_seconds = delay_seconds
        self.max_faults = max_faults
        self.rng = np.random.default_rng(seed)
        self.counts: dict = {}

    def total_faults(self) -> int:
        return sum(self.counts.values())

    def _fire(self, kind: str, prob: float) -> bool:
        if prob <= 0.0:
            return False
        if (self.max_faults is not None
                and self.total_faults() >= self.max_faults):
            return False
        if self.rng.random() >= prob:
            return False
        self.counts[kind] = self.counts.get(kind, 0) + 1
        return True

    # -- decision points (called by SubprocessWorker) -------------------------

    def spawn_dead_on_arrival(self) -> bool:
        return self._fire("doa", self.doa_prob)

    def kill_mid_solve(self) -> bool:
        return self._fire("kill", self.kill_prob)

    def wedge_solve(self) -> bool:
        return self._fire("wedge", self.wedge_prob)

    def mangle_chunk(self, chunk: bytes) -> Optional[bytes]:
        """Pass one inbound chunk through the lossy wire.  Returns the
        (possibly mangled) chunk, or ``None`` when it was dropped; a delay
        fault sleeps before delivering.  With all probabilities zero the
        chunk comes back untouched — chaos-disabled transport is
        byte-identical."""
        if not chunk:
            return chunk
        if self._fire("drop", self.drop_prob):
            return None
        if len(chunk) > 1 and self._fire("truncate", self.truncate_prob):
            return chunk[: int(self.rng.integers(1, len(chunk)))]
        if self._fire("corrupt", self.corrupt_prob):
            i = int(self.rng.integers(len(chunk)))
            mangled = bytearray(chunk)
            mangled[i] ^= 0xFF
            return bytes(mangled)
        if self._fire("delay", self.delay_prob):
            time.sleep(self.delay_seconds)
        return chunk
