"""Random application/platform generators for the paper's experiments (5.1).

Common to all experiments: b = 10, processor speeds uniform integers in
[1, 20].  Per-experiment application parameters:

  E1  balanced comm/comp, homogeneous comms:     delta_i = 10,        w in [1, 20]
  E2  balanced comm/comp, heterogeneous comms:   delta in [1, 100],   w in [1, 20]
  E3  large computations:                        delta in [1, 20],    w in [10, 1000]
  E4  small computations:                        delta in [1, 20],    w in [0.01, 10]

The paper draws integer w for E1-E3 ("randomly chosen between 1 and 20");
E4's range [0.01, 10] is continuous.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from ..core import Platform, Workload


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    name: str
    description: str
    gen_delta: Callable  # (rng, n) -> (n+1,) array
    gen_w: Callable      # (rng, n) -> (n,) array


EXPERIMENTS = {
    "E1": ExperimentSpec(
        "E1", "balanced comm/comp, homogeneous comms",
        lambda rng, n: np.full(n + 1, 10.0),
        lambda rng, n: rng.integers(1, 21, n).astype(float),
    ),
    "E2": ExperimentSpec(
        "E2", "balanced comm/comp, heterogeneous comms",
        lambda rng, n: rng.integers(1, 101, n + 1).astype(float),
        lambda rng, n: rng.integers(1, 21, n).astype(float),
    ),
    "E3": ExperimentSpec(
        "E3", "large computations",
        lambda rng, n: rng.integers(1, 21, n + 1).astype(float),
        lambda rng, n: rng.integers(10, 1001, n).astype(float),
    ),
    "E4": ExperimentSpec(
        "E4", "small computations",
        lambda rng, n: rng.integers(1, 21, n + 1).astype(float),
        lambda rng, n: rng.uniform(0.01, 10.0, n),
    ),
}

BANDWIDTH = 10.0
SPEED_LOW, SPEED_HIGH = 1, 20


def gen_instance(exp: str, n: int, p: int, seed: int) -> tuple:
    """One random (workload, platform) pair for experiment ``exp``."""
    spec = EXPERIMENTS[exp]
    rng = np.random.default_rng(seed)
    w = spec.gen_w(rng, n)
    delta = spec.gen_delta(rng, n)
    s = rng.integers(SPEED_LOW, SPEED_HIGH + 1, p).astype(float)
    return (
        Workload(w, delta, name=f"{exp}-n{n}-seed{seed}"),
        Platform(s, BANDWIDTH, name=f"{exp}-p{p}-seed{seed}"),
    )


@dataclasses.dataclass
class InstanceBatch:
    """A campaign's instances as stacked structure-of-arrays state.

    Rows are the instances of :func:`gen_instance` for ``seeds`` (identical
    draws — the per-instance objects are kept in ``workloads``/``platforms``
    for the scalar reference path and for tests).  ``prefix`` (stage-work
    prefix sums) and ``order`` (speed-sorted processor indices) are
    precomputed once here; the batched engine (:mod:`repro.core.batched`)
    consumes this object directly.
    """

    exp: str
    n: int
    p: int
    seeds: tuple
    w: np.ndarray          # (B, n)
    delta: np.ndarray      # (B, n+1)
    s: np.ndarray          # (B, p)
    b: float
    prefix: np.ndarray     # (B, n+1)
    order: np.ndarray      # (B, p) int
    workloads: tuple       # per-instance Workload objects
    platforms: tuple       # per-instance Platform objects

    def __len__(self) -> int:
        return len(self.seeds)

    def __iter__(self):
        return iter(zip(self.workloads, self.platforms))

    def instance(self, i: int) -> tuple:
        return self.workloads[i], self.platforms[i]


def gen_instance_batch(exp: str, n: int, p: int, seeds: Sequence[int]) -> InstanceBatch:
    """B random instances stacked for the batched campaign engine."""
    pairs = [gen_instance(exp, n, p, seed=int(sd)) for sd in seeds]
    return InstanceBatch(
        exp=exp, n=n, p=p, seeds=tuple(int(sd) for sd in seeds),
        w=np.stack([wl.w for wl, _ in pairs]),
        delta=np.stack([wl.delta for wl, _ in pairs]),
        s=np.stack([pf.s for _, pf in pairs]),
        b=BANDWIDTH,
        prefix=np.stack([wl.prefix_w() for wl, _ in pairs]),
        order=np.stack([pf.sorted_indices() for _, pf in pairs]),
        workloads=tuple(wl for wl, _ in pairs),
        platforms=tuple(pf for _, pf in pairs),
    )
