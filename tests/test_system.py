"""End-to-end behaviour tests: training learns, serving completes, and the
dry-run machinery works on a small mesh (subprocess)."""

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_training_reduces_loss():
    """~100-step smoke train on the synthetic motif stream must learn."""
    from repro.launch.train import train_loop

    out = train_loop(arch="qwen3-4b", smoke=True, steps=60, batch=8, seq=64,
                     log_every=1000)
    assert out["steps_run"] == 60
    assert out["final_loss"] < out["first_loss"] - 0.2, out


def test_serving_completes_all_requests():
    from repro.launch.serve import serve_pool

    out = serve_pool(arch="qwen3-4b", smoke=True, n_requests=6, batch=2,
                     prompt_len=8, max_new=8)
    assert out["all_done"]
    assert out["tokens_generated"] == 6 * 8


_DRYRUN_SMALL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.launch.hlo_analysis import analyze
    from repro.models import get_model, make_train_step
    from repro.models.sharding import named, param_specs, zero1_specs, batch_spec
    from repro.models.train import init_optimizer
    from repro.optim.adamw import AdamWState

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("qwen2.5-14b").replace(d_model=128, n_heads=8,
                                                  n_kv_heads=2, d_ff=256)
    api = get_model(cfg)
    with jax.set_mesh(mesh):
        params_sds = jax.eval_shape(api.init, jax.random.key(0))
        pn = named(param_specs(params_sds, cfg, mesh), mesh)
        opt_sds = jax.eval_shape(init_optimizer, params_sds)
        zn = named(zero1_specs(params_sds, cfg, mesh), mesh)
        on = AdamWState(step=NamedSharding(mesh, P()), m=zn, v=zn)
        batch_sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bn = {k: NamedSharding(mesh, P(("pod", "data"))) for k in batch_sds}
        ts = make_train_step(api.forward, cfg)
        lowered = jax.jit(ts, in_shardings=(pn, on, bn),
                          out_shardings=(pn, on, None),
                          donate_argnums=(0, 1)).lower(params_sds, opt_sds,
                                                       batch_sds)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    res = analyze(compiled.as_text())
    assert res["dot_flops"] > 0
    assert res["collective_bytes"] > 0          # DP gradient sync must appear
    assert mem.temp_size_in_bytes > 0
    print("DRYRUN_SMALL_OK", res["dot_flops"], res["collective_bytes"])
""")


@pytest.mark.slow
def test_dryrun_machinery_small_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SMALL], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "DRYRUN_SMALL_OK" in r.stdout


def test_production_mesh_shapes():
    """make_production_mesh contract (shape/axes), via subprocess with 512
    fake devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.size == 512
        print("MESH_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=240)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MESH_OK" in r.stdout
