"""Reliability layer: failure samplers, greedy replication, -rel solvers,
tri-criteria planning, and the R experiment families.

The consensus model of the sequel paper (arXiv 0711.1231): interval j runs
replicated on a disjoint processor set, every replica processes every data
set, so period/latency are charged at the slowest replica and the interval
fails only when ALL replicas fail — R = prod_j (1 - prod_{u in g_j} f_u).
"""

import numpy as np
import pytest

from repro.core import (Objective, ReplicatedMapping, evaluate_batch,
                        evaluate_tri, latency, pareto_front_tri, period,
                        plan_pareto, plan_pareto_tri, reliability,
                        replicate_greedy, sample_failures, solve)
from repro.sim import RELIABILITY_FAMILIES
from repro.sim.generators import gen_instance

SEED = 1234


def _instance(exp="R1", n=8, p=6, seed=SEED):
    return gen_instance(exp, n, p, seed=seed)


# ---------------------------------------------------------------------------
# Failure samplers
# ---------------------------------------------------------------------------

def test_sample_failures_deterministic_and_bounded():
    for kind in ("uniform", "bimodal", "loguniform"):
        a = sample_failures(16, kind=kind, seed=3)
        b = sample_failures(16, kind=kind, seed=3)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (16,)
        assert np.all(a >= 0.0) and np.all(a < 1.0)
    with pytest.raises(ValueError):
        sample_failures(4, kind="nope", seed=0)


def test_r_families_share_workload_streams():
    """R1 draws failure probabilities LAST: its workload and speeds are
    byte-identical to E2's at the same (n, p, seed) — reliability columns can
    be compared against bi-criteria results on literally the same instances."""
    wl_r, pf_r = _instance("R1")
    wl_e, pf_e = _instance("E2")
    np.testing.assert_array_equal(wl_r.w, wl_e.w)
    np.testing.assert_array_equal(wl_r.delta, wl_e.delta)
    np.testing.assert_array_equal(pf_r.s, pf_e.s)
    assert pf_e.fail is None
    assert pf_r.fail is not None and np.all(pf_r.fail > 0)
    for exp in RELIABILITY_FAMILIES:
        _, pf = _instance(exp)
        assert pf.fail is not None


# ---------------------------------------------------------------------------
# Greedy replication
# ---------------------------------------------------------------------------

def _base_plan(wl, pf):
    return solve("H5", wl, pf, Objective("period"))


def test_replicate_greedy_valid_and_improves():
    wl, pf = _instance()
    base = _base_plan(wl, pf).mapping
    rm = replicate_greedy(wl, pf, base)
    rm.validate(wl.n, pf.p)
    assert reliability(wl, pf, rm) >= reliability(wl, pf, base)
    assert rm.intervals == tuple(base.intervals)
    assert rm.alloc == tuple(base.alloc)   # leaders are the base processors


def test_replicate_greedy_respects_period_bound():
    wl, pf = _instance()
    base = _base_plan(wl, pf).mapping
    bound = period(wl, pf, base) * 1.05
    rm = replicate_greedy(wl, pf, base, period_bound=bound)
    assert period(wl, pf, rm) <= bound * (1 + 1e-12)
    assert reliability(wl, pf, rm) >= reliability(wl, pf, base)


def test_replicate_greedy_stops_at_target():
    wl, pf = _instance()
    base = _base_plan(wl, pf).mapping
    full = replicate_greedy(wl, pf, base)
    target = 0.5 * (reliability(wl, pf, base) + reliability(wl, pf, full))
    rm = replicate_greedy(wl, pf, base, target=target)
    assert reliability(wl, pf, rm) >= target - 1e-12
    assert (sum(len(g) for g in rm.groups)
            <= sum(len(g) for g in full.groups))


def test_replicate_greedy_no_failures_is_identity():
    wl, pf = _instance("E2")
    assert pf.fail is None
    base = _base_plan(wl, pf).mapping
    rm = replicate_greedy(wl, pf, base)
    assert all(len(g) == 1 for g in rm.groups)
    assert period(wl, pf, rm) == period(wl, pf, base)
    assert latency(wl, pf, rm) == latency(wl, pf, base)


# ---------------------------------------------------------------------------
# -rel solvers and the tri-criteria portfolio
# ---------------------------------------------------------------------------

def test_rel_solver_degenerates_to_plain_without_failures():
    """On a failure-free platform H1-rel IS H1: same mapping, same metrics,
    bit for bit."""
    wl, pf = _instance("E2")
    from repro.core import min_period_exhaustive
    bound = 2.0 * min_period_exhaustive(wl, pf).period
    plain = solve("H1", wl, pf, Objective("latency", bound=bound))
    rel = solve("H1-rel", wl, pf, Objective("latency", bound=bound))
    assert rel.feasible and plain.feasible
    assert rel.mapping == plain.mapping
    assert rel.period == plain.period
    assert rel.latency == plain.latency


def test_rel_solver_meets_bound_and_replicates():
    wl, pf = _instance("R2", n=8, p=8)
    from repro.core import min_period_exhaustive
    bound = 2.0 * min_period_exhaustive(wl, pf).period
    cand = solve("H1-rel", wl, pf, Objective("latency", bound=bound))
    assert cand.feasible
    assert cand.period <= bound * (1 + 1e-9)
    assert cand.reliability is not None


def test_plan_pareto_tri_front_nondominated():
    wl, pf = _instance("R1", n=8, p=6)
    report = plan_pareto_tri(wl, pf, k=6)
    assert report.plan is not None
    front = report.pareto
    assert front and all(len(pt) == 3 for pt in front)
    for a in front:
        for b in front:
            if a is b:
                continue
            assert not (b[0] <= a[0] * (1 + 1e-12)
                        and b[1] <= a[1] * (1 + 1e-12)
                        and b[2] >= a[2] * (1 - 1e-12)
                        and (b[0] < a[0] or b[1] < a[1] or b[2] > a[2]))


def test_plan_pareto_tri_floor_prefers_reliable_plans():
    """With a reliability floor, the chosen plan clears it when any candidate
    can; and the knee never picks something less reliable than what the
    bi-criteria portfolio would have shipped."""
    wl, pf = _instance("R2", n=8, p=8)
    tri = plan_pareto_tri(wl, pf, reliability_floor=0.95)
    rm = (ReplicatedMapping(tri.plan.mapping.intervals, tri.plan.groups)
          if tri.plan.groups is not None else tri.plan.mapping)
    tri_rel = reliability(wl, pf, rm)
    best = max(pt[2] for pt in tri.pareto)
    if best >= 0.95:
        assert tri_rel >= 0.95 - 1e-9
    bi = plan_pareto(wl, pf)
    assert tri_rel >= reliability(wl, pf, bi.plan.mapping) - 1e-12


def test_pareto_front_tri_hand_case():
    pts = [
        (1.0, 9.0, 0.90),   # fast, short, fragile       -> kept
        (1.0, 9.0, 0.99),   # same but more reliable     -> dominates above
        (2.0, 8.0, 0.95),   # slower period, better lat  -> kept
        (3.0, 9.5, 0.90),   # dominated by all           -> dropped
        (0.5, 20.0, 0.50),  # fastest period             -> kept
    ]
    front = pareto_front_tri(pts)
    assert (1.0, 9.0, 0.99) in front
    assert (1.0, 9.0, 0.90) not in front
    assert (3.0, 9.5, 0.90) not in front
    assert (2.0, 8.0, 0.95) in front
    assert (0.5, 20.0, 0.50) in front


# ---------------------------------------------------------------------------
# Vectorized reliability column
# ---------------------------------------------------------------------------

def test_evaluate_batch_reliability_matches_scalar():
    wl, pf = _instance("R3", n=8, p=8)
    base = _base_plan(wl, pf).mapping
    rm = replicate_greedy(wl, pf, base)
    mappings = [base, rm, base]
    out = evaluate_batch(wl, pf, mappings, with_reliability=True)
    assert out.shape == (3, 3)
    for row, mp in zip(out, mappings):
        per, lat, rel = evaluate_tri(wl, pf, mp)
        assert row[0] == per and row[1] == lat and row[2] == rel


def test_evaluate_batch_reliability_ones_without_failures():
    wl, pf = _instance("E2")
    base = _base_plan(wl, pf).mapping
    out = evaluate_batch(wl, pf, [base, base], with_reliability=True)
    np.testing.assert_array_equal(out[:, 2], [1.0, 1.0])
