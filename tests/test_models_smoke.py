"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness assertions) and decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import get_model, init_optimizer, make_train_step


def _batch(cfg, B, S):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vis_tokens, cfg.d_model)) * 0.02, cfg.jdtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)) * 0.02, cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = _batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: api.forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    ts = jax.jit(make_train_step(api.forward, cfg))
    p2, o2, metrics = ts(params, init_optimizer(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B = 2
    state = api.init_decode_state(B, 32)
    dec = jax.jit(api.decode)
    logits, state = dec(params, state, jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    # a second step must consume the updated state without recompile errors
    logits2, _ = dec(params, state, jnp.full((B, 1), 2, jnp.int32))
    assert not np.isnan(np.asarray(logits2, np.float32)).any()


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mixtral-8x7b", "zamba2-7b",
                                  "xlstm-350m"])
def test_decode_matches_forward(arch):
    """Incremental decode must reproduce the teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        # align train-time capacity dropping with the boosted decode capacity
        cfg = cfg.replace(capacity_factor=8.0)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 1, 12
    rng = np.random.default_rng(3)
    toks = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    full_logits, _ = jax.jit(lambda p, b: api.forward(p, b, cfg))(params, batch)

    state = api.init_decode_state(B, 32)
    dec = jax.jit(api.decode)
    outs = []
    for t in range(S):
        lg, state = dec(params, state, jnp.asarray(toks[:, t:t + 1]))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec_logits = np.stack(outs, axis=1)
    want = np.asarray(full_logits, np.float32)
    # chunked-parallel vs sequential recurrences in bf16: numeric closeness
    # (argmax on random-init near-flat logits is not a stable criterion)
    err = np.abs(dec_logits - want)
    rel = err.mean() / (np.abs(want).mean() + 1e-9)
    assert err.max() < 0.35, f"max err {err.max()}"
    assert rel < 0.05, f"mean relative err {rel}"


def test_param_count_analytic_close_to_actual():
    from repro.models.common import param_count

    for arch in ("qwen3-4b", "mixtral-8x7b", "whisper-large-v3"):
        cfg = get_smoke_config(arch)
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = param_count(cfg)
        assert abs(actual - analytic) / actual < 0.35, (arch, actual, analytic)
