"""Fleet replanning benchmark: burst-trace replay through the service.

Replays the *standard trace* — a fixed-seed correlated burst trace over a
replicated fleet — through :class:`repro.fleet.ReplanService` and records
ROADMAP item 2's success metrics as ``fleet_replan_*`` rows:

  - ``fleet_replan_throughput`` — replans/sec over the whole replay
  - ``fleet_replan_latency``    — p50/p99 per-request replan latency
  - ``fleet_replan_dedup``      — signature dedup hit-rate (gated floor)
  - ``fleet_replan_churn``      — mean fraction of layers remapped

With ``--chaos`` the same standard trace is run through
:func:`repro.fleet.inject_chaos` (pod-failure storms, flapping pods, event
drop/dup/reorder) against a fleet whose platforms carry seeded failure
probabilities, with a ``reliability_floor`` enabled; the graceful-degradation
counters land as ``fleet_chaos_*`` rows.  The chaos run deliberately leaves
``solve_deadline`` off: wall-clock deferral is machine-dependent, and the
gated numbers (zero invalid published plans, bounded floor recovery) must be
deterministic.  The deadline path is covered by tests/test_fleet.py instead.

With ``--recovery`` the standard chaos trace is run through
:func:`repro.fleet.crash_restart_run`: the controller is journaled
(write-ahead log + snapshots), killed mid-tick at two seeded ticks, and
restarted from its journal each time.  The ``fleet_recovery_*`` rows record
the restore wall time, the WAL replay length, and — the gated contract —
whether the survivor's ``fleet_digest()`` is bit-identical to an
uninterrupted run with zero invalid published ticks and zero quarantines.

Unlike ``planner_bench.py`` (which regenerates BENCH_planner.json wholesale),
this script MERGES its rows into the existing file so the two benchmarks can
run independently; ``benchmarks/bench_gate.py`` requires the rows and gates
the dedup and throughput floors.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] [--chaos]
                                                    [--recovery] [--backend B]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
BENCH_JSON = REPO_ROOT / "BENCH_planner.json"

from repro.core import sample_failures  # noqa: E402
from repro.fleet import (ChaosSpec, Journal, ReplanService,  # noqa: E402
                         crash_restart_run, gen_burst_trace, inject_chaos,
                         make_fleet)

# The standard trace: every number fixed so the measured dedup hit-rate and
# throughput are comparable across PRs (bench_gate floors assume this shape).
STANDARD = dict(n_groups=16, replicas=16, n=12, p=6, fleet_seed=2007,
                num_ticks=30, trace_seed=42, burst_prob=0.6)
QUICK = dict(n_groups=6, replicas=8, n=8, p=4, fleet_seed=2007,
             num_ticks=12, trace_seed=42, burst_prob=0.6)
# The standard chaos overlay: seeded fault injection + per-group bimodal
# failure probabilities + a reliability floor for the repair pass.  The 0.98
# floor is deliberately strict enough that storm-degraded platforms cannot
# always reach it until flapped capacity returns — that is what produces the
# below-floor time and the recovery latencies the gate bounds (measured 428
# instance-ticks below / 19 recoveries / max 18 ticks on this trace).
CHAOS = dict(chaos_seed=77, fail_seed=5, reliability_floor=0.98)
# The recovery run crashes the controller at 1/3 and 2/3 of the trace (one
# crash lands mid-snapshot-interval, one right after a cadence snapshot) and
# snapshots every 8 ticks — so the gated max WAL replay length is <= 8.
RECOVERY = dict(snapshot_every=8, crash_fracs=(1 / 3, 2 / 3))


def _with_failures(pairs, seed: int) -> list:
    """Attach seeded bimodal failure probabilities, one draw per platform
    template so replicas keep sharing their platform (dedup stays honest)."""
    shared: dict = {}
    out = []
    for wl, pf in pairs:
        if id(pf) not in shared:
            shared[id(pf)] = pf.with_failures(sample_failures(
                pf.p, kind="bimodal", seed=seed + len(shared)))
        out.append((wl, shared[id(pf)]))
    return out


def run(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    svc = ReplanService(pairs, backend=backend)
    metrics = svc.run_trace(trace)
    extra = {"backend": backend, "fleet_size": len(pairs),
             "digest": svc.fleet_digest()}
    return metrics.bench_rows(extra=extra)


def run_chaos(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    pairs = _with_failures(pairs, CHAOS["fail_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    trace = inject_chaos(trace, groups, ChaosSpec(),
                         seed=CHAOS["chaos_seed"], initial_pods=cfg["p"])
    svc = ReplanService(pairs, backend=backend,
                        reliability_floor=CHAOS["reliability_floor"])
    metrics = svc.run_trace(trace)
    extra = {"backend": backend, "fleet_size": len(pairs),
             "reliability_floor": CHAOS["reliability_floor"],
             "chaos_seed": CHAOS["chaos_seed"],
             "digest": svc.fleet_digest()}
    return metrics.chaos_rows(extra=extra)


def run_recovery(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    pairs = _with_failures(pairs, CHAOS["fail_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    trace = inject_chaos(trace, groups, ChaosSpec(),
                         seed=CHAOS["chaos_seed"], initial_pods=cfg["p"])
    svc_kwargs = dict(backend=backend,
                      reliability_floor=CHAOS["reliability_floor"])
    ref = ReplanService(pairs, **svc_kwargs)
    ref.run_trace(trace)
    crash_ticks = sorted({max(1, int(cfg["num_ticks"] * f))
                          for f in RECOVERY["crash_fracs"]})
    with tempfile.TemporaryDirectory() as d:
        journal = Journal(d, snapshot_every=RECOVERY["snapshot_every"],
                          fsync=False)
        svc, restarts = crash_restart_run(pairs, trace, journal,
                                          crash_ticks=crash_ticks,
                                          **svc_kwargs)
    match = svc.fleet_digest() == ref.fleet_digest()
    replayed = max(r["replayed_ticks"] for r in restarts)
    wall = sum(r["restore_wall"] for r in restarts)
    shared = {"backend": backend, "fleet_size": len(pairs),
              "crash_ticks": crash_ticks,
              "snapshot_every": RECOVERY["snapshot_every"]}
    return [
        ("fleet_recovery_restart", wall * 1e6 / len(restarts),
         f"{len(restarts)} crash/restart cycles, max {replayed} WAL ticks "
         f"replayed, {wall:.3f}s total restore wall",
         dict(shared, restarts=len(restarts), max_replayed_ticks=replayed,
              total_restore_wall_s=wall)),
        ("fleet_recovery_digest", None,
         f"restored fleet digest "
         f"{'matches' if match else 'MISMATCHES'} the uninterrupted run "
         f"({svc.metrics.invalid_published} invalid published, "
         f"{svc.metrics.quarantined_problems} quarantined)",
         dict(shared, digest_match=bool(match), digest=svc.fleet_digest(),
              ref_digest=ref.fleet_digest(), ticks=svc.metrics.ticks,
              invalid_published=svc.metrics.invalid_published,
              quarantined_problems=svc.metrics.quarantined_problems)),
    ]


def merge_bench_json(rows, path: pathlib.Path = BENCH_JSON,
                     mode: str = "full") -> None:
    """Merge rows into the existing BENCH json (planner_bench owns the file
    and overwrites it wholesale; we only add/update our rows)."""
    payload = json.loads(path.read_text()) if path.exists() else {}
    payload.setdefault("_meta", {})["mode"] = mode
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        entry = {"us_per_call": us, "derived": derived}
        if len(row) > 3 and row[3]:
            entry.update(row[3])
        payload[name] = entry
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="run the standard trace through fault injection and "
                         "emit fleet_chaos_* robustness rows instead")
    ap.add_argument("--recovery", action="store_true",
                    help="crash/restart the journaled controller mid-trace "
                         "and emit fleet_recovery_* durability rows instead")
    ap.add_argument("--backend", default="numpy")
    args = ap.parse_args()
    runner = (run_recovery if args.recovery
              else run_chaos if args.chaos else run)
    rows = runner(quick=args.quick, backend=args.backend)
    for name, us, derived, _ in rows:
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}")
    merge_bench_json(rows, mode="quick" if args.quick else "full")
    print(f"# merged into {BENCH_JSON}")


if __name__ == "__main__":
    main()
