"""The paper's own experimental configurations (Section 5.1) as framework
configs, plus TPU-cluster planner presets."""

from __future__ import annotations

from ..core import Platform, Workload, tpu_pod_platform
from ..sim.generators import gen_instance


def paper_instance(exp: str = "E1", n: int = 20, p: int = 10, seed: int = 0):
    """One of the paper's random (workload, platform) pairs."""
    return gen_instance(exp, n, p, seed)


def tpu_two_pod_platform(straggler: dict | None = None) -> Platform:
    """The production dry-run target: 2 pods x 256 chips, DCN-linked."""
    return tpu_pod_platform(pods=2, chips_per_pod=256, degraded=straggler)


def tpu_many_pod_platform(pods: int = 8, straggler: dict | None = None) -> Platform:
    """1000+-chip scale-out preset (8 pods x 256 = 2048 chips)."""
    return tpu_pod_platform(pods=pods, chips_per_pod=256, degraded=straggler)
