"""Kernel micro-bench: per-call time of the jnp reference paths (the kernels
themselves run interpret-mode on CPU, so wall-times are structural only) and
the block-pair schedule's FLOP savings (the number that matters on TPU)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.models.attention import _block_pairs


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    B, S, H, K, hd = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.float32)

    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    rows.append(("attention_ref_1k", _time(fa, q, k, v), ""))

    x = jnp.asarray(rng.normal(size=(8, 512, 1024)), jnp.bfloat16)
    sc = jnp.ones((1024,), jnp.float32)
    rn = jax.jit(lambda x, s: ref.rmsnorm_ref(x, s))
    rows.append(("rmsnorm_ref_4M", _time(rn, x, sc), ""))

    # block-pair schedule density: compiled attention FLOPs vs dense S^2
    for S2, win in ((32768, None), (32768, 4096), (524288, 4096)):
        nq = S2 // 512
        pairs = len(_block_pairs(nq, nq, 512, 512, causal=True, window=win))
        density = pairs / (nq * nq)
        rows.append((f"attn_sched_S{S2}_win{win}", 0.0, f"density={density:.4f}"))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
