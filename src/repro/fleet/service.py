"""The fleet controller loop: collect → dedup → warm-start → batch → publish.

Per tick the service applies every arriving drift event to its instance's
state (EWMA straggler monitor, platform degradation, elastic resize, pod
removal), collects the *dirty* instances — those whose effective platform
changed — and answers all of their replan requests together:

  1. each dirty instance's problem is canonicalized and signed
     (:mod:`repro.fleet.signatures`); instances that are the same problem up
     to processor relabeling share one signature,
  2. signatures already in the cross-tick plan cache are warm-start hits:
     the previous solve is reused byte-for-byte (exact-bytes signatures mean
     a hit can never change a result, only skip work),
  3. the remaining distinct problems are grouped by (n, p, b) shape, stacked
     with :meth:`ProblemBatch.from_arrays`, and solved in two lockstep runs
     per group via :func:`repro.core.batched.batched_min_period` —
     thousands of requests become a handful of engine programs,
  4. every dirty instance receives its plan by remapping the canonical
     allocation through its own speed-sort permutation and is republished as
     a :class:`StagePlan`; its straggler monitor resets to the new stage
     count.

The published plans are bit-identical to running the scalar portfolio
``min_period_exhaustive(workload, platform)`` per instance (relabeling
theorem + the batched engine's equivalence contract; asserted in
tests/test_fleet.py).

Graceful degradation (the chaos-harness contract, tests/test_fleet.py +
``fleet_bench.py --chaos``):

  - ``solve_deadline`` — a per-tick solve budget in seconds.  Groups past
    the budget are NOT solved this tick: their instances keep their last
    valid plan and are retried next tick.  Instances whose current plan is
    *invalid* (it addresses pods that no longer exist) are never deferred —
    their groups solve regardless of the budget, which is what guarantees
    zero ticks ending with an invalid published plan.
  - scalar fallback — when a batched group solve raises, each member is
    re-solved with the scalar reference portfolio on its canonical problem
    (bit-identical by the equivalence contract), so one poisoned batch
    degrades throughput, not correctness.
  - ``reliability_floor`` — when platforms carry failure probabilities, any
    instance whose plan's reliability drops below the floor gets a greedy
    replication pass (:func:`repro.core.replication.replicate_stage_plan`);
    time spent below the floor and recovery latency are counted in
    :class:`FleetMetrics` and floor-gated in ``bench_gate.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Optional, Sequence

import numpy as np

from ..core import (Mapping, Platform, ReplicatedMapping, StagePlan,
                    interval_cycle_times, min_period_exhaustive, reliability)
from ..core.batched import ProblemBatch, batched_min_period
from ..core.planner import _realize
from ..core.replication import replicate_stage_plan
from ..pipeline.replan import StragglerMonitor, elastic_platform
from .metrics import FleetMetrics
from .signatures import canonicalize, remap_alloc, signature
from .telemetry import (PodCountChange, PodFailure, StageDrift, StageTimings,
                        Trace)


@dataclasses.dataclass
class InstanceState:
    """One pipeline instance as the service sees it: the workload, the
    *effective* platform (with every observed degradation folded in), the
    current published plan, and the straggler monitor for that plan."""

    workload: object
    platform: Platform
    plan: Optional[StagePlan] = None
    monitor: Optional[StragglerMonitor] = None


class ReplanService:
    """Telemetry-driven, dedup-batched replanning over a fleet of instances.

    ``instances`` is a sequence of (workload, platform) pairs; instance ids
    are positions.  ``backend`` is the lockstep engine backend ("numpy" is
    the bit-exact reference; "fused" runs each solve group as one jitted
    device program).  ``warm_start=False`` drops the cross-tick plan cache
    at every tick (same-tick dedup always applies) — it exists to *prove*
    warm-starting never changes results, not to be used.

    ``solve_deadline`` (seconds per tick) and ``reliability_floor`` (minimum
    plan reliability, needs platforms with failure probabilities) enable the
    graceful-degradation behaviors documented in the module docstring; both
    default to off, keeping the clean path byte-identical.
    """

    def __init__(self, instances: Sequence, backend: str = "numpy",
                 warm_start: bool = True,
                 solve_deadline: Optional[float] = None,
                 reliability_floor: Optional[float] = None):
        self.backend = backend
        self.warm_start = warm_start
        self.solve_deadline = solve_deadline
        self.reliability_floor = reliability_floor
        self.metrics = FleetMetrics()
        self.states = [InstanceState(wl, pf) for wl, pf in instances]
        self.plan_cache: dict = {}   # digest -> canonical HeuristicResult
        self.tick_count = 0
        self._pending: dict = {}     # deadline-deferred ids, retried next tick
        self._dropped = 0            # stale events discarded this tick
        self._below_since: dict = {} # iid -> tick it dipped below the floor
        # Initial fleet-wide planning runs through the same dedup+batch path
        # but is not a *re*plan: it stays out of the metrics.  (No plan
        # exists yet, so nothing is deferrable: a deadline cannot leave an
        # instance unplanned.)
        self._replan(range(len(self.states)))
        self._repair_reliability(dict.fromkeys(range(len(self.states))))

    # -- event application ----------------------------------------------------

    def _observe(self, st: InstanceState, observed: np.ndarray) -> bool:
        """Feed one timing observation; degrade the platform if the EWMA
        flags stragglers (the ``replan_for_straggler`` recipe).  Returns
        whether the platform changed."""
        if not _plan_valid(st) or len(observed) != st.plan.num_stages:
            self._dropped += 1
            return False   # stale report from a pre-replan plan shape
        st.monitor.observe(observed)
        predicted = interval_cycle_times(st.workload, st.platform,
                                         st.plan.mapping)
        bad = st.monitor.stragglers(predicted)
        if not bad:
            return False
        pf = st.platform
        for j in bad:
            pf = pf.degrade(st.plan.mapping.alloc[j],
                            float(st.monitor.ewma[j] / predicted[j]))
        st.platform = pf
        return True

    def _apply(self, ev) -> bool:
        """Apply one event; returns True when the instance needs a replan."""
        st = self.states[ev.instance]
        if isinstance(ev, StageTimings):
            return self._observe(st, np.asarray(ev.times, dtype=float))
        if isinstance(ev, StageDrift):
            if not _plan_valid(st):
                return False   # platform already changed this tick
            if not (0 <= ev.stage < st.plan.num_stages):
                # stale event addressed at a pre-replan plan shape: drop it,
                # like stale StageTimings — remapping it (the old
                # ``stage % num_stages``) would slow an arbitrary stage
                self._dropped += 1
                return False
            predicted = interval_cycle_times(st.workload, st.platform,
                                             st.plan.mapping)
            observed = predicted.copy()
            observed[ev.stage] *= ev.factor
            return self._observe(st, observed)
        if isinstance(ev, PodCountChange):
            target = max(1, int(ev.num_pods))
            if target == st.platform.p:
                return False
            st.platform = elastic_platform(st.platform, target)
            return True
        if isinstance(ev, PodFailure):
            if st.platform.p <= 1:
                return False   # last pod: nothing to fail over to
            pod = int(ev.pod) % st.platform.p
            # Platform.without appends "-failed" at most once (names stay
            # bounded over long traces) and drops the pod's failure
            # probability alongside its speed.
            st.platform = st.platform.without(pod)
            return True
        raise TypeError(f"unknown fleet event {type(ev).__name__}")

    # -- solve + publish ------------------------------------------------------

    def _replan(self, ids) -> dict:
        """Dedup, batch-solve, and publish new plans for the given instance
        ids.  Returns {iid: StagePlan}; sets ``self._last_tick_stats``.

        With a ``solve_deadline``, canonical problems are solved group by
        group until the budget runs out; later groups are deferred — their
        subscribers keep their last valid plan and are retried next tick —
        EXCEPT problems with a subscriber whose plan is invalid or missing,
        which always solve (keep-last-VALID-plan, never keep-broken-plan).
        A batched group solve that raises falls back to per-member scalar
        solves of the same canonical problems (bit-identical results)."""
        ids = list(ids)
        t0 = time.perf_counter()
        deadline = (None if self.solve_deadline is None
                    else t0 + self.solve_deadline)
        sig_of = {i: signature(self.states[i].workload,
                               self.states[i].platform) for i in ids}
        warm_hits = sum(sig_of[i].digest in self.plan_cache for i in ids)
        need: dict = {}
        for i in ids:
            sig = sig_of[i]
            if sig.digest not in self.plan_cache and sig.digest not in need:
                need[sig.digest] = (sig, self.states[i])
        must = {sig_of[i].digest for i in ids
                if self.states[i].plan is None
                or not _plan_valid(self.states[i])}
        by_shape: dict = {}
        for digest, (sig, st) in need.items():
            by_shape.setdefault(sig.shape, []).append((digest, st))
        fallback_solves = 0
        solved = 0
        for (n, p, b), entries in by_shape.items():
            if deadline is not None and time.perf_counter() > deadline:
                entries = [e for e in entries if e[0] in must]
            if not entries:
                continue
            pb = ProblemBatch.from_arrays(
                np.stack([st.workload.w for _, st in entries]),
                np.stack([st.workload.delta for _, st in entries]),
                np.stack([st.platform.s[st.platform.sorted_indices()]
                          for _, st in entries]),
                b)
            try:
                results = list(batched_min_period(pb, self.backend))
            except Exception:  # noqa: BLE001 — degrade, don't die mid-tick
                results = [min_period_exhaustive(st.workload,
                                                 canonicalize(st.platform)[0])
                           for _, st in entries]
                fallback_solves += len(entries)
            for (digest, _), res in zip(entries, results):
                self.plan_cache[digest] = res
            solved += len(entries)
        published, churns, deferred = {}, [], []
        for i in ids:
            st = self.states[i]
            res = self.plan_cache.get(sig_of[i].digest)
            if res is None:
                deferred.append(i)   # keep the last valid plan, retry next tick
                continue
            _, perm = canonicalize(st.platform)
            mapping = Mapping(res.mapping.intervals,
                              remap_alloc(res.mapping.alloc, perm))
            plan = _realize(mapping, res.period, res.latency, res.name)
            if st.plan is not None:
                churns.append(_plan_churn(st.plan, plan, st.workload.n))
            st.plan = plan
            st.monitor = StragglerMonitor(plan.num_stages)
            published[i] = plan
        self._pending.update(dict.fromkeys(deferred))
        self._last_tick_stats = (len(ids), solved, warm_hits, churns,
                                 len(deferred), fallback_solves)
        return published

    def _plan_reliability(self, st: InstanceState) -> float:
        """Reliability of the instance's published plan (consensus model when
        the plan carries replication groups)."""
        if st.plan.groups is not None:
            rm = ReplicatedMapping(st.plan.mapping.intervals, st.plan.groups)
            return reliability(st.workload, st.platform, rm)
        return reliability(st.workload, st.platform, st.plan.mapping)

    def _repair_reliability(self, published: dict) -> tuple:
        """Reliability-floor pass: re-replicate any instance whose plan sits
        below the floor, republishing into ``published`` when the plan
        actually changed.  Returns (instance-ticks below the floor, list of
        recovery latencies closed this tick)."""
        floor = self.reliability_floor
        if floor is None:
            return 0, []
        below, recoveries = 0, []
        for i, st in enumerate(self.states):
            if st.platform.fail is None or not _plan_valid(st):
                continue
            rel = self._plan_reliability(st)
            if rel < floor - _FLOOR_EPS:
                new = replicate_stage_plan(st.workload, st.platform, st.plan,
                                           target=floor)
                if (new is not st.plan
                        and (new.groups != st.plan.groups
                             or new.mapping != st.plan.mapping)):
                    st.plan = new
                    st.monitor = StragglerMonitor(new.num_stages)
                    published[i] = new
                rel = self._plan_reliability(st)
            if rel < floor - _FLOOR_EPS:
                below += 1
                self._below_since.setdefault(i, self.tick_count)
            elif i in self._below_since:
                recoveries.append(self.tick_count - self._below_since.pop(i))
        return below, recoveries

    def tick(self, events: Sequence) -> dict:
        """Process one tick's events; returns the republished plans."""
        t0 = time.perf_counter()
        if not self.warm_start:
            self.plan_cache.clear()
        self._dropped = 0
        # Deadline-deferred instances retry before this tick's events touch
        # anything; new dirtiness merges in behind them.
        dirty: dict = dict.fromkeys(self._pending)
        self._pending = {}
        for ev in events:
            if self._apply(ev):
                dirty[ev.instance] = None
        published = self._replan(dirty.keys())
        below, recoveries = self._repair_reliability(published)
        (requests, solves, warm_hits, churns,
         deferred, fallback_solves) = self._last_tick_stats
        invalid = sum(not _plan_valid(st) for st in self.states)
        self.metrics.record_tick(requests=requests, solves=solves,
                                 warm_hits=warm_hits, events=len(events),
                                 wall=time.perf_counter() - t0, churns=churns,
                                 deferred=deferred,
                                 fallback_solves=fallback_solves,
                                 dropped_events=self._dropped,
                                 below_floor=below, recoveries=recoveries,
                                 invalid_published=invalid)
        self.tick_count += 1
        return published

    def run_trace(self, trace: Trace) -> FleetMetrics:
        """Replay a telemetry trace tick by tick.  Deterministic: the same
        trace over the same fleet yields the same plans and counters."""
        for events in trace.ticks:
            self.tick(events)
        return self.metrics

    # -- introspection --------------------------------------------------------

    @property
    def plans(self) -> list:
        return [st.plan for st in self.states]

    def fleet_digest(self) -> str:
        """Hash of every instance's current plan — determinism fingerprint."""
        h = hashlib.blake2b(digest_size=16)
        for st in self.states:
            h.update(repr((st.plan.mapping.intervals, st.plan.mapping.alloc,
                           st.plan.period, st.plan.latency,
                           st.plan.groups)).encode())
        return h.hexdigest()


_FLOOR_EPS = 1e-12   # matches the greedy replicator's target tolerance


def _plan_valid(st: InstanceState) -> bool:
    """Whether the published plan still addresses the current platform — a
    same-tick pod removal/resize invalidates the plan's allocation until the
    end-of-tick replan; timing reports against it are meaningless."""
    if st.plan is None:
        return False
    if max(st.plan.mapping.alloc) >= st.platform.p:
        return False
    if st.plan.groups is not None:
        return max(u for g in st.plan.groups for u in g) < st.platform.p
    return True


def _plan_churn(old: StagePlan, new: StagePlan, n: int) -> float:
    """Fraction of the n layers whose pod assignment changed."""
    old_alloc = np.repeat(np.asarray(old.mapping.alloc), old.stage_sizes)
    new_alloc = np.repeat(np.asarray(new.mapping.alloc), new.stage_sizes)
    return float(np.mean(old_alloc != new_alloc))
