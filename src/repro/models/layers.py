"""Building blocks shared by all architectures: sharding helper, norms,
embeddings, rotary embeddings, MLPs (dense + swiglu)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ModelConfig, abstract_mesh

# ---------------------------------------------------------------------------
# Logical sharding
# ---------------------------------------------------------------------------
# Logical axis names used throughout the models; the mesh mapping below is the
# single place where logical axes bind to physical mesh axes.  'batch' spreads
# over the pure-data axes ('pod','data' when the pod axis is used for DP,
# 'data' otherwise); 'model' carries tensor parallelism.

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": None,                # sequences are replicated except for long-context decode
    "seq_sp": ("model",),       # megatron-style sequence parallelism at block edges
    "seq_kv": ("data",),        # KV-cache sequence dim for B=1 long-context decode
    "d_model": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "moe_cap": ("data",),       # MoE capacity dim: shard expert token-slots over data
    "stage": ("pod",),          # pipeline stage axis (paper technique)
}


def _resolve(axis, mesh_axes):
    if axis is None:
        return None
    rule = LOGICAL_RULES.get(axis, None)
    if rule is None:
        return None
    picked = tuple(a for a in rule if a in mesh_axes)
    if not picked:
        return None
    return picked if len(picked) > 1 else picked[0]


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names; no-op without a mesh.
    Axes whose dimension is not divisible by the mesh-axis size are dropped
    (uneven constraints trigger GSPMD resharding storms)."""
    am = abstract_mesh()
    if am is None or am.empty:
        return x
    mesh_axes = set(am.axis_names) - set(getattr(am, "manual_axes", ()) or ())
    entries = []
    used: set = set()
    for dim, a in enumerate(logical_axes):
        r = _resolve(a, mesh_axes)
        if r is not None:
            axes = r if isinstance(r, tuple) else (r,)
            if used & set(axes):
                r = None  # a mesh axis can appear at most once per spec
            else:
                size = 1
                for ax in axes:
                    size *= am.shape[ax]
                if x.shape[dim] % size:
                    r = None
                else:
                    used |= set(axes)
        entries.append(r)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, spec)


def logical_sharding(logical_axes, mesh) -> jax.sharding.NamedSharding:
    """NamedSharding for parameter/batch placement from logical axis names."""
    mesh_axes = set(mesh.axis_names)
    spec = P(*(_resolve(a, mesh_axes) for a in logical_axes))
    return jax.sharding.NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(fan, 1))
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def embed_init(key, shape, dtype):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(0.02, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float, use_pallas: bool = False) -> jax.Array:
    if use_pallas:
        from ..kernels import ops as kops

        return kops.rmsnorm(x, scale, eps=eps)
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    ff = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    pdt = cfg.jparam_dtype
    if cfg.act == "swiglu":
        return {
            "wi": dense_init(ks[0], (d, ff), pdt),
            "wg": dense_init(ks[1], (d, ff), pdt),
            "wo": dense_init(ks[2], (ff, d), pdt),
        }
    return {
        "wi": dense_init(ks[0], (d, ff), pdt),
        "wo": dense_init(ks[2], (ff, d), pdt),
    }


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.act == "swiglu":
        h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
        h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    pdt = cfg.jparam_dtype
    out = {"tok": embed_init(k1, (cfg.vocab_size, cfg.d_model), pdt)}
    if not cfg.tie_embeddings:
        out["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), pdt)
    return out


def embed(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["tok"].astype(cfg.jdtype), tokens, axis=0)
    return shard(x, "batch", "seq", "d_model")


def unembed(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = jnp.einsum("...d,dv->...v", x, w)
    return shard(logits, "batch", "seq", "vocab")
