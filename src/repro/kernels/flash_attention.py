"""Pallas TPU flash attention (causal / sliding-window, GQA).

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) with the KV loop innermost;
online-softmax statistics (m, l) and the fp32 accumulator live in VMEM scratch
across KV iterations.  Block shapes are MXU-aligned (q/k blocks multiples of
128 on the sequence dims, full head_dim lanes).  Out-of-band blocks (future
blocks under causality, blocks left of the sliding window) are skipped with
``pl.when`` so they cost neither MXU flops nor VMEM traffic.

GQA is handled in the index maps: query head h reads KV head ``h // group``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Blocks entirely outside the causal / sliding-window band skip all compute.
    in_band = jnp.bool_(True)
    if causal:
        in_band &= k_start <= q_start + block_q - 1
    if window is not None:
        in_band &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

        pq = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        pk = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= pq >= pk
        if window is not None:
            mask &= (pq - pk) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_blk = s.max(axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H % K == 0.  Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    nq, nk = S // block_q, T // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
