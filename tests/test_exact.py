"""Exact solvers agree with brute force; DPs are optimal in their domains."""

import math

import numpy as np
import pytest

from repro.core import (Mapping, brute_force, dp_homogeneous_period,
                        dp_speed_ordered, evaluate, exact_min_period,
                        make_platform, make_workload, pareto_exact, period)


def _rand_small(rng, n_max=7, p_max=4):
    n = int(rng.integers(2, n_max))
    p = int(rng.integers(2, p_max))
    wl = make_workload(rng.integers(1, 11, n).astype(float),
                       rng.integers(0, 21, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 11, p).astype(float), 5.0)
    return wl, pf


def test_exact_matches_brute_force():
    rng = np.random.default_rng(0)
    for _ in range(20):
        wl, pf = _rand_small(rng)
        bf = brute_force(wl, pf)
        ex = exact_min_period(wl, pf)
        assert bf is not None and ex is not None
        assert period(wl, pf, ex) == pytest.approx(period(wl, pf, bf), rel=1e-9)


def test_exact_with_latency_cap():
    rng = np.random.default_rng(1)
    for _ in range(10):
        wl, pf = _rand_small(rng)
        front = pareto_exact(wl, pf)
        # pick a cap between min and max latency on the front
        lats = [l for _, l in front]
        cap = (min(lats) + max(lats)) / 2
        ex = exact_min_period(wl, pf, latency_cap=cap)
        bf = brute_force(wl, pf, latency_cap=cap)
        if bf is None:
            assert ex is None
        else:
            assert ex is not None
            per_e, lat_e = evaluate(wl, pf, ex)
            assert lat_e <= cap + 1e-9
            assert per_e == pytest.approx(period(wl, pf, bf), rel=1e-9)


def test_dp_homogeneous_matches_brute_force():
    rng = np.random.default_rng(2)
    for _ in range(15):
        n = int(rng.integers(2, 8))
        p = int(rng.integers(2, 4))
        s = float(rng.integers(1, 5))
        wl = make_workload(rng.integers(1, 11, n).astype(float),
                           rng.integers(0, 11, n + 1).astype(float))
        pf = make_platform([s] * p, 3.0)
        per_dp, intervals = dp_homogeneous_period(wl, p, s, 3.0)
        bf = brute_force(wl, pf)
        assert per_dp == pytest.approx(period(wl, pf, bf), rel=1e-9)
        # returned intervals realize the claimed period
        mp = Mapping(intervals, tuple(range(len(intervals))))
        assert period(wl, pf, mp) == pytest.approx(per_dp)


def test_dp_speed_ordered_bounds():
    """Speed-ordered DP is >= the true optimum and <= single-processor."""
    rng = np.random.default_rng(3)
    for _ in range(15):
        wl, pf = _rand_small(rng)
        mp = dp_speed_ordered(wl, pf)
        assert mp is not None
        mp.validate(wl.n, pf.p)
        opt = period(wl, pf, exact_min_period(wl, pf))
        single = period(wl, pf, brute_force(
            make_workload(wl.w, wl.delta), make_platform([pf.s.max()], pf.b)))
        assert period(wl, pf, mp) >= opt - 1e-9
        assert period(wl, pf, mp) <= single + 1e-9


def test_pareto_front_is_nondominated_and_anchored():
    rng = np.random.default_rng(4)
    for _ in range(10):
        wl, pf = _rand_small(rng, n_max=6, p_max=4)
        front = pareto_exact(wl, pf)
        assert front
        pers = [p for p, _ in front]
        lats = [l for _, l in front]
        assert pers == sorted(pers)
        assert lats == sorted(lats, reverse=True)
        # anchors: min period == exact optimum; min latency == optimal latency
        opt_per = period(wl, pf, exact_min_period(wl, pf))
        assert min(pers) == pytest.approx(opt_per, rel=1e-9)
