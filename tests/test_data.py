"""Data pipeline: determinism, restart reproducibility, prefetch."""

import numpy as np

from repro.data import SyntheticLMDataset, ShardedLoader


def test_batches_deterministic_in_step():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=64, global_batch=4, seed=7)
    a = ds.batch(10)
    b = ds.batch(10)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = ds.batch(11)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=64, global_batch=2, seed=0)
    b = ds.batch(0)
    # labels[t] is the next token of tokens[t] within the same stream
    assert b["tokens"].shape == b["labels"].shape == (2, 64)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_restart_reproduces_stream():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=32, global_batch=2, seed=1)
    run1 = [ds.batch(s)["tokens"] for s in range(8)]
    # "restart" from step 5
    ds2 = SyntheticLMDataset(vocab_size=128, seq_len=32, global_batch=2, seed=1)
    run2 = [ds2.batch(s)["tokens"] for s in range(5, 8)]
    for a, b in zip(run1[5:], run2):
        assert np.array_equal(a, b)


def test_sharded_loader_prefetch_order():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=2, seed=2)
    loader = ShardedLoader(ds, mesh=None, start_step=3, prefetch=2)
    got = []
    for step, batch in loader:
        got.append((step, np.asarray(batch["tokens"])))
        if len(got) == 4:
            break
    loader.close()
    assert [s for s, _ in got] == [3, 4, 5, 6]
    for s, toks in got:
        assert np.array_equal(toks, ds.batch(s)["tokens"])


def test_learnable_structure():
    """Motif structure: batches share n-grams (a model can learn them)."""
    ds = SyntheticLMDataset(vocab_size=512, seq_len=64, global_batch=8, seed=0,
                            motif_len=8, n_motifs=4)
    b = ds.batch(0)
    # with 4 motifs of len 8, many 8-grams must repeat across the batch
    grams = set()
    for row in b["tokens"]:
        for i in range(0, 56, 8):
            grams.add(tuple(row[i:i + 8]))
    assert len(grams) < 40
