"""Property-based tests for engine invariants.

Uses hypothesis when installed (CI does); falls back to a seeded random
sweep otherwise so the invariants are exercised in every environment.  Each
invariant is a plain checker over a random instance:

  - splitting never increases the period (and never decreases the latency —
    enrolled processors are speed-sorted, so every split trades latency for
    period), for all of H1-H4;
  - H4's returned result is minimal over its probe set (the binary search
    never returns a probe dominated by another probe it made);
  - Pareto fronts are non-dominated and anchored at the optimal latency
    (Lemma 1: all-on-fastest);
  - padding a batch with already-converged rows never changes the converged
    outputs (per-row masks in the numpy lockstep loop and chunk padding in
    the fused traced loop alike).
"""

import numpy as np
import pytest

from repro.core import (Mapping, ReplicatedMapping, evaluate_tri, latency,
                        make_platform, make_workload, optimal_latency, period,
                        reliability)
from repro.core.batched import batched_trajectories
from repro.core.heuristics import _EPS, split_trajectory
from repro.sim.generators import SPEED_HIGH, SPEED_LOW

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - hypothesis is installed in CI
    HAVE_HYPOTHESIS = False

N_FALLBACK_SEEDS = 16


def _draw_instance(rng, n_max=10, p_max=8):
    n = int(rng.integers(2, n_max + 1))
    p = int(rng.integers(2, p_max + 1))
    w = rng.uniform(0.1, 100.0, n)
    delta = rng.uniform(0.0, 100.0, n + 1)
    s = rng.uniform(0.5, 20.0, p)
    b = float(rng.uniform(0.5, 50.0))
    return make_workload(w, delta), make_platform(s, b)


def instance_property(f):
    """Run ``f(workload, platform)`` over random instances: hypothesis-driven
    when available, a fixed seeded sweep otherwise."""
    if HAVE_HYPOTHESIS:
        @st.composite
        def instances(draw):
            n = draw(st.integers(2, 10))
            p = draw(st.integers(2, 8))
            w = draw(st.lists(st.floats(0.1, 100), min_size=n, max_size=n))
            delta = draw(st.lists(st.floats(0.0, 100), min_size=n + 1,
                                  max_size=n + 1))
            s = draw(st.lists(st.floats(0.5, 20), min_size=p, max_size=p))
            b = draw(st.floats(0.5, 50))
            return make_workload(w, delta), make_platform(s, b)

        @settings(max_examples=20, deadline=None)
        @given(instances())
        def wrapper(inst):
            f(*inst)
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

    @pytest.mark.parametrize("seed", range(N_FALLBACK_SEEDS))
    def wrapper(seed):
        f(*_draw_instance(np.random.default_rng(seed)))
    wrapper.__name__ = f.__name__
    wrapper.__doc__ = f.__doc__
    return wrapper


@instance_property
def test_splitting_never_increases_period(wl, pf):
    """Every accepted split lowers (or keeps) the period and raises (or
    keeps) the latency: trajectories are monotone, anchored at the optimal
    latency."""
    l_opt = optimal_latency(wl, pf)
    for code in ("H1", "H2", "H3", "H4"):
        traj = split_trajectory(code, wl, pf)
        assert traj[0][1] == pytest.approx(l_opt, rel=1e-9), code
        for (p0, l0), (p1, l1) in zip(traj, traj[1:]):
            assert p1 <= p0 + 1e-9 * max(1.0, abs(p0)), code
            assert l1 >= l0 - 1e-9 * max(1.0, abs(l0)), code


@instance_property
def test_h4_result_minimal_over_probe_set(wl, pf):
    """sp_bi_p returns a probe from its own probe set, with minimal latency
    among the feasible probes (and no feasible probe beats it on period at
    an eps-tied latency)."""
    import repro.core.heuristics as H

    traj = split_trajectory("H4", wl, pf)
    p_fix = 0.6 * traj[0][0] + 0.4 * min(per for per, _ in traj)
    probes = []
    orig = H._bi_split_under_latency

    def recording(workload, platform, bound, lat_limit):
        r = orig(workload, platform, bound, lat_limit)
        probes.append(r)
        return r

    H._bi_split_under_latency = recording
    try:
        res = H.sp_bi_p(wl, pf, p_fix, iters=8)
    finally:
        H._bi_split_under_latency = orig
    assert probes, "binary search made no probes"
    if not res.feasible:
        assert not probes[0].feasible
        return
    feas = [pr for pr in probes if pr.feasible]
    assert any(pr.period == res.period and pr.latency == res.latency
               and pr.splits == res.splits for pr in feas)
    assert res.period <= p_fix + _EPS
    for pr in feas:
        assert res.latency <= pr.latency + _EPS
        if abs(res.latency - pr.latency) <= _EPS:
            assert res.period <= pr.period + _EPS


@instance_property
def test_pareto_front_nondominated_and_anchored(wl, pf):
    """plan_pareto's achieved front has no dominated points and is anchored
    at the optimal latency (Lemma 1: the all-on-fastest mapping)."""
    from repro.core import plan_pareto

    report = plan_pareto(wl, pf, k=6, exclude=("brute-force",))
    front = report.pareto
    assert front, "empty front"
    for a in front:
        for b in front:
            assert not (b[0] < a[0] * (1 - 1e-9) and b[1] < a[1] * (1 - 1e-9))
    l_opt = optimal_latency(wl, pf)
    assert min(lat for _, lat in front) == pytest.approx(l_opt, rel=1e-9)


def _fixed_shape_instance(rng, n=12, p=10):
    w = rng.uniform(0.5, 100.0, n)
    delta = rng.uniform(0.0, 100.0, n + 1)
    s = rng.integers(SPEED_LOW, SPEED_HIGH + 1, p).astype(float)
    return make_workload(w, delta), make_platform(s, 10.0)


def fixed_shape_property(f):
    """Like :func:`instance_property` but with a FIXED (n, p) = (12, 10)
    shape, so the fused engine reuses one trace across all examples."""
    if HAVE_HYPOTHESIS:
        @settings(max_examples=10, deadline=None)
        @given(st.integers(0, 2 ** 31 - 1))
        def wrapper(seed):
            f(*_fixed_shape_instance(np.random.default_rng(seed)))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

    @pytest.mark.parametrize("seed", range(8))
    def wrapper(seed):
        f(*_fixed_shape_instance(np.random.default_rng(seed)))
    wrapper.__name__ = f.__name__
    wrapper.__doc__ = f.__doc__
    return wrapper


def _engine_backends():
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return ("numpy",)
    return ("numpy", "fused")


def test_bucket_routing_smallest_covering():
    """Span-bucket routing: for every possible worst-interval span, the fused
    engine's bucket index selects the SMALLEST bucket covering it — and the
    bucket ladder is geometric (each at most double the previous, O(log n)
    rungs), so the trace cap follows."""
    pytest.importorskip("jax")
    from repro.core import fused

    for n in (2, 3, 4, 5, 9, 12, 16, 40, 160, 161):
        for k, lo_need, hi_need in ((1, 1, n - 1), (2, 3, n)):
            sizes = fused.bucket_sizes(n, k)
            if not sizes:
                assert k == 2 and n < 3
                continue
            assert len(sizes) <= int(np.ceil(np.log2(max(n, 2)))) + 1, (n, k)
            assert all(b <= 2 * a for a, b in zip(sizes, sizes[1:])), (n, k)
            assert sizes[-1] == hi_need  # top bucket exactly covers the grid
            for need in range(lo_need, hi_need + 1):
                idx = fused.bucket_index(need, sizes)
                assert sizes[idx] >= need, (n, k, need)          # covering
                covering = [s for s in sizes if s >= need]
                assert sizes[idx] == covering[0], (n, k, need)   # smallest


def test_bucket_padding_lanes_inert():
    """Adversarial span skew: a batch mixing a row whose worst interval stays
    WIDE (flat works on a rich platform splits evenly) with rows that
    collapse to tiny spans immediately must route every iteration to the
    wide row's bucket — and the small-span rows' masked padding lanes must
    not change any trajectory vs the numpy engine (which compacts spans
    per-iteration instead of bucketing)."""
    pytest.importorskip("jax")
    n, p = 24, 12
    wide = (make_workload([10.0] * n, [1.0] * (n + 1)),
            make_platform([20.0, 19.0, 18.0, 17.0, 16.0, 15.0] + [14.0] * (p - 6),
                          b=10.0))
    # one huge stage: the worst interval narrows to a tiny span right away
    skew_w = [1.0] * n
    skew_w[n // 2] = 1000.0
    skewed = (make_workload(skew_w, [1.0] * (n + 1)),
              make_platform([20.0, 10.0, 5.0, 2.5] + [1.0] * (p - 4), b=10.0))
    pairs = [skewed, wide, skewed, skewed]
    for code in ("H1", "H2", "H3", "H4"):
        ref = batched_trajectories(code, pairs, backend="numpy")
        got = batched_trajectories(code, pairs, backend="fused")
        assert got == ref, code


def test_sharded_nonmultiple_batch_pads_inertly():
    """``backend="sharded"`` pads the stacked batch up to a device multiple
    with inert (never-active) rows; EVERY batch size — including sizes not
    divisible by the mesh — must match the fused engine exactly, with no
    phantom rows in the output."""
    pytest.importorskip("jax")
    pairs = [_fixed_shape_instance(np.random.default_rng(s))
             for s in range(5)]
    for B in (1, 2, 3, 5):
        batch = pairs[:B]
        for code in ("H1", "H2", "H3", "H4"):
            ref = batched_trajectories(code, batch, backend="fused")
            got = batched_trajectories(code, batch, backend="sharded")
            assert got == ref, (code, B)
            assert len(got) == B, (code, B)


def test_sharded_multidevice_bit_identical():
    """Under 8 FORCED host devices a 13-row batch (not a multiple of 8,
    so the engine pads 3 inert rows onto the last shard) through
    ``backend="sharded"`` equals the numpy reference exactly — run in a
    subprocess because the forced device count must be set before jax
    initializes its backend."""
    pytest.importorskip("jax")
    import os
    import pathlib
    import subprocess
    import sys

    repo = pathlib.Path(__file__).resolve().parent.parent
    child = (
        "import jax\n"
        "assert len(jax.devices()) == 8, jax.devices()\n"
        "import numpy as np\n"
        "from repro.core.batched import batched_min_period, "
        "batched_trajectories\n"
        "from repro.sim import gen_instance_batch\n"
        "batch = gen_instance_batch('I2', 9, 7, range(500, 513))\n"
        "assert len(batch) == 13\n"
        "for code in ('H1', 'H2', 'H3'):\n"
        "    ref = batched_trajectories(code, batch, backend='numpy')\n"
        "    got = batched_trajectories(code, batch, backend='sharded')\n"
        "    assert got == ref, code\n"
        "ref = batched_min_period(batch, backend='numpy')\n"
        "got = batched_min_period(batch, backend='sharded')\n"
        "for a, b in zip(got, ref):\n"
        "    assert (a.mapping == b.mapping and a.period == b.period\n"
        "            and a.latency == b.latency and a.splits == b.splits\n"
        "            and a.name == b.name)\n"
        "print('SHARDED_OK')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(repo / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", child], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# Reliability / replication invariants (the sequel's consensus model)
# ---------------------------------------------------------------------------

def _reliable_instance(rng, n_max=10, p_max=8):
    wl, pf = _draw_instance(rng, n_max, p_max)
    fail = rng.uniform(1e-4, 0.2, pf.p)
    return wl, pf.with_failures(fail)


def _contiguous_mapping(rng, n, p):
    """A random valid interval mapping: m contiguous intervals on m distinct
    processors."""
    m = int(rng.integers(1, min(n, p) + 1))
    cuts = (sorted(int(c) for c in
                   rng.choice(np.arange(1, n), size=m - 1, replace=False))
            if m > 1 else [])
    bounds = [0] + cuts + [n]
    intervals = tuple((bounds[j] + 1, bounds[j + 1]) for j in range(m))
    alloc = tuple(int(a) for a in rng.choice(p, size=m, replace=False))
    return Mapping(intervals, alloc)


def seeded_property(f):
    """Run ``f(rng)`` over random seeds: hypothesis-driven when available,
    a fixed seeded sweep otherwise."""
    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(st.integers(0, 2 ** 31 - 1))
        def wrapper(seed):
            f(np.random.default_rng(seed))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper

    @pytest.mark.parametrize("seed", range(N_FALLBACK_SEEDS))
    def wrapper(seed):
        f(np.random.default_rng(seed))
    wrapper.__name__ = f.__name__
    wrapper.__doc__ = f.__doc__
    return wrapper


@seeded_property
def test_replication_monotone(rng):
    """Adding a replica to any group never DEcreases reliability (the
    interval fails only when every replica fails) and never DEcreases period
    or latency (the consensus interval runs at its slowest replica's speed) —
    with reliability in [0, 1] throughout."""
    wl, pf = _reliable_instance(rng)
    base = _contiguous_mapping(rng, wl.n, pf.p)
    groups = [[a] for a in base.alloc]
    free = [u for u in range(pf.p) if u not in base.alloc]
    rng.shuffle(free)
    prev_per, prev_lat, prev_rel = evaluate_tri(
        wl, pf, ReplicatedMapping(base.intervals,
                                  tuple(tuple(g) for g in groups)))
    for u in free:
        groups[int(rng.integers(len(groups)))].append(int(u))
        rm = ReplicatedMapping(base.intervals, tuple(tuple(g) for g in groups))
        rm.validate(wl.n, pf.p)
        per, lat, rel = evaluate_tri(wl, pf, rm)
        assert 0.0 <= rel <= 1.0
        assert rel >= prev_rel - 1e-12
        assert per >= prev_per * (1 - 1e-12)
        assert lat >= prev_lat * (1 - 1e-12)
        prev_per, prev_lat, prev_rel = per, lat, rel


@seeded_property
def test_reliability_bounds(rng):
    """Reliability is always in [0, 1]; without failure probabilities it is
    exactly 1.0."""
    wl, pf = _reliable_instance(rng)
    mapping = _contiguous_mapping(rng, wl.n, pf.p)
    rel = reliability(wl, pf, mapping)
    assert 0.0 <= rel <= 1.0
    bare = make_platform(pf.s, pf.b)
    assert reliability(wl, bare, mapping) == 1.0


@seeded_property
def test_singleton_replication_bit_identical(rng):
    """A ReplicatedMapping whose groups are all singletons IS the plain
    mapping: period and latency agree bit-for-bit (same array reads, same
    accumulation order), and reliability matches the per-interval product."""
    wl, pf = _reliable_instance(rng)
    mapping = _contiguous_mapping(rng, wl.n, pf.p)
    rm = ReplicatedMapping(mapping.intervals,
                           tuple((a,) for a in mapping.alloc))
    assert period(wl, pf, rm) == period(wl, pf, mapping)
    assert latency(wl, pf, rm) == latency(wl, pf, mapping)
    assert reliability(wl, pf, rm) == reliability(wl, pf, mapping)


@fixed_shape_property
def test_padding_with_converged_rows_is_inert(wl, pf):
    """Batching an instance together with rows that converge immediately
    (a flat workload on a platform whose extra processors are uselessly
    slow) must not change the instance's trajectories in any engine."""
    n, p = wl.n, pf.p
    stuck_wl = make_workload([10.0] * n, [0.0] * (n + 1))
    stuck_pf = make_platform([20.0] + [0.001] * (p - 1), b=10.0)
    solo = [(wl, pf)]
    padded = [(stuck_wl, stuck_pf), (wl, pf), (stuck_wl, stuck_pf)]
    for backend in _engine_backends():
        for code in ("H1", "H2", "H3", "H4"):
            ref = batched_trajectories(code, solo, backend=backend)[0]
            got = batched_trajectories(code, padded, backend=backend)
            assert got[1] == ref, (backend, code)
            assert len(got[0]) == 1 and len(got[2]) == 1, (backend, code)
