"""Planner quality + speed: heuristic optimality gap vs the exact solver on
small/medium instances, and runtime scaling (name,us_per_call,derived CSV)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Objective, exact_min_period, make_platform,
                        make_workload, period, plan, run_heuristic)
from repro.sim.generators import gen_instance


def optimality_gaps(n_inst: int = 20, seed: int = 0) -> dict:
    """Mean period gap (heuristic / exact - 1) on instances small enough for
    the exact bitmask solver (n<=14, p<=9)."""
    rng = np.random.default_rng(seed)
    gaps = {c: [] for c in ("H1", "H2", "H3", "auto")}
    for _ in range(n_inst):
        n = int(rng.integers(4, 14))
        p = int(rng.integers(3, 9))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        opt = period(wl, pf, exact_min_period(wl, pf))
        for code in ("H1", "H2", "H3"):
            r = run_heuristic(code, wl, pf, 0.0)  # run to exhaustion
            gaps[code].append(r.period / opt - 1)
        a = plan(wl, pf, Objective("period"), mode="auto")
        gaps["auto"].append(a.period / opt - 1)
    return {c: float(np.mean(v)) for c, v in gaps.items()}


def timing(reps: int = 10) -> list:
    """us_per_call for each heuristic at the paper's largest size (n=40, p=100)."""
    rows = []
    wl, pf = gen_instance("E2", 40, 100, seed=1)
    for code in ("H1", "H2", "H3", "H5", "H6"):
        bound = 0.0 if code in ("H1", "H2", "H3") else 1e18
        t0 = time.perf_counter()
        for _ in range(reps):
            run_heuristic(code, wl, pf, bound)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"heuristic_{code}_n40_p100", us, ""))
    t0 = time.perf_counter()
    plan(wl, pf, Objective("period"), mode="auto")
    rows.append(("planner_auto_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    return rows


def run() -> list:
    rows = timing()
    gaps = optimality_gaps()
    for c, g in gaps.items():
        rows.append((f"gap_vs_exact_{c}", 0.0, f"{g:.4f}"))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
