"""The perf-probe -> planner bridge.

``repro.launch.perf_probe.probe`` measures a lowered model cell (per-device
dot flops, bytes, collective bytes — and roofline terms in seconds); the
adapter must turn that into a planner ``Workload``/``PlanRequest`` with the
documented normalization: w in FLOPS, delta in BYTES, pod speeds in FLOPS/s
and bandwidth in BYTES/s — so planned periods come out in SECONDS, the same
unit as the probe's roofline terms.  These tests drive the adapter with a
synthetic probe dict (the real probe lowers a full model across a forced
512-device mesh — far too heavy for tier-1)."""

import os

# keep perf_probe's import-time default (512 forced host devices, meant for
# the CLI probe) from leaking into this test process's jax backend
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import numpy as np
import pytest

from repro.launch.perf_probe import probe_to_request, probe_to_workload

ARCH, SHAPE = "qwen3-4b", "decode_32k"


def _base_workload():
    from repro.configs import get_smoke_config
    from repro.models.common import SHAPES
    from repro.models.registry import lm_workload

    return lm_workload(get_smoke_config(ARCH), SHAPES[SHAPE])


def _probe_out(base, devices=8, flop_factor=2.0, comm_factor=3.0):
    """A synthetic probe dict whose PER-DEVICE totals are the analytic
    totals scaled by the given factors and split across ``devices``."""
    return {
        "terms": {"compute": 0.1, "memory": 0.2, "collective": 0.05},
        "res": {
            "dot_flops": flop_factor * float(base.w.sum()) / devices,
            "bytes_accessed": 1e9,
            "collective_bytes": comm_factor * float(base.delta.sum()) / devices,
        },
        "temp_gb": 1.0,
        "devices": devices,
    }


def test_probe_to_workload_calibrates_totals_preserving_shape():
    base = _base_workload()
    wl = probe_to_workload(_probe_out(base), ARCH, SHAPE, smoke=True)
    # totals pinned to the measured (global) numbers ...
    assert wl.w.sum() == pytest.approx(2.0 * base.w.sum())
    assert wl.delta.sum() == pytest.approx(3.0 * base.delta.sum())
    # ... while the relative per-stage profile is the analytic one
    assert np.allclose(wl.w, base.w * 2.0)
    assert np.allclose(wl.delta, base.delta * 3.0)
    assert wl.n == base.n


def test_probe_to_workload_per_device_scaling():
    """The HLO numbers are per-device: the same measured totals reported
    from meshes of different sizes must yield proportionally different
    global workloads."""
    base = _base_workload()
    wl8 = probe_to_workload(_probe_out(base, devices=8), ARCH, SHAPE,
                            smoke=True)
    out = _probe_out(base, devices=8)
    out["devices"] = 16
    wl16 = probe_to_workload(out, ARCH, SHAPE, smoke=True)
    assert np.allclose(wl16.w, 2.0 * wl8.w)


def test_probe_to_workload_zero_collectives_keeps_analytic_delta():
    """A cell with no measured collectives (single-device lowering) must not
    zero out the boundary bytes — the analytic activation sizes stand."""
    base = _base_workload()
    out = _probe_out(base)
    out["res"]["collective_bytes"] = 0.0
    wl = probe_to_workload(out, ARCH, SHAPE, smoke=True)
    assert np.allclose(wl.delta, base.delta)


def test_probe_to_request_plans_in_seconds():
    """End to end: the adapter's PlanRequest solves, and the planned period
    lands in seconds — no worse than serializing the measured workload on
    the fastest single pod (the planner's trivial fallback)."""
    from repro.core import period, plan_request
    from repro.core.metrics import single_processor_mapping

    base = _base_workload()
    req = probe_to_request(_probe_out(base), ARCH, SHAPE, pods=4, smoke=True)
    report = plan_request(req)
    assert report.feasible
    serial_s = period(req.workload, req.platform,
                      single_processor_mapping(req.workload,
                                               req.platform.fastest()))
    assert 0.0 < report.plan.period <= serial_s
