"""Tri-criteria planning: reliability via interval replication (arXiv 0711.1231).

The sequel to the source paper keeps the interval-mapping structure but lets
each interval run on a *set* of processors under the consensus model: every
replica processes every data set, so the interval's speed is its slowest
replica's and the interval fails only when ALL replicas fail.  This module
contributes:

  - :func:`replicate_greedy` — the greedy replica-assignment pass: repeatedly
    add the fastest unused processor to the reliability-critical interval
    (the one most likely to lose all replicas), as long as the period/latency
    bounds still hold.  A replica at least as fast as the group's slowest
    member costs NOTHING on period/latency — the greedy exploits exactly
    that, which is why it takes the fastest free processor first.
  - ``H1-rel`` .. ``H6-rel`` — replication-aware variants of the paper
    heuristics, registered via ``@register_solver`` with
    ``supports_groups=True`` so they stay out of the bi-criteria default
    portfolio (same mechanism as the deal extension) and join tri-criteria
    requests via ``allow_groups=True``.
  - :func:`plan_pareto_tri` — the tri-criteria analogue of ``plan_pareto``:
    sweep plain + replicated bounded solvers over bound grids, evaluate
    (period, latency, reliability) per candidate, and report the 3-D
    non-dominated front (:func:`repro.core.pareto.pareto_front_tri`).
  - :func:`replicate_stage_plan` — replication pass over an existing
    StagePlan, used by the fleet service's ``reliability_floor`` knob.

Note the semantic contrast with :mod:`repro.core.deal`: a deal group
round-robins tasks (aggregate rate, NO redundancy), a replica group repeats
them (slowest-replica speed, survives member failures).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from .heuristics import run_heuristic
from .metrics import (Mapping, ReplicatedMapping, evaluate_batch, evaluate_tri,
                      reliability)
from .pareto import default_latency_grid, default_period_grid, pareto_front_tri
from .planner import (Objective, PlanReport, PlanRequest, StagePlan, _realize,
                      _run_jobs)
from .platform import Platform
from .solvers import Solution, register_solver
from .workload import Workload

_EPS = 1e-12


def replicate_greedy(
    workload: Workload,
    platform: Platform,
    base: Mapping,
    *,
    period_bound: Optional[float] = None,
    latency_bound: Optional[float] = None,
    target: Optional[float] = None,
) -> ReplicatedMapping:
    """Greedily replicate ``base``'s intervals over unused processors.

    Each step adds the FASTEST free processor to the reliability-critical
    interval — the one with the largest probability that all current
    replicas fail (Π f_u).  The step is rejected (and the greedy stops) when
    it would violate ``period_bound``/``latency_bound``; since every later
    free processor is no faster, no later candidate could do better.  Stops
    early once overall reliability reaches ``target`` (when given), when the
    free pool is exhausted, or when every interval is already perfectly
    reliable.  With ``platform.fail`` unset there is nothing to improve and
    the base mapping comes back as singleton replica sets.
    """
    if isinstance(base, ReplicatedMapping):
        intervals, groups = base.intervals, [list(g) for g in base.groups]
    else:
        intervals, groups = base.intervals, [[a] for a in base.alloc]
    w, delta, b, s = workload.w, workload.delta, platform.b, platform.s
    f = platform.failures
    used = {u for g in groups for u in g}
    free = [int(u) for u in platform.sorted_indices() if int(u) not in used]

    iv = np.asarray(intervals, dtype=np.int64)
    D, E = iv[:, 0], iv[:, 1]
    wsum = np.array([w[d - 1:e].sum() for d, e in iv])
    din = delta[D - 1] / b
    dout = delta[E] / b
    tail = delta[workload.n] / b
    smin = np.array([s[g].min() for g in groups])
    miss = np.array([np.prod(f[g]) for g in groups])

    if platform.fail is not None:
        while free:
            if not (miss > 0.0).any():
                break                      # every interval already certain
            if target is not None and float(np.prod(1.0 - miss)) >= target - _EPS:
                break
            j = int(np.argmax(miss))       # reliability-critical interval
            u = free[0]                    # fastest free processor
            new_smin = min(float(smin[j]), float(s[u]))
            sm = smin.copy()
            sm[j] = new_smin
            lat_terms = din + wsum / sm
            per = float((lat_terms + dout).max())
            lat = float(lat_terms.sum() + tail)
            if period_bound is not None and per > period_bound + _EPS:
                break
            if latency_bound is not None and lat > latency_bound + _EPS:
                break
            free.pop(0)
            groups[j].append(u)
            smin[j] = new_smin
            miss[j] *= float(f[u])
    return ReplicatedMapping(intervals=intervals,
                             groups=tuple(tuple(g) for g in groups))


def replicate_stage_plan(
    workload: Workload,
    platform: Platform,
    plan: StagePlan,
    *,
    target: Optional[float] = None,
    period_bound: Optional[float] = None,
    latency_bound: Optional[float] = None,
) -> StagePlan:
    """Replication pass over an existing plan (the fleet's reliability-floor
    repair): greedy replicas on the base mapping, metrics re-evaluated under
    the consensus model, planner name suffixed ``+rel``.  Returns ``plan``
    unchanged when the platform carries no failure probabilities or no
    replica was added."""
    rm = replicate_greedy(workload, platform, plan.mapping, target=target,
                          period_bound=period_bound, latency_bound=latency_bound)
    if all(len(g) == 1 for g in rm.groups):
        return plan
    per, lat, _rel = evaluate_tri(workload, platform, rm)
    out = _realize(rm.leader_mapping(), per, lat,
                   plan.planner if plan.planner.endswith("+rel")
                   else plan.planner + "+rel",
                   groups=rm.groups)
    return out


def _rel_solver(code: str, direction: str):
    def fn(workload, platform, objective):
        res = run_heuristic(code, workload, platform,
                            objective.bound if objective.bound is not None
                            else math.inf)
        if res.mapping is None:
            return None
        kw = ({"period_bound": objective.bound} if direction == "latency"
              else {"latency_bound": objective.bound})
        rm = replicate_greedy(workload, platform, res.mapping, **kw)
        per, lat, rel = evaluate_tri(workload, platform, rm)
        return Solution(mapping=rm.leader_mapping(), groups=rm.groups,
                        period=per, latency=lat, reliability=rel)
    fn.__name__ = f"_solve_{code.lower()}_rel"
    return fn


for _code in ("H1", "H2", "H3", "H4"):
    register_solver(
        f"{_code}-rel", optimizes="latency", needs_bound=True,
        supports_groups=True,
        description=f"{_code} + greedy interval replication: min latency "
                    "s.t. period <= bound, reliability-maximizing replicas",
    )(_rel_solver(_code, "latency"))

for _code in ("H5", "H6"):
    register_solver(
        f"{_code}-rel", optimizes="period", needs_bound=True,
        supports_groups=True,
        description=f"{_code} + greedy interval replication: min period "
                    "s.t. latency <= bound, reliability-maximizing replicas",
    )(_rel_solver(_code, "period"))


def _fill_reliability(workload: Workload, platform: Platform, cands: list) -> list:
    """Candidates from plain bi-criteria solvers carry reliability=None;
    compute it (singleton replica per interval) in one vectorized pass."""
    need = [i for i, c in enumerate(cands)
            if c.mapping is not None and c.reliability is None]
    if not need:
        return cands
    if platform.fail is None:
        rel = np.ones(len(need))
    else:
        rel = evaluate_batch(workload, platform,
                             [cands[i].mapping for i in need],
                             with_reliability=True)[:, 2]
    out = list(cands)
    for j, i in enumerate(need):
        out[i] = dataclasses.replace(out[i], reliability=float(rel[j]))
    return out


def _select_tri(reliability_floor: Optional[float]):
    """Tri-criteria selection: among admissible candidates at/above the
    reliability floor, the knee of the normalized (period, latency,
    unreliability) distance to the ideal point; when nothing reaches the
    floor, the most reliable candidate (tie-break knee) — graceful
    degradation instead of infeasibility."""
    def policy(candidates, request):
        feas = [c for c in candidates if c.mapping is not None and c.feasible]
        if not feas:
            return None
        atfloor = (feas if reliability_floor is None else
                   [c for c in feas if (c.reliability or 0.0) >= reliability_floor - _EPS])
        pool = atfloor or feas
        pers = np.array([c.period for c in pool])
        lats = np.array([c.latency for c in pool])
        unrel = np.array([1.0 - (c.reliability if c.reliability is not None else 1.0)
                          for c in pool])
        pr = max(pers.max() - pers.min(), 1e-30)
        lr = max(lats.max() - lats.min(), 1e-30)
        rr = max(unrel.max() - unrel.min(), 1e-30)
        score = np.sqrt(((pers - pers.min()) / pr) ** 2
                        + ((lats - lats.min()) / lr) ** 2
                        + ((unrel - unrel.min()) / rr) ** 2)
        if not atfloor:
            best_rel = unrel.min()
            mask = unrel <= best_rel + _EPS
            score = np.where(mask, score, np.inf)
        return pool[int(np.argmin(score))]
    return policy


def plan_pareto_tri(
    workload: Workload,
    platform: Platform,
    *,
    k: int = 20,
    reliability_floor: Optional[float] = None,
    include: Optional[tuple] = None,
    exclude: tuple = ("deal",),
    exact_max_p: int = 12,
    time_budget: Optional[float] = None,
) -> PlanReport:
    """Tri-criteria Pareto planning: ``plan_pareto`` extended with the
    replication-aware solvers and 3-D (period, latency, reliability)
    non-domination.

    Sweeps every applicable bounded solver — the plain heuristics AND their
    ``-rel`` variants (admitted via ``allow_groups=True``; the deal extension
    is excluded by default because its farm groups do not replicate work) —
    over the usual bound grids, evaluates all three criteria per candidate,
    and reports the 3-D front in ``report.pareto`` as (period, latency,
    reliability) triples.  The chosen plan is the knee of the normalized
    3-D trade-off among candidates meeting ``reliability_floor`` (falling
    back to the most reliable candidate when none does).
    """
    policy = _select_tri(reliability_floor)
    request = PlanRequest(
        workload, platform, (Objective("period"), Objective("latency")),
        include=include, exclude=exclude, exact_max_p=exact_max_p,
        time_budget=time_budget, allow_groups=True, selection=policy,
    )
    t0 = time.perf_counter()
    deadline = None if time_budget is None else t0 + time_budget
    pgrid = default_period_grid(workload, platform, k)
    lgrid = default_latency_grid(workload, platform, k)
    jobs = []
    seen = set()
    for obj in request.objectives:
        for spec in request.solver_specs(obj):
            if spec.needs_bound:
                grid = pgrid if obj.minimize == "latency" else lgrid
                jobs.extend((spec, Objective(obj.minimize, bound=float(bd)))
                            for bd in grid)
            elif spec.name not in seen:
                seen.add(spec.name)
                jobs.append((spec, obj))
    cands = _run_jobs(workload, platform, jobs, deadline)
    cands = _fill_reliability(workload, platform, cands)
    pts = [(c.period, c.latency, c.reliability if c.reliability is not None else 1.0)
           for c in cands if c.feasible]
    front = tuple(pareto_front_tri(pts)) if pts else ()
    chosen = policy(cands, request)
    plan = (_realize(chosen.mapping, chosen.period, chosen.latency, chosen.solver,
                     groups=chosen.groups)
            if chosen is not None else None)
    return PlanReport(request, plan, chosen, tuple(cands), front,
                      time.perf_counter() - t0)
