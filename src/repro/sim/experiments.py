"""The paper's simulation study (Section 5), reproduced.

For each experiment (E1-E4), each n in {5,10,20,40} and p in {10,100}, we draw
50 random application/platform pairs and run the six heuristics over a grid of
bounds, producing:

 - trade-off curves: averaged (period, latency) per bound index — the paper's
   Figures 2-7;
 - failure thresholds: the largest bound for which a heuristic finds no
   solution — the paper's Table 1.

Fixed-period heuristics H1-H3 (and H4's inner splitter) are evaluated via a
single exhaustion-run *trajectory* per instance (see
``repro.core.heuristics.split_trajectory``), which is exact and ~20x faster
than re-running per bound.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..core import Objective, Platform, Workload, optimal_latency, solve
from ..core.heuristics import split_trajectory, sp_bi_p
from ..core.metrics import period as eval_period
from ..core.metrics import single_processor_mapping
from .generators import gen_instance

N_STAGES_DEFAULT = (5, 10, 20, 40)
N_PROCS_DEFAULT = (10, 100)


def trajectory(code: str, wl: Workload, pf: Platform) -> list:
    return split_trajectory(code, wl, pf)


def _result_from_trajectory(traj: list, p_fix: float) -> Optional[tuple]:
    """First trajectory state with period <= p_fix, or None (failure)."""
    for per, lat in traj:
        if per <= p_fix + 1e-12:
            return per, lat
    return None


@dataclasses.dataclass
class ExperimentResult:
    exp: str
    n: int
    p: int
    n_pairs: int
    bounds_rel: np.ndarray            # relative bound grid (fraction of single-proc period / L_opt mult)
    # curves[heuristic] = (mean_period, mean_latency, feasible_frac) arrays over the grid
    curves: dict
    thresholds: dict                  # heuristic -> (mean, max) failure threshold


def run_experiment(
    exp: str,
    n: int,
    p: int,
    n_pairs: int = 50,
    n_bounds: int = 16,
    seed0: int = 1234,
    h4_iters: int = 10,
    include_h4: bool = True,
) -> ExperimentResult:
    period_fracs = np.geomspace(0.04, 1.0, n_bounds)     # x single-processor period
    latency_mults = np.linspace(1.0, 3.0, n_bounds)      # x optimal latency

    codes_p = ["H1", "H2", "H3"] + (["H4"] if include_h4 else [])
    codes_l = ["H5", "H6"]
    acc = {c: [[] for _ in range(n_bounds)] for c in codes_p + codes_l}
    thresholds = {c: [] for c in codes_p + codes_l}

    for k in range(n_pairs):
        wl, pf = gen_instance(exp, n, p, seed=seed0 + k)
        hi = eval_period(wl, pf, single_processor_mapping(wl, pf.fastest()))
        l_opt = optimal_latency(wl, pf)
        pgrid = hi * period_fracs
        lgrid = l_opt * latency_mults

        trajs = {c: split_trajectory(c, wl, pf) for c in ["H1", "H2", "H3", "H4"]}
        for c in ["H1", "H2", "H3"]:
            if c not in acc:
                continue
            thresholds[c].append(min(per for per, _ in trajs[c]))
            for bi, pb in enumerate(pgrid):
                r = _result_from_trajectory(trajs[c], pb)
                if r is not None:
                    acc[c][bi].append(r)
        if include_h4:
            # H4 feasibility is characterized by its inner splitter's trajectory;
            # the binary search then trades latency. Run the real H4 per bound.
            thresholds["H4"].append(min(per for per, _ in trajs["H4"]))
            for bi, pb in enumerate(pgrid):
                if _result_from_trajectory(trajs["H4"], pb) is None:
                    continue  # provably infeasible for H4 — skip the binary search
                r = sp_bi_p(wl, pf, pb, iters=h4_iters)
                if r.feasible:
                    acc["H4"][bi].append((r.period, r.latency))

        for c in codes_l:
            thresholds[c].append(l_opt)
            for bi, lb in enumerate(lgrid):
                cand = solve(c, wl, pf, Objective("period", bound=float(lb)))
                if cand.feasible:
                    acc[c][bi].append((cand.period, cand.latency))

    curves = {}
    for c, cols in acc.items():
        mean_per = np.array([np.mean([a for a, _ in col]) if col else np.nan for col in cols])
        mean_lat = np.array([np.mean([b for _, b in col]) if col else np.nan for col in cols])
        frac = np.array([len(col) / n_pairs for col in cols])
        curves[c] = (mean_per, mean_lat, frac)

    thr = {c: (float(np.mean(v)), float(np.max(v))) for c, v in thresholds.items()}
    grid = period_fracs  # stored for reference; latency grids are the mults
    return ExperimentResult(exp, n, p, n_pairs, grid, curves, thr)


def failure_thresholds(
    exps=("E1", "E2", "E3", "E4"),
    ns=N_STAGES_DEFAULT,
    p: int = 10,
    n_pairs: int = 50,
    seed0: int = 1234,
) -> dict:
    """The paper's Table 1: per (experiment, heuristic, n), the failure
    threshold, averaged over instances.  Returns {exp: {code: {n: value}}}."""
    out: dict = {}
    for exp in exps:
        out[exp] = {c: {} for c in ["H1", "H2", "H3", "H4", "H5", "H6"]}
        for n in ns:
            vals = {c: [] for c in out[exp]}
            for k in range(n_pairs):
                wl, pf = gen_instance(exp, n, p, seed=seed0 + k)
                for c in ["H1", "H2", "H3", "H4"]:
                    traj = split_trajectory(c, wl, pf)
                    vals[c].append(min(per for per, _ in traj))
                l_opt = optimal_latency(wl, pf)
                vals["H5"].append(l_opt)
                vals["H6"].append(l_opt)
            for c, v in vals.items():
                out[exp][c][n] = float(np.mean(v))
    return out


def summarize_experiment(res: ExperimentResult) -> str:
    lines = [f"# {res.exp} n={res.n} p={res.p} pairs={res.n_pairs}"]
    lines.append("heuristic,bound_idx,mean_period,mean_latency,feasible_frac")
    for c, (mp, ml, fr) in sorted(res.curves.items()):
        for i in range(len(mp)):
            lines.append(f"{c},{i},{mp[i]:.6g},{ml[i]:.6g},{fr[i]:.3f}")
    lines.append("heuristic,threshold_mean,threshold_max")
    for c, (m, mx) in sorted(res.thresholds.items()):
        lines.append(f"{c},{m:.6g},{mx:.6g}")
    return "\n".join(lines)
