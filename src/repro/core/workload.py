"""Pipeline workload description — the application side of the paper's model.

A pipeline of ``n`` stages S_1..S_n.  Stage S_k reads ``delta[k-1]`` bytes,
performs ``w[k]`` flops, writes ``delta[k]`` bytes (paper Section 2, Figure 1).
``delta`` therefore has ``n + 1`` entries: delta[0] is the input from the
outside world, delta[n] the final output.

``from_arch`` derives a workload from a model architecture config: layers are
stages, ``w_k`` is the per-layer analytic FLOP count, ``delta_k`` the
inter-layer activation bytes for the given input shape.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    """The (w, delta) description of an n-stage pipeline."""

    w: np.ndarray        # shape (n,), flops per stage, w[i] is stage i+1 of the paper
    delta: np.ndarray    # shape (n+1,), bytes between stages (delta[0]=input, delta[n]=output)
    name: str = "workload"

    def __post_init__(self):
        w = np.asarray(self.w, dtype=np.float64)
        delta = np.asarray(self.delta, dtype=np.float64)
        object.__setattr__(self, "w", w)
        object.__setattr__(self, "delta", delta)
        if w.ndim != 1 or delta.ndim != 1:
            raise ValueError("w and delta must be 1-D")
        if len(delta) != len(w) + 1:
            raise ValueError(f"need len(delta) == n+1, got n={len(w)}, len(delta)={len(delta)}")
        if (w < 0).any() or (delta < 0).any():
            raise ValueError("w and delta must be non-negative")

    @property
    def n(self) -> int:
        return int(len(self.w))

    @property
    def total_work(self) -> float:
        return float(self.w.sum())

    def prefix_w(self) -> np.ndarray:
        """prefix_w()[i] = sum of w_1..w_i  (prefix_w()[0] == 0)."""
        return np.concatenate([[0.0], np.cumsum(self.w)])

    def interval_work(self, d: int, e: int) -> float:
        """Sum of w over stages d..e inclusive (1-indexed, paper convention)."""
        if not (1 <= d <= e <= self.n):
            raise ValueError(f"bad interval [{d},{e}] for n={self.n}")
        return float(self.w[d - 1 : e].sum())


def make_workload(w: Sequence[float], delta: Sequence[float], name: str = "workload") -> Workload:
    return Workload(np.asarray(w, dtype=np.float64), np.asarray(delta, dtype=np.float64), name)


def uniform_workload(n: int, w: float = 1.0, delta: float = 0.0) -> Workload:
    return Workload(np.full(n, w), np.full(n + 1, delta), name=f"uniform-{n}")
