"""Whisper-style encoder-decoder backbone (conv/audio frontend is a stub:
``input_specs()`` supplies precomputed frame embeddings of shape
(B, enc_seq, d_model), per the assignment).

Encoder: bidirectional self-attention blocks over the frames.
Decoder: causal self-attention + cross-attention + GELU MLP.
Whisper uses LayerNorm (with bias) and GELU; both are honored here.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attention, cache_from_prefill,
                        decode_attention_step, init_attention, _project_qkv,
                        plain_attention)
from .common import ModelConfig
from .layers import dense_init, embed, init_embed, init_mlp, layer_norm, mlp, shard, unembed


def _init_ln(d, pdt):
    return {"scale": jnp.ones((d,), pdt), "bias": jnp.zeros((d,), pdt)}


def _ln(x, p, cfg):
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


def init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    pdt = cfg.jparam_dtype
    return {
        "ln1": _init_ln(cfg.d_model, pdt),
        "attn": init_attention(k1, cfg),
        "ln2": _init_ln(cfg.d_model, pdt),
        "mlp": init_mlp(k2, cfg),
    }


def init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    pdt = cfg.jparam_dtype
    return {
        "ln1": _init_ln(cfg.d_model, pdt),
        "self_attn": init_attention(k1, cfg),
        "ln2": _init_ln(cfg.d_model, pdt),
        "cross_attn": init_attention(k2, cfg),
        "ln3": _init_ln(cfg.d_model, pdt),
        "mlp": init_mlp(k3, cfg),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": init_embed(ke, cfg),
        "enc": jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        "dec": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "ln_enc": _init_ln(cfg.d_model, cfg.jparam_dtype),
        "ln_f": _init_ln(cfg.d_model, cfg.jparam_dtype),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, enc_seq, d) stub embeddings -> encoder output."""
    x = frames.astype(cfg.jdtype)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg)
        h = attention(lp["attn"], h, cfg, positions=positions, causal=False)
        x = x + h
        h = _ln(x, lp["ln2"], cfg)
        x = x + mlp(lp["mlp"], h, cfg)
        return x, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(x, params["ln_enc"], cfg)


def _dec_block(lp, x, enc_out, cfg, positions, self_kv=None):
    h = _ln(x, lp["ln1"], cfg)
    h = attention(lp["self_attn"], h, cfg, positions=positions, causal=True)
    x = x + h
    h = _ln(x, lp["ln2"], cfg)
    h = attention(lp["cross_attn"], h, cfg, positions=positions, causal=False,
                  kv_x=enc_out, rope=False)
    x = x + h
    h = _ln(x, lp["ln3"], cfg)
    return x + mlp(lp["mlp"], h, cfg)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            frames: jax.Array = None) -> tuple:
    """tokens: (B, S) decoder tokens; frames: (B, enc_seq, d) stub embeddings."""
    enc_out = encode(params, frames, cfg)
    x = embed(params["embed"], tokens, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        return _dec_block(lp, x, enc_out, cfg, positions), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = _ln(x, params["ln_f"], cfg)
    return unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class EncDecState(NamedTuple):
    self_caches: KVCache     # (L, B, C, K, hd)
    cross_k: jax.Array       # (L, B, T, K, hd) — static after encode
    cross_v: jax.Array


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int) -> EncDecState:
    L, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    T = cfg.enc_seq
    return EncDecState(
        self_caches=KVCache(
            k=jnp.zeros((L, batch, capacity, K, hd), cfg.jdtype),
            v=jnp.zeros((L, batch, capacity, K, hd), cfg.jdtype),
            pos=jnp.zeros((L, batch), jnp.int32),
            positions=jnp.full((L, batch, capacity), -1, jnp.int32),
        ),
        cross_k=jnp.zeros((L, batch, T, K, hd), cfg.jdtype),
        cross_v=jnp.zeros((L, batch, T, K, hd), cfg.jdtype),
    )


def precompute_cross(params: dict, enc_out: jax.Array, cfg: ModelConfig) -> tuple:
    """Per-layer cross K/V from the encoder output."""
    T = enc_out.shape[1]
    pos = jnp.arange(T)[None, :]

    def body(_, lp):
        kq = lp["cross_attn"]
        dt = enc_out.dtype
        k = jnp.einsum("btd,dhk->bthk", enc_out, kq["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", enc_out, kq["wv"].astype(dt))
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec"])
    return ks, vs


def decode_step(params: dict, state: EncDecState, token: jax.Array,
                cfg: ModelConfig) -> tuple:
    x = embed(params["embed"], token, cfg)

    def body(x, inp):
        lp, cache, ck, cv = inp
        h = _ln(x, lp["ln1"], cfg)
        h, new_cache = decode_attention_step(lp["self_attn"], h, cache, cfg)
        x = x + h
        h = _ln(x, lp["ln2"], cfg)
        # cross attention against static K/V
        dt = h.dtype
        ca = lp["cross_attn"]
        q = jnp.einsum("bsd,dhk->bshk", h, ca["wq"].astype(dt))
        out = plain_attention(q, ck, cv, causal=False, window=None)
        h = jnp.einsum("bshk,hkd->bsd", out, ca["wo"].astype(dt))
        x = x + h
        h = _ln(x, lp["ln3"], cfg)
        return x + mlp(lp["mlp"], h, cfg), new_cache

    x, new_caches = jax.lax.scan(
        body, x, (params["dec"], state.self_caches, state.cross_k, state.cross_v))
    x = _ln(x, params["ln_f"], cfg)
    logits = unembed(params["embed"], x, cfg)
    return logits, EncDecState(new_caches, state.cross_k, state.cross_v)
