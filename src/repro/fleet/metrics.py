"""Fleet service metrics: the numbers ROADMAP item 2 asks to be gated.

The service calls :meth:`FleetMetrics.record_tick` once per controller tick
with that tick's request count, solve count, warm-start hits, wall time, and
per-instance plan churn.  Aggregates:

  - ``replans_per_sec``  — published replans / total solve wall time
  - ``p50 / p99 latency`` — per-request replan latency; every request in a
    tick shares the tick's collect-to-publish wall time (requests are only
    answered at the tick boundary, so that *is* each request's latency)
  - ``dedup_hit_rate``   — fraction of requests that did NOT need their own
    solve (same-tick signature sharing + cross-tick warm-start hits)
  - ``plan_churn``       — mean fraction of layers whose pod assignment
    changed across a replan (placement stability)

``bench_rows`` formats these as ``fleet_replan_*`` rows in the
BENCH_planner.json row schema ((name, us_per_call, derived, extra-dict)) so
``benchmarks/bench_gate.py`` can gate floors on structured numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FleetMetrics:
    """Aggregated counters over a service run (one trace replay)."""

    ticks: int = 0
    requests: int = 0       # replan requests = dirty instances across ticks
    solves: int = 0         # canonical problems actually solved (batched rows)
    warm_hits: int = 0      # cross-tick plan-cache hits
    events: int = 0
    solve_wall: float = 0.0  # seconds spent collect-to-publish
    latencies: list = dataclasses.field(default_factory=list)
    churns: list = dataclasses.field(default_factory=list)
    # Robustness counters (the chaos-harness surface; all zero on the clean
    # path, so PR-6 consumers see identical numbers):
    degraded_ticks: int = 0      # ticks where a deadline deferral or solver
                                 # fallback fired (service ran but degraded)
    deferred: int = 0            # replan requests pushed to a later tick
    fallback_solves: int = 0     # scalar solves after a batched group raised
    dropped_events: int = 0      # stale/out-of-range events discarded
    below_floor_ticks: int = 0   # instance-ticks spent below the reliability floor
    recovery_ticks: list = dataclasses.field(default_factory=list)
    #                            ^ ticks from dipping below the floor to recovery
    invalid_published: int = 0   # instance-ticks ending with an invalid plan
    #                            (must stay 0: the keep-last-valid guarantee)
    # Durability / supervision counters (PR-8; zero on the clean path):
    quarantined_requests: int = 0  # requests answered by a quarantined
    #                              problem's last valid plan (not retried)
    quarantine_strikes: int = 0    # batched+scalar double-failure rounds
    quarantined_problems: int = 0  # canonical problems quarantined
    solve_retries: int = 0         # supervisor retry attempts (with backoff)
    worker_restarts: int = 0       # workers replaced (timeout / stale heartbeat)
    worker_timeouts: int = 0       # hung solves reaped/abandoned on deadline
    cache_evictions: int = 0       # plan-cache LRU evictions (cap pressure)

    def record_tick(self, *, requests: int, solves: int, warm_hits: int,
                    events: int, wall: float, churns,
                    deferred: int = 0, fallback_solves: int = 0,
                    dropped_events: int = 0, below_floor: int = 0,
                    recoveries=(), invalid_published: int = 0,
                    quarantined_requests: int = 0, quarantine_strikes: int = 0,
                    quarantined_problems: int = 0, solve_retries: int = 0,
                    worker_restarts: int = 0, worker_timeouts: int = 0,
                    cache_evictions: int = 0) -> None:
        self.ticks += 1
        self.requests += requests
        self.solves += solves
        self.warm_hits += warm_hits
        self.events += events
        self.solve_wall += wall
        self.latencies.extend([wall] * requests)
        self.churns.extend(float(c) for c in churns)
        if deferred or fallback_solves or quarantined_requests:
            self.degraded_ticks += 1
        self.deferred += deferred
        self.fallback_solves += fallback_solves
        self.dropped_events += dropped_events
        self.below_floor_ticks += below_floor
        self.recovery_ticks.extend(int(r) for r in recoveries)
        self.invalid_published += invalid_published
        self.quarantined_requests += quarantined_requests
        self.quarantine_strikes += quarantine_strikes
        self.quarantined_problems += quarantined_problems
        self.solve_retries += solve_retries
        self.worker_restarts += worker_restarts
        self.worker_timeouts += worker_timeouts
        self.cache_evictions += cache_evictions

    # -- aggregates -----------------------------------------------------------
    def dedup_hit_rate(self) -> float:
        if not self.requests:
            return 0.0
        return 1.0 - self.solves / self.requests

    def replans_per_sec(self) -> float:
        if self.solve_wall <= 0:
            return 0.0
        return self.requests / self.solve_wall

    def latency_percentile(self, q: float) -> float:
        """Percentile over the per-request latency samples.  An EMPTY sample
        set returns NaN, not 0.0 — a 0 would read as "instant replans" in the
        BENCH rows and sail through the gate's floors; NaN is unambiguous and
        :meth:`bench_rows` turns it into an explicit 0-sample row that
        ``bench_gate.py`` rejects.  A singleton sample is fine (every
        percentile is that sample)."""
        if not self.latencies:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies, dtype=float), q))

    def mean_churn(self) -> float:
        if not self.churns:
            return 0.0
        return float(np.mean(self.churns))

    def max_recovery_ticks(self) -> int:
        return max(self.recovery_ticks) if self.recovery_ticks else 0

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "events": self.events,
            "requests": self.requests,
            "solves": self.solves,
            "warm_hits": self.warm_hits,
            "dedup_hit_rate": self.dedup_hit_rate(),
            "replans_per_sec": self.replans_per_sec(),
            "latency_samples": len(self.latencies),
            "p50_latency_us": self.latency_percentile(50) * 1e6,
            "p99_latency_us": self.latency_percentile(99) * 1e6,
            "mean_churn": self.mean_churn(),
        }

    def robustness_summary(self) -> dict:
        return {
            "degraded_ticks": self.degraded_ticks,
            "deferred": self.deferred,
            "fallback_solves": self.fallback_solves,
            "dropped_events": self.dropped_events,
            "below_floor_ticks": self.below_floor_ticks,
            "recoveries": len(self.recovery_ticks),
            "max_recovery_ticks": self.max_recovery_ticks(),
            "mean_recovery_ticks": (float(np.mean(self.recovery_ticks))
                                    if self.recovery_ticks else 0.0),
            "invalid_published": self.invalid_published,
            "quarantined_requests": self.quarantined_requests,
            "quarantine_strikes": self.quarantine_strikes,
            "quarantined_problems": self.quarantined_problems,
            "solve_retries": self.solve_retries,
            "worker_restarts": self.worker_restarts,
            "worker_timeouts": self.worker_timeouts,
            "cache_evictions": self.cache_evictions,
        }

    def bench_rows(self, suffix: str = "", extra: Optional[dict] = None) -> list:
        """BENCH_planner.json rows (name, us_per_call, derived, extra).

        A run that recorded ZERO per-request latency samples (e.g. a --quick
        trace whose every tick deduped away) emits an explicit 0-sample
        latency row with ``None`` percentiles instead of fake zeros or JSON
        NaNs — ``bench_gate.py`` fails on it, so an empty measurement can
        never pass as a fast one."""
        s = self.summary()
        tag = f"_{suffix}" if suffix else ""
        shared = dict(s)
        if extra:
            shared.update(extra)
        n_lat = s["latency_samples"]
        finite = lambda x: float(x) if np.isfinite(x) else None
        p50, p99 = finite(s["p50_latency_us"]), finite(s["p99_latency_us"])
        shared["p50_latency_us"] = p50
        shared["p99_latency_us"] = p99
        lat_derived = (f"p50={p50:.0f}us p99={p99:.0f}us "
                       f"({n_lat} samples)" if n_lat
                       else "NO SAMPLES — latency unmeasured")
        return [
            (f"fleet_replan_throughput{tag}",
             1e6 / s["replans_per_sec"] if s["replans_per_sec"] else None,
             f"{s['replans_per_sec']:.0f} replans/s over {s['requests']} "
             f"requests in {s['ticks']} ticks",
             shared),
            (f"fleet_replan_latency{tag}", p50, lat_derived,
             {"p50_latency_us": p50,
              "p99_latency_us": p99,
              "latency_samples": n_lat}),
            (f"fleet_replan_dedup{tag}", None,
             f"hit-rate {s['dedup_hit_rate']:.3f} "
             f"({s['requests']} requests -> {s['solves']} solves, "
             f"{s['warm_hits']} warm hits)",
             {"dedup_hit_rate": s["dedup_hit_rate"],
              "requests": s["requests"], "solves": s["solves"],
              "warm_hits": s["warm_hits"]}),
            (f"fleet_replan_churn{tag}", None,
             f"mean fraction of layers remapped per replan: "
             f"{s['mean_churn']:.3f}",
             {"mean_churn": s["mean_churn"]}),
        ]

    def chaos_rows(self, suffix: str = "", extra: Optional[dict] = None) -> list:
        """``fleet_chaos_*`` BENCH rows: graceful-degradation counters under
        fault injection.  ``bench_gate.py`` floors ``invalid_published == 0``
        (never publish a plan addressing dead pods) and bounds
        ``max_recovery_ticks`` (bounded return above the reliability floor)."""
        r = self.robustness_summary()
        tag = f"_{suffix}" if suffix else ""
        shared = dict(r)
        shared["ticks"] = self.ticks
        if extra:
            shared.update(extra)
        return [
            (f"fleet_chaos_robustness{tag}", None,
             f"{r['degraded_ticks']} degraded ticks, {r['deferred']} deferred, "
             f"{r['fallback_solves']} fallback solves, "
             f"{r['dropped_events']} dropped events, "
             f"{r['invalid_published']} invalid published",
             shared),
            (f"fleet_chaos_recovery{tag}", None,
             f"{r['recoveries']} floor recoveries, max {r['max_recovery_ticks']} "
             f"ticks, mean {r['mean_recovery_ticks']:.2f}; "
             f"{r['below_floor_ticks']} instance-ticks below floor",
             {"below_floor_ticks": r["below_floor_ticks"],
              "recoveries": r["recoveries"],
              "max_recovery_ticks": r["max_recovery_ticks"],
              "mean_recovery_ticks": r["mean_recovery_ticks"],
              "invalid_published": r["invalid_published"]}),
        ]
