"""Fleet replanning benchmark: burst-trace replay through the service.

Replays the *standard trace* — a fixed-seed correlated burst trace over a
replicated fleet — through :class:`repro.fleet.ReplanService` and records
ROADMAP item 2's success metrics as ``fleet_replan_*`` rows:

  - ``fleet_replan_throughput`` — replans/sec over the whole replay
  - ``fleet_replan_latency``    — p50/p99 per-request replan latency
  - ``fleet_replan_dedup``      — signature dedup hit-rate (gated floor)
  - ``fleet_replan_churn``      — mean fraction of layers remapped

With ``--chaos`` the same standard trace is run through
:func:`repro.fleet.inject_chaos` (pod-failure storms, flapping pods, event
drop/dup/reorder) against a fleet whose platforms carry seeded failure
probabilities, with a ``reliability_floor`` enabled; the graceful-degradation
counters land as ``fleet_chaos_*`` rows.  The chaos run deliberately leaves
``solve_deadline`` off: wall-clock deferral is machine-dependent, and the
gated numbers (zero invalid published plans, bounded floor recovery) must be
deterministic.  The deadline path is covered by tests/test_fleet.py instead.

With ``--recovery`` the standard chaos trace is run through
:func:`repro.fleet.crash_restart_run`: the controller is journaled
(write-ahead log + snapshots), killed mid-tick at two seeded ticks, and
restarted from its journal each time.  The ``fleet_recovery_*`` rows record
the restore wall time, the WAL replay length, and — the gated contract —
whether the survivor's ``fleet_digest()`` is bit-identical to an
uninterrupted run with zero invalid published ticks and zero quarantines.

With ``--remote`` the same standard chaos trace is served by
**process-isolated subprocess workers** (:class:`repro.fleet.SubprocessWorker`
over the CRC-framed stdio transport) with seeded SIGKILLs injected mid-solve
by :class:`repro.fleet.TransportChaos`, plus a separate wedge probe: a worker
that ignores SIGTERM is handed a 30s in-band hang and must be reaped by the
supervisor's SIGTERM→SIGKILL escalation within the configured solve timeout.
The ``fleet_remote_*`` rows record throughput over the process boundary, the
restart accounting (every restart must be attributable to an injected fault),
and the gated contract: subprocess ``fleet_digest()`` bit-identical to the
inline run, ``invalid_published == 0``, and ``reaped_within_timeout``.

Unlike ``planner_bench.py`` (which regenerates BENCH_planner.json wholesale),
this script MERGES its rows into the existing file so the two benchmarks can
run independently; ``benchmarks/bench_gate.py`` requires the rows and gates
the dedup and throughput floors.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] [--chaos]
                                                    [--recovery] [--remote]
                                                    [--backend B]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
BENCH_JSON = REPO_ROOT / "BENCH_planner.json"

from repro.core import sample_failures  # noqa: E402
from repro.fleet import (ChaosSpec, Journal, ReplanService,  # noqa: E402
                         TransportChaos, crash_restart_run, gen_burst_trace,
                         inject_chaos, make_fleet, subprocess_supervisor)

# The standard trace: every number fixed so the measured dedup hit-rate and
# throughput are comparable across PRs (bench_gate floors assume this shape).
STANDARD = dict(n_groups=16, replicas=16, n=12, p=6, fleet_seed=2007,
                num_ticks=30, trace_seed=42, burst_prob=0.6)
QUICK = dict(n_groups=6, replicas=8, n=8, p=4, fleet_seed=2007,
             num_ticks=12, trace_seed=42, burst_prob=0.6)
# The standard chaos overlay: seeded fault injection + per-group bimodal
# failure probabilities + a reliability floor for the repair pass.  The 0.98
# floor is deliberately strict enough that storm-degraded platforms cannot
# always reach it until flapped capacity returns — that is what produces the
# below-floor time and the recovery latencies the gate bounds (measured 428
# instance-ticks below / 19 recoveries / max 18 ticks on this trace).
CHAOS = dict(chaos_seed=77, fail_seed=5, reliability_floor=0.98)
# The recovery run crashes the controller at 1/3 and 2/3 of the trace (one
# crash lands mid-snapshot-interval, one right after a cadence snapshot) and
# snapshots every 8 ticks — so the gated max WAL replay length is <= 8.
RECOVERY = dict(snapshot_every=8, crash_fracs=(1 / 3, 2 / 3))
# The remote run: subprocess workers under seeded mid-solve SIGKILLs (every
# second dispatch on average, capped), a generous solve timeout so the only
# timeouts are injected ones, and a wedge probe whose reap budget is
# timeout + term_grace + scheduler slack.
REMOTE = dict(workers=2, kill_prob=0.5, kill_seed=1, max_kills=6,
              solve_timeout=60.0, wedge_timeout=0.75, term_grace=0.2,
              reap_slack=2.0)


def _with_failures(pairs, seed: int) -> list:
    """Attach seeded bimodal failure probabilities, one draw per platform
    template so replicas keep sharing their platform (dedup stays honest)."""
    shared: dict = {}
    out = []
    for wl, pf in pairs:
        if id(pf) not in shared:
            shared[id(pf)] = pf.with_failures(sample_failures(
                pf.p, kind="bimodal", seed=seed + len(shared)))
        out.append((wl, shared[id(pf)]))
    return out


def run(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    svc = ReplanService(pairs, backend=backend)
    metrics = svc.run_trace(trace)
    extra = {"backend": backend, "fleet_size": len(pairs),
             "digest": svc.fleet_digest()}
    return metrics.bench_rows(extra=extra)


def run_chaos(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, trace = _chaos_trace(cfg)
    svc = ReplanService(pairs, backend=backend,
                        reliability_floor=CHAOS["reliability_floor"])
    metrics = svc.run_trace(trace)
    extra = {"backend": backend, "fleet_size": len(pairs),
             "reliability_floor": CHAOS["reliability_floor"],
             "chaos_seed": CHAOS["chaos_seed"],
             "digest": svc.fleet_digest()}
    return metrics.chaos_rows(extra=extra)


def run_recovery(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, trace = _chaos_trace(cfg)
    svc_kwargs = dict(backend=backend,
                      reliability_floor=CHAOS["reliability_floor"])
    ref = ReplanService(pairs, **svc_kwargs)
    ref.run_trace(trace)
    crash_ticks = sorted({max(1, int(cfg["num_ticks"] * f))
                          for f in RECOVERY["crash_fracs"]})
    with tempfile.TemporaryDirectory() as d:
        journal = Journal(d, snapshot_every=RECOVERY["snapshot_every"],
                          fsync=False)
        svc, restarts = crash_restart_run(pairs, trace, journal,
                                          crash_ticks=crash_ticks,
                                          **svc_kwargs)
    match = svc.fleet_digest() == ref.fleet_digest()
    replayed = max(r["replayed_ticks"] for r in restarts)
    wall = sum(r["restore_wall"] for r in restarts)
    shared = {"backend": backend, "fleet_size": len(pairs),
              "crash_ticks": crash_ticks,
              "snapshot_every": RECOVERY["snapshot_every"]}
    return [
        ("fleet_recovery_restart", wall * 1e6 / len(restarts),
         f"{len(restarts)} crash/restart cycles, max {replayed} WAL ticks "
         f"replayed, {wall:.3f}s total restore wall",
         dict(shared, restarts=len(restarts), max_replayed_ticks=replayed,
              total_restore_wall_s=wall)),
        ("fleet_recovery_digest", None,
         f"restored fleet digest "
         f"{'matches' if match else 'MISMATCHES'} the uninterrupted run "
         f"({svc.metrics.invalid_published} invalid published, "
         f"{svc.metrics.quarantined_problems} quarantined)",
         dict(shared, digest_match=bool(match), digest=svc.fleet_digest(),
              ref_digest=ref.fleet_digest(), ticks=svc.metrics.ticks,
              invalid_published=svc.metrics.invalid_published,
              quarantined_problems=svc.metrics.quarantined_problems)),
    ]


def _chaos_trace(cfg):
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    pairs = _with_failures(pairs, CHAOS["fail_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    return pairs, inject_chaos(trace, groups, ChaosSpec(),
                               seed=CHAOS["chaos_seed"],
                               initial_pods=cfg["p"])


def _wedge_probe(backend: str) -> dict:
    """Hand a SIGTERM-ignoring worker a 30s in-band hang and time the
    supervisor's SIGTERM→SIGKILL reap.  Returns the measured reap wall, the
    budget it must beat, and whether the kernel kill actually landed."""
    import time as _time

    import numpy as np

    from repro.core.batched import ProblemBatch
    from repro.fleet import WorkerFailed

    rng = np.random.default_rng(0)
    pb = ProblemBatch.from_arrays(
        rng.uniform(0.5, 2.0, (2, 8)), rng.uniform(0.1, 1.0, (2, 9)),
        np.sort(rng.uniform(0.5, 2.0, (2, 4)))[:, ::-1].copy(), 10.0)
    chaos = TransportChaos(wedge_prob=1.0, wedge_seconds=30.0, max_faults=1,
                           seed=5)
    sup = subprocess_supervisor(
        backend=backend, workers=1, timeout=REMOTE["wedge_timeout"],
        chaos=chaos, max_attempts=1, term_grace=REMOTE["term_grace"],
        ignore_sigterm=True)
    wedged = sup.pool[0]
    t0 = _time.perf_counter()
    try:
        sup.solve(pb)
        raise RuntimeError("wedge probe: the 30s hang was not injected")
    except WorkerFailed:
        wall = _time.perf_counter() - t0
    sup.close()
    budget = (REMOTE["wedge_timeout"] + REMOTE["term_grace"]
              + REMOTE["reap_slack"])
    return {"reap_wall_s": wall, "reap_budget_s": budget,
            "wedge_timeout_s": REMOTE["wedge_timeout"],
            "term_grace_s": REMOTE["term_grace"],
            "wedge_returncode": wedged._proc.returncode,
            "sigkills": sup.stats.sigkills,
            "reaped_within_timeout": bool(
                wall <= budget and wedged._proc.returncode == -9
                and sup.stats.timeouts == 1)}


def run_remote(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, trace = _chaos_trace(cfg)
    svc_kwargs = dict(backend=backend,
                      reliability_floor=CHAOS["reliability_floor"])
    ref = ReplanService(pairs, **svc_kwargs)
    ref.run_trace(trace)

    chaos = TransportChaos(kill_prob=REMOTE["kill_prob"],
                           max_faults=REMOTE["max_kills"],
                           seed=REMOTE["kill_seed"])
    svc = ReplanService(pairs, **svc_kwargs)
    svc.supervisor = subprocess_supervisor(
        backend=backend, workers=REMOTE["workers"],
        timeout=REMOTE["solve_timeout"], chaos=chaos, max_attempts=3,
        backoff_base=0.0)
    svc._sync_acct_baselines()
    metrics = svc.run_trace(trace)
    svc.supervisor.close()

    match = svc.fleet_digest() == ref.fleet_digest()
    reap = _wedge_probe(backend)
    sup_stats = svc.supervisor.stats.as_dict()
    shared = {"backend": backend, "fleet_size": len(pairs),
              "workers": REMOTE["workers"], "kill_prob": REMOTE["kill_prob"],
              "solve_timeout_s": REMOTE["solve_timeout"]}
    s = metrics.summary()
    return [
        ("fleet_remote_throughput",
         1e6 / s["replans_per_sec"] if s["replans_per_sec"] else None,
         f"{s['replans_per_sec']:.0f} replans/s over {s['requests']} requests "
         f"in {s['ticks']} ticks across the process boundary "
         f"({sup_stats['dispatches']} dispatches)",
         dict(shared, replans_per_sec=s["replans_per_sec"],
              requests=s["requests"], ticks=s["ticks"],
              dispatches=sup_stats["dispatches"])),
        ("fleet_remote_restarts", None,
         f"{metrics.worker_restarts} worker restarts for "
         f"{chaos.total_faults()} injected faults "
         f"({chaos.counts.get('kill', 0)} kills), "
         f"{metrics.worker_timeouts} timeouts, "
         f"{sup_stats['sigkills']} sigkill escalations",
         dict(shared, worker_restarts=metrics.worker_restarts,
              restart_ceiling=chaos.total_faults(),
              injected=dict(chaos.counts),
              kills=chaos.counts.get("kill", 0),
              worker_timeouts=metrics.worker_timeouts,
              solve_retries=metrics.solve_retries,
              sigkills=sup_stats["sigkills"],
              fallback_solves=metrics.fallback_solves)),
        ("fleet_remote_digest", None,
         f"subprocess fleet digest "
         f"{'matches' if match else 'MISMATCHES'} the inline run "
         f"({metrics.invalid_published} invalid published); wedged worker "
         f"reaped in {reap['reap_wall_s']:.2f}s "
         f"(budget {reap['reap_budget_s']:.2f}s, "
         f"rc {reap['wedge_returncode']})",
         dict(shared, digest_match=bool(match), digest=svc.fleet_digest(),
              ref_digest=ref.fleet_digest(),
              invalid_published=metrics.invalid_published, **reap)),
    ]


def merge_bench_json(rows, path: pathlib.Path = BENCH_JSON,
                     mode: str = "full") -> None:
    """Merge rows into the existing BENCH json (planner_bench owns the file
    and overwrites it wholesale; we only add/update our rows)."""
    payload = json.loads(path.read_text()) if path.exists() else {}
    payload.setdefault("_meta", {})["mode"] = mode
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        entry = {"us_per_call": us, "derived": derived}
        if len(row) > 3 and row[3]:
            entry.update(row[3])
        payload[name] = entry
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="run the standard trace through fault injection and "
                         "emit fleet_chaos_* robustness rows instead")
    ap.add_argument("--recovery", action="store_true",
                    help="crash/restart the journaled controller mid-trace "
                         "and emit fleet_recovery_* durability rows instead")
    ap.add_argument("--remote", action="store_true",
                    help="serve the chaos trace with subprocess workers "
                         "under injected SIGKILLs and emit fleet_remote_* "
                         "process-isolation rows instead")
    ap.add_argument("--backend", default="numpy")
    args = ap.parse_args()
    runner = (run_remote if args.remote
              else run_recovery if args.recovery
              else run_chaos if args.chaos else run)
    rows = runner(quick=args.quick, backend=args.backend)
    for name, us, derived, _ in rows:
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}")
    merge_bench_json(rows, mode="quick" if args.quick else "full")
    print(f"# merged into {BENCH_JSON}")


if __name__ == "__main__":
    main()
