"""Paper simulation study (Section 5): the scenario-family registry (the
source paper's E1-E4, the image-processing follow-up's I1-I4, and the
reliability sequel's R1-R4), experiment runner (scalar / batched / fused
engines), replication sweeps, failure thresholds."""

from .generators import (EXPERIMENTS, FAMILY_SETS, IMAGE_FAMILIES,
                         PAPER_FAMILIES, RELIABILITY_FAMILIES, ExperimentSpec,
                         InstanceBatch, gen_instance, gen_instance_batch,
                         register_experiment)
from .experiments import (ReplicatedResult, failure_thresholds, run_campaign,
                          run_experiment, run_replicated, summarize_experiment,
                          summarize_replicated, trajectory)

__all__ = ["EXPERIMENTS", "FAMILY_SETS", "PAPER_FAMILIES", "IMAGE_FAMILIES",
           "RELIABILITY_FAMILIES",
           "ExperimentSpec", "register_experiment", "InstanceBatch",
           "gen_instance", "gen_instance_batch",
           "ReplicatedResult", "run_experiment", "run_campaign",
           "run_replicated", "failure_thresholds", "trajectory",
           "summarize_experiment", "summarize_replicated"]
