"""Planner quality + speed: heuristic optimality gap vs the exact solver on
small/medium instances, runtime scaling, the vectorized candidate-evaluation
speedup, and the batched-vs-scalar campaign-engine speedup.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
machine-readable ``BENCH_planner.json`` at the repo root so the perf
trajectory is tracked across PRs.  Quality-only rows (optimality gaps) carry
no ``us_per_call`` — gaps are reported in ``derived`` only.

    PYTHONPATH=src python benchmarks/planner_bench.py [--quick]
"""

from __future__ import annotations

import json
import pathlib
import sys
import time

import numpy as np

from repro.core import (Objective, PlanRequest, auto_request, evaluate,
                        evaluate_batch, exact_min_period, make_platform,
                        make_workload, pareto_exact, period, plan_request,
                        solve)
from repro.sim.experiments import run_campaign, run_experiment, summarize_experiment
from repro.sim.generators import gen_instance

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_planner.json"


def optimality_gaps(n_inst: int = 20, seed: int = 0) -> dict:
    """Mean period gap (heuristic / exact - 1) on instances small enough for
    the exact bitmask solver (n<=14, p<=9)."""
    rng = np.random.default_rng(seed)
    gaps = {c: [] for c in ("H1", "H2", "H3", "auto")}
    for _ in range(n_inst):
        n = int(rng.integers(4, 14))
        p = int(rng.integers(3, 9))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        opt = period(wl, pf, exact_min_period(wl, pf))
        for code in ("H1", "H2", "H3"):
            # run to exhaustion: an unreachable period bound minimizes period
            c = solve(code, wl, pf, Objective("latency", bound=0.0))
            gaps[code].append(c.period / opt - 1)
        rep = plan_request(auto_request(wl, pf, Objective("period")))
        gaps["auto"].append(rep.plan.period / opt - 1)
    return {c: float(np.mean(v)) for c, v in gaps.items()}


def timing(reps: int = 10) -> list:
    """us_per_call for each solver at the paper's largest size (n=40, p=100),
    plus the full request/report portfolio."""
    rows = []
    wl, pf = gen_instance("E2", 40, 100, seed=1)
    for code in ("H1", "H2", "H3", "H5", "H6"):
        obj = (Objective("latency", bound=0.0) if code in ("H1", "H2", "H3")
               else Objective("period", bound=1e18))
        t0 = time.perf_counter()
        for _ in range(reps):
            solve(code, wl, pf, obj)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"heuristic_{code}_n40_p100", us, ""))
    t0 = time.perf_counter()
    plan_request(auto_request(wl, pf, Objective("period")))
    rows.append(("planner_auto_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    t0 = time.perf_counter()
    plan_request(PlanRequest(wl, pf, Objective("period")))
    rows.append(("plan_request_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    return rows


def vectorized_eval(reps: int = 5, seed: int = 3) -> list:
    """The tentpole perf claim: batch candidate evaluation vs the per-mapping
    Python loop, on the full mapping enumeration of a small instance (the
    workload of portfolio tables, sweeps, and pareto_exact)."""
    import itertools

    from repro.core import Mapping, all_interval_partitions

    rng = np.random.default_rng(seed)
    n, p = 8, 5
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 51, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    mappings = [Mapping(iv, procs)
                for m in range(1, min(n, p) + 1)
                for iv in all_interval_partitions(n, m)
                for procs in itertools.permutations(range(p), m)]

    t0 = time.perf_counter()
    for _ in range(reps):
        loop = np.array([evaluate(wl, pf, mp) for mp in mappings])
    us_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        batch = evaluate_batch(wl, pf, mappings)
    us_batch = (time.perf_counter() - t0) / reps * 1e6
    assert np.allclose(loop, batch)

    t0 = time.perf_counter()
    for _ in range(reps):
        pareto_exact(wl, pf)
    us_pex = (time.perf_counter() - t0) / reps * 1e6

    k = len(mappings)
    return [
        (f"evaluate_loop_{k}_mappings", us_loop, ""),
        (f"evaluate_batch_{k}_mappings", us_batch,
         f"speedup={us_loop / us_batch:.1f}x"),
        (f"pareto_exact_n{n}_p{p}", us_pex, "vectorized enumeration"),
    ]


def _engine_comparison_rows(exps, points, kw, row_prefix) -> list:
    """Time a family set through all three engines (scalar reference, numpy
    lockstep, fused cold + warm), asserting byte-identical outputs, and emit
    ``{row_prefix}{scalar,batched,fused}_<tag>`` rows."""
    t0 = time.perf_counter()
    scal = {(e, n, p): run_experiment(e, n, p, engine="scalar", **kw)
            for n, p in points for e in exps}
    us_scal = (time.perf_counter() - t0) * 1e6

    def run_engine(backend):
        t0 = time.perf_counter()
        out = {}
        for n, p in points:
            camp = run_campaign(exps, n, p, backend=backend, **kw)
            for e in exps:
                out[(e, n, p)] = camp[e]
        return out, (time.perf_counter() - t0) * 1e6

    batc, us_batc = run_engine("numpy")
    fusd, us_cold = run_engine("fused")    # includes jit traces
    _, us_fusd = run_engine("fused")       # warm: traces cached
    for key in scal:
        assert summarize_experiment(scal[key]) == summarize_experiment(batc[key]), key
        assert summarize_experiment(scal[key]) == summarize_experiment(fusd[key]), key
    tag = (f"{exps[0]}-{exps[-1]}_"
           + "_".join(f"n{n}p{p}" for n, p in points))
    return [
        (f"{row_prefix}scalar_{tag}", us_scal, "per-instance reference path"),
        (f"{row_prefix}batched_{tag}", us_batc,
         f"speedup={us_scal / us_batc:.1f}x vs scalar, identical outputs"),
        (f"{row_prefix}fused_{tag}", us_fusd,
         f"warm; speedup={us_scal / us_fusd:.1f}x vs scalar, "
         f"cold_with_traces_us={us_cold:.0f}, identical outputs"),
    ]


def campaign_speedup(quick: bool = False) -> list:
    """The batched and fused campaign engines vs the per-instance reference
    path on a representative Section-5 slice (all four experiment families,
    paper batch size, small and large (n, p) points), asserting identical
    outputs while timing all three.  The fused engine is timed twice: cold
    (including its one-off jit traces) and warm (the steady-state cost every
    further campaign of the same shapes pays)."""
    if quick:
        points = ((10, 10),)
        kw = dict(n_pairs=4, n_bounds=4, h4_iters=4, include_h4=True)
    else:
        points = ((10, 10), (20, 100), (40, 100))
        kw = dict(n_pairs=50, n_bounds=12, h4_iters=10, include_h4=True)
    return _engine_comparison_rows(("E1", "E2", "E3", "E4"), points, kw,
                                   "campaign_")


def fused_large_grid(quick: bool = False) -> list:
    """The n in {80, 160}, p = 1000 follow-up families under the fused
    engine (the campaign shape the batched engine was host-bound on),
    asserting byte-identical outputs vs the numpy lockstep path."""
    if quick:
        points, n_pairs = ((80, 1000),), 2
    else:
        points, n_pairs = ((80, 1000), (160, 1000)), 4
    exps = ("E1", "E2", "E3", "E4")
    kw = dict(n_pairs=n_pairs, n_bounds=8, h4_iters=6, include_h4=True)
    rows = []
    for n, p in points:
        t0 = time.perf_counter()
        ref = run_campaign(exps, n, p, backend="numpy", **kw)
        us_np = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        run_campaign(exps, n, p, backend="fused", **kw)   # cold: jit traces
        us_cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        fus = run_campaign(exps, n, p, backend="fused", **kw)
        us_warm = (time.perf_counter() - t0) * 1e6
        for e in exps:
            assert summarize_experiment(ref[e]) == summarize_experiment(fus[e]), (e, n)
        rows.append((f"campaign_fused_largegrid_E1-E4_n{n}p{p}", us_warm,
                     f"warm; numpy_batched_us={us_np:.0f}, "
                     f"cold_with_traces_us={us_cold:.0f}, identical outputs"))
    return rows


def image_family_campaign(quick: bool = False) -> list:
    """The image-processing follow-up families (I1-I4: JPEG encoder profile,
    bimodal, correlated comm∝comp, uniform-wide) through the campaign
    engines, asserting byte-identical outputs across scalar/batched/fused."""
    if quick:
        points = ((10, 10),)
        kw = dict(n_pairs=4, n_bounds=4, h4_iters=4, include_h4=True)
    else:
        points = ((10, 10), (20, 100))
        kw = dict(n_pairs=50, n_bounds=12, h4_iters=10, include_h4=True)
    return _engine_comparison_rows(("I1", "I2", "I3", "I4"), points, kw,
                                   "image_family_")


def fused_h4_bisection(quick: bool = False) -> list:
    """The fused ``lax.scan`` H4 bisection (one dispatch per row-chunk for
    the WHOLE binary search) vs the host-driven probe loop it replaced
    (~iters+1 dispatches), identical outputs — dispatch counts recorded in
    ``derived`` so the O(1) contract is tracked across PRs."""
    from repro.core import batched, fused
    from repro.core.metrics import period, single_processor_mapping
    from repro.sim import gen_instance_batch

    n, p = (10, 10) if quick else (20, 100)
    B = 12 if quick else 48
    iters = 10
    batch = gen_instance_batch("E2", n, p, range(100, 100 + B))
    pb = batched._as_problem_batch(batch)
    fracs = np.tile([0.05, 0.2, 0.4, 0.6, 0.8, 1.0], B)[:B]
    bounds = np.array(
        [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
         for (wl, pf), f in zip(batch, fracs)])
    lo, hi = batched.h4_search_bounds(pb)

    batched.batched_sp_bi_p(pb, bounds, iters=iters,
                            backend="fused")  # cold: traces
    fused.reset_dispatch_count()
    t0 = time.perf_counter()
    rs_scan = batched.batched_sp_bi_p(pb, bounds, iters=iters, backend="fused")
    us_scan = (time.perf_counter() - t0) * 1e6
    d_scan = fused.dispatch_count()

    fused.reset_dispatch_count()
    t0 = time.perf_counter()
    rs_loop = batched._sp_bi_p_rowwise(pb, bounds, iters, "fused",
                                       lo.copy(), hi.copy(), True)
    us_loop = (time.perf_counter() - t0) * 1e6
    d_loop = fused.dispatch_count()

    for a, b in zip(rs_scan, rs_loop):
        assert (a.mapping == b.mapping and a.period == b.period
                and a.latency == b.latency and a.feasible == b.feasible
                and a.splits == b.splits)
    assert d_loop >= 2 * d_scan, (d_loop, d_scan)
    return [
        (f"campaign_fused_h4scan_n{n}p{p}_B{B}", us_scan,
         f"dispatches={d_scan} vs {d_loop} probe-loop "
         f"({d_loop / d_scan:.0f}x fewer), identical outputs"),
        (f"campaign_fused_h4probe_loop_n{n}p{p}_B{B}", us_loop,
         f"PR-3 style host-driven bisection, dispatches={d_loop}"),
    ]


def deal_speedup(quick: bool = False) -> list:
    """Satellite before/after: the deal extension's candidate enumeration as
    per-mapping ``_deal_metrics`` Python loops vs the stacked-numpy
    ``_DealState.candidate_metrics`` batch, on identical enumerations."""
    from repro.core import Mapping
    from repro.core.deal import _DealState, _deal_metrics

    rng = np.random.default_rng(7)
    n, p = 24, 64
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 51, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    m = 8
    cuts = sorted(rng.choice(np.arange(2, n), size=m - 1, replace=False))
    iv, prev = [], 1
    for c in list(cuts) + [n]:
        iv.append((prev, int(c)))
        prev = int(c) + 1
    mapping = Mapping(tuple(iv), tuple(range(m)))
    free = list(range(m, p))
    st = _DealState(wl, pf, mapping)
    j = 0
    reps = 20 if quick else 200

    t0 = time.perf_counter()
    for _ in range(reps):
        loop = np.array([
            _deal_metrics(wl, pf, mapping,
                          [[u] if t != j else [u, cand]
                           for t, u in enumerate(mapping.alloc)])
            for cand in free])
    us_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        batch = st.candidate_metrics(j, pf.s[np.asarray(free)])
    us_batch = (time.perf_counter() - t0) / reps * 1e6
    assert np.array_equal(loop, batch)
    k = len(free)
    return [
        (f"deal_enum_loop_{k}_candidates", us_loop,
         "per-candidate _deal_metrics Python loops"),
        (f"deal_enum_batched_{k}_candidates", us_batch,
         f"speedup={us_loop / us_batch:.1f}x, identical metrics"),
    ]


def run(quick: bool = False) -> list:
    rows = timing(reps=2 if quick else 10)
    rows += vectorized_eval(reps=2 if quick else 5)
    rows += campaign_speedup(quick=quick)
    rows += fused_large_grid(quick=quick)
    rows += image_family_campaign(quick=quick)
    rows += fused_h4_bisection(quick=quick)
    rows += deal_speedup(quick=quick)
    gaps = optimality_gaps(n_inst=4 if quick else 20)
    for c, g in gaps.items():
        # quality-only rows: no us_per_call, the gap lives in `derived`
        rows.append((f"gap_vs_exact_{c}", None, f"gap={g:.4f}"))
    return rows


def write_bench_json(rows, path: pathlib.Path = BENCH_JSON,
                     mode: str = "full") -> None:
    """Persist benchmark rows as {name: {us_per_call, derived}} JSON.

    ``_meta.mode`` records quick vs full so cross-PR comparisons never mix
    the two (they use different reps/instance counts under the same names).
    """
    payload = {name: {"us_per_call": us, "derived": derived}
               for name, us, derived in rows}
    payload["_meta"] = {"mode": mode}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def format_row(name, us, derived) -> str:
    return f"{name},{'' if us is None else f'{us:.1f}'},{derived}"


def main() -> None:
    quick = "--quick" in sys.argv
    rows = run(quick=quick)
    for row in rows:
        print(format_row(*row))
    write_bench_json(rows, mode="quick" if quick else "full")
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
