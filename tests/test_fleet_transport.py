"""Wire-protocol tests: frame codec integrity matrix + chaos determinism.

Mirrors the journal CRC matrix (tests/test_fleet_recovery.py) at the frame
layer: every corruption class — flipped payload byte, bad magic, oversize
length field, torn frame — must be *detected* (FrameError or "incomplete"),
never silently absorbed, and the solve/result codecs must round-trip
bit-exactly so subprocess workers are digest-equivalent to inline ones.
"""

import json

import numpy as np
import pytest

from repro.core.batched import ProblemBatch, batched_min_period
from repro.fleet.transport import (HEADER_BYTES, MAGIC, MAX_FRAME_BYTES,
                                   FrameError, FrameReader, TransportChaos,
                                   decode_results, decode_solve, encode_frame,
                                   encode_results, encode_solve)


def _batch(seed=0, rows=3, n=8, p=4):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, size=(rows, n))
    delta = rng.uniform(0.1, 1.0, size=(rows, n + 1))
    s = np.sort(rng.uniform(0.5, 2.0, size=(rows, p)))[:, ::-1].copy()
    return ProblemBatch.from_arrays(w, delta, s, 10.0)


# ---------------------------------------------------------------------------
# Frame codec round trip
# ---------------------------------------------------------------------------

def test_frame_round_trip():
    reader = FrameReader()
    payloads = [["hello", {"pid": 1, "backend": "numpy"}],
                ["solve", {"id": 7, "w": [[1.5, 2.25]]}],
                ["bye", {}]]
    for p in payloads:
        reader.feed(encode_frame(p))
    assert [reader.next_frame() for _ in payloads] == payloads
    assert reader.next_frame() is None
    assert reader.buffered == 0


def test_frame_incremental_feed_one_byte_at_a_time():
    payload = ["result", {"id": 3, "results": [{"x": 0.1 + 0.2}]}]
    wire = encode_frame(payload)
    reader = FrameReader()
    for i, b in enumerate(wire):
        assert reader.next_frame() is None or i == len(wire)
        reader.feed(bytes([b]))
    assert reader.next_frame() == payload


def test_frame_exact_float_round_trip():
    # Shortest-repr JSON floats round-trip float64 exactly — the property
    # the digest-identity contract rests on.
    vals = [0.1, 1 / 3, np.nextafter(1.0, 2.0), 1e-308, 12345.6789e300]
    payload = ["solve", {"id": 1, "w": vals}]
    reader = FrameReader()
    reader.feed(encode_frame(payload))
    got = reader.next_frame()[1]["w"]
    assert all(a == b for a, b in zip(got, vals))


def test_frame_payload_is_canonical_json():
    wire = encode_frame(["solve", {"b": 1, "a": 2}])
    body = wire[HEADER_BYTES:]
    assert body == json.dumps(json.loads(body), separators=(",", ":"),
                              sort_keys=True).encode()


# ---------------------------------------------------------------------------
# Corruption matrix — every fault detected, none absorbed
# ---------------------------------------------------------------------------

def _wire(payload=None):
    return encode_frame(payload or ["solve", {"id": 1, "w": [1.0, 2.0]}])


def test_flipped_payload_byte_trips_crc():
    wire = bytearray(_wire())
    wire[HEADER_BYTES + 3] ^= 0x01
    reader = FrameReader()
    reader.feed(bytes(wire))
    with pytest.raises(FrameError, match="CRC"):
        reader.next_frame()


def test_flipped_crc_field_trips_crc():
    wire = bytearray(_wire())
    wire[HEADER_BYTES - 1] ^= 0xFF
    reader = FrameReader()
    reader.feed(bytes(wire))
    with pytest.raises(FrameError, match="CRC"):
        reader.next_frame()


def test_bad_magic_detected():
    wire = bytearray(_wire())
    wire[0] ^= 0xFF
    reader = FrameReader()
    reader.feed(bytes(wire))
    with pytest.raises(FrameError, match="magic"):
        reader.next_frame()


def test_oversize_length_field_fails_fast():
    # A corrupted length field must not leave the reader waiting on
    # gigabytes that will never arrive.
    import struct
    hdr = struct.pack("<2sII", MAGIC, MAX_FRAME_BYTES + 1, 0)
    reader = FrameReader()
    reader.feed(hdr)
    with pytest.raises(FrameError, match="ceiling"):
        reader.next_frame()


def test_short_header_and_torn_payload_are_incomplete_not_errors():
    wire = _wire()
    reader = FrameReader()
    reader.feed(wire[:HEADER_BYTES - 2])   # torn header
    assert reader.next_frame() is None
    reader.feed(wire[HEADER_BYTES - 2:len(wire) - 3])   # torn payload
    assert reader.next_frame() is None
    reader.feed(wire[len(wire) - 3:])      # completion drains it
    assert reader.next_frame() is not None


def test_valid_json_but_wrong_shape_rejected():
    for bad in [{"kind": "x"}, ["only-kind"], [1, {}], "str", [["a"], {}]]:
        reader = FrameReader()
        reader.feed(encode_frame(bad) if bad != "str"
                    else encode_frame("str"))
        with pytest.raises(FrameError, match="kind"):
            reader.next_frame()


def test_no_resync_after_poison():
    # A good frame appended after a corrupt one must NOT be recovered:
    # poisoned stream means replaced worker, not best-effort resync.
    bad = bytearray(_wire())
    bad[0] ^= 0xFF
    reader = FrameReader()
    reader.feed(bytes(bad) + _wire(["bye", {}]))
    with pytest.raises(FrameError):
        reader.next_frame()


# ---------------------------------------------------------------------------
# Solve / result codecs — bit-exact round trip
# ---------------------------------------------------------------------------

def test_solve_codec_rebuilds_batch_bit_identically():
    pb = _batch(seed=3)
    reader = FrameReader()
    reader.feed(encode_frame(encode_solve(9, pb)))
    kind, body = reader.next_frame()
    assert kind == "solve" and body["id"] == 9
    pb2 = decode_solve(body)
    for name in ("w", "delta", "s", "prefix"):
        a, b = getattr(pb, name), getattr(pb2, name)
        assert a.dtype == b.dtype and np.array_equal(a, b)
    assert pb.b == pb2.b
    assert np.array_equal(pb.order, pb2.order)


def test_result_codec_round_trips_solutions_exactly():
    pb = _batch(seed=4)
    results = batched_min_period(pb, "numpy")
    reader = FrameReader()
    reader.feed(encode_frame(encode_results(2, results)))
    kind, body = reader.next_frame()
    assert kind == "result" and body["id"] == 2
    assert decode_results(body) == results


# ---------------------------------------------------------------------------
# TransportChaos
# ---------------------------------------------------------------------------

def test_chaos_zero_probabilities_is_identity():
    chaos = TransportChaos(seed=0)
    chunk = bytes(range(256))
    assert chaos.mangle_chunk(chunk) == chunk
    assert not chaos.spawn_dead_on_arrival()
    assert not chaos.kill_mid_solve()
    assert not chaos.wedge_solve()
    assert chaos.total_faults() == 0


def test_chaos_is_seed_deterministic():
    def run(seed):
        chaos = TransportChaos(kill_prob=0.3, corrupt_prob=0.3,
                               drop_prob=0.2, seed=seed)
        out = []
        for i in range(50):
            out.append(chaos.kill_mid_solve())
            out.append(chaos.mangle_chunk(bytes([i]) * 64))
        return out, dict(chaos.counts)

    a, ca = run(7)
    b, cb = run(7)
    c, cc = run(8)
    assert a == b and ca == cb
    assert a != c


def test_chaos_max_faults_caps_total_injections():
    chaos = TransportChaos(kill_prob=1.0, corrupt_prob=1.0, max_faults=3,
                           seed=0)
    for _ in range(20):
        chaos.kill_mid_solve()
        chaos.mangle_chunk(b"xyzw")
    assert chaos.total_faults() == 3


def test_chaos_corrupt_flips_exactly_one_byte():
    chaos = TransportChaos(corrupt_prob=1.0, max_faults=1, seed=5)
    chunk = bytes(64)
    mangled = chaos.mangle_chunk(chunk)
    assert mangled is not None and len(mangled) == 64
    assert sum(a != b for a, b in zip(chunk, mangled)) == 1


def test_chaos_truncate_shortens_drop_removes():
    chaos = TransportChaos(truncate_prob=1.0, max_faults=1, seed=6)
    chunk = bytes(64)
    out = chaos.mangle_chunk(chunk)
    assert out is not None and 1 <= len(out) < 64
    chaos = TransportChaos(drop_prob=1.0, max_faults=1, seed=6)
    assert chaos.mangle_chunk(chunk) is None
    assert chaos.mangle_chunk(chunk) == chunk   # capped: second passes clean


def test_chaos_rejects_bad_probabilities():
    with pytest.raises(ValueError):
        TransportChaos(kill_prob=1.5)
    with pytest.raises(ValueError):
        TransportChaos(drop_prob=-0.1)
    with pytest.raises(ValueError):
        TransportChaos(max_faults=-1)


def test_oversize_payload_refused_at_encode():
    with pytest.raises(FrameError, match="ceiling"):
        encode_frame(["solve", {"blob": "x" * (MAX_FRAME_BYTES + 16)}])
