"""qwen1.5-110b [dense]: GQA + QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab_size=152064,
        qkv_bias=True,
        fsdp_params=True,     # 444 GB fp32 params exceed 16 GB/chip under TP-only
        accum_steps=4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen1.5-110b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=384, vocab_size=512,
        qkv_bias=True,
    )
