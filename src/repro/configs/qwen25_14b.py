"""qwen2.5-14b [dense]: GQA + QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064,
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2.5-14b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        qkv_bias=True,
    )
