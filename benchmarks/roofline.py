"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
per-device partitioned HLO (loop-aware parse, see repro.launch.hlo_analysis):

    compute    = perdev_dot_flops       / PEAK_FLOPS      (197 TF/s bf16/chip)
    memory     = perdev_bytes_accessed  / HBM_BW          (819 GB/s)
    collective = perdev_collective_bytes/ LINK_BW         (~50 GB/s/link ICI)

(dividing per-device quantities by per-chip rates is identical to the spec's
global/(chips x rate) form).  Also reported: the dominant term, the step-time
bound max(terms), MODEL_FLOPS (analytic useful flops) and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs, and the roofline fraction
compute_term / max(terms) (the score: 1.0 = compute-bound at peak).

Reads results/dryrun/*.json; writes results/roofline.csv and prints a table.
"""

from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"
OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "roofline.csv"


def analyze_record(rec: dict) -> dict:
    chips = rec["devices"] if rec["mesh"] != "pod16x16" else 256
    hlo = rec["hlo"]
    compute = hlo["dot_flops"] / PEAK_FLOPS
    memory = hlo["bytes_accessed"] / HBM_BW
    collective = hlo["collective_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = rec.get("model_flops", 0.0)
    hlo_flops_global = hlo["dot_flops"] * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant, "bound_s": bound,
        "roofline_frac": compute / bound if bound else 0.0,
        "model_flops": model_flops, "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful,
        "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "arg_gb": rec.get("memory", {}).get("argument_size_in_bytes", 0) / 1e9,
    }


def load_all(dryrun_dir=DRYRUN) -> list:
    rows = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("ok"):
            rows.append(analyze_record(rec))
        else:
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "dominant": "FAILED",
                         "error": rec.get("error", "?")[:80]})
    return rows


def run() -> list:
    rows = load_all()
    header = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "bound_s,roofline_frac,useful_ratio,temp_gb")
    lines = [header]
    out_rows = []
    for r in rows:
        if r.get("dominant") == "FAILED":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,FAILED,,,,")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4f},"
            f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
            f"{r['bound_s']:.4f},{r['roofline_frac']:.3f},"
            f"{r['useful_ratio']:.3f},{r['temp_gb']:.2f}")
        out_rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                         f"frac={r['roofline_frac']:.3f};dom={r['dominant']}"))
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text("\n".join(lines))
    return out_rows


def main() -> None:
    rows = load_all()
    print(f"{'arch':18s} {'shape':12s} {'mesh':12s} {'comp_s':>8s} {'mem_s':>8s} "
          f"{'coll_s':>8s} {'dominant':>10s} {'frac':>6s} {'useful':>7s} {'tmpGB':>6s}")
    for r in rows:
        if r.get("dominant") == "FAILED":
            print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:12s} "
                  f"{'FAILED: ' + r.get('error', ''):s}")
            continue
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:12s} "
              f"{r['compute_s']:8.3f} {r['memory_s']:8.3f} {r['collective_s']:8.3f} "
              f"{r['dominant']:>10s} {r['roofline_frac']:6.3f} "
              f"{r['useful_ratio']:7.3f} {r['temp_gb']:6.1f}")
    run()
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
