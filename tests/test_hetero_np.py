"""Machine-check of the Theorem-1 NMWTS reduction (both directions)."""

import numpy as np
import pytest

from repro.core.hetero_partition import (Hetero1DInstance, NMWTSInstance,
                                         extract_nmwts_solution, reduce_nmwts,
                                         witness_from_nmwts_solution)


def _yes_instance(rng, m=3, M=6):
    """Build a YES NMWTS instance by construction."""
    x = rng.integers(1, M, m)
    y = rng.integers(1, M, m)
    z = np.array(sorted(x + rng.permutation(y)))
    rng.shuffle(z)
    return NMWTSInstance(x, y, z)


def test_reduction_yes_direction():
    """NMWTS solution -> K=1 witness for the reduced instance (proof, 'if')."""
    rng = np.random.default_rng(0)
    for _ in range(10):
        inst = _yes_instance(rng)
        sol = inst.solve_small()
        assert sol is not None
        s1, s2 = sol
        hinst = reduce_nmwts(inst)
        intervals, procs = witness_from_nmwts_solution(inst, s1, s2)
        assert hinst.check(intervals, procs), "witness must satisfy K=1"


def test_reduction_witness_structure_recovers_solution():
    """K=1 witness -> NMWTS solution (proof, 'only if')."""
    rng = np.random.default_rng(1)
    for _ in range(10):
        inst = _yes_instance(rng)
        s1, s2 = inst.solve_small()
        hinst = reduce_nmwts(inst)
        intervals, procs = witness_from_nmwts_solution(inst, s1, s2)
        rec = extract_nmwts_solution(inst, hinst, intervals, procs)
        assert rec is not None
        r1, r2 = rec
        # recovered permutations must solve the NMWTS instance
        for i in range(inst.m):
            assert inst.x[i] + inst.y[r1[i]] == inst.z[r2[i]]


def test_reduction_no_instance_has_no_witness():
    """For a NO instance, no partition meets K=1 (checked by exact solver on
    the derived mapping problem, small sizes)."""
    from repro.core.exact import exact_min_period
    from repro.core.metrics import period

    # equal sums (the reduction's precondition) but unmatchable targets:
    # x_i + y_j is always 2, z needs {1, 3} -> NO instance
    inst = NMWTSInstance(np.array([1, 1]), np.array([1, 1]), np.array([1, 3]))
    assert inst.solve_small() is None
    hinst = reduce_nmwts(inst)
    wl, pf = hinst.as_mapping_problem()
    mp = exact_min_period(wl, pf)
    assert mp is not None
    assert period(wl, pf, mp) > 1.0 + 1e-9


def test_reduction_shapes():
    inst = NMWTSInstance(np.array([1, 2]), np.array([2, 1]), np.array([3, 3]))
    h = reduce_nmwts(inst)
    M = 3
    assert len(h.a) == (M + 3) * 2
    assert len(h.s) == 6
    assert h.K == 1.0
