"""Fleet service metrics: the numbers ROADMAP item 2 asks to be gated.

The service calls :meth:`FleetMetrics.record_tick` once per controller tick
with that tick's request count, solve count, warm-start hits, wall time, and
per-instance plan churn.  Aggregates:

  - ``replans_per_sec``  — published replans / total solve wall time
  - ``p50 / p99 latency`` — per-request replan latency; every request in a
    tick shares the tick's collect-to-publish wall time (requests are only
    answered at the tick boundary, so that *is* each request's latency)
  - ``dedup_hit_rate``   — fraction of requests that did NOT need their own
    solve (same-tick signature sharing + cross-tick warm-start hits)
  - ``plan_churn``       — mean fraction of layers whose pod assignment
    changed across a replan (placement stability)

``bench_rows`` formats these as ``fleet_replan_*`` rows in the
BENCH_planner.json row schema ((name, us_per_call, derived, extra-dict)) so
``benchmarks/bench_gate.py`` can gate floors on structured numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FleetMetrics:
    """Aggregated counters over a service run (one trace replay)."""

    ticks: int = 0
    requests: int = 0       # replan requests = dirty instances across ticks
    solves: int = 0         # canonical problems actually solved (batched rows)
    warm_hits: int = 0      # cross-tick plan-cache hits
    events: int = 0
    solve_wall: float = 0.0  # seconds spent collect-to-publish
    latencies: list = dataclasses.field(default_factory=list)
    churns: list = dataclasses.field(default_factory=list)

    def record_tick(self, *, requests: int, solves: int, warm_hits: int,
                    events: int, wall: float, churns) -> None:
        self.ticks += 1
        self.requests += requests
        self.solves += solves
        self.warm_hits += warm_hits
        self.events += events
        self.solve_wall += wall
        self.latencies.extend([wall] * requests)
        self.churns.extend(float(c) for c in churns)

    # -- aggregates -----------------------------------------------------------
    def dedup_hit_rate(self) -> float:
        if not self.requests:
            return 0.0
        return 1.0 - self.solves / self.requests

    def replans_per_sec(self) -> float:
        if self.solve_wall <= 0:
            return 0.0
        return self.requests / self.solve_wall

    def latency_percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies), q))

    def mean_churn(self) -> float:
        if not self.churns:
            return 0.0
        return float(np.mean(self.churns))

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "events": self.events,
            "requests": self.requests,
            "solves": self.solves,
            "warm_hits": self.warm_hits,
            "dedup_hit_rate": self.dedup_hit_rate(),
            "replans_per_sec": self.replans_per_sec(),
            "p50_latency_us": self.latency_percentile(50) * 1e6,
            "p99_latency_us": self.latency_percentile(99) * 1e6,
            "mean_churn": self.mean_churn(),
        }

    def bench_rows(self, suffix: str = "", extra: Optional[dict] = None) -> list:
        """BENCH_planner.json rows (name, us_per_call, derived, extra)."""
        s = self.summary()
        tag = f"_{suffix}" if suffix else ""
        shared = dict(s)
        if extra:
            shared.update(extra)
        return [
            (f"fleet_replan_throughput{tag}",
             1e6 / s["replans_per_sec"] if s["replans_per_sec"] else None,
             f"{s['replans_per_sec']:.0f} replans/s over {s['requests']} "
             f"requests in {s['ticks']} ticks",
             shared),
            (f"fleet_replan_latency{tag}", s["p50_latency_us"],
             f"p50={s['p50_latency_us']:.0f}us p99={s['p99_latency_us']:.0f}us",
             {"p50_latency_us": s["p50_latency_us"],
              "p99_latency_us": s["p99_latency_us"]}),
            (f"fleet_replan_dedup{tag}", None,
             f"hit-rate {s['dedup_hit_rate']:.3f} "
             f"({s['requests']} requests -> {s['solves']} solves, "
             f"{s['warm_hits']} warm hits)",
             {"dedup_hit_rate": s["dedup_hit_rate"],
              "requests": s["requests"], "solves": s["solves"],
              "warm_hits": s["warm_hits"]}),
            (f"fleet_replan_churn{tag}", None,
             f"mean fraction of layers remapped per replan: "
             f"{s['mean_churn']:.3f}",
             {"mean_churn": s["mean_churn"]}),
        ]
