"""Interval mappings and the paper's two metrics (Eq. 1 and Eq. 2).

A mapping is a partition of stages [1..n] into m <= p intervals
I_j = [d_j, e_j] (1-indexed, consecutive, covering) together with an
allocation of each interval to a *distinct* processor.

    T_period  = max_j ( delta[d_j-1]/b + sum(w[d_j..e_j])/s_alloc(j) + delta[e_j]/b )
    T_latency = sum_j ( delta[d_j-1]/b + sum(w[d_j..e_j])/s_alloc(j) ) + delta[n]/b

Note the asymmetry, faithful to the paper: the period charges *both* the input
and the output communication of every interval (one-port: each processor both
receives and sends every period), while the latency charges each inter-processor
hand-off once, plus the final output.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .platform import Platform
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Mapping:
    """Interval mapping: intervals[j] = (d_j, e_j) 1-indexed, alloc[j] = processor id."""

    intervals: tuple  # tuple[tuple[int, int], ...]
    alloc: tuple      # tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "intervals", tuple((int(d), int(e)) for d, e in self.intervals))
        object.__setattr__(self, "alloc", tuple(int(a) for a in self.alloc))
        if len(self.intervals) != len(self.alloc):
            raise ValueError("one processor per interval")

    @property
    def m(self) -> int:
        return len(self.intervals)

    def validate(self, n: int, p: int) -> None:
        """Check the partition conditions of the paper (d_1=1, d_{j+1}=e_j+1, e_m=n)
        and that allocated processors are distinct and in range."""
        if self.m == 0:
            raise ValueError("empty mapping")
        if self.m > p:
            raise ValueError(f"more intervals ({self.m}) than processors ({p})")
        d0, _ = self.intervals[0]
        if d0 != 1:
            raise ValueError("first interval must start at stage 1")
        prev_e = 0
        for (d, e) in self.intervals:
            if d != prev_e + 1:
                raise ValueError(f"interval [{d},{e}] does not follow previous end {prev_e}")
            if e < d:
                raise ValueError(f"empty interval [{d},{e}]")
            prev_e = e
        if prev_e != n:
            raise ValueError(f"last interval ends at {prev_e}, expected n={n}")
        if len(set(self.alloc)) != len(self.alloc):
            raise ValueError("processors must be distinct")
        for a in self.alloc:
            if not (0 <= a < p):
                raise ValueError(f"processor {a} out of range")


def interval_cycle_times(workload: Workload, platform: Platform, mapping: Mapping) -> np.ndarray:
    """Per-interval cycle time: in-comm + compute + out-comm (the max of these is the period)."""
    w, delta, b, s = workload.w, workload.delta, platform.b, platform.s
    out = np.empty(mapping.m)
    for j, ((d, e), a) in enumerate(zip(mapping.intervals, mapping.alloc)):
        out[j] = delta[d - 1] / b + w[d - 1 : e].sum() / s[a] + delta[e] / b
    return out


def period(workload: Workload, platform: Platform, mapping: Mapping) -> float:
    """Eq. (1)."""
    return float(interval_cycle_times(workload, platform, mapping).max())


def latency(workload: Workload, platform: Platform, mapping: Mapping) -> float:
    """Eq. (2)."""
    w, delta, b, s = workload.w, workload.delta, platform.b, platform.s
    tot = 0.0
    for (d, e), a in zip(mapping.intervals, mapping.alloc):
        tot += delta[d - 1] / b + w[d - 1 : e].sum() / s[a]
    return float(tot + delta[workload.n] / b)


def evaluate(workload: Workload, platform: Platform, mapping: Mapping) -> tuple:
    """(period, latency) for a mapping."""
    return (period(workload, platform, mapping), latency(workload, platform, mapping))


def evaluate_batch(workload: Workload, platform: Platform,
                   mappings: Sequence[Mapping]) -> np.ndarray:
    """Vectorized ``evaluate`` over a batch of mappings.

    Returns an array of shape (len(mappings), 2): column 0 the period (Eq. 1),
    column 1 the latency (Eq. 2).  Mappings are stacked into (B, m) index
    arrays per interval count so the cycle and latency terms of the whole
    batch are computed with numpy instead of per-mapping Python loops — this
    is what makes portfolio and sweep evaluation cheap.
    """
    out = np.empty((len(mappings), 2))
    if not len(mappings):
        return out
    pre = workload.prefix_w()
    delta, b, s = workload.delta, platform.b, platform.s
    tail = delta[workload.n] / b
    by_m: dict = {}
    for i, mp in enumerate(mappings):
        by_m.setdefault(mp.m, []).append(i)
    for idxs in by_m.values():
        iv = np.array([mappings[i].intervals for i in idxs])   # (B, m, 2)
        al = np.array([mappings[i].alloc for i in idxs])       # (B, m)
        D, E = iv[:, :, 0], iv[:, :, 1]
        lat_terms = delta[D - 1] / b + (pre[E] - pre[D - 1]) / s[al]
        cyc = lat_terms + delta[E] / b
        ix = np.asarray(idxs)
        out[ix, 0] = cyc.max(axis=1)
        out[ix, 1] = lat_terms.sum(axis=1) + tail
    return out


def single_processor_mapping(workload: Workload, proc: int) -> Mapping:
    return Mapping(intervals=((1, workload.n),), alloc=(proc,))


def optimal_latency(workload: Workload, platform: Platform) -> float:
    """Lemma 1: minimum latency = whole chain on the fastest processor."""
    m = single_processor_mapping(workload, platform.fastest())
    return latency(workload, platform, m)


def intervals_from_cuts(n: int, cuts: Sequence[int]) -> tuple:
    """cuts = sorted interior cut points; cut c means a boundary between stage c and c+1.
    Returns the interval tuple for Mapping."""
    prev = 1
    out = []
    for c in cuts:
        out.append((prev, c))
        prev = c + 1
    out.append((prev, n))
    return tuple(out)


def all_interval_partitions(n: int, m: int) -> Iterable[tuple]:
    """Yield every partition of [1..n] into exactly m intervals (as interval tuples)."""
    import itertools

    for cuts in itertools.combinations(range(1, n), m - 1):
        yield intervals_from_cuts(n, cuts)
