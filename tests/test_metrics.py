"""Eq. (1)/(2) metric correctness and Mapping validation."""

import numpy as np
import pytest

from repro.core import (Mapping, Workload, Platform, evaluate, latency,
                        make_platform, make_workload, optimal_latency, period,
                        single_processor_mapping, intervals_from_cuts,
                        all_interval_partitions)


def test_period_latency_hand_computed():
    # 3 stages, delta = [4, 2, 6, 8], w = [10, 20, 30]; b = 2
    wl = make_workload([10, 20, 30], [4, 2, 6, 8])
    pf = make_platform([5.0, 10.0], b=2.0)
    # intervals: [1,1] on P0, [2,3] on P1
    mp = Mapping(((1, 1), (2, 3)), (0, 1))
    # cycle(1,1,P0) = 4/2 + 10/5 + 2/2 = 2+2+1 = 5
    # cycle(2,3,P1) = 2/2 + 50/10 + 8/2 = 1+5+4 = 10
    assert period(wl, pf, mp) == pytest.approx(10.0)
    # latency = (4/2 + 10/5) + (2/2 + 50/10) + 8/2 = 4 + 6 + 4 = 14
    assert latency(wl, pf, mp) == pytest.approx(14.0)


def test_single_processor_mapping():
    wl = make_workload([1, 2, 3], [1, 1, 1, 1])
    pf = make_platform([2.0, 4.0], b=1.0)
    mp = single_processor_mapping(wl, pf.fastest())
    assert mp.alloc == (1,)
    # period == latency for a single interval
    per, lat = evaluate(wl, pf, mp)
    assert per == pytest.approx(1 / 1 + 6 / 4 + 1 / 1)
    assert lat == pytest.approx(per)


def test_optimal_latency_is_fastest_processor():
    wl = make_workload([5, 5], [0, 0, 0])
    pf = make_platform([1.0, 10.0, 2.0], b=1.0)
    assert optimal_latency(wl, pf) == pytest.approx(1.0)


def test_mapping_validation():
    wl = make_workload([1, 1, 1], [0, 0, 0, 0])
    Mapping(((1, 2), (3, 3)), (0, 1)).validate(3, 2)
    with pytest.raises(ValueError):
        Mapping(((1, 1), (3, 3)), (0, 1)).validate(3, 2)  # gap
    with pytest.raises(ValueError):
        Mapping(((1, 2), (3, 3)), (0, 0)).validate(3, 2)  # dup processor
    with pytest.raises(ValueError):
        Mapping(((1, 3),), (5,)).validate(3, 2)           # proc out of range
    with pytest.raises(ValueError):
        Mapping(((2, 3),), (0,)).validate(3, 2)           # must start at 1


def test_intervals_from_cuts_and_enumeration():
    assert intervals_from_cuts(5, [2, 3]) == ((1, 2), (3, 3), (4, 5))
    parts = list(all_interval_partitions(4, 2))
    assert ((1, 1), (2, 4)) in parts and ((1, 3), (4, 4)) in parts
    assert len(parts) == 3
    # m intervals of n stages: C(n-1, m-1)
    assert len(list(all_interval_partitions(6, 3))) == 10


def test_workload_platform_validation():
    with pytest.raises(ValueError):
        make_workload([1, 2], [1, 1])          # delta too short
    with pytest.raises(ValueError):
        make_workload([-1], [0, 0])            # negative work
    with pytest.raises(ValueError):
        make_platform([0.0], b=1.0)            # zero speed
    with pytest.raises(ValueError):
        make_platform([1.0], b=0.0)            # zero bandwidth


def test_sorted_indices_stable_ties():
    pf = make_platform([3.0, 5.0, 5.0, 1.0], b=1.0)
    assert list(pf.sorted_indices()) == [1, 2, 0, 3]
