"""shard_map pipeline runtime executing a planner StagePlan.

The paper's interval mapping becomes executable here:

 1. ``make_stage_params`` packs the stacked per-layer weights (L, ...) into
    padded per-stage stacks (S, L_max, ...) + a validity mask, following the
    plan's (possibly unequal) intervals — heterogeneous-speed pods get
    intervals sized by the paper's heuristics.
 2. ``pipelined_loss_fn`` builds a differentiable GPipe pipeline:
    ``shard_map`` manual over the stage axis (explicit ``ppermute`` hand-offs
    = the delta/b terms of Eq. 1/2), everything else left to GSPMD (DP/TP
    inside a stage).  Backward is JAX autodiff through the tick scan — the
    reversed pipeline — with each stage step rematerialized.

The microbatch loop is a ``lax.scan`` over M + S - 1 ticks; stage 0 injects
microbatch t at tick t, the last stage computes per-microbatch CE loss, and
the scalar losses are summed across stages with ``psum`` (only the last
stage contributes non-zeros).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.planner import StagePlan
from ..models.common import ModelConfig
from ..models.layers import embed, rms_norm, unembed
from ..models.train import cross_entropy
from ..models.transformer import block_forward
from .schedule import gpipe_ticks


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    num_stages: int
    layers_per_stage: int            # padded depth L_max
    num_microbatches: int
    stage_axis: str = "stage"


def make_stage_params(layer_params, plan: StagePlan, num_pods: int):
    """Pack (L, ...) stacked layer weights into per-POD stacks
    (num_pods, L_max, ...) + validity mask (num_pods, L_max).

    The paper's mapping allocates interval j to processor alloc(j); weights of
    interval j therefore land in pod slot alloc(j), pods not enrolled by the
    plan stay empty (all-masked) and just idle.  Padding slots carry zeros and
    are masked to identity in the stage body.
    """
    Lmax = plan.max_stage_size
    sizes = plan.stage_sizes
    alloc = plan.mapping.alloc
    assert max(alloc) < num_pods, (alloc, num_pods)
    starts = np.cumsum([0] + list(sizes))[:-1]

    def pack(leaf):
        out = jnp.zeros((num_pods, Lmax) + leaf.shape[1:], leaf.dtype)
        for j, (start, size) in enumerate(zip(starts, sizes)):
            out = out.at[alloc[j], :size].set(leaf[start:start + size])
        return out

    return jax.tree.map(pack, layer_params), make_stage_mask(plan, num_pods)


def make_stage_mask(plan: StagePlan, num_pods: int):
    """(num_pods, L_max) bool validity mask for the plan (no weights needed)."""
    mask = jnp.zeros((num_pods, plan.max_stage_size), bool)
    for j, size in enumerate(plan.stage_sizes):
        mask = mask.at[plan.mapping.alloc[j], :size].set(True)
    return mask


def _stage_fn(stage_layers, mask, x, cfg: ModelConfig, positions):
    """Run this stage's (padded) layers; masked slots are identity."""

    def body(x, inp):
        lp, m = inp
        y, _ = block_forward(lp, x, cfg, positions)
        return jnp.where(m, y, x), None

    x, _ = jax.lax.scan(body, x, (stage_layers, mask))
    return x


def pipelined_loss_fn(cfg: ModelConfig, plan: StagePlan, num_microbatches: int,
                      mask, mesh=None, stage_axis: str = "stage") -> Callable:
    """Returns loss(params, batch) running the plan's pipeline.

    params = {"embed": ..., "stages": (S, L_max, ...) packed tree, "ln_f": ...}
    (the bool validity ``mask`` (S, L_max) is closed over — it must not
    receive gradients); batch = {"tokens": (B, S_seq), "labels": (B, S_seq)}
    with B divisible by num_microbatches.
    """
    m = plan.num_stages                  # enrolled intervals (may be < pods)
    M = num_microbatches
    ticks = gpipe_ticks(m, M)
    alloc = list(plan.mapping.alloc)     # chain position j -> pod alloc[j]

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, seq = tokens.shape
        assert B % M == 0, (B, M)
        mb = B // M
        tok_q = tokens.reshape(M, mb, seq)
        lab_q = labels.reshape(M, mb, seq)

        def pipe(stages, mask, embed_p, lnf, tok_q, lab_q):
            sidx = jax.lax.axis_index(stage_axis)
            npods = jax.lax.axis_size(stage_axis)
            stages = jax.tree.map(lambda a: a[0], stages)      # local pod's stack
            mask_l = mask[0]
            positions = jnp.arange(seq)[None, :]

            # pod -> chain position (or -1 if not enrolled by the plan)
            chain_pos_arr = np.full(npods, -1, np.int64)
            for j, a in enumerate(alloc):
                chain_pos_arr[a] = j
            chain_pos = jnp.asarray(chain_pos_arr)[sidx]

            x0 = jnp.zeros((mb, seq, cfg.d_model), cfg.jdtype)
            losses0 = jnp.zeros((M,), jnp.float32)

            def tick_fn(carry, t):
                x_in, losses = carry
                mb_idx = t - chain_pos
                # the plan's first pod injects microbatch t (embedded)
                tok = tok_q[jnp.clip(t, 0, M - 1)]
                injected = embed(embed_p, tok, cfg)
                x = jnp.where(sidx == alloc[0], injected, x_in)
                y = _stage_fn(stages, mask_l, x, cfg, positions)
                active = (chain_pos >= 0) & (mb_idx >= 0) & (mb_idx < M)
                # the plan's last pod computes this microbatch's loss
                lab = lab_q[jnp.clip(mb_idx, 0, M - 1)]
                h = rms_norm(y, lnf, cfg.norm_eps)
                logits = unembed(embed_p, h, cfg)
                ce = cross_entropy(logits, lab)
                take = active & (sidx == alloc[-1])
                losses = losses.at[jnp.clip(mb_idx, 0, M - 1)].add(
                    jnp.where(take, ce, 0.0))
                # hand off along the plan's chain (the paper's delta/b edges)
                perm = [(alloc[j], alloc[j + 1]) for j in range(m - 1)]
                x_next = jax.lax.ppermute(y, stage_axis, perm) if perm else y
                return (x_next, losses), None

            tick_body = jax.checkpoint(tick_fn)
            (_, losses), _ = jax.lax.scan(tick_body, (x0, losses0),
                                          jnp.arange(ticks))
            # only the last stage holds real losses; share them
            losses = jax.lax.psum(losses, stage_axis)
            return losses.mean()

        pipe_mapped = jax.shard_map(
            pipe,
            mesh=mesh,
            in_specs=(P(stage_axis), P(stage_axis), P(), P(), P(), P()),
            out_specs=P(),
            axis_names={stage_axis},
            check_vma=False,
        )
        return pipe_mapped(params["stages"], mask, params["embed"],
                           params["ln_f"], tok_q, lab_q)

    return loss_fn


def sequential_loss_fn(cfg: ModelConfig) -> Callable:
    """Reference: same math, no pipeline (for equivalence tests)."""

    def loss_fn(params, batch):
        from ..models.transformer import forward

        logits, _ = forward({"embed": params["embed"],
                             "layers": params["layers"],
                             "ln_f": params["ln_f"]},
                            batch["tokens"], cfg)
        return cross_entropy(logits, batch["labels"])

    return loss_fn
