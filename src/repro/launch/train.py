"""Training driver: checkpointed, fault-tolerant, planner-integrated.

CLI (CPU-scale example; the same loop drives the production mesh):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised here (and by tests/examples):
  - deterministic restart: data stream is a pure function of (seed, step), so
    crash + restore_latest resumes the exact token sequence;
  - straggler watch: per-step wall times feed a StragglerMonitor; on
    detection the paper planner recomputes the stage intervals (logged);
  - throughput metrics: tokens/s, step time EWMA, loss.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import ShardedLoader, SyntheticLMDataset
from ..models import get_model, init_optimizer, make_train_step
from ..models.common import ShapeSpec


def build(arch: str, smoke: bool, batch: int, seq: int, base_lr: float,
          total_steps: int):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    api = get_model(cfg)
    train_step = make_train_step(api.forward, cfg, base_lr=base_lr,
                                 total_steps=total_steps)
    return cfg, api, jax.jit(train_step, donate_argnums=(0, 1))


def train_loop(arch: str = "qwen3-4b", smoke: bool = True, steps: int = 100,
               batch: int = 8, seq: int = 128, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50, base_lr: float = 3e-4, seed: int = 0,
               log_every: int = 10, fail_at_step: Optional[int] = None) -> dict:
    """Returns final metrics.  ``fail_at_step`` simulates a crash (tests)."""
    cfg, api, train_step = build(arch, smoke, batch, seq, base_lr, steps)
    params = api.init(jax.random.PRNGKey(seed))
    opt_state = init_optimizer(params)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            tree, manifest = restored
            params, opt_state = tree["params"], tree["opt"]
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            start_step = manifest["step"] + 1
            print(f"[train] restored checkpoint at step {manifest['step']}")

    ds = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=seed)
    losses = []
    t_last = time.time()
    step_times = []
    for step in range(start_step, steps):
        batch_np = ds.batch(step)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.family == "vlm":
            batch_dev["patch_embeds"] = jnp.zeros(
                (batch, cfg.n_vis_tokens, cfg.d_model), cfg.jdtype)
        if cfg.family == "encdec":
            batch_dev["frames"] = jnp.zeros(
                (batch, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        params, opt_state, metrics = train_step(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        now = time.time()
        step_times.append(now - t_last)
        t_last = now
        if step % log_every == 0:
            tps = batch * seq / max(step_times[-1], 1e-9)
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({step_times[-1]*1000:.0f} ms, {tps:.0f} tok/s)")
        if mgr is not None and step > 0 and step % ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state},
                     extras={"loss": loss})
        if fail_at_step is not None and step == fail_at_step:
            mgr and mgr.wait()
            raise RuntimeError(f"simulated failure at step {step}")
    if mgr is not None:
        mgr.save(steps - 1, {"params": params, "opt": opt_state},
                 extras={"loss": losses[-1]})
        mgr.wait()
    return {
        "first_loss": losses[0] if losses else None,
        "final_loss": float(np.mean(losses[-5:])) if losses else None,
        "steps_run": len(losses),
        "start_step": start_step,
        "mean_step_s": float(np.mean(step_times[1:])) if len(step_times) > 1 else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train_loop(arch=args.arch, smoke=args.smoke, steps=args.steps,
                     batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, base_lr=args.lr, seed=args.seed)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
