"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (7:1).  [arXiv:2405.04517; unverified]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-350m", family="xlstm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=8, xlstm_chunk=256,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-350m-smoke", family="xlstm",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=512,
        slstm_every=2, xlstm_chunk=32,
    )
