"""Deal-skeleton extension (the paper's Section-7 'natural extension').

When a stage interval is both the period bottleneck and splitting is stuck
(single stage, or no improving cut), the paper suggests nesting a *deal*
(farm) skeleton: round-robin the tasks of that interval over a GROUP of
processors.  With a group U processing every |U|-th task, the interval's
cycle time becomes

    cycle_deal = delta_in/b + w_I / sum_{u in U} s_u + delta_out/b

under perfect dealing (each task goes to a processor proportionally often to
its speed; the aggregate rate is the sum of speeds), while its LATENCY
contribution uses the slowest group member (a task may land on it):

    lat_deal = delta_in/b + w_I / min_{u in U} s_u

``plan_with_deal`` runs the base planner, then greedily assigns remaining
unused processors as replicas of the current bottleneck interval while the
period improves.  In the TPU mapping this is data parallelism *within* a
stage group — which the runtime already executes (DP inside a pod) — so the
extension closes the loop between the paper's future work and what modern
pipelines actually do.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .metrics import Mapping
from .planner import Objective, StagePlan, auto_request, plan, plan_request
from .platform import Platform
from .solvers import Solution, register_solver
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class DealPlan:
    """A stage plan where each interval may own a GROUP of processors."""

    base: StagePlan
    groups: tuple              # tuple[tuple[int, ...]] — processors per interval
    period: float
    latency: float

    @property
    def num_stages(self) -> int:
        return self.base.num_stages


def _deal_metrics(workload: Workload, platform: Platform, mapping: Mapping,
                  groups) -> tuple:
    """Per-candidate Python-loop reference for grouped-mapping metrics.

    Kept as the behavioral reference (and the "before" side of the
    deal-extension benchmark): the vectorized greedy below must reproduce it
    bit-for-bit, which it does because both accumulate group rates in
    append order and latency terms in chain order."""
    w, delta, b, s = workload.w, workload.delta, platform.b, platform.s
    per = 0.0
    lat = 0.0
    for (d, e), grp in zip(mapping.intervals, groups):
        wsum = w[d - 1: e].sum()
        rate = sum(s[u] for u in grp)
        cyc = delta[d - 1] / b + wsum / rate + delta[e] / b
        per = max(per, cyc)
        lat += delta[d - 1] / b + wsum / min(s[u] for u in grp)
    lat += delta[workload.n] / b
    return float(per), float(lat)


class _DealState:
    """Stacked per-interval state for the greedy deal loop (cf.
    ``metrics.evaluate_batch``): interval constants are computed ONCE as
    arrays, groups are summarized by their aggregate ``rate`` and slowest
    member ``smin``, and every candidate evaluation is elementwise numpy
    instead of a per-mapping Python loop over intervals."""

    def __init__(self, workload: Workload, platform: Platform, mapping: Mapping):
        w, delta, b = workload.w, workload.delta, platform.b
        iv = np.asarray(mapping.intervals, dtype=np.int64)
        D, E = iv[:, 0], iv[:, 1]
        # same reduction as the reference's w[d-1:e].sum(), cached per interval
        self.wsum = np.array([w[d - 1:e].sum() for d, e in iv])
        self.din = delta[D - 1] / b
        self.dout = delta[E] / b
        self.tail = delta[workload.n] / b
        alloc = np.asarray(mapping.alloc, dtype=np.int64)
        self.rate = platform.s[alloc].astype(float)   # append-order running sums
        self.smin = platform.s[alloc].astype(float)

    def metrics(self, rate: np.ndarray, smin: np.ndarray) -> tuple:
        """(period, latency) of one group summary — elementwise arrays, with
        the reference's chain-order latency accumulation."""
        cyc = self.din + self.wsum / rate + self.dout
        lat_terms = self.din + self.wsum / smin
        lat = 0.0
        for t in lat_terms:            # reference order: interval chain, then tail
            lat += float(t)
        return float(max(cyc.max(), 0.0)), float(lat + self.tail)

    def candidate_metrics(self, j: int, cand_speeds: np.ndarray) -> np.ndarray:
        """Stacked enumeration: (period, latency) for EVERY candidate
        processor joining bottleneck group ``j``, in one (F, m) numpy
        evaluation — the deal analogue of ``evaluate_batch`` replacing the
        per-candidate ``_deal_metrics`` Python loops.  Returns (F, 2)."""
        F = cand_speeds.size
        rate = np.broadcast_to(self.rate, (F, self.rate.size)).copy()
        smin = np.broadcast_to(self.smin, (F, self.smin.size)).copy()
        rate[:, j] = self.rate[j] + cand_speeds
        smin[:, j] = np.minimum(self.smin[j], cand_speeds)
        cyc = self.din[None] + self.wsum[None] / rate + self.dout[None]
        lat_terms = self.din[None] + self.wsum[None] / smin
        out = np.empty((F, 2))
        out[:, 0] = np.maximum(cyc.max(axis=1), 0.0)
        for f in range(F):             # chain-order accumulation per candidate
            lat = 0.0
            for t in lat_terms[f]:
                lat += float(t)
            out[f, 1] = lat + self.tail
        return out

    def accept(self, j: int, speed: float) -> None:
        self.rate[j] += speed
        self.smin[j] = min(self.smin[j], speed)


def plan_with_deal(workload: Workload, platform: Platform,
                   objective: Optional[Objective] = None,
                   mode: str = "auto") -> DealPlan:
    """Base interval plan + greedy deal-replication of the bottleneck stage.

    Back-compat facade: the base plan goes through the PlanRequest portfolio
    (explicit heuristic/exact modes fall back to the ``plan()`` facade).
    Candidate evaluation runs through the stacked-numpy :class:`_DealState`
    (one array expression per greedy step over all free candidates) instead
    of per-mapping Python loops; results are bit-identical to the
    ``_deal_metrics`` reference (asserted by tests/test_deal.py)."""
    objective = objective or Objective("period")
    if mode == "auto":
        from .planner import InfeasiblePlan

        report = plan_request(auto_request(workload, platform, objective))
        if report.plan is None:
            raise InfeasiblePlan(
                f"no planner produced a feasible mapping for {objective}")
        base = dataclasses.replace(report.plan,
                                   planner=f"auto({report.chosen.solver})")
    else:
        base = plan(workload, platform, objective, mode=mode)
    used = set(base.mapping.alloc)
    free = [int(u) for u in platform.sorted_indices() if int(u) not in used]
    groups = [[u] for u in base.mapping.alloc]

    st = _DealState(workload, platform, base.mapping)
    per, lat = st.metrics(st.rate, st.smin)
    while free:
        # bottleneck interval under the current group rates
        cyc = st.din + st.wsum / st.rate + st.dout
        j = int(np.argmax(cyc))
        # the greedy only ever enrolls the fastest free processor, so only
        # that one candidate is evaluated (stacked-numpy interval math); the
        # full-enumeration batch lives in candidate_metrics for sweep callers
        cands = st.candidate_metrics(j, platform.s[free[:1]])
        new_per, new_lat = float(cands[0, 0]), float(cands[0, 1])
        if new_per >= per - 1e-12:
            break                      # bottleneck is communication-bound
        if objective.minimize == "period" and objective.bound is not None \
                and new_lat > objective.bound + 1e-12:
            break
        cand = free.pop(0)
        groups[j].append(cand)
        st.accept(j, float(platform.s[cand]))
        per, lat = new_per, new_lat
    return DealPlan(base=base, groups=tuple(tuple(g) for g in groups),
                    period=per, latency=lat)


@register_solver("deal", optimizes="period", supports_groups=True,
                 description="interval plan + greedy deal-replication of the "
                             "bottleneck stage over unused processors")
def _solve_deal(workload, platform, objective):
    """Registry entry for the deal extension: only selected by requests with
    ``allow_groups=True`` (or an explicit include)."""
    dp = plan_with_deal(workload, platform, objective)
    return Solution(mapping=dp.base.mapping, groups=dp.groups,
                    period=dp.period, latency=dp.latency)
