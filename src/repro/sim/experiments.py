"""The paper's simulation study (Section 5), reproduced.

For each experiment (E1-E4), each n in {5,10,20,40} and p in {10,100}, we draw
50 random application/platform pairs and run the six heuristics over a grid of
bounds, producing:

 - trade-off curves: averaged (period, latency) per bound index — the paper's
   Figures 2-7;
 - failure thresholds: the largest bound for which a heuristic finds no
   solution — the paper's Table 1.

Fixed-period heuristics H1-H3 (and H4's inner splitter) are evaluated via a
single exhaustion-run *trajectory* per instance (see
``repro.core.heuristics.split_trajectory``), which is exact and ~20x faster
than re-running per bound.

Three engines produce identical outputs (asserted by tests/test_batched.py):

  - ``engine="batched"`` (default): the whole campaign runs through the
    lockstep stacked-instance engine (:mod:`repro.core.batched`) — one
    trajectory pass per heuristic over all instances, H5/H6 over the full
    (instance x bound) grid in one pass, and an H4 binary search probing all
    feasible (instance, bound) problems per bisection step.
  - ``engine="fused"``: the same campaign structure, but every lockstep loop
    is a single ``jax.jit``-compiled ``lax.while_loop``
    (:mod:`repro.core.fused`) — O(1) host dispatches per heuristic arity
    instead of O(iterations), which is what lets campaigns run
    device-resident and unlocks the large-grid (n in {80, 160}, p = 1000)
    and many-seed replication sweeps.
  - ``engine="sharded"``: the fused campaign as one ``shard_map`` SPMD
    program per row-chunk with the stacked-instance axis sharded across
    every device (:mod:`repro.core.sharded`) — a whole replication study
    scales out while staying bit-identical to the fused column.
  - ``engine="scalar"``: the per-instance reference path (one Python loop per
    instance/bound), kept as the behavioral reference in the same spirit as
    ``heuristics.reference_mode``.
  - ``engine="auto"``: pick batched/fused per (n, p) from the measured
    crossover table (:func:`auto_engine`; scalar never wins a campaign).

Replication sweeps (:func:`run_replicated`) rerun a campaign over R disjoint
seed banks and report mean +/- 95% confidence intervals on the Figures 2-7
curves and Table 1 thresholds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..core import Objective, Platform, Workload, optimal_latency, solve
from ..core.batched import (ProblemBatch, _as_problem_batch,
                            _fixed_latency_state, batched_sp_bi_p,
                            batched_trajectory_sets, evaluate_state_rows)
from ..core.heuristics import split_trajectory, sp_bi_p
from ..core.metrics import period as eval_period
from ..core.metrics import single_processor_mapping
from .generators import gen_instance_batch

N_STAGES_DEFAULT = (5, 10, 20, 40)
N_PROCS_DEFAULT = (10, 100)
# the large-grid follow-up shapes (ROADMAP), unlocked by the fused engine
N_STAGES_LARGE = (80, 160)
N_PROCS_LARGE = (1000,)
# scenario-family sets (the paper's E1-E4, the image study's I1-I4) live in
# sim.generators.FAMILY_SETS; every campaign entry point here takes any
# family mix sharing (n, p).

ENGINES = ("batched", "fused", "sharded", "scalar", "auto")

# Measured engine-crossover table (2-core CPU reference box, warm jits; the
# README's engine-selection section reproduces it).  Scalar never wins a
# campaign — it exists as the behavioral reference.  The span-bucketed fused
# engine wins the small/medium grids; the numpy lockstep engine keeps a small
# edge once per-(n,arity) chunking splits the batch (large n at p=1000):
#
#   (n, p)       scalar    numpy-batched   fused (warm)
#   (5, 10)      1.4 s     0.13 s          0.10 s
#   (10, 10)     2.1 s     0.17 s          0.13 s
#   (20, 100)    4.0 s     0.32 s          0.31 s
#   (40, 100)    9.6 s     0.85 s          0.89 s
#   (80, 1000)   —         0.41 s          0.61 s
#   (160, 1000)  —         1.06 s          1.37 s
#
# (E1-E4, n_pairs=50 small / 4 large, n_bounds=8, h4_iters=6.)
_AUTO_FUSED_MAX_NP = 2_000     # n * p at/below which fused wins on CPU


def auto_engine(n: int, p: int) -> str:
    """Pick the fastest engine for an (n, p) campaign point from the measured
    crossover table above: on accelerators always ``fused`` (the O(1)-dispatch
    design is the point); on CPU ``fused`` below the measured ``n * p``
    crossover, ``batched`` above it; ``batched`` when jax is unavailable."""
    from ..core.fused import fused_available

    if not fused_available():
        return "batched"
    import jax

    if jax.default_backend() in ("tpu", "gpu"):
        return "fused"
    return "fused" if n * p <= _AUTO_FUSED_MAX_NP else "batched"


def _resolve_engine(engine: str, n: int, p: int) -> str:
    if engine == "auto":
        return auto_engine(n, p)
    return engine


def _campaign_backend(engine: str, backend: str) -> str:
    """Map the (engine, backend) pair onto the lockstep runner's backend
    string: the fused/sharded engines ignore the kernels-only backend knob."""
    if engine in ("fused", "sharded"):
        return engine
    return backend


def trajectory(code: str, wl: Workload, pf: Platform) -> list:
    return split_trajectory(code, wl, pf)


def _result_from_trajectory(traj: list, p_fix: float) -> Optional[tuple]:
    """First trajectory state with period <= p_fix, or None (failure)."""
    for per, lat in traj:
        if per <= p_fix + 1e-12:
            return per, lat
    return None


@dataclasses.dataclass
class ExperimentResult:
    exp: str
    n: int
    p: int
    n_pairs: int
    bounds_rel: np.ndarray            # relative bound grid (fraction of single-proc period / L_opt mult)
    # curves[heuristic] = (mean_period, mean_latency, feasible_frac) arrays over the grid
    curves: dict
    thresholds: dict                  # heuristic -> (mean, max) failure threshold


def run_experiment(
    exp: str,
    n: int,
    p: int,
    n_pairs: int = 50,
    n_bounds: int = 16,
    seed0: int = 1234,
    h4_iters: int = 10,
    include_h4: bool = True,
    engine: str = "batched",
    backend: str = "numpy",
) -> ExperimentResult:
    period_fracs = np.geomspace(0.04, 1.0, n_bounds)     # x single-processor period
    latency_mults = np.linspace(1.0, 3.0, n_bounds)      # x optimal latency

    engine = _resolve_engine(engine, n, p)
    if engine in ("batched", "fused", "sharded"):
        return run_campaign([exp], n, p, n_pairs=n_pairs, n_bounds=n_bounds,
                            seed0=seed0, h4_iters=h4_iters,
                            include_h4=include_h4,
                            backend=_campaign_backend(engine, backend))[exp]
    if engine != "scalar":
        raise ValueError(f"unknown engine {engine!r}; use one of {ENGINES}")
    codes_p = ["H1", "H2", "H3"] + (["H4"] if include_h4 else [])
    codes_l = ["H5", "H6"]
    acc = {c: [[] for _ in range(n_bounds)] for c in codes_p + codes_l}
    thresholds = {c: [] for c in codes_p + codes_l}
    # one gen_instance_batch serves both engines: the scalar path iterates
    # its per-instance objects, the batched path consumes its stacked arrays
    batch = gen_instance_batch(exp, n, p, [seed0 + k for k in range(n_pairs)])
    _run_scalar(batch, h4_iters, include_h4,
                period_fracs, latency_mults, codes_l, acc, thresholds)

    curves = {}
    for c, cols in acc.items():
        mean_per = np.array([np.mean([a for a, _ in col]) if col else np.nan for col in cols])
        mean_lat = np.array([np.mean([b for _, b in col]) if col else np.nan for col in cols])
        frac = np.array([len(col) / n_pairs for col in cols])
        curves[c] = (mean_per, mean_lat, frac)

    thr = {c: (float(np.mean(v)), float(np.max(v))) for c, v in thresholds.items()}
    grid = period_fracs  # stored for reference; latency grids are the mults
    return ExperimentResult(exp, n, p, n_pairs, grid, curves, thr)


def _run_scalar(batch, h4_iters, include_h4,
                period_fracs, latency_mults, codes_l, acc, thresholds) -> None:
    """Per-instance reference path: one Python loop per (instance, bound),
    over the per-instance objects of an already-generated InstanceBatch (the
    same one whose stacked arrays the batched engine would consume — the
    instances are generated exactly once per campaign, never re-drawn from
    seeds)."""
    for wl, pf in batch:
        hi = eval_period(wl, pf, single_processor_mapping(wl, pf.fastest()))
        l_opt = optimal_latency(wl, pf)
        pgrid = hi * period_fracs
        lgrid = l_opt * latency_mults

        trajs = {c: split_trajectory(c, wl, pf) for c in ["H1", "H2", "H3", "H4"]}
        for c in ["H1", "H2", "H3"]:
            if c not in acc:
                continue
            thresholds[c].append(min(per for per, _ in trajs[c]))
            for bi, pb in enumerate(pgrid):
                r = _result_from_trajectory(trajs[c], pb)
                if r is not None:
                    acc[c][bi].append(r)
        if include_h4:
            # H4 feasibility is characterized by its inner splitter's trajectory;
            # the binary search then trades latency. Run the real H4 per bound.
            thresholds["H4"].append(min(per for per, _ in trajs["H4"]))
            for bi, pb in enumerate(pgrid):
                if _result_from_trajectory(trajs["H4"], pb) is None:
                    continue  # provably infeasible for H4 — skip the binary search
                r = sp_bi_p(wl, pf, pb, iters=h4_iters)
                if r.feasible:
                    acc["H4"][bi].append((r.period, r.latency))

        for c in codes_l:
            thresholds[c].append(l_opt)
            for bi, lb in enumerate(lgrid):
                cand = solve(c, wl, pf, Objective("period", bound=float(lb)))
                if cand.feasible:
                    acc[c][bi].append((cand.period, cand.latency))


def _campaign_core(pb, workloads, platforms, pgrids, lgrids, n_bounds,
                   h4_iters, include_h4, backend):
    """Batched-engine evaluation of G stacked instances (any mix of
    experiment families sharing (n, p)) over per-instance bound grids.

    Returns ``(points, thr)``: ``points[code][g][bi]`` is the accumulated
    (period, latency) or None, ``thr[code][g]`` the failure threshold — both
    bit-identical to what the scalar path produces per instance.
    """
    G = len(workloads)
    codes_p = ["H1", "H2", "H3"] + (["H4"] if include_h4 else [])
    points = {c: [[None] * n_bounds for _ in range(G)] for c in codes_p + ["H5", "H6"]}
    thr = {}

    trajs = batched_trajectory_sets(codes_p, pb, backend=backend)
    for c in ["H1", "H2", "H3"]:
        thr[c] = [min(per for per, _ in trajs[c][g]) for g in range(G)]
        for g in range(G):
            for bi in range(n_bounds):
                points[c][g][bi] = _result_from_trajectory(trajs[c][g], pgrids[g][bi])
    if include_h4:
        thr["H4"] = [min(per for per, _ in trajs["H4"][g]) for g in range(G)]
        # One lockstep binary search over every (instance, bound) problem that
        # the trajectory proves feasible.
        todo = [(g, bi) for g in range(G) for bi in range(n_bounds)
                if _result_from_trajectory(trajs["H4"][g], pgrids[g][bi]) is not None]
        if todo:
            sub = pb.take([g for g, _ in todo])
            bounds = [pgrids[g][bi] for g, bi in todo]
            res4 = batched_sp_bi_p(sub, bounds, iters=h4_iters, backend=backend,
                                   with_mappings=False,
                                   groups=[g for g, _ in todo])
            for (g, bi), r in zip(todo, res4):
                if r.feasible:
                    points["H4"][g][bi] = (r.period, r.latency)

    # H5/H6 over the (instance x bound) grid.  The running latency of the
    # splitting loop is monotone non-decreasing (new processors are never
    # faster than enrolled ones, so dlat >= 0), hence every bound at or above
    # the *unconstrained* run's final latency provably reproduces that run —
    # one lockstep pass per instance covers the whole tail of its bound grid,
    # and only the binding bounds run individually.
    for c in ("H5", "H6"):
        st_inf, _ = _fixed_latency_state(c, pb, np.full(G, np.inf), backend)
        m_inf = st_inf.latency()
        metr_inf = evaluate_state_rows(workloads, platforms, st_inf)
        # safety margin: the loop's cur_lat+dlat feasibility probe can exceed
        # the post-step state latency by a few ulps
        cut = m_inf + 1e-9 * np.maximum(1.0, np.abs(m_inf))
        con = [(g, bi) for g in range(G) for bi in range(n_bounds)
               if lgrids[g][bi] < cut[g]]
        metr_con = {}
        if con:
            sub = pb.take([g for g, _ in con])
            bnds = np.array([lgrids[g][bi] for g, bi in con])
            st_c, failed_c = _fixed_latency_state(c, sub, bnds, backend)
            mc = evaluate_state_rows([workloads[g] for g, _ in con],
                                     [platforms[g] for g, _ in con],
                                     st_c, skip=failed_c)
            for row, gb in enumerate(con):
                metr_con[gb] = None if failed_c[row] else (mc[row, 0], mc[row, 1])
        # Replicate the solve() layer: candidate metrics come from
        # metrics.evaluate on the mapping, feasibility from meets_bound.
        for g in range(G):
            for bi in range(n_bounds):
                v = metr_con.get((g, bi), (metr_inf[g, 0], metr_inf[g, 1]))
                if v is None:
                    continue
                per, lat = float(v[0]), float(v[1])
                if (math.isfinite(per) and math.isfinite(lat)
                        and lat <= float(lgrids[g][bi]) + 1e-12):
                    points[c][g][bi] = (per, lat)
    return points, thr


def run_campaign(
    exps,
    n: int,
    p: int,
    n_pairs: int = 50,
    n_bounds: int = 16,
    seed0: int = 1234,
    h4_iters: int = 10,
    include_h4: bool = True,
    backend: str = "numpy",
) -> dict:
    """Batched engine entry point: run SEVERAL experiment families sharing
    (n, p) as ONE stacked-instance campaign and return {exp: ExperimentResult}.

    All instances of all families are stacked into a single ProblemBatch, so
    every lockstep pass (trajectories, H4 bisection, H5/H6 grid) amortizes its
    per-iteration overhead over ``len(exps) * n_pairs`` rows instead of
    ``n_pairs`` — this cross-family batching is where most of the campaign
    speedup over the scalar path comes from.  Outputs are bit-identical to
    per-exp ``run_experiment(engine="scalar")`` runs.
    """
    exps = list(exps)
    period_fracs = np.geomspace(0.04, 1.0, n_bounds)     # x single-processor period
    latency_mults = np.linspace(1.0, 3.0, n_bounds)      # x optimal latency
    seeds = [seed0 + k for k in range(n_pairs)]
    batches = [gen_instance_batch(exp, n, p, seeds) for exp in exps]
    workloads = [wl for b in batches for wl in b.workloads]
    platforms = [pf for b in batches for pf in b.platforms]
    pb = ProblemBatch.concat(batches)
    his = [eval_period(wl, pf, single_processor_mapping(wl, pf.fastest()))
           for wl, pf in zip(workloads, platforms)]
    lopts = [optimal_latency(wl, pf) for wl, pf in zip(workloads, platforms)]
    pgrids = [hi * period_fracs for hi in his]
    lgrids = [l_opt * latency_mults for l_opt in lopts]

    points, thr_vals = _campaign_core(pb, workloads, platforms, pgrids, lgrids,
                                      n_bounds, h4_iters, include_h4, backend)
    thr_vals = dict(thr_vals)
    for c in ("H5", "H6"):
        thr_vals[c] = lopts

    out = {}
    codes = ["H1", "H2", "H3"] + (["H4"] if include_h4 else []) + ["H5", "H6"]
    for ei, exp in enumerate(exps):
        lo = ei * n_pairs
        curves = {}
        for c in codes:
            cols = [[points[c][g][bi] for g in range(lo, lo + n_pairs)
                     if points[c][g][bi] is not None] for bi in range(n_bounds)]
            mean_per = np.array([np.mean([a for a, _ in col]) if col else np.nan
                                 for col in cols])
            mean_lat = np.array([np.mean([b for _, b in col]) if col else np.nan
                                 for col in cols])
            frac = np.array([len(col) / n_pairs for col in cols])
            curves[c] = (mean_per, mean_lat, frac)
        thr = {c: (float(np.mean(thr_vals[c][lo:lo + n_pairs])),
                   float(np.max(thr_vals[c][lo:lo + n_pairs]))) for c in codes}
        out[exp] = ExperimentResult(exp, n, p, n_pairs, period_fracs, curves, thr)
    return out


def failure_thresholds(
    exps=("E1", "E2", "E3", "E4"),
    ns=N_STAGES_DEFAULT,
    p: int = 10,
    n_pairs: int = 50,
    seed0: int = 1234,
    engine: str = "batched",
    backend: str = "numpy",
) -> dict:
    """The paper's Table 1: per (experiment, heuristic, n), the failure
    threshold, averaged over instances.  Returns {exp: {code: {n: value}}}."""
    exps = list(exps)
    out: dict = {exp: {c: {} for c in ["H1", "H2", "H3", "H4", "H5", "H6"]}
                 for exp in exps}
    if engine in ("batched", "fused", "sharded", "auto"):
        # one stacked pass per n across ALL experiment families; "auto"
        # resolves per n (each n is its own campaign point)
        seeds = [seed0 + k for k in range(n_pairs)]
        for n in ns:
            batches = [gen_instance_batch(exp, n, p, seeds) for exp in exps]
            pb = ProblemBatch.concat(batches)
            trajsets = batched_trajectory_sets(
                ["H1", "H2", "H3", "H4"], pb,
                backend=_campaign_backend(_resolve_engine(engine, n, p),
                                          backend))
            for c, trajs in trajsets.items():
                for ei, exp in enumerate(exps):
                    sl = trajs[ei * n_pairs:(ei + 1) * n_pairs]
                    out[exp][c][n] = float(np.mean([min(per for per, _ in t)
                                                    for t in sl]))
            for ei, exp in enumerate(exps):
                lopts = [optimal_latency(wl, pf) for wl, pf in batches[ei]]
                out[exp]["H5"][n] = float(np.mean(lopts))
                out[exp]["H6"][n] = float(np.mean(lopts))
        return out
    for exp in exps:
        for n in ns:
            vals = {c: [] for c in out[exp]}
            batch = gen_instance_batch(exp, n, p,
                                       [seed0 + k for k in range(n_pairs)])
            for wl, pf in batch:
                for c in ["H1", "H2", "H3", "H4"]:
                    traj = split_trajectory(c, wl, pf)
                    vals[c].append(min(per for per, _ in traj))
                l_opt = optimal_latency(wl, pf)
                vals["H5"].append(l_opt)
                vals["H6"].append(l_opt)
            for c, v in vals.items():
                out[exp][c][n] = float(np.mean(v))
    return out


# ---------------------------------------------------------------------------
# Replication sweeps: the Section-5 study across many seed banks, with
# confidence intervals on the Figures 2-7 curves and Table 1 thresholds.
# ---------------------------------------------------------------------------

# normal-approximation 95% two-sided quantile; replications are cheap under
# the batched/fused engines, so R is expected to be large enough (>= ~10)
# that the t-correction would not change any qualitative call.
_Z95 = 1.959963984540054


@dataclasses.dataclass
class ReplicatedResult:
    """Aggregate of R independent campaign replications of one experiment.

    ``curves[code] = (mean_per, ci_per, mean_lat, ci_lat, mean_frac)`` over
    the bound grid, where the means average each replication's curve point
    (nan-skipping: a replication with no feasible instance at a bound does
    not contribute) and ``ci_*`` is the 95% half-width of the mean across
    replications.  ``thresholds[code] = (mean, ci)`` aggregates the
    per-replication mean failure thresholds.
    """

    exp: str
    n: int
    p: int
    n_pairs: int
    replications: int
    bounds_rel: np.ndarray
    curves: dict
    thresholds: dict


def _mean_ci(stack: np.ndarray) -> tuple:
    """(nan-mean, 95% CI half-width of the mean) along axis 0.  All-NaN
    columns (a bound infeasible in every replication) stay NaN."""
    import warnings

    cnt = np.sum(~np.isnan(stack), axis=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        mean = np.where(cnt > 0, np.nanmean(stack, axis=0), np.nan)
        sd = np.where(cnt > 1, np.nanstd(stack, axis=0, ddof=1), np.nan)
    ci = np.where(cnt > 1, _Z95 * sd / np.sqrt(np.maximum(cnt, 1)), np.nan)
    return mean, ci


def run_replicated(
    exps,
    n: int,
    p: int,
    n_pairs: int = 50,
    replications: int = 10,
    n_bounds: int = 16,
    seed0: int = 1234,
    h4_iters: int = 10,
    include_h4: bool = True,
    engine: str = "batched",
    backend: str = "numpy",
) -> tuple:
    """Run :func:`run_campaign` over ``replications`` disjoint seed banks
    (bank r uses seeds ``seed0 + r * n_pairs + k``; bank 0 is exactly the
    non-replicated campaign) and aggregate mean +/- 95% CI per experiment.

    Returns ``(replicated, first)`` where ``replicated`` maps each exp to a
    :class:`ReplicatedResult` and ``first`` is bank 0's plain
    ``{exp: ExperimentResult}`` (so callers can emit the byte-identical
    single-bank outputs alongside the CI files).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use one of {ENGINES}")
    engine = _resolve_engine(engine, n, p)
    if engine == "scalar":  # the reference path replicates per experiment
        camps = [{exp: run_experiment(exp, n, p, n_pairs=n_pairs,
                                      n_bounds=n_bounds,
                                      seed0=seed0 + r * n_pairs,
                                      h4_iters=h4_iters,
                                      include_h4=include_h4, engine="scalar")
                  for exp in exps} for r in range(replications)]
    else:
        camps = [run_campaign(exps, n, p, n_pairs=n_pairs, n_bounds=n_bounds,
                              seed0=seed0 + r * n_pairs, h4_iters=h4_iters,
                              include_h4=include_h4,
                              backend=_campaign_backend(engine, backend))
                 for r in range(replications)]
    out = {}
    for exp in exps:
        reps = [c[exp] for c in camps]
        codes = sorted(reps[0].curves)
        curves = {}
        thr = {}
        for c in codes:
            per = np.stack([r.curves[c][0] for r in reps])
            lat = np.stack([r.curves[c][1] for r in reps])
            frac = np.stack([r.curves[c][2] for r in reps])
            mean_per, ci_per = _mean_ci(per)
            mean_lat, ci_lat = _mean_ci(lat)
            curves[c] = (mean_per, ci_per, mean_lat, ci_lat, frac.mean(axis=0))
            tvals = np.array([r.thresholds[c][0] for r in reps])
            tm, tci = _mean_ci(tvals[:, None])
            thr[c] = (float(tm[0]), float(tci[0]))
        out[exp] = ReplicatedResult(exp, n, p, n_pairs, replications,
                                    reps[0].bounds_rel, curves, thr)
    return out, camps[0]


def summarize_replicated(res: ReplicatedResult) -> str:
    lines = [f"# {res.exp} n={res.n} p={res.p} pairs={res.n_pairs} "
             f"replications={res.replications}"]
    lines.append("heuristic,bound_idx,mean_period,period_ci95,"
                 "mean_latency,latency_ci95,feasible_frac")
    for c, (mp, cp, ml, cl, fr) in sorted(res.curves.items()):
        for i in range(len(mp)):
            lines.append(f"{c},{i},{mp[i]:.6g},{cp[i]:.6g},{ml[i]:.6g},"
                         f"{cl[i]:.6g},{fr[i]:.3f}")
    lines.append("heuristic,threshold_mean,threshold_ci95")
    for c, (m, ci) in sorted(res.thresholds.items()):
        lines.append(f"{c},{m:.6g},{ci:.6g}")
    return "\n".join(lines)


def summarize_experiment(res: ExperimentResult) -> str:
    lines = [f"# {res.exp} n={res.n} p={res.p} pairs={res.n_pairs}"]
    lines.append("heuristic,bound_idx,mean_period,mean_latency,feasible_frac")
    for c, (mp, ml, fr) in sorted(res.curves.items()):
        for i in range(len(mp)):
            lines.append(f"{c},{i},{mp[i]:.6g},{ml[i]:.6g},{fr[i]:.3f}")
    lines.append("heuristic,threshold_mean,threshold_max")
    for c, (m, mx) in sorted(res.thresholds.items()):
        lines.append(f"{c},{m:.6g},{mx:.6g}")
    return "\n".join(lines)
