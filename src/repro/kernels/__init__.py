"""Pallas TPU kernels for the perf-critical compute layers.

Substrate hot spots — attention (prefill + decode), fused RMSNorm, and the
Mamba2 SSD intra-chunk — as TPU-native pallas_call kernels with explicit
BlockSpec VMEM tiling (``ref.py`` holds the pure-jnp oracles; ``ops.py`` the
jitted wrappers, interpret mode on CPU), plus the planner's own hot path:
``split_score.py`` implements the heuristics' chains-to-chains 2-way/3-way
candidate scoring as masked-tile pallas kernels, selected behind
``repro.core.heuristics.score_kernels("pallas")``.
"""

from . import ops, ref, split_score

__all__ = ["ops", "ref", "split_score"]
