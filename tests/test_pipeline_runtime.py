"""Pipeline runtime: schedule math (in-process) and pipelined-vs-sequential
equivalence (subprocess with 8 fake devices, so the main test process keeps
its single-device view)."""

import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Mapping, Objective, Platform, StagePlan, plan
from repro.core.planner import _realize
from repro.pipeline.schedule import bubble_fraction, gpipe_ticks, stage_microbatch

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_gpipe_schedule_math():
    assert gpipe_ticks(4, 8) == 11
    assert stage_microbatch(5, 2) == 3
    assert bubble_fraction(4, 8) == pytest.approx(3 / 11)
    assert bubble_fraction(1, 8) == 0.0


def test_make_stage_params_packing():
    import jax.numpy as jnp

    from repro.pipeline.runtime import make_stage_params

    L, d = 5, 3
    layers = {"w": jnp.arange(L * d, dtype=jnp.float32).reshape(L, d)}
    mapping = Mapping(((1, 2), (3, 3), (4, 5)), (1, 0, 3))
    pl = _realize(mapping, 1.0, 2.0, "test")
    stages, mask = make_stage_params(layers, pl, num_pods=4)
    assert stages["w"].shape == (4, 2, 3)
    # interval 1 (layers 0,1) -> pod 1; interval 2 (layer 2) -> pod 0; 3 -> pod 3
    np.testing.assert_array_equal(np.asarray(stages["w"][1]),
                                  np.arange(6).reshape(2, 3))
    np.testing.assert_array_equal(np.asarray(stages["w"][0, 0]),
                                  np.arange(6, 9))
    np.testing.assert_array_equal(np.asarray(mask),
                                  [[True, False], [True, True],
                                   [False, False], [True, True]])
    # padding rows are zero
    assert float(np.abs(np.asarray(stages["w"][2])).sum()) == 0.0


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import Platform, Objective, plan
    from repro.models import ModelConfig
    from repro.models.transformer import init_params
    from repro.models.registry import lm_workload
    from repro.models.common import ShapeSpec
    from repro.pipeline.runtime import (make_stage_params, pipelined_loss_fn,
                                        sequential_loss_fn)
    from repro.launch.mesh import make_mesh

    cfg = ModelConfig(arch_id="pipe-test", family="dense", n_layers=6,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, remat="none")
    params = init_params(jax.random.PRNGKey(0), cfg)
    wl = lm_workload(cfg, ShapeSpec("t", "train", 64, 8))
    pf = Platform(np.array([4.0, 4.0, 2.0, 4.0]), b=1e9)
    pl = plan(wl, pf, Objective("period"), mode="auto")
    stages, mask = make_stage_params(params["layers"], pl, num_pods=4)
    pipe_params = {"embed": params["embed"], "stages": stages,
                   "ln_f": params["ln_f"]}
    mesh = make_mesh((4, 2), ("stage", "data"))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 64)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (8, 64)), jnp.int32)}
    with jax.set_mesh(mesh):
        lf = pipelined_loss_fn(cfg, pl, num_microbatches=4, mask=mask, mesh=mesh)
        loss_pipe = float(jax.jit(lf)(pipe_params, batch))
        g = jax.jit(jax.grad(lf))(pipe_params, batch)
    loss_seq = float(jax.jit(sequential_loss_fn(cfg))(params, batch))
    gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                            for l in jax.tree.leaves(g))))
    assert abs(loss_pipe - loss_seq) < 2e-3, (loss_pipe, loss_seq)
    assert np.isfinite(gn) and gn > 0
    # gradients for padded (masked) slots must be zero
    pad_g = np.asarray(g["stages"]["mlp"]["wi"])[2]   # pod 2 unused by plan? ensure via mask
    mask_np = np.asarray(mask)
    for pod in range(4):
        for slot in range(mask_np.shape[1]):
            if not mask_np[pod, slot]:
                blk = np.asarray(g["stages"]["mlp"]["wi"])[pod, slot]
                assert np.abs(blk).max() == 0.0, (pod, slot)
    print("SUBPROCESS_OK", loss_pipe, loss_seq, gn)
""")


@pytest.mark.slow
def test_pipelined_equals_sequential_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "SUBPROCESS_OK" in r.stdout
