"""Straggler mitigation demo: the paper's heterogeneous-processor scenario
arising online.

A 4-pod pipeline plan is computed for qwen1.5-110b (80 layers).  Mid-training
one pod slows down 1.8x (thermal throttling).  The StragglerMonitor detects
it from observed stage times; the paper's planner re-balances the intervals
onto the now-heterogeneous platform, shrinking the straggler's interval.

Run:  PYTHONPATH=src python examples/replan_straggler.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import (Objective, PlanRequest, interval_cycle_times,
                        make_platform, plan_request)
from repro.models.common import SHAPES
from repro.models.registry import lm_workload
from repro.pipeline.replan import StragglerMonitor, replan_stages


def main() -> None:
    cfg = get_config("qwen1.5-110b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    pf = make_platform([25.2e15] * 4, b=25e9)

    report = plan_request(PlanRequest(wl, pf, Objective("period")))
    p0 = report.plan
    pred = interval_cycle_times(wl, pf, p0.mapping)
    print(f"initial plan: stages={p0.stage_sizes} period={p0.period*1e3:.2f}ms "
          f"(chosen from {len(report.candidates)} candidates)")

    # pod serving stage 1 degrades 1.8x
    mon = StragglerMonitor(num_stages=p0.num_stages, alpha=0.5)
    for step in range(5):
        observed = pred.copy()
        observed[1] *= 1.8
        mon.observe(observed)
    print(f"observed stage times (ms): {np.round(mon.ewma*1e3, 2)}")

    new_plan, degraded = replan_stages(wl, pf, p0, mon)
    assert new_plan is not None, "straggler must be detected"
    new_pred = interval_cycle_times(wl, degraded, new_plan.mapping)
    old_pred = interval_cycle_times(wl, degraded, p0.mapping)
    print(f"re-plan:      stages={new_plan.stage_sizes} on pods "
          f"{new_plan.mapping.alloc}")
    print(f"period with straggler: old={old_pred.max()*1e3:.2f}ms "
          f"-> new={new_pred.max()*1e3:.2f}ms "
          f"({(1 - new_pred.max()/old_pred.max()):.1%} better)")
    assert new_pred.max() <= old_pred.max() + 1e-9


if __name__ == "__main__":
    main()
