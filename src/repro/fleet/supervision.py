"""Supervised solve workers: the fleet controller/worker split.

The controller (:class:`~repro.fleet.service.ReplanService`) no longer calls
the batched engine inline; each deduped solve group is dispatched to a
**worker actor** through a :class:`Supervisor`.  A worker owns its execution
context, exposes a heartbeat, and can be killed and replaced without touching
controller state.  Three transports implement the same ``solve/alive/close``
actor API:

  - :class:`InlineWorker` — synchronous in-process execution, the default.
    No threads, no timeouts, bit-identical to calling the engine directly.
  - :class:`ThreadWorker` — runs each solve on a dedicated worker thread so
    the supervisor can enforce a per-group ``timeout``.  Preemption is
    *advisory*: a thread cannot be killed, so a timed-out solve is abandoned
    (counted in ``leaked``/``SupervisorStats.leaked_threads``) and keeps
    burning CPU until it returns on its own.
  - :class:`SubprocessWorker` — the real process boundary.  Solves run in a
    ``python -m repro.fleet.worker_main`` child speaking the CRC-framed wire
    protocol of :mod:`repro.fleet.transport` over stdio; results are
    bit-identical to inline execution (exact-float codecs).  On timeout the
    supervisor **reaps** the child — SIGTERM, a grace period, then SIGKILL —
    so preemption is real: a wedged or leaking solve dies with its process
    and the abandoned-thread leak class disappears.  Heartbeat frames let
    ``alive()`` distinguish a slow worker from a dead one, and any wire
    corruption (CRC/magic/length) marks the stream poisoned so the worker is
    replaced, never trusted past the first bad byte.

The supervisor dispatches round-robin over its pool, retries a failed group
with **exponential backoff** (``backoff_base`` doubling up to
``backoff_max``), and **restarts** workers that time out, die, poison their
stream, or whose heartbeat has gone stale.  After ``max_attempts`` failures
it raises :class:`WorkerFailed` — at which point the service falls back to
per-member scalar solves, and problems that fail *that* too are quarantined
(see ``ReplanService``).  On the clean path none of this machinery fires, so
published plans remain bit-identical to the pre-supervision service
(asserted in tests/test_fleet.py and tests/test_fleet_recovery.py).
"""

from __future__ import annotations

import concurrent.futures
import functools
import os
import pathlib
import subprocess
import sys
import time
from select import select
from typing import Callable, Optional

from .transport import (FrameError, FrameReader, decode_results, encode_frame,
                        encode_solve)

#: The src/ directory that holds the ``repro`` package — prepended to the
#: child's PYTHONPATH so ``-m repro.fleet.worker_main`` resolves no matter
#: where the controller was launched from.
_SRC_DIR = pathlib.Path(__file__).resolve().parents[2]


class WorkerFailed(RuntimeError):
    """A solve group failed on every attempt; the last cause is chained."""


class WorkerTimeout(RuntimeError):
    """A worker exceeded the per-group solve timeout (hung or wedged)."""


class WorkerCrash(RuntimeError):
    """The worker process died or its wire stream is poisoned (EOF, broken
    pipe, or a frame that failed its CRC/magic/length check)."""


class WorkerSolveError(RuntimeError):
    """The worker is alive and well but the solve itself raised; carries the
    remote exception type and message."""


class InlineWorker:
    """Synchronous in-process worker — deterministic, zero overhead.

    ``timeout`` cannot preempt a synchronous call; constructing a
    :class:`Supervisor` with a timeout over inline workers raises
    ``ValueError`` so a misconfigured service cannot believe it has
    preemption it lacks.  Use :class:`ThreadWorker` (advisory) or
    :class:`SubprocessWorker` (real, kill-based) when a hung solve must not
    wedge the controller.
    """

    #: A synchronous call cannot be preempted — Supervisor(timeout=...)
    #: refuses this worker class up front.
    supports_timeout = False

    def __init__(self, solve_fn: Callable, worker_id: int = 0):
        self.solve_fn = solve_fn
        self.worker_id = worker_id
        self.solves = 0
        self.heartbeat = time.monotonic()

    def solve(self, batch, timeout: Optional[float] = None):
        self.heartbeat = time.monotonic()
        out = self.solve_fn(batch)
        self.heartbeat = time.monotonic()
        self.solves += 1
        return out

    def alive(self, heartbeat_timeout: Optional[float]) -> bool:
        # A synchronous worker cannot be secretly wedged: if control returned
        # to the supervisor, the worker is idle.
        return True

    def close(self) -> None:
        pass


class ThreadWorker:
    """Worker actor on its own thread: per-group timeout + heartbeat.

    ``solve`` submits to the worker's single-thread executor and bounds the
    wait.  On timeout the controller raises :class:`WorkerTimeout` and the
    supervisor replaces the worker — but a thread cannot be killed, so the
    abandoned solve keeps running until it returns on its own; each such
    abandonment is counted in ``leaked`` (rolled up into
    ``SupervisorStats.leaked_threads`` at restart).  ``close()`` shuts the
    executor down with ``cancel_futures=True`` so *queued* work is cancelled
    rather than silently run by an abandoned executor; only the
    already-running solve can leak.  :class:`SubprocessWorker` is the
    transport without this caveat.
    """

    supports_timeout = True

    def __init__(self, solve_fn: Callable, worker_id: int = 0):
        self.solve_fn = solve_fn
        self.worker_id = worker_id
        self.solves = 0
        self.leaked = 0   # timed-out solves still running on the dead executor
        self.heartbeat = time.monotonic()
        self._ex = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"fleet-worker-{worker_id}")

    def _run(self, batch):
        out = self.solve_fn(batch)
        self.heartbeat = time.monotonic()
        self.solves += 1
        return out

    def solve(self, batch, timeout: Optional[float] = None):
        self.heartbeat = time.monotonic()
        fut = self._ex.submit(self._run, batch)
        try:
            return fut.result(timeout)
        except concurrent.futures.TimeoutError:
            if not fut.cancel():
                # Already running: the thread is abandoned, not preempted.
                self.leaked += 1
            raise WorkerTimeout(
                f"worker {self.worker_id} exceeded {timeout}s solve "
                "timeout") from None

    def alive(self, heartbeat_timeout: Optional[float]) -> bool:
        if heartbeat_timeout is None:
            return True
        return time.monotonic() - self.heartbeat <= heartbeat_timeout

    def close(self) -> None:
        # cancel_futures: queued (not-yet-started) solves are cancelled
        # instead of being silently run to completion by an executor nothing
        # is listening to anymore.
        self._ex.shutdown(wait=False, cancel_futures=True)


class SubprocessWorker:
    """Worker actor in its own OS process: kill-based preemption.

    Spawns ``python -m repro.fleet.worker_main --backend <backend>`` and
    drives it over the CRC-framed stdio protocol.  ``solve_fn`` is accepted
    for actor-API compatibility but unused — the child runs
    ``batched_min_period`` itself, and the exact-float wire codecs make its
    results bit-identical to the inline path.

    Timeout semantics: when a reply misses the deadline the child is
    *reaped* — SIGTERM, ``term_grace`` seconds to comply, then SIGKILL — and
    :class:`WorkerTimeout` is raised.  Unlike :class:`ThreadWorker`, nothing
    leaks: the wedged solve's memory, threads, and file descriptors die with
    the process.  ``sigkills`` counts escalations that actually needed the
    hard kill.

    ``chaos`` (a :class:`repro.fleet.transport.TransportChaos`) injects
    wire-level faults — dead-on-arrival spawns, SIGKILL mid-solve,
    drop/corrupt/truncate/delay on the reply path, in-band wedges — at this
    transport boundary, so the supervisor's recovery machinery is exercised
    against the same fault classes a real remote host exhibits.
    """

    supports_timeout = True

    def __init__(self, solve_fn: Optional[Callable] = None, worker_id: int = 0,
                 *, backend: str = "numpy", chaos=None,
                 term_grace: float = 1.0, heartbeat_interval: float = 0.5,
                 ignore_sigterm: bool = False,
                 wedge_every: int = 0, wedge_seconds: float = 0.0,
                 python: str = sys.executable):
        self.worker_id = worker_id
        self.backend = backend
        self.chaos = chaos
        self.term_grace = float(term_grace)
        self.solves = 0
        self.sigkills = 0        # reaps that escalated past SIGTERM
        self.heartbeat = time.monotonic()
        self._reader = FrameReader()
        self._broken: Optional[str] = None
        self._req = 0
        cmd = [python, "-m", "repro.fleet.worker_main",
               "--backend", backend,
               "--heartbeat-interval", str(heartbeat_interval)]
        if ignore_sigterm:
            cmd.append("--ignore-sigterm")
        if wedge_every:
            cmd += ["--wedge-every", str(wedge_every),
                    "--wedge-seconds", str(wedge_seconds)]
        env = dict(os.environ)
        env["PYTHONPATH"] = str(_SRC_DIR) + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        self._proc = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                                      stdout=subprocess.PIPE, env=env)
        if self.chaos is not None and self.chaos.spawn_dead_on_arrival():
            # Dead on arrival: the child never gets to its first heartbeat.
            self._proc.kill()

    @property
    def pid(self) -> int:
        return self._proc.pid

    # -- wire helpers ---------------------------------------------------------

    def _mark_broken(self, why: str) -> None:
        self._broken = why

    def _send(self, payload) -> None:
        try:
            self._proc.stdin.write(encode_frame(payload))
            self._proc.stdin.flush()
        except (BrokenPipeError, OSError, ValueError):
            self._mark_broken("request pipe broken")
            raise WorkerCrash(f"worker {self.worker_id} (pid {self.pid}): "
                              "request pipe broken — process died") from None

    def _recv_chunk(self, deadline: Optional[float]) -> bool:
        """Read one chunk from the child's stdout into the frame reader
        (through the chaos layer if armed).  Returns False on timeout;
        raises :class:`WorkerCrash` on EOF."""
        fd = self._proc.stdout.fileno()
        wait = (None if deadline is None
                else max(0.0, deadline - time.monotonic()))
        ready, _, _ = select([fd], [], [], wait)
        if not ready:
            return False
        chunk = os.read(fd, 1 << 16)
        if not chunk:
            self._mark_broken("reply pipe EOF")
            raise WorkerCrash(f"worker {self.worker_id} (pid {self.pid}): "
                              "reply pipe EOF — process died mid-solve")
        if self.chaos is not None:
            chunk = self.chaos.mangle_chunk(chunk)
            if chunk is None:
                return True   # dropped on the wire; keep waiting
        self._reader.feed(chunk)
        return True

    def _next_payload(self, deadline: Optional[float]):
        """Next frame payload, or ``None`` on deadline expiry.  Heartbeats
        refresh ``self.heartbeat`` in passing."""
        while True:
            try:
                payload = self._reader.next_frame()
            except FrameError as e:
                self._mark_broken(f"poisoned stream: {e}")
                raise WorkerCrash(
                    f"worker {self.worker_id} (pid {self.pid}): {e}"
                ) from None
            if payload is not None:
                if payload[0] in ("heartbeat", "hello"):
                    self.heartbeat = time.monotonic()
                    continue
                return payload
            if deadline is not None and time.monotonic() >= deadline:
                return None
            if not self._recv_chunk(deadline):
                return None

    # -- actor API ------------------------------------------------------------

    def solve(self, batch, timeout: Optional[float] = None):
        if self._broken or self._proc.poll() is not None:
            self._mark_broken(self._broken or "process exited")
            raise WorkerCrash(f"worker {self.worker_id} (pid {self.pid}) is "
                              f"dead before dispatch ({self._broken})")
        self._req += 1
        rid = self._req
        if self.chaos is not None and self.chaos.wedge_solve():
            # In-band hang injection: the child sleeps before it ever sees
            # the solve frame — indistinguishable from a wedged solve.
            self._send(["wedge", {"seconds": self.chaos.wedge_seconds}])
        self._send(encode_solve(rid, batch))
        if self.chaos is not None and self.chaos.kill_mid_solve():
            # The request is on the wire; the worker dies holding it.
            self._proc.kill()
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            payload = self._next_payload(deadline)
            if payload is None:
                self.reap()
                raise WorkerTimeout(
                    f"worker {self.worker_id} (pid {self.pid}) exceeded "
                    f"{timeout}s solve timeout; process reaped")
            kind, body = payload
            if kind == "result":
                if int(body["id"]) != rid:
                    continue   # stale reply from an earlier, abandoned request
                self.solves += 1
                self.heartbeat = time.monotonic()
                return decode_results(body)
            if kind == "error":
                if int(body["id"]) != rid:
                    continue
                raise WorkerSolveError(
                    f"worker {self.worker_id}: solve raised "
                    f"{body.get('kind', 'Exception')}: "
                    f"{body.get('message', '')}")
            # Unknown-but-valid frame kinds are ignored (forward compat).

    def alive(self, heartbeat_timeout: Optional[float]) -> bool:
        if self._broken is not None or self._proc.poll() is not None:
            return False
        # Drain any queued heartbeat frames (non-blocking) so idle liveness
        # reflects the newest beat, not the last solve.
        try:
            while True:
                fd = self._proc.stdout.fileno()
                ready, _, _ = select([fd], [], [], 0)
                if not ready:
                    break
                if not self._recv_chunk(time.monotonic()):
                    break
                while True:
                    payload = self._reader.next_frame()
                    if payload is None:
                        break
                    if payload[0] in ("heartbeat", "hello"):
                        self.heartbeat = time.monotonic()
        except WorkerCrash:
            return False
        if self._broken is not None:
            return False
        if heartbeat_timeout is None:
            return True
        return time.monotonic() - self.heartbeat <= heartbeat_timeout

    def reap(self) -> None:
        """SIGTERM → ``term_grace`` seconds → SIGKILL.  The escalation is the
        preemption guarantee: a worker too wedged to honor SIGTERM (or
        ignoring it outright) is killed by the kernel, not negotiated with."""
        self._mark_broken("reaped")
        if self._proc.poll() is not None:
            return
        self._proc.terminate()
        try:
            self._proc.wait(timeout=self.term_grace)
        except subprocess.TimeoutExpired:
            self._proc.kill()
            self.sigkills += 1
            self._proc.wait()

    def close(self) -> None:
        if self._proc.poll() is None and self._broken is None:
            try:   # polite first: a clean 'bye' lets the child exit 0
                self._proc.stdin.write(encode_frame(["bye", {}]))
                self._proc.stdin.flush()
                self._proc.stdin.close()
                self._proc.wait(timeout=self.term_grace)
            except (BrokenPipeError, OSError, ValueError,
                    subprocess.TimeoutExpired):
                pass
        self.reap()
        for pipe in (self._proc.stdin, self._proc.stdout):
            try:
                if pipe is not None:
                    pipe.close()
            except (OSError, ValueError):
                pass


class SupervisorStats:
    """Lifetime counters the service folds into :class:`FleetMetrics`.

    ``timeouts`` (reaped/abandoned hung solves) is counted separately from
    ``failures`` (solves that raised) — a hung engine and a raising engine
    are different pathologies and the metrics must not conflate them.
    ``leaked_threads`` counts ThreadWorker solves that were abandoned
    mid-flight (the leak class SubprocessWorker eliminates); ``sigkills``
    counts subprocess reaps that had to escalate past SIGTERM."""

    def __init__(self):
        self.dispatches = 0
        self.failures = 0
        self.timeouts = 0
        self.retries = 0
        self.restarts = 0
        self.leaked_threads = 0
        self.sigkills = 0

    def as_dict(self) -> dict:
        return {"dispatches": self.dispatches, "failures": self.failures,
                "timeouts": self.timeouts, "retries": self.retries,
                "restarts": self.restarts,
                "leaked_threads": self.leaked_threads,
                "sigkills": self.sigkills}


def _worker_class(worker_cls):
    """Unwrap ``functools.partial`` layers to the underlying worker class."""
    while isinstance(worker_cls, functools.partial):
        worker_cls = worker_cls.func
    return worker_cls


class Supervisor:
    """Dispatch solve groups to a supervised worker pool.

    ``solve_fn`` is the actual group solver (the service binds it to
    ``batched_min_period`` on its backend); pass ``None`` when the pool runs
    :class:`SubprocessWorker` actors, which execute the solve in their own
    process.  ``worker_cls`` picks the actor flavor — a class or a
    ``functools.partial`` carrying transport options (all workers run the
    same pure function, so pool width only affects liveness, never results).
    A failed dispatch is retried up to ``max_attempts`` total attempts with
    exponential backoff; timed-out, crashed, or heartbeat-stale workers are
    closed and replaced (counted in ``stats.restarts``).  ``sleep`` is
    injectable so tests can assert the backoff schedule without waiting it
    out.

    ``timeout`` demands a worker transport that can actually preempt:
    constructing with a worker class whose ``supports_timeout`` is false
    (:class:`InlineWorker`) raises ``ValueError`` — deadline protection that
    silently does nothing is worse than none.
    """

    def __init__(self, solve_fn: Optional[Callable], *, workers: int = 1,
                 worker_cls=InlineWorker, max_attempts: int = 2,
                 timeout: Optional[float] = None,
                 backoff_base: float = 0.01, backoff_max: float = 1.0,
                 heartbeat_timeout: Optional[float] = None,
                 sleep: Callable = time.sleep):
        if workers < 1:
            raise ValueError(f"need at least one worker, got {workers}")
        if max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
        if timeout is not None and \
                not getattr(_worker_class(worker_cls), "supports_timeout",
                            True):
            raise ValueError(
                f"timeout={timeout} has no effect with "
                f"{_worker_class(worker_cls).__name__}: a synchronous worker "
                "cannot be preempted, so the deadline protection would be "
                "fictional.  Use ThreadWorker (advisory) or SubprocessWorker "
                "(kill-based), or drop the timeout.")
        self.solve_fn = solve_fn
        self.worker_cls = worker_cls
        self.max_attempts = int(max_attempts)
        self.timeout = timeout
        self.backoff_base = float(backoff_base)
        self.backoff_max = float(backoff_max)
        self.heartbeat_timeout = heartbeat_timeout
        self.sleep = sleep
        self.stats = SupervisorStats()
        self._next_id = 0
        self.pool = [self._spawn() for _ in range(workers)]
        self._rr = 0

    def _spawn(self):
        w = self.worker_cls(self.solve_fn, worker_id=self._next_id)
        self._next_id += 1
        return w

    def _restart(self, idx: int) -> None:
        old = self.pool[idx]
        self.stats.leaked_threads += getattr(old, "leaked", 0)
        self.stats.sigkills += getattr(old, "sigkills", 0)
        old.close()
        self.pool[idx] = self._spawn()
        self.stats.restarts += 1

    def solve(self, batch):
        """Solve one group, supervising the worker.  Returns the worker's
        result list; raises :class:`WorkerFailed` after ``max_attempts``
        failed attempts (the service then degrades to scalar fallback)."""
        delay = self.backoff_base
        last: Optional[BaseException] = None
        for attempt in range(self.max_attempts):
            idx = self._rr % len(self.pool)
            self._rr += 1
            worker = self.pool[idx]
            if not worker.alive(self.heartbeat_timeout):
                self._restart(idx)
                worker = self.pool[idx]
            self.stats.dispatches += 1
            try:
                return worker.solve(batch, timeout=self.timeout)
            except Exception as e:  # noqa: BLE001 — supervise, don't die
                if isinstance(e, WorkerTimeout):
                    self.stats.timeouts += 1
                else:
                    self.stats.failures += 1
                last = e
                if isinstance(e, WorkerTimeout) or \
                        not worker.alive(self.heartbeat_timeout):
                    self._restart(idx)
                if attempt + 1 < self.max_attempts:
                    self.stats.retries += 1
                    if delay > 0:
                        self.sleep(delay)
                    delay = min(delay * 2 if delay > 0 else delay,
                                self.backoff_max)
        raise WorkerFailed(
            f"solve group failed after {self.max_attempts} attempts") from last

    def close(self) -> None:
        for w in self.pool:
            self.stats.leaked_threads += getattr(w, "leaked", 0)
            self.stats.sigkills += getattr(w, "sigkills", 0)
            w.close()


def subprocess_supervisor(*, backend: str = "numpy", workers: int = 1,
                          timeout: Optional[float] = 30.0,
                          chaos=None, term_grace: float = 1.0,
                          heartbeat_interval: float = 0.5,
                          ignore_sigterm: bool = False,
                          wedge_every: int = 0, wedge_seconds: float = 0.0,
                          **supervisor_kw) -> Supervisor:
    """A :class:`Supervisor` over process-isolated workers, pre-wired.

    ``backend`` must match the ``ReplanService``'s own backend for the
    published digests to be comparable (both default to ``"numpy"``).  The
    remaining keywords configure the transport (``chaos``, ``term_grace``,
    ``ignore_sigterm``, wedge test hooks) or pass through to
    :class:`Supervisor` (``max_attempts``, ``backoff_base``, ...).
    """
    worker_cls = functools.partial(
        SubprocessWorker, backend=backend, chaos=chaos, term_grace=term_grace,
        heartbeat_interval=heartbeat_interval, ignore_sigterm=ignore_sigterm,
        wedge_every=wedge_every, wedge_seconds=wedge_seconds)
    return Supervisor(None, workers=workers, worker_cls=worker_cls,
                      timeout=timeout, **supervisor_kw)
