"""Gradient compression for bandwidth-constrained (inter-pod) reduction.

Two codecs, both with exact decompress-side shapes so they compose with any
collective schedule:

 - top-k sparsification with error feedback (memory = residual pytree),
 - int8 linear quantization (per-tensor scale).

At 1000+ node scale, inter-pod gradient all-reduce over DCN is the scarcest
link; top-k (k ~ 1%) plus error feedback is the standard trick to push the
collective term of the roofline down ~100x at negligible quality cost.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def topk_compress(x: jax.Array, frac: float):
    """Keep the top ``frac`` fraction of entries by magnitude.
    Returns (values, flat_indices, original_shape)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    taken = flat[idx]
    return taken, idx, x.shape


def topk_decompress(values, idx, shape) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), jnp.float32)
    out = out.at[idx].set(values)
    return out.reshape(shape)


def int8_compress(x: jax.Array):
    flat = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedbackState(NamedTuple):
    residual: dict  # pytree like grads


def ef_init(grads) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def ef_compress_update(grads, state: ErrorFeedbackState, frac: float = 0.01):
    """Error-feedback top-k: compress (grad + residual); residual accumulates
    what was dropped.  Returns (compressed_pytree, new_state) where each leaf
    of compressed is (values, idx, shape)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        vals, idx, shape = topk_compress(corrected, frac)
        dense = topk_decompress(vals, idx, shape)
        new_r = corrected - dense
        return (vals, idx, shape), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = tdef.unflatten([o[0] for o in outs])
    new_res = tdef.unflatten([o[1] for o in outs])
    return comp, ErrorFeedbackState(new_res)
