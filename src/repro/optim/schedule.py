"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, total_steps: int, min_ratio: float = 0.1):
    frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return base_lr * (min_ratio + (1.0 - min_ratio) * cos)


def linear_warmup_cosine(step, *, base_lr: float, warmup_steps: int,
                         total_steps: int, min_ratio: float = 0.1):
    step_f = step.astype(jnp.float32)
    warm = step_f / max(warmup_steps, 1)
    after = cosine_schedule(step - warmup_steps,
                            base_lr=base_lr,
                            total_steps=max(total_steps - warmup_steps, 1),
                            min_ratio=min_ratio)
    return jnp.where(step_f < warmup_steps, base_lr * warm, after)
