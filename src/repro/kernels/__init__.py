"""Pallas TPU kernels for the perf-critical compute layers.

The paper (pipeline-workflow scheduling) has no kernel-level contribution;
these kernels implement the substrate's hot spots — attention (prefill +
decode), fused RMSNorm, and the Mamba2 SSD intra-chunk — as TPU-native
pallas_call kernels with explicit BlockSpec VMEM tiling.  ``ref.py`` holds
the pure-jnp oracles; ``ops.py`` the jitted wrappers (interpret mode on CPU).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
