"""Uniform model API over all architecture families.

``get_model(cfg)`` returns a :class:`ModelAPI` with:
  - init(key) -> params
  - forward(params, batch, cfg) -> (logits, aux)          [train / prefill]
  - init_decode_state(batch, capacity) -> state
  - decode(params, state, token) -> (logits, state)       [serve_step core]
  - input_specs(shape) -> dict of ShapeDtypeStruct        [dry-run stand-ins]
  - workload(shape) -> repro.core.Workload                [planner integration]
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.workload import Workload
from .common import ModelConfig, ShapeSpec
from . import encdec, hybrid, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    forward: Callable            # (params, batch, cfg) -> (logits, aux)
    init_decode_state: Callable  # (batch, capacity) -> state
    decode: Callable             # (params, state, token) -> (logits, state)
    input_specs: Callable        # (ShapeSpec) -> dict
    workload: Callable           # (ShapeSpec) -> Workload


def _tok_specs(shape: ShapeSpec, cfg: ModelConfig, extra: Optional[dict] = None) -> dict:
    B, S = shape.global_batch, shape.seq_len
    d = {}
    if shape.kind == "train":
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    else:  # decode
        d = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if extra:
        d.update(extra)
    return d


# ---------------------------------------------------------------------------
# Per-family wiring
# ---------------------------------------------------------------------------

def _lm_forward(params, batch, cfg):
    return transformer.forward(params, batch["tokens"], cfg)


def _vlm_forward(params, batch, cfg):
    return transformer.forward(params, batch["tokens"], cfg,
                               prefix_embeds=batch["patch_embeds"])


def _hybrid_forward(params, batch, cfg):
    return hybrid.forward(params, batch["tokens"], cfg)


def _xlstm_forward(params, batch, cfg):
    return xlstm.forward(params, batch["tokens"], cfg)


def _encdec_forward(params, batch, cfg):
    return encdec.forward(params, batch["tokens"], cfg, frames=batch["frames"])


def get_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        fwd = _vlm_forward if fam == "vlm" else _lm_forward

        def specs(shape: ShapeSpec) -> dict:
            extra = None
            if fam == "vlm" and shape.kind != "decode":
                extra = {"patch_embeds": jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.n_vis_tokens, cfg.d_model), cfg.jdtype)}
            return _tok_specs(shape, cfg, extra)

        return ModelAPI(
            cfg=cfg,
            init=lambda key: transformer.init_params(key, cfg),
            forward=fwd,
            init_decode_state=lambda b, cap: transformer.init_decode_state(cfg, b, cap),
            decode=lambda p, st, tok: transformer.decode_step(p, st, tok, cfg),
            input_specs=specs,
            workload=lambda shape: lm_workload(cfg, shape),
        )

    if fam in ("ssm", "hybrid"):
        return ModelAPI(
            cfg=cfg,
            init=lambda key: hybrid.init_params(key, cfg),
            forward=_hybrid_forward,
            init_decode_state=lambda b, cap: hybrid.init_decode_state(cfg, b, cap),
            decode=lambda p, st, tok: hybrid.decode_step(p, st, tok, cfg),
            input_specs=lambda shape: _tok_specs(shape, cfg),
            workload=lambda shape: lm_workload(cfg, shape),
        )

    if fam == "xlstm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: xlstm.init_params(key, cfg),
            forward=_xlstm_forward,
            init_decode_state=lambda b, cap: xlstm.init_decode_state(cfg, b, cap),
            decode=lambda p, st, tok: xlstm.decode_step(p, st, tok, cfg),
            input_specs=lambda shape: _tok_specs(shape, cfg),
            workload=lambda shape: lm_workload(cfg, shape),
        )

    if fam == "encdec":

        def specs(shape: ShapeSpec) -> dict:
            extra = None
            if shape.kind != "decode":
                extra = {"frames": jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.enc_seq, cfg.d_model), cfg.jdtype)}
            return _tok_specs(shape, cfg, extra)

        return ModelAPI(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=_encdec_forward,
            init_decode_state=lambda b, cap: encdec.init_decode_state(cfg, b, cap),
            decode=lambda p, st, tok: encdec.decode_step(p, st, tok, cfg),
            input_specs=specs,
            workload=lambda shape: lm_workload(cfg, shape),
        )

    raise KeyError(f"unknown family {fam}")


# ---------------------------------------------------------------------------
# Workload extraction (planner integration): layers as pipeline stages
# ---------------------------------------------------------------------------

def layer_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    """Analytic forward FLOPs of one block at (batch, seq)."""
    d, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    T = batch * seq
    qkvo = 2 * T * d * (H * hd + 2 * K * hd + H * hd)
    if cfg.sliding_window:
        eff = min(seq, cfg.sliding_window)
        attn = 2 * T * eff * hd * H * 2 / 2
    else:
        attn = 2 * T * seq * hd * H * 2 / 2          # causal: half the square
    if cfg.family == "moe":
        ffn = 2 * T * cfg.top_k * 3 * d * cfg.expert_d_ff
        if cfg.dense_residual:
            ffn += 2 * T * 3 * d * cfg.d_ff
    elif cfg.family in ("ssm", "hybrid"):
        from .ssm import ssm_dims

        d_in, Hm, P, N = ssm_dims(cfg)
        ffn = 2 * T * d * (2 * d_in + 2 * N + Hm) + 2 * T * d_in * d \
            + 2 * T * d_in * N * 2                    # in/out proj + state update/read
        qkvo, attn = 0.0, 0.0                         # attention only in shared block
    elif cfg.family == "xlstm":
        from .xlstm import mlstm_dims

        d_in, Hm, P = mlstm_dims(cfg)
        ffn = 2 * T * d * 2 * d_in + 3 * 2 * T * d_in * d_in + 2 * T * d_in * d
        qkvo, attn = 0.0, 0.0
    else:
        mult = 3 if cfg.act == "swiglu" else 2
        ffn = 2 * T * mult * d * cfg.d_ff
    return float(qkvo + attn + ffn)


def _attn_block_flops(cfg: ModelConfig, seq: int, batch: int) -> float:
    d, hd, H, K = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    T = batch * seq
    mlp_f = 2 * T * (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    return float(2 * T * d * (2 * H * hd + 2 * K * hd) + 2 * T * seq * hd * H + mlp_f)


def lm_workload(cfg: ModelConfig, shape: ShapeSpec) -> Workload:
    """Layers (blocks) as pipeline stages; delta = inter-layer activation bytes."""
    seq = shape.seq_len if shape.kind != "decode" else 1
    B = shape.global_batch
    act_bytes = B * seq * cfg.d_model * 2.0           # bf16 activations
    if cfg.family == "encdec":
        n = cfg.n_enc_layers + cfg.n_layers
        # decode reuses precomputed cross K/V: the encoder contributes nothing
        enc_w = 0.0 if shape.kind == "decode" else layer_flops(cfg, cfg.enc_seq, B) * 0.75
        w = [enc_w] * cfg.n_enc_layers + \
            [layer_flops(cfg, seq, B)] * cfg.n_layers
        delta = [B * cfg.enc_seq * cfg.d_model * 2.0] * (cfg.n_enc_layers + 1) + \
                [act_bytes] * cfg.n_layers
        return Workload(np.array(w), np.array(delta), name=cfg.arch_id)
    w = np.full(cfg.n_layers, layer_flops(cfg, seq, B))
    if cfg.family == "hybrid" and cfg.attn_every:
        w = w.copy()
        for i in range(0, cfg.n_layers, cfg.attn_every):
            w[i] += _attn_block_flops(cfg, seq, B)
    delta = np.full(cfg.n_layers + 1, act_bytes)
    return Workload(w, delta, name=cfg.arch_id)
