"""Bi-criteria sweeps: trace (period, latency) trade-off curves with the
registered bounded solvers, and compute Pareto fronts."""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from .heuristics import run_heuristic
from .platform import Platform
from .workload import Workload


def pareto_front(points: Iterable, rtol: float = 1e-9) -> list:
    """Non-dominated subset of (period, latency) points, sorted by period.
    Points whose coordinates differ by less than ``rtol`` (relative) are
    considered equal, so floating-point noise cannot leak dominated points."""
    pts = sorted(set((float(a), float(b)) for a, b in points))
    front = []
    best_lat = float("inf")
    for per, lat in pts:
        if lat < best_lat * (1 - rtol):
            # drop a predecessor with (numerically) equal period but worse latency
            while front and per <= front[-1][0] * (1 + rtol) and lat < front[-1][1]:
                front.pop()
            front.append((per, lat))
            best_lat = lat
    return front


def pareto_front_tri(points: Iterable, rtol: float = 1e-9) -> list:
    """Non-dominated subset of (period, latency, reliability) points.

    Period and latency are minimized, reliability is MAXIMIZED (the sequel's
    third criterion).  Point a dominates b when a is no worse on all three
    coordinates (within relative tolerance ``rtol``, so floating-point noise
    cannot leak dominated points) — equal-within-tolerance duplicates
    collapse onto the first in sort order.  Returned sorted by (period,
    latency, -reliability).  O(k^2), fine for portfolio-sized fronts."""
    pts = sorted(set((float(p), float(l), float(r)) for p, l, r in points),
                 key=lambda t: (t[0], t[1], -t[2]))
    front: list = []

    def dominates(a, b):
        return (a[0] <= b[0] * (1 + rtol) and a[1] <= b[1] * (1 + rtol)
                and a[2] >= b[2] * (1 - rtol))

    for cand in pts:
        if any(dominates(f, cand) for f in front):
            continue
        front = [f for f in front if not dominates(cand, f)]
        front.append(cand)
    front.sort(key=lambda t: (t[0], t[1], -t[2]))
    return front


def sweep_heuristic(
    code: str,
    workload: Workload,
    platform: Platform,
    bounds: Sequence[float],
) -> list:
    """Run heuristic ``code`` for every bound; return list of HeuristicResult."""
    return [run_heuristic(code, workload, platform, float(b)) for b in bounds]


def sweep_solver(
    name: str,
    workload: Workload,
    platform: Platform,
    bounds: Sequence[float],
) -> list:
    """Registry-level sweep: run a bounded solver for every bound, returning
    one provenance :class:`~repro.core.solvers.Candidate` per bound."""
    from .planner import Objective
    from .solvers import get_solver, solve

    spec = get_solver(name)
    minimize = "latency" if spec.optimizes == "latency" else "period"
    return [solve(name, workload, platform, Objective(minimize, bound=float(b)))
            for b in bounds]


def default_period_grid(workload: Workload, platform: Platform, k: int = 20) -> np.ndarray:
    """Geometric grid of fixed-period bounds between the best single-processor
    cycle / p and the single-processor period."""
    from .metrics import period, single_processor_mapping

    hi = period(workload, platform, single_processor_mapping(workload, platform.fastest()))
    lo = max(hi / (2 * platform.p), 1e-9)
    return np.geomspace(lo, hi, k)


def default_latency_grid(workload: Workload, platform: Platform, k: int = 20) -> np.ndarray:
    from .metrics import optimal_latency

    lo = optimal_latency(workload, platform)
    hi = lo * 5.0
    return np.linspace(lo, hi, k)


def tradeoff_curves(workload: Workload, platform: Platform, k: int = 20) -> dict:
    """For each registered bounded solver, the list of achieved feasible
    (period, latency) points over a grid of bounds (the paper's Figures 2-7
    are averages of these across random instances)."""
    from .solvers import registered_solvers

    out = {}
    pgrid = default_period_grid(workload, platform, k)
    lgrid = default_latency_grid(workload, platform, k)
    for spec in registered_solvers():
        if not spec.needs_bound:
            continue
        grid = pgrid if spec.optimizes == "latency" else lgrid
        res = sweep_solver(spec.name, workload, platform, grid)
        out[spec.name] = [(c.period, c.latency) for c in res if c.feasible]
    return out
