"""Loop-aware HLO analysis: trip-count multipliers, dot flops, collectives."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def test_scan_flops_counted_with_trip_count():
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    L, d = 7, 128
    comp = jax.jit(f).lower(jnp.ones((L, d, d)), jnp.ones((8, d))).compile()
    res = analyze(comp.as_text())
    analytic = 2 * L * 8 * d * d
    assert res["dot_flops"] == pytest.approx(analytic, rel=1e-6)
    # XLA's own cost_analysis undercounts by ~L (documents why we parse HLO)
    ca = comp.cost_analysis()
    if ca and ca.get("flops"):
        assert ca["flops"] < analytic / 2


def test_nested_scan_multipliers_compose():
    def g(ws, x):
        def outer(x, wgrp):
            def inner(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, wgrp)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    d = 128
    comp = jax.jit(g).lower(jnp.ones((3, 5, d, d)), jnp.ones((4, d))).compile()
    res = analyze(comp.as_text())
    assert res["dot_flops"] == pytest.approx(2 * 15 * 4 * d * d, rel=1e-6)


def test_unrolled_matmul_flops():
    def f(a, b):
        return a @ b

    m, k, n = 32, 64, 48
    comp = jax.jit(f).lower(jnp.ones((m, k)), jnp.ones((k, n))).compile()
    res = analyze(comp.as_text())
    assert res["dot_flops"] == pytest.approx(2 * m * k * n, rel=1e-6)


def test_bytes_accessed_nonzero_and_bounded():
    def f(a, b):
        return jnp.tanh(a @ b)

    comp = jax.jit(f).lower(jnp.ones((64, 64)), jnp.ones((64, 64))).compile()
    res = analyze(comp.as_text())
    lo = 3 * 64 * 64 * 4                 # operands + result, once each
    assert lo * 0.5 <= res["bytes_accessed"] <= lo * 6


def test_no_collectives_on_single_device():
    comp = jax.jit(lambda x: x * 2).lower(jnp.ones((8,))).compile()
    res = analyze(comp.as_text())
    assert res["collective_bytes"] == 0.0
    assert res["collectives"] == {}
