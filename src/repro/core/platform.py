"""Target platform description — Communication-Homogeneous platforms.

Different-speed processors ``s_u`` interconnected by links of identical
bandwidth ``b`` (paper Section 2).  The one-port linear cost model is captured
by the metric functions in :mod:`repro.core.metrics`; the platform itself only
stores speeds and bandwidth.

The sequel paper ("Optimizing Latency and Reliability of Pipeline Workflow
Applications", arXiv 0711.1231) adds a third criterion: each processor ``u``
carries an independent failure probability ``f_u``, and replicating an
interval across a set of processors trades period/latency for reliability.
``Platform.fail`` is that optional per-processor failure vector (``None`` —
the default everywhere the bi-criteria model is enough — means "processors
never die" and keeps every original code path byte-identical).  Seeded
failure samplers live here (:func:`sample_failures`) and as scenario-family
combinators in :mod:`repro.sim.generators` (the R1-R4 families).

For the TPU adaptation a "processor" is a pod slice: its speed is
``chips * peak_flops * efficiency`` and can be degraded online to model
stragglers (see :mod:`repro.pipeline.replan`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def _suffix_once(name: str, suffix: str) -> str:
    """Append ``suffix`` unless the name already carries it — event-driven
    platform updates (stragglers, pod failures) fire repeatedly over long
    traces, and naively appending per event grows names without bound."""
    return name if name.endswith(suffix) else name + suffix


@dataclasses.dataclass(frozen=True)
class Platform:
    """p processors with speeds ``s``, homogeneous link bandwidth ``b``, and
    optional per-processor failure probabilities ``fail`` (None = reliable)."""

    s: np.ndarray          # shape (p,), processor speeds (flops / time-unit)
    b: float               # link bandwidth (bytes / time-unit), identical links
    name: str = "platform"
    fail: Optional[np.ndarray] = None   # shape (p,), failure prob in [0, 1)

    def __post_init__(self):
        s = np.asarray(self.s, dtype=np.float64)
        object.__setattr__(self, "s", s)
        if s.ndim != 1 or len(s) == 0:
            raise ValueError("s must be a non-empty 1-D array")
        if (s <= 0).any():
            raise ValueError("processor speeds must be positive")
        if self.b <= 0:
            raise ValueError("bandwidth must be positive")
        if self.fail is not None:
            f = np.asarray(self.fail, dtype=np.float64)
            object.__setattr__(self, "fail", f)
            if f.shape != s.shape:
                raise ValueError(f"fail must have shape {s.shape}, got {f.shape}")
            if ((f < 0) | (f >= 1)).any():
                raise ValueError("failure probabilities must be in [0, 1)")

    @property
    def p(self) -> int:
        return int(len(self.s))

    @property
    def failures(self) -> np.ndarray:
        """Per-processor failure probabilities; zeros when ``fail`` is None
        (the bi-criteria model's perfectly reliable processors)."""
        if self.fail is None:
            return np.zeros(self.p)
        return self.fail

    def sorted_indices(self) -> np.ndarray:
        """Processor indices by non-increasing speed (ties broken by index,
        matching the paper's 'sort processors by non-increasing speed')."""
        return np.lexsort((np.arange(self.p), -self.s))

    def fastest(self) -> int:
        return int(self.sorted_indices()[0])

    def degrade(self, proc: int, factor: float) -> "Platform":
        """Return a platform where processor ``proc`` runs ``factor`` times slower.
        Used for straggler modeling."""
        if not (0 < factor):
            raise ValueError("factor must be positive")
        s = self.s.copy()
        s[proc] = s[proc] / factor
        return Platform(s, self.b, name=_suffix_once(self.name, "-degraded"),
                        fail=self.fail)

    def without(self, proc: int) -> "Platform":
        """The platform after processor ``proc`` died (sequel-paper failure
        event): speeds and failure probabilities both lose that row."""
        if self.p <= 1:
            raise ValueError("cannot remove the last processor")
        return Platform(np.delete(self.s, proc), self.b,
                        name=_suffix_once(self.name, "-failed"),
                        fail=(None if self.fail is None
                              else np.delete(self.fail, proc)))

    def with_failures(self, fail) -> "Platform":
        """The same platform with per-processor failure probabilities
        attached (or stripped, with ``fail=None``)."""
        return Platform(self.s, self.b, name=self.name,
                        fail=None if fail is None else np.asarray(fail, float))


def make_platform(s: Sequence[float], b: float, name: str = "platform",
                  fail=None) -> Platform:
    return Platform(np.asarray(s, dtype=np.float64), float(b), name,
                    fail=None if fail is None else np.asarray(fail, float))


def homogeneous_platform(p: int, s: float = 1.0, b: float = 10.0) -> Platform:
    return Platform(np.full(p, s), b, name=f"homog-{p}")


def sample_failures(p: int, *, kind: str = "uniform", lo: float = 1e-3,
                    hi: float = 2e-2, seed: Optional[int] = None,
                    rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Seeded per-processor failure-probability sampler (sequel model).

      - ``"uniform"``  — i.i.d. uniform in [lo, hi];
      - ``"bimodal"``  — mostly near ``lo`` with a flaky minority near ``hi``
        (20% of processors), the realistic mixed-fleet shape;
      - ``"loguniform"`` — log-uniform in [lo, hi], spanning orders of
        magnitude of hardware quality.

    Pass either ``seed`` (new Generator) or an existing ``rng`` (draws
    consume its stream — the scenario-family contract)."""
    if rng is None:
        rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(lo, hi, p)
    if kind == "bimodal":
        flaky = rng.random(p) < 0.2
        base = rng.uniform(lo, 2 * lo, p)
        bad = rng.uniform(0.5 * hi, hi, p)
        return np.where(flaky, bad, base)
    if kind == "loguniform":
        return np.exp(rng.uniform(np.log(lo), np.log(hi), p))
    raise ValueError(f"unknown failure sampler kind {kind!r}")


def tpu_pod_platform(
    pods: int,
    chips_per_pod: int = 256,
    peak_flops: float = 197e12,
    efficiency: float = 0.4,
    dcn_bandwidth: float = 25e9,
    degraded: dict | None = None,
) -> Platform:
    """A multi-pod TPU platform for the planner: one 'processor' per pod.

    ``degraded`` maps pod index -> slowdown factor (straggler modeling).
    """
    s = np.full(pods, chips_per_pod * peak_flops * efficiency)
    if degraded:
        for k, f in degraded.items():
            s[k] /= f
    return Platform(s, dcn_bandwidth, name=f"tpu-{pods}x{chips_per_pod}")
