"""Scenario-family subsystem: random application/platform generators.

Common to all families: b = 10, processor speeds uniform integers in [1, 20].
A family is an :class:`ExperimentSpec` carrying two pluggable *samplers* —
``comp(rng, n) -> (n,)`` stage works and ``comm(rng, n, w) -> (n+1,)``
inter-stage data volumes (the comm sampler sees the drawn works so families
can correlate communication with computation).  Sampler combinators below
(:func:`uniform_comp`, :func:`bimodal_comp`, :func:`correlated_comm`,
:func:`jpeg_profile_comp` / :func:`jpeg_profile_comm`, ...) cover every
registered family; new families plug in via :func:`register_experiment` and
automatically flow through every engine (scalar / batched / jax / fused), the
campaign harness, and the cross-engine differential test suite.

The source paper's families (Section 5.1):

  E1  balanced comm/comp, homogeneous comms:     delta_i = 10,        w in [1, 20]
  E2  balanced comm/comp, heterogeneous comms:   delta in [1, 100],   w in [1, 20]
  E3  large computations:                        delta in [1, 20],    w in [10, 1000]
  E4  small computations:                        delta in [1, 20],    w in [0.01, 10]

(The paper draws integer w for E1-E3; E4's range [0.01, 10] is continuous.)

The follow-up study's families ("Bi-criteria Pipeline Mappings for Parallel
Image Processing", Benoit, Kosch, Rehn-Sonigo & Robert, 2008) model realistic
per-stage comm/comp structure; we register them as I1-I4:

  I1  JPEG encoder stage profile: the 7-stage encoder pipeline (scale,
      RGB->YCbCr, 4:2:0 subsample, block split, DCT, quantize, entropy encode)
      tiled to n stages with multiplicative jitter — data volumes shrink at
      subsampling and at entropy coding, DCT dominates compute;
  I2  bimodal computations: light preprocessing stages mixed with heavy
      transform/encode stages (mixture of uniform ranges);
  I3  correlated comm ∝ comp: inter-stage volumes proportional to the
      adjacent stages' work (heavy stages exchange heavy data);
  I4  uniform wide-range: continuous uniform comm and comp over [0.5, 50].

The reliability sequel (arXiv 0711.1231) adds per-processor failure
probabilities; its scenario families are registered as R1-R4 (family
"reliability"), each an E-style comm/comp pair plus a pluggable *failure
sampler* ``fail(rng, p, s) -> (p,)`` which sees the drawn speeds so failure
can correlate with hardware quality:

  R1  balanced comm/comp, uniform failures:      f in [1e-3, 2e-2] i.i.d.
  R2  balanced comm/comp, bimodal failures:      reliable majority + a flaky
      20% minority an order of magnitude worse;
  R3  speed-correlated failures: slower processors (older hardware) fail
      more — f interpolates [1e-3, 3e-2] from fastest to slowest, with
      multiplicative jitter;
  R4  large computations + bimodal failures: E3's compute-heavy stages on a
      mixed-quality fleet (long intervals concentrate work on few
      processors, making replication decisions non-trivial).

Failure draws happen AFTER comp/comm/speeds so the E/I streams are untouched
(the draw order is the seed contract asserted by the golden CSVs).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from ..core import Platform, Workload


# ---------------------------------------------------------------------------
# Sampler combinators.
#
# comp samplers:  fn(rng, n)    -> (n,)   per-stage work
# comm samplers:  fn(rng, n, w) -> (n+1,) inter-stage data volumes (see the
#                 drawn works, so communication can correlate with computation)
# ---------------------------------------------------------------------------

def uniform_comp(lo: float, hi: float, integer: bool = True) -> Callable:
    """Per-stage i.i.d. uniform work; integer draws match the paper's
    'randomly chosen between lo and hi' wording for E1-E3."""
    if integer:
        return lambda rng, n: rng.integers(int(lo), int(hi) + 1, n).astype(float)
    return lambda rng, n: rng.uniform(lo, hi, n)


def uniform_comm(lo: float, hi: float, integer: bool = True) -> Callable:
    """I.i.d. uniform inter-stage data volumes (independent of the works)."""
    if integer:
        return lambda rng, n, w: rng.integers(int(lo), int(hi) + 1,
                                              n + 1).astype(float)
    return lambda rng, n, w: rng.uniform(lo, hi, n + 1)


def constant_comm(value: float) -> Callable:
    """Homogeneous data volumes (E1's delta_i = 10)."""
    return lambda rng, n, w: np.full(n + 1, float(value))


def bimodal_comp(light=(1.0, 4.0), heavy=(50.0, 100.0),
                 heavy_frac: float = 0.3) -> Callable:
    """Mixture of light and heavy stages: each stage is heavy with
    probability ``heavy_frac`` (uniform within its range) — the image
    pipelines' cheap pixel passes vs dominant transform/encode stages."""
    def fn(rng, n):
        is_heavy = rng.random(n) < heavy_frac
        light_w = rng.uniform(light[0], light[1], n)
        heavy_w = rng.uniform(heavy[0], heavy[1], n)
        return np.where(is_heavy, heavy_w, light_w)
    return fn


def correlated_comm(rho: float = 1.0, noise: float = 0.5) -> Callable:
    """Inter-stage volumes proportional to the adjacent stages' mean work
    (edge volumes see the boundary stage only), with multiplicative jitter:
    heavy stages exchange heavy data."""
    def fn(rng, n, w):
        wpad = np.concatenate([w[:1], w, w[-1:]])
        adj = 0.5 * (wpad[:-1] + wpad[1:])               # (n+1,)
        return rho * adj * rng.uniform(1.0 - noise, 1.0 + noise, n + 1)
    return fn


# The JPEG encoder pipeline of the image-processing follow-up study: per-stage
# relative compute cost and the data volume flowing OUT of each stage
# (relative units per image tile).  Chroma subsampling (4:2:0) halves the
# volume, entropy coding compresses it; the DCT dominates compute.
JPEG_STAGES = ("scale", "rgb2ycbcr", "subsample", "blocksplit", "dct",
               "quantize", "encode")
JPEG_COMP = np.array([4.0, 6.0, 2.0, 1.0, 12.0, 3.0, 8.0])
JPEG_OUT = np.array([16.0, 16.0, 8.0, 8.0, 8.0, 8.0, 2.0])
JPEG_IN_RAW = 16.0   # raw image volume entering the first stage


def jpeg_profile_comp(jitter: float = 0.2) -> Callable:
    """The encoder's per-stage compute profile tiled cyclically to n stages
    with multiplicative uniform jitter (instance diversity)."""
    def fn(rng, n):
        base = JPEG_COMP[np.arange(n) % len(JPEG_COMP)]
        return base * rng.uniform(1.0 - jitter, 1.0 + jitter, n)
    return fn


def jpeg_profile_comm(jitter: float = 0.2) -> Callable:
    """The encoder's inter-stage volumes: raw input ahead of stage 1, then
    each stage's output volume, tiled with the compute profile."""
    def fn(rng, n, w):
        base = np.empty(n + 1)
        base[0] = JPEG_IN_RAW
        base[1:] = JPEG_OUT[np.arange(n) % len(JPEG_OUT)]
        return base * rng.uniform(1.0 - jitter, 1.0 + jitter, n + 1)
    return fn


# ---------------------------------------------------------------------------
# Failure samplers (the reliability sequel's platform model).
#
# fail samplers: fn(rng, p, s) -> (p,) per-processor failure probabilities in
#                [0, 1); they see the drawn speeds so failure probability can
#                correlate with hardware quality.
# ---------------------------------------------------------------------------

def uniform_fail(lo: float = 1e-3, hi: float = 2e-2) -> Callable:
    """I.i.d. uniform failure probabilities (R1)."""
    return lambda rng, p, s: rng.uniform(lo, hi, p)


def bimodal_fail(lo: float = 1e-3, hi: float = 2e-2,
                 flaky_frac: float = 0.2) -> Callable:
    """A reliable majority near ``lo`` plus a flaky minority near ``hi`` (R2):
    the realistic mixed-fleet shape, where replication pays only when it
    avoids pairing two flaky processors."""
    def fn(rng, p, s):
        flaky = rng.random(p) < flaky_frac
        base = rng.uniform(lo, 2 * lo, p)
        bad = rng.uniform(0.5 * hi, hi, p)
        return np.where(flaky, bad, base)
    return fn


def speed_correlated_fail(lo: float = 1e-3, hi: float = 3e-2,
                          noise: float = 0.25) -> Callable:
    """Failure probability anti-correlated with speed (R3): the slowest
    processor sits near ``hi``, the fastest near ``lo`` (older hardware is
    both slower and flakier), with multiplicative jitter.  Homogeneous
    speeds degenerate to ~``hi`` everywhere."""
    def fn(rng, p, s):
        s = np.asarray(s, dtype=float)
        span = s.max() - s.min()
        t = (s.max() - s) / span if span > 0 else np.ones(p)   # 0 fast .. 1 slow
        base = lo + (hi - lo) * t
        f = base * rng.uniform(1.0 - noise, 1.0 + noise, p)
        return np.clip(f, 0.0, 0.999)
    return fn


# ---------------------------------------------------------------------------
# Family registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A named scenario family: per-stage comm/comp samplers plus metadata.

    ``family`` groups specs into selectable sets ("paper" = the source
    paper's E1-E4, "image" = the image-processing follow-up's I1-I4).
    """

    name: str
    description: str
    comp: Callable            # (rng, n) -> (n,) stage works
    comm: Callable            # (rng, n, w) -> (n+1,) inter-stage volumes
    family: str = "paper"
    # Reliability-sequel families carry a failure sampler (rng, p, s) -> (p,);
    # None keeps the platform's fail unset (bi-criteria families unchanged).
    fail: "Callable | None" = None


EXPERIMENTS: dict = {}


def register_experiment(spec: ExperimentSpec, *,
                        override: bool = False) -> ExperimentSpec:
    """Register a scenario family; it immediately flows through every engine,
    ``run_campaign``/``paper_sim``, and the differential test harness (which
    parametrizes over ``EXPERIMENTS``).  Re-registering an existing name
    raises unless ``override=True`` — the built-in families' random streams
    are part of the seed contract (golden CSVs assert them byte-for-byte),
    so silently replacing one would corrupt every seeded campaign."""
    if not override and spec.name in EXPERIMENTS:
        raise ValueError(f"scenario family {spec.name!r} is already "
                         "registered; pass override=True to replace it")
    EXPERIMENTS[spec.name] = spec
    return spec


for _spec in (
    ExperimentSpec("E1", "balanced comm/comp, homogeneous comms",
                   uniform_comp(1, 20), constant_comm(10.0)),
    ExperimentSpec("E2", "balanced comm/comp, heterogeneous comms",
                   uniform_comp(1, 20), uniform_comm(1, 100)),
    ExperimentSpec("E3", "large computations",
                   uniform_comp(10, 1000), uniform_comm(1, 20)),
    ExperimentSpec("E4", "small computations",
                   uniform_comp(0.01, 10.0, integer=False),
                   uniform_comm(1, 20)),
    ExperimentSpec("I1", "JPEG encoder stage profile (image study)",
                   jpeg_profile_comp(), jpeg_profile_comm(), family="image"),
    ExperimentSpec("I2", "bimodal computations (light/heavy stages)",
                   bimodal_comp(), uniform_comm(1, 20), family="image"),
    ExperimentSpec("I3", "correlated comm proportional to comp",
                   uniform_comp(1, 20), correlated_comm(), family="image"),
    ExperimentSpec("I4", "uniform wide-range comm/comp",
                   uniform_comp(0.5, 50.0, integer=False),
                   uniform_comm(0.5, 50.0, integer=False), family="image"),
    ExperimentSpec("R1", "balanced comm/comp, uniform failures",
                   uniform_comp(1, 20), uniform_comm(1, 100),
                   family="reliability", fail=uniform_fail()),
    ExperimentSpec("R2", "balanced comm/comp, bimodal failures (flaky minority)",
                   uniform_comp(1, 20), uniform_comm(1, 100),
                   family="reliability", fail=bimodal_fail()),
    ExperimentSpec("R3", "speed-correlated failures (slow = old = flaky)",
                   uniform_comp(1, 20), uniform_comm(1, 100),
                   family="reliability", fail=speed_correlated_fail()),
    ExperimentSpec("R4", "large computations on a mixed-quality fleet",
                   uniform_comp(10, 1000), uniform_comm(1, 20),
                   family="reliability", fail=bimodal_fail()),
):
    register_experiment(_spec)

PAPER_FAMILIES = ("E1", "E2", "E3", "E4")
IMAGE_FAMILIES = ("I1", "I2", "I3", "I4")
RELIABILITY_FAMILIES = ("R1", "R2", "R3", "R4")
FAMILY_SETS = {
    "paper": PAPER_FAMILIES,
    "image": IMAGE_FAMILIES,
    "reliability": RELIABILITY_FAMILIES,
    "all": PAPER_FAMILIES + IMAGE_FAMILIES + RELIABILITY_FAMILIES,
}

BANDWIDTH = 10.0
SPEED_LOW, SPEED_HIGH = 1, 20


def gen_instance(exp: str, n: int, p: int, seed: int) -> tuple:
    """One random (workload, platform) pair for family ``exp``.

    Draw order (comp, then comm, then speeds) is part of the seed contract:
    the E1-E4 streams are byte-identical to the original generators, so every
    seeded campaign/golden CSV stays reproducible across the refactor.
    """
    spec = EXPERIMENTS[exp]
    rng = np.random.default_rng(seed)
    w = np.asarray(spec.comp(rng, n), dtype=float)
    delta = np.asarray(spec.comm(rng, n, w), dtype=float)
    if w.shape != (n,) or delta.shape != (n + 1,):
        raise ValueError(f"family {exp!r} sampler shapes {w.shape}/{delta.shape}"
                         f" do not match (n,)/(n+1,) for n={n}")
    s = rng.integers(SPEED_LOW, SPEED_HIGH + 1, p).astype(float)
    # failure draws come LAST so families without a fail sampler keep their
    # original byte-identical streams (the seed contract)
    fail = (np.asarray(spec.fail(rng, p, s), dtype=float)
            if spec.fail is not None else None)
    return (
        Workload(w, delta, name=f"{exp}-n{n}-seed{seed}"),
        Platform(s, BANDWIDTH, name=f"{exp}-p{p}-seed{seed}", fail=fail),
    )


@dataclasses.dataclass
class InstanceBatch:
    """A campaign's instances as stacked structure-of-arrays state.

    Rows are the instances of :func:`gen_instance` for ``seeds`` (identical
    draws — the per-instance objects are kept in ``workloads``/``platforms``
    for the scalar reference path and for tests).  ``prefix`` (stage-work
    prefix sums) and ``order`` (speed-sorted processor indices) are
    precomputed once here; the batched engine (:mod:`repro.core.batched`)
    consumes this object directly.
    """

    exp: str
    n: int
    p: int
    seeds: tuple
    w: np.ndarray          # (B, n)
    delta: np.ndarray      # (B, n+1)
    s: np.ndarray          # (B, p)
    b: float
    prefix: np.ndarray     # (B, n+1)
    order: np.ndarray      # (B, p) int
    workloads: tuple       # per-instance Workload objects
    platforms: tuple       # per-instance Platform objects

    def __len__(self) -> int:
        return len(self.seeds)

    def __iter__(self):
        return iter(zip(self.workloads, self.platforms))

    def instance(self, i: int) -> tuple:
        return self.workloads[i], self.platforms[i]


def gen_instance_batch(exp: str, n: int, p: int, seeds: Sequence[int]) -> InstanceBatch:
    """B random instances stacked for the batched campaign engine."""
    pairs = [gen_instance(exp, n, p, seed=int(sd)) for sd in seeds]
    return InstanceBatch(
        exp=exp, n=n, p=p, seeds=tuple(int(sd) for sd in seeds),
        w=np.stack([wl.w for wl, _ in pairs]),
        delta=np.stack([wl.delta for wl, _ in pairs]),
        s=np.stack([pf.s for _, pf in pairs]),
        b=BANDWIDTH,
        prefix=np.stack([wl.prefix_w() for wl, _ in pairs]),
        order=np.stack([pf.sorted_indices() for _, pf in pairs]),
        workloads=tuple(wl for wl, _ in pairs),
        platforms=tuple(pf for _, pf in pairs),
    )
