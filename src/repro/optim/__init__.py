from .adamw import AdamWState, adamw_init, adamw_update
from .schedule import cosine_schedule, linear_warmup_cosine
from .clip import global_norm, clip_by_global_norm
from .compression import (topk_compress, topk_decompress, int8_compress,
                          int8_decompress, ErrorFeedbackState, ef_init, ef_compress_update)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_warmup_cosine", "global_norm", "clip_by_global_norm",
           "topk_compress", "topk_decompress", "int8_compress", "int8_decompress",
           "ErrorFeedbackState", "ef_init", "ef_compress_update"]
