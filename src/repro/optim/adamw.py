"""AdamW in pure JAX, pytree-based, with optional ZeRO-1 style sharded moments."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # scalar int32
    m: dict             # first moment, pytree like params
    v: dict             # second moment


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """Returns (new_params, new_state).  lr may be a scalar or a schedule value."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * (g32 * g32)
        mhat = m / c1
        vhat = v / c2
        step_val = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_val).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
