import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, without allocating any real arrays.

For each cell we lower the right step function —
  train_4k     -> train_step  (fwd + bwd + AdamW, donated params/opt)
  prefill_32k  -> prefill forward (inference logits)
  decode_*     -> serve_step  (one token against a seq_len KV cache / SSM state)
— with explicit in/out shardings (megatron TP + DP from
repro.models.sharding), compile it, and record:
  - compiled.memory_analysis()  (per-device bytes: proves it fits)
  - compiled.cost_analysis()    (XLA's aggregate flops/bytes — loop bodies
                                 counted once; kept for reference)
  - loop-aware HLO analysis     (repro.launch.hlo_analysis: true flops, HBM
                                 traffic model, collective bytes by kind)
into results/dryrun/<arch>__<shape>__<mesh>.json for the roofline stage.

Run one cell per process (clean device state):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback
import warnings

warnings.filterwarnings("ignore")

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def cell_path(out_dir: pathlib.Path, arch: str, shape: str, multi_pod: bool) -> pathlib.Path:
    return out_dir / f"{arch}__{shape}__{_mesh_tag(multi_pod)}.json"


def run_cell(arch: str, shape_name: str, multi_pod: bool, donate: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_model, make_train_step
    from repro.models.sharding import (batch_spec, named, param_specs,
                                       state_specs, zero1_specs)
    from repro.models.train import init_optimizer
    from repro.optim.adamw import AdamWState

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    api = get_model(cfg)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len

    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind,
           "mesh": _mesh_tag(multi_pod), "devices": int(len(jax.devices())),
           "seq_len": S, "global_batch": B}

    with jax.set_mesh(mesh):
        params_sds = jax.eval_shape(api.init, jax.random.key(0))
        pspec_fn = zero1_specs if cfg.fsdp_params else param_specs
        pn = named(pspec_fn(params_sds, cfg, mesh), mesh)
        bspec = batch_spec(mesh)

        from repro.launch.mesh import data_axis_size, model_axis_size

        dsize = data_axis_size(mesh)
        msize = model_axis_size(mesh)
        batch_sds = api.input_specs(shape)
        bn = {k: NamedSharding(mesh, P(bspec[0] if v.shape[0] % dsize == 0 else None))
              for k, v in batch_sds.items()}

        if shape.kind == "train":
            opt_sds = jax.eval_shape(init_optimizer, params_sds)
            zspecs = zero1_specs(params_sds, cfg, mesh)
            on = AdamWState(step=NamedSharding(mesh, P()),
                            m=named(zspecs, mesh), v=named(zspecs, mesh))
            ts = make_train_step(api.forward, cfg)
            jitted = jax.jit(
                ts,
                in_shardings=(pn, on, bn),
                out_shardings=(pn, on, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":

            def infer(params, batch):
                logits, _ = api.forward(params, batch, cfg)
                return logits

            vocab_ok = cfg.vocab_size % msize == 0
            out_spec = P(bspec[0] if B % dsize == 0 else None, None,
                         "model" if vocab_ok else None)
            jitted = jax.jit(
                infer,
                in_shardings=(pn, bn),
                out_shardings=NamedSharding(mesh, out_spec),
            )
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            state_sds = jax.eval_shape(lambda: api.init_decode_state(B, S))
            sspecs = state_specs(state_sds, cfg, mesh, batch=B)
            sn = named(sspecs, mesh)

            def serve_step(params, state, batch):
                return api.decode(params, state, batch["token"])

            jitted = jax.jit(
                serve_step,
                in_shardings=(pn, sn, bn),
                out_shardings=(None, sn),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_sds, state_sds, batch_sds)

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")
        if hasattr(mem, k)
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float)) and k in
                            ("flops", "bytes accessed", "transcendentals",
                             "utilization operand 0 {}", "optimal_seconds")}
    txt = compiled.as_text()
    rec["hlo"] = analyze(txt)
    rec["hlo_chars"] = len(txt)
    rec["lower_s"] = round(t_lower - t0, 2)
    rec["compile_s"] = round(t_compile - t_lower, 2)

    # analytic model flops for the roofline's usefulness ratio
    from repro.models.common import active_param_count

    wl = api.workload(shape)
    unembed = 2.0 * B * (S if shape.kind != "decode" else 1) * cfg.d_model * cfg.vocab_size
    fwd = wl.total_work + unembed
    rec["model_flops"] = float(fwd * (3.0 if shape.kind == "train" else 1.0))
    tokens = B * (S if shape.kind != "decode" else 1)
    rec["model_flops_6nd"] = float(
        (6.0 if shape.kind == "train" else 2.0) * active_param_count(cfg) * tokens)
    rec["ok"] = True
    return rec


def run_pipeline_cell(arch: str, num_microbatches: int = 8,
                      straggler: float = 1.0) -> dict:
    """Lower + compile the PAPER'S TECHNIQUE at production scale: the planner
    partitions the arch's layers into intervals, the pipeline runtime executes
    them over the 2-pod mesh ('pod' = the stage axis; data/model stay GSPMD),
    and we compile loss+grad of the pipelined step.  ``straggler`` > 1
    degrades pod 1's planning speed, producing unequal intervals."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config
    from repro.core import Objective, Platform, plan
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import lm_workload
    from repro.models.sharding import param_specs
    from repro.models.train import cross_entropy
    from repro.models import transformer
    from repro.pipeline.runtime import (make_stage_mask, make_stage_params,
                                        pipelined_loss_fn)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=True)
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    wl = lm_workload(cfg, shape)
    speeds = np.array([256 * 197e12 * 0.4, 256 * 197e12 * 0.4 / straggler])
    pf = Platform(speeds, b=25e9)
    pl = plan(wl, pf, Objective("period"), mode="auto")

    with jax.set_mesh(mesh):
        params_sds = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg), jax.random.key(0))
        stages_sds = jax.eval_shape(
            lambda lp: make_stage_params(lp, pl, 2)[0], params_sds["layers"])
        mask = make_stage_mask(pl, 2)
        pipe_sds = {"embed": params_sds["embed"], "stages": stages_sds,
                    "ln_f": params_sds["ln_f"]}

        # shardings: per-layer TP specs, stages get 'pod' on dim 0
        base = param_specs({"embed": params_sds["embed"],
                            "layers": params_sds["layers"],
                            "ln_f": params_sds["ln_f"]}, cfg, mesh)
        # base layer specs already carry the stacked-L dim (-> the L_max slot
        # dim); the packed stages just gain a leading 'pod' stage dim
        stage_specs = jax.tree.map(
            lambda s: P("pod", *list(s)), base["layers"],
            is_leaf=lambda x: isinstance(x, P))
        pipe_pn = {
            "embed": jax.tree.map(lambda s: NamedSharding(mesh, s), base["embed"],
                                  is_leaf=lambda x: isinstance(x, P)),
            "stages": jax.tree.map(lambda s: NamedSharding(mesh, s), stage_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            "ln_f": NamedSharding(mesh, P(None)),
        }
        B, S = shape.global_batch, shape.seq_len
        batch_sds = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bn = {k: NamedSharding(mesh, P("data")) for k in batch_sds}

        loss_fn = pipelined_loss_fn(cfg, pl, num_microbatches, mask,
                                    mesh=mesh, stage_axis="pod")
        jitted = jax.jit(jax.value_and_grad(loss_fn),
                         in_shardings=(pipe_pn, bn))
        lowered = jitted.lower(pipe_sds, batch_sds)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": "train_4k", "mesh": "pod2x16x16",
        "mode": "pipeline", "ok": True,
        "plan": {"planner": pl.planner, "stage_sizes": list(pl.stage_sizes),
                 "alloc": list(pl.mapping.alloc),
                 "period_s": pl.period, "latency_s": pl.latency,
                 "padding_overhead": pl.padding_overhead,
                 "straggler": straggler},
        "num_microbatches": num_microbatches,
        "memory": {k: int(getattr(mem, k))
                   for k in ("argument_size_in_bytes", "output_size_in_bytes",
                             "temp_size_in_bytes")
                   if hasattr(mem, k)},
        "hlo": analyze(compiled.as_text()),
        "lower_s": round(t_lower - t0, 2),
        "compile_s": round(t_compile - t_lower, 2),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="compile the planner-driven pipeline over the pod axis")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--straggler", type=float, default=1.0)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.pipeline:
        tag = f"straggler{args.straggler}" if args.straggler != 1.0 else "even"
        path = out_dir / f"{args.arch}__pipeline_{tag}__pod2x16x16.json"
        try:
            rec = run_pipeline_cell(args.arch, args.microbatches, args.straggler)
        except Exception as e:
            rec = {"arch": args.arch, "mode": "pipeline", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            path.write_text(json.dumps(rec, indent=2))
            raise
        path.write_text(json.dumps(rec, indent=2))
        show = {k: rec[k] for k in ("arch", "mode", "ok", "compile_s")}
        show["plan"] = rec["plan"]
        show["temp_gb"] = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
        show["collective_gb"] = rec["hlo"]["collective_bytes"] / 1e9
        print(json.dumps(show, indent=2))
        return

    from repro.configs import cells  # light import (no jax state)

    if args.list:
        for a, s in cells():
            print(f"{a:18s} {s.name}")
        return

    if args.all:
        # one subprocess per cell: clean jax state, bounded memory
        pods = [False, True] if args.multi_pod == "both" else [args.multi_pod == "yes"]
        todo = [(a, s.name, mp) for mp in pods for a, s in cells()]
        done = fails = 0
        for a, sname, mp in todo:
            path = cell_path(out_dir, a, sname, mp)
            if path.exists() and not args.force:
                done += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
                   "--shape", sname, "--multi-pod", "yes" if mp else "no",
                   "--out", str(out_dir)]
            print(f"[dryrun] {a} {sname} {_mesh_tag(mp)} ...", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                fails += 1
                print(r.stdout[-2000:])
                print(r.stderr[-2000:])
            else:
                done += 1
        print(f"[dryrun] complete: {done} ok, {fails} failed")
        sys.exit(1 if fails else 0)

    path = cell_path(out_dir, args.arch, args.shape, args.multi_pod == "yes")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod == "yes")
    except Exception as e:  # record failures too — they are bugs to fix
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": _mesh_tag(args.multi_pod == "yes"),
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=2))
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "ok", "error")},
                         indent=2))
        raise
    path.write_text(json.dumps(rec, indent=2))
    show = {k: rec.get(k) for k in ("arch", "shape", "mesh", "ok", "compile_s")}
    show["temp_gb"] = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
    show["dot_tflops"] = rec["hlo"]["dot_flops"] / 1e12
    show["collective_gb"] = rec["hlo"]["collective_bytes"] / 1e9
    print(json.dumps(show, indent=2))


if __name__ == "__main__":
    main()
