"""Pallas TPU decode attention (flash-decoding style).

Single-token query against a (possibly ring-buffered) KV cache.  Grid:
(batch, q_heads, num_cache_blocks); the cache-length loop is the innermost
grid dim with online-softmax scratch, so arbitrarily long caches stream
through VMEM block by block.  Slot validity (unwritten slots, ring-buffer
wraparound, sliding-window ageing) is precomputed by the caller as a bool
mask — the kernel stays pure attention.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_c: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)                  # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bc, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    valid = mask_ref[0, :]                                  # (bc,) bool

    s = jnp.einsum("d,cd->c", q, k) * scale
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_blk = s.max()
    m_new = jnp.maximum(m_prev, m_blk)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = alpha * l_ref[0] + p.sum()
    acc_ref[...] = alpha * acc_ref[...] + jnp.einsum("c,cd->d", p, v)[None]
    m_ref[0] = m_new

    @pl.when(ci == nc - 1)
    def _finish():
        l = jnp.where(l_ref[0] == 0.0, 1.0, l_ref[0])
        o_ref[0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, *, block_c: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (B,H,hd); k,v: (B,C,K,hd); mask: (B,C) bool.  Returns (B,H,hd)."""
    B, H, hd = q.shape
    C, K = k.shape[1], k.shape[2]
    G = H // K
    block_c = min(block_c, C)
    assert C % block_c == 0
    nc = C // block_c
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ci: (b, h, 0)),
            pl.BlockSpec((1, block_c, 1, hd), lambda b, h, ci: (b, ci, h // G, 0)),
            pl.BlockSpec((1, block_c, 1, hd), lambda b, h, ci: (b, ci, h // G, 0)),
            pl.BlockSpec((1, block_c), lambda b, h, ci: (b, ci)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ci: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)
