"""Pipeline-parallel runtime driven by the paper's interval planner."""

from .schedule import gpipe_ticks, stage_microbatch, bubble_fraction
from .runtime import (PipelineSpec, make_stage_params, pipelined_loss_fn,
                      sequential_loss_fn)
from .replan import (StragglerMonitor, elastic_platform, elastic_replan,
                     replan_stages)

__all__ = ["gpipe_ticks", "stage_microbatch", "bubble_fraction",
           "PipelineSpec", "make_stage_params", "pipelined_loss_fn",
           "sequential_loss_fn", "StragglerMonitor", "replan_stages",
           "elastic_platform", "elastic_replan"]
