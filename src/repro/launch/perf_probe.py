import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration instrument: lower one (arch x shape x mesh) cell and print
the roofline terms plus the top contributors (collectives / dots / HBM bytes)
with HLO op_name attribution — the 'profile' of the dry-run methodology.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch mixtral-8x7b \\
        --shape train_4k [--multi-pod] [--top 12] [--set use_pallas=True]

The measured profile no longer dead-ends at stdout: :func:`probe_to_workload`
/ :func:`probe_to_request` convert a probe's output into a planner
``Workload`` / ``PlanRequest`` (flops/bytes/seconds normalization documented
there), so the pipeline planner can place the PROBED model rather than the
purely analytic one.
"""

import argparse
import re
import warnings

warnings.filterwarnings("ignore")

PEAK_FLOPS, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def probe(arch: str, shape_name: str, multi_pod: bool = False,
          overrides: dict = None, top: int = 12) -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.launch import hlo_analysis as H
    from repro.launch.dryrun import run_cell
    import repro.launch.dryrun as dr
    import repro.configs as configs

    overrides = overrides or {}
    if overrides:
        base_get = configs.get_config
        cfg0 = base_get(arch).replace(**overrides)
        configs.get_config = lambda a: cfg0 if a == arch else base_get(a)
        import repro.launch.dryrun
        repro.launch.dryrun.get_config = configs.get_config  # not imported there; safe

    # Re-implement enough of run_cell to keep the compiled object
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_production_mesh, data_axis_size, model_axis_size
    from repro.models import get_model, make_train_step
    from repro.models.sharding import batch_spec, named, param_specs, state_specs, zero1_specs
    from repro.models.train import init_optimizer
    from repro.optim.adamw import AdamWState

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch).replace(**overrides) if overrides else get_config(arch)
    api = get_model(cfg)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    with jax.set_mesh(mesh):
        params_sds = jax.eval_shape(api.init, jax.random.key(0))
        pspec_fn = zero1_specs if cfg.fsdp_params else param_specs
        pn = named(pspec_fn(params_sds, cfg, mesh), mesh)
        bspec = batch_spec(mesh)
        dsize = data_axis_size(mesh)
        batch_sds = api.input_specs(shape)
        bn = {k: NamedSharding(mesh, P(bspec[0] if v.shape[0] % dsize == 0 else None))
              for k, v in batch_sds.items()}
        if shape.kind == "train":
            opt_sds = jax.eval_shape(init_optimizer, params_sds)
            zn = named(zero1_specs(params_sds, cfg, mesh), mesh)
            on = AdamWState(step=NamedSharding(mesh, P()), m=zn, v=zn)
            ts = make_train_step(api.forward, cfg)
            compiled = jax.jit(ts, in_shardings=(pn, on, bn),
                               out_shardings=(pn, on, None),
                               donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, batch_sds).compile()
        elif shape.kind == "prefill":
            def infer(params, batch):
                return api.forward(params, batch, cfg)[0]
            compiled = jax.jit(infer, in_shardings=(pn, bn)).lower(
                params_sds, batch_sds).compile()
        else:
            state_sds = jax.eval_shape(lambda: api.init_decode_state(B, S))
            sn = named(state_specs(state_sds, cfg, mesh, batch=B), mesh)
            def serve(params, state, batch):
                return api.decode(params, state, batch["token"])
            compiled = jax.jit(serve, in_shardings=(pn, sn, bn),
                               out_shardings=(None, sn),
                               donate_argnums=(1,)).lower(
                params_sds, state_sds, batch_sds).compile()

    txt = compiled.as_text()
    res = H.analyze(txt, detail=True)
    mem = compiled.memory_analysis()
    terms = {"compute": res["dot_flops"] / PEAK_FLOPS,
             "memory": res["bytes_accessed"] / HBM_BW,
             "collective": res["collective_bytes"] / LINK_BW}
    dom = max(terms, key=terms.get)
    print(f"== {arch} {shape_name} {'2x16x16' if multi_pod else '16x16'} "
          f"{overrides or ''}")
    print(f"terms: compute={terms['compute']:.3f}s memory={terms['memory']:.3f}s "
          f"collective={terms['collective']:.3f}s  dominant={dom}  "
          f"frac={terms['compute']/max(terms.values()):.3f}")
    print(f"temp={mem.temp_size_in_bytes/1e9:.1f}GB  "
          f"args={mem.argument_size_in_bytes/1e9:.1f}GB")
    print("bytes_by_kind (GB):",
          {k: round(v / 1e9, 1) for k, v in sorted(
              res["bytes_by_kind"].items(), key=lambda kv: -kv[1])[:8]})

    comps, sizes, dims = H.parse(txt)
    mult, _ = H.call_multipliers(comps)
    colls, dots, bigbytes = [], [], []
    for cname, ops in comps.items():
        k = mult.get(cname, 0)
        if not k:
            continue
        for op in ops:
            line = op["line"]
            mm = re.search(r'op_name="([^"]*)"', line)
            oname = (mm.group(1) if mm else "?")[-85:]
            kind = op["kind"][:-6] if op["kind"].endswith("-start") else op["kind"]
            if kind in H.COLLECTIVES:
                ob = sum(sizes.get((cname, o), 0)
                         for o in H._operands(line, op["op_end"]))
                shapes = H._SHAPE_RE.findall(line)
                dt0 = shapes[0][0] if shapes else "?"
                colls.append((k * ob, k, kind, dt0, oname))
            if kind == "dot":
                shapes = H._SHAPE_RE.findall(line)
                res_elems = 1
                for d in shapes[0][1].split(","):
                    if d:
                        res_elems *= int(d)
                opnds = H._operands(line, op["op_end"])
                cm = H._DOT_CONTRACT_RE.search(line)
                contract, lhs_dims = 1, None
                if len(shapes) > 1:
                    lhs_dims = tuple(int(x) for x in shapes[1][1].split(",") if x)
                elif opnds:
                    dl = dims.get((cname, opnds[0]))
                    if dl and len(dl) == 1:
                        lhs_dims = dl[0]
                if cm and lhs_dims is not None:
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                dots.append((k * 2.0 * res_elems * max(contract, 1), k, oname))
    colls.sort(reverse=True)
    dots.sort(reverse=True)
    print("-- top HBM-bytes ops (traffic model):")
    for b in (res["detail"] or [])[:top]:
        print(f"  {b[0]/1e9:8.1f}GB x{b[1]:5.0f} {b[2]:12s} res={b[3]/1e9:.2f}GB {b[4]}")
    print(f"-- top collectives ({sum(c[0] for c in colls)/1e9:.0f} GB total):")
    for c in colls[:top]:
        print(f"  {c[0]/1e9:8.1f}GB x{c[1]:5.0f} {c[2]:18s} [{c[3]}] {c[4]}")
    print(f"-- top dots ({sum(d[0] for d in dots)/1e12:.0f} TF total):")
    for d in dots[:max(top // 2, 6)]:
        print(f"  {d[0]/1e12:8.1f}TF x{d[1]:5.0f} {d[2]}")
    return {"terms": terms, "res": res,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "devices": int(mesh.devices.size)}


def probe_to_workload(probe_out: dict, arch: str, shape_name: str,
                      smoke: bool = False, devices: int = None):
    """Calibrate the analytic per-layer pipeline workload with a probe's
    MEASURED totals — the bridge from a measured profile to the planner.

    Units (the normalization contract, so planner outputs line up with the
    probe's roofline terms):

    - probe ``terms`` are SECONDS (per-device quantities over per-chip peak
      rates);
    - workload ``w`` is FLOPS per stage, ``delta`` is BYTES per boundary;
    - :func:`repro.core.tpu_pod_platform` speeds are FLOPS/SECOND and
      bandwidth BYTES/SECOND —

    so every period/latency the planner reports on the returned workload is
    in SECONDS, directly comparable to ``max(terms.values())``.

    ``res["dot_flops"]`` / ``res["collective_bytes"]`` are PER-DEVICE
    numbers from the partitioned HLO; they are scaled by the probe mesh's
    device count (recorded in ``probe_out["devices"]``) back to global
    totals, then spread over the analytic per-layer profile
    (:func:`repro.models.registry.lm_workload`), preserving its relative
    stage shape (encoder/decoder and hybrid-attention asymmetries) while
    pinning the totals to what the compiled program actually does.
    """
    from repro.configs import get_config, get_smoke_config
    from repro.core import make_workload
    from repro.models.common import SHAPES
    from repro.models.registry import lm_workload

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    base = lm_workload(cfg, SHAPES[shape_name])
    devices = devices if devices is not None else int(probe_out.get("devices", 1))
    res = probe_out["res"]
    flops_global = float(res["dot_flops"]) * devices
    coll_global = float(res["collective_bytes"]) * devices
    w_total = float(base.w.sum())
    d_total = float(base.delta.sum())
    flop_scale = flops_global / w_total if w_total and flops_global else 1.0
    comm_scale = coll_global / d_total if d_total and coll_global else 1.0
    return make_workload(base.w * flop_scale, base.delta * comm_scale,
                         name=f"{cfg.arch_id}-probed")


def probe_to_request(probe_out: dict, arch: str, shape_name: str, pods: int,
                     objective=None, smoke: bool = False,
                     devices: int = None):
    """A ready-to-solve :class:`repro.core.PlanRequest` for the probed cell:
    the measured-calibrated workload of :func:`probe_to_workload` over a
    ``pods``-pod TPU platform (same second/flop/byte normalization, so the
    planned period is in seconds)."""
    from repro.core import Objective, PlanRequest, tpu_pod_platform

    wl = probe_to_workload(probe_out, arch, shape_name, smoke=smoke,
                           devices=devices)
    return PlanRequest(wl, tpu_pod_platform(pods),
                       objective or Objective("period"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (evaluated)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = eval(v)
    probe(args.arch, args.shape, args.multi_pod, overrides, args.top)


if __name__ == "__main__":
    main()
