"""Deal-skeleton extension (the paper's Section-7 future work)."""

import numpy as np
import pytest

from repro.core import (Objective, make_platform, make_workload, plan,
                        plan_with_deal)


def test_deal_improves_single_bottleneck_stage():
    """One huge stage dominates: interval splitting cannot help (single
    stage), but dealing it over extra processors must."""
    wl = make_workload([1.0, 100.0, 1.0], [0.1, 0.1, 0.1, 0.1])
    pf = make_platform([10.0] * 6, b=100.0)
    base = plan(wl, pf, Objective("period"), mode="auto")
    dealt = plan_with_deal(wl, pf, Objective("period"))
    assert dealt.period < base.period - 1e-9
    # the bottleneck interval got the replicas
    sizes = [len(g) for g in dealt.groups]
    bott = max(range(dealt.num_stages),
               key=lambda j: wl.interval_work(*dealt.base.mapping.intervals[j]))
    assert sizes[bott] > 1


def test_deal_never_worse_than_base():
    rng = np.random.default_rng(0)
    for _ in range(15):
        n = int(rng.integers(2, 12))
        p = int(rng.integers(3, 10))
        wl = make_workload(rng.integers(1, 50, n).astype(float),
                           rng.integers(0, 20, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        base = plan(wl, pf, Objective("period"), mode="auto")
        dealt = plan_with_deal(wl, pf, Objective("period"))
        assert dealt.period <= base.period + 1e-9
        # all groups disjoint
        seen = set()
        for g in dealt.groups:
            assert not (seen & set(g))
            seen |= set(g)


def test_deal_stops_when_comm_bound():
    """If the bottleneck cycle is pure communication, dealing cannot help and
    must not consume processors."""
    wl = make_workload([0.01, 0.01], [1000.0, 1000.0, 1000.0])
    pf = make_platform([10.0] * 4, b=1.0)
    dealt = plan_with_deal(wl, pf, Objective("period"))
    assert all(len(g) == 1 for g in dealt.groups)


def test_deal_respects_latency_bound():
    wl = make_workload([1.0, 100.0, 1.0], [0.1] * 4)
    pf = make_platform([10.0, 10.0, 10.0, 1.0, 1.0], b=100.0)
    base = plan(wl, pf, Objective("period"), mode="auto")
    # a tight latency bound: dealing onto the slow processors would blow the
    # latency (slowest group member bounds it), so it must hold the bound
    dealt = plan_with_deal(wl, pf, Objective("period", bound=base.latency * 1.01))
    assert dealt.latency <= base.latency * 1.01 + 1e-9
