"""Batched campaign engine: grouping/padding/convergence-mask behavior, the
campaign wiring, and the fused engine's trace- and dispatch-count contracts.

Cross-engine bit-identity (scalar vs numpy vs jax vs fused, for every
scenario family) lives in tests/test_engine_equivalence.py — the differential
harness subsumed the per-engine identity tests that used to sit here.
"""

import numpy as np
import pytest

from repro.core import make_platform, make_workload, period
from repro.core.batched import (batched_sp_bi_p, batched_trajectories,
                                batched_trajectory_sets, stack_instances)
from repro.core.heuristics import split_trajectory
from repro.core.metrics import single_processor_mapping
from repro.sim import gen_instance_batch
from repro.sim.experiments import (run_campaign, run_experiment,
                                   run_replicated, summarize_experiment,
                                   summarize_replicated)

SEEDS = range(7000, 7006)


def test_trajectory_sets_group_codes():
    """Grouped runs (H1+H4 and H2+H3 share lockstep batches) return the same
    trajectories as separate runs."""
    batch = gen_instance_batch("E2", 15, 10, SEEDS)
    grouped = batched_trajectory_sets(["H1", "H2", "H3", "H4"], batch)
    for code in ("H1", "H2", "H3", "H4"):
        assert grouped[code] == batched_trajectories(code, batch), code


def _mixed_convergence_pairs():
    n = 12
    fast_flat = make_workload([10.0] * n, [0.0] * (n + 1))
    wl2 = make_workload(list(range(1, n + 1)), [5.0] * (n + 1))
    pf_stuck = make_platform([20.0] + [0.001] * 9, b=10.0)   # splitting never helps
    pf_rich = make_platform([20.0, 19.0, 18.0, 17.0, 16.0, 15.0, 14.0, 13.0,
                             12.0, 11.0], b=10.0)
    return [(fast_flat, pf_stuck), (fast_flat, pf_rich), (wl2, pf_stuck),
            (wl2, pf_rich)]


@pytest.mark.parametrize("backend", ["numpy", "fused"])
def test_padding_mixed_convergence(backend):
    """A batch mixing an instance that converges immediately (no improving
    split: every extra processor is uselessly slow) with one that splits many
    times: per-problem masks must keep trajectories independent and padded
    state must not leak across rows — in the numpy lockstep loop and inside
    the traced fused loop alike."""
    if backend == "fused":
        pytest.importorskip("jax")
    pairs = _mixed_convergence_pairs()
    pb = stack_instances(pairs)
    for code in ("H1", "H2", "H3", "H4"):
        bt = batched_trajectories(code, pb, backend=backend)
        lengths = [len(t) for t in bt]
        # stuck instances record only the initial state; rich ones split
        assert lengths[0] == 1 and lengths[2] == 1, (code, lengths)
        assert lengths[1] > 1 and lengths[3] > 1, (code, lengths)
        for i, (wl, pf) in enumerate(pairs):
            assert bt[i] == split_trajectory(code, wl, pf), (code, i)


def test_stack_instances_validates_shapes():
    wl_a = make_workload([1.0, 2.0], [0.0, 0.0, 0.0])
    wl_b = make_workload([1.0, 2.0, 3.0], [0.0] * 4)
    pf = make_platform([1.0, 2.0], 10.0)
    with pytest.raises(ValueError):
        stack_instances([(wl_a, pf), (wl_b, pf)])
    with pytest.raises(ValueError):
        stack_instances([])


def test_run_campaign_matches_per_exp():
    """Cross-family stacking (paper + image families in one batch) changes
    nothing about per-family results."""
    exps = ("E1", "E2", "I2", "I4")
    camp = run_campaign(exps, 8, 10, n_pairs=4, n_bounds=4)
    for exp in exps:
        solo = run_experiment(exp, 8, 10, n_pairs=4, n_bounds=4, engine="scalar")
        assert summarize_experiment(solo) == summarize_experiment(camp[exp]), exp


def test_unknown_code_and_engine_raise():
    batch = gen_instance_batch("E1", 5, 5, [1, 2])
    with pytest.raises(KeyError):
        batched_trajectories("H5", batch)
    with pytest.raises(ValueError):
        run_experiment("E1", 5, 5, n_pairs=2, n_bounds=3, engine="bogus")


# ---------------------------------------------------------------------------
# Fused device-resident engine (repro.core.fused): the whole lockstep loop —
# and the whole H4 bisection — under jit, O(1) dispatches per campaign.
# ---------------------------------------------------------------------------


def test_fused_large_grid_smoke():
    """The large-grid follow-up shape (n=80, p=1000) completes under the
    fused engine and matches the numpy engine exactly."""
    pytest.importorskip("jax")
    batch = gen_instance_batch("E3", 80, 1000, range(2))
    got = batched_trajectory_sets(["H1", "H4"], batch, backend="fused")
    ref = batched_trajectory_sets(["H1", "H4"], batch, backend="numpy")
    assert got == ref
    assert all(len(t) > 1 for t in got["H1"])


def test_fused_trace_count_per_campaign():
    """The trace contracts: a whole campaign (trajectories for H1-H4, the
    fused-scan H4 bisection, H5/H6 over the bound grid) compiles at most 3
    fused programs — one lockstep loop per split arity plus one bisection
    scan — whose span-bucketed candidate branches stay within the O(log n)
    buckets-per-arity budget; a rerun of the same shapes compiles none."""
    pytest.importorskip("jax")
    from repro.core import fused

    # a shape no other test uses, so the lru-cached programs are cold
    kw = dict(n_pairs=3, n_bounds=5, h4_iters=4, include_h4=True)
    fused.reset_trace_count()
    fused.reset_bucket_trace_count()
    camp = run_campaign(("E1", "I2"), 9, 7, backend="fused", **kw)
    assert fused.trace_count() <= 3
    # every traced program traces each of its arity's buckets exactly once:
    # the per-campaign bucket-trace count is capped at O(log n) per arity
    assert fused.bucket_trace_count() <= fused.trace_budget(9)
    assert fused.trace_budget(9) <= 3 * (int(np.ceil(np.log2(9))) + 1)
    fused.reset_trace_count()
    fused.reset_bucket_trace_count()
    camp2 = run_campaign(("E1", "I2"), 9, 7, backend="fused", **kw)
    assert fused.trace_count() == 0  # warm: dispatches only, no re-trace
    assert fused.bucket_trace_count() == 0
    for exp in ("E1", "I2"):
        assert summarize_experiment(camp[exp]) == summarize_experiment(camp2[exp])
        solo = run_experiment(exp, 9, 7, engine="scalar", **kw)
        assert summarize_experiment(solo) == summarize_experiment(camp[exp]), exp


def test_fused_h4_bisection_dispatch_count():
    """The fused ``lax.scan`` bisection runs a whole H4 campaign in ONE
    dispatch per row-chunk — independent of the iteration count — where the
    host-driven probe loop pays ~iters+1.  Outputs are identical."""
    pytest.importorskip("jax")
    from repro.core import batched, fused

    batch = gen_instance_batch("E2", 10, 10, SEEDS)
    pb = batched._as_problem_batch(batch)
    fracs = [0.05, 0.2, 0.4, 0.6, 0.8, 1.0]
    bounds = np.array(
        [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
         for (wl, pf), f in zip(batch, fracs)])
    for iters in (4, 8):
        fused.reset_dispatch_count()
        rs_scan = batched_sp_bi_p(pb, bounds, iters=iters, backend="fused")
        d_scan = fused.dispatch_count()
        assert d_scan == 1, d_scan  # one chunk, any iteration count

        # PR-3 style host-driven bisection: _run_loop(fused) per probe
        lo, hi = batched.h4_search_bounds(pb)
        fused.reset_dispatch_count()
        rs_loop = batched._sp_bi_p_rowwise(pb, bounds, iters, "fused",
                                           lo, hi, True)
        d_loop = fused.dispatch_count()
        assert d_loop >= iters  # one dispatch per probe (early-exit aside)
        assert d_loop >= 2 * d_scan
        for a, b in zip(rs_scan, rs_loop):
            assert (a.mapping == b.mapping and a.period == b.period
                    and a.latency == b.latency and a.feasible == b.feasible
                    and a.splits == b.splits)


def test_fused_campaign_dispatches_constant_in_iterations():
    """Whole-campaign dispatch count must not scale with h4_iters: the
    bisection is the only iteration-dependent phase and it is now fused."""
    pytest.importorskip("jax")
    from repro.core import fused

    kw = dict(n_pairs=3, n_bounds=4, include_h4=True)
    counts = {}
    for iters in (4, 16):
        run_campaign(("E2",), 8, 6, backend="fused", h4_iters=iters, **kw)
        fused.reset_dispatch_count()
        run_campaign(("E2",), 8, 6, backend="fused", h4_iters=iters, **kw)
        counts[iters] = fused.dispatch_count()
    assert counts[4] == counts[16], counts


def test_replicated_campaign_cis():
    """run_replicated: bank 0 equals the plain campaign; CI half-widths are
    finite where every replication has feasible points; engines agree."""
    rep, first = run_replicated(("E2",), 8, 10, n_pairs=3, replications=4,
                                n_bounds=4)
    camp = run_campaign(("E2",), 8, 10, n_pairs=3, n_bounds=4)
    assert summarize_experiment(first["E2"]) == summarize_experiment(camp["E2"])
    r = rep["E2"]
    assert r.replications == 4
    mean_per, ci_per, mean_lat, ci_lat, frac = r.curves["H5"]
    sel = frac == 1.0
    assert np.isfinite(mean_per[sel]).all() and np.isfinite(ci_per[sel]).all()
    assert (ci_per[sel] >= 0).all() and (ci_lat[sel] >= 0).all()
    m, ci = r.thresholds["H1"]
    assert np.isfinite(m) and np.isfinite(ci) and ci >= 0
    text = summarize_replicated(r)
    assert "period_ci95" in text and "threshold_ci95" in text
    repf, _ = run_replicated(("E2",), 8, 10, n_pairs=3, replications=4,
                             n_bounds=4, engine="fused")
    assert summarize_replicated(repf["E2"]) == text
