"""whisper-large-v3 [audio]: enc-dec transformer backbone; conv/audio frontend
is a stub (input_specs supplies precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-large-v3", family="encdec",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        n_enc_layers=32, enc_seq=1500, act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-large-v3-smoke", family="encdec",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        n_enc_layers=2, enc_seq=48, act="gelu",
    )
