"""Paper simulation study (Section 5): random instance generators E1-E4,
experiment runner, failure thresholds."""

from .generators import EXPERIMENTS, InstanceBatch, gen_instance, gen_instance_batch
from .experiments import (run_experiment, failure_thresholds, trajectory,
                          summarize_experiment)

__all__ = ["EXPERIMENTS", "InstanceBatch", "gen_instance", "gen_instance_batch",
           "run_experiment", "failure_thresholds", "trajectory",
           "summarize_experiment"]
