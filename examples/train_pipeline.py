"""End-to-end training driver: a ~100M-param qwen3-family model trained for a
few hundred steps on the synthetic stream, with checkpointing enabled.

Run:  PYTHONPATH=src python examples/train_pipeline.py [--steps 300]
"""

import argparse
import json
import tempfile

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        out = train_loop(arch=args.arch, smoke=True, steps=args.steps,
                         batch=8, seq=128, ckpt_dir=ckpt, ckpt_every=100,
                         log_every=20)
    print(json.dumps(out, indent=2))
    assert out["final_loss"] < out["first_loss"], "model must learn"


if __name__ == "__main__":
    main()
