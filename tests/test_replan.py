"""Online replanning primitives: StragglerMonitor EWMA behavior,
replan_stages, and the elastic resize's heterogeneity preservation."""

import numpy as np
import pytest

from repro.core import (Objective, StagePlan, interval_cycle_times,
                        make_platform, make_workload, plan)
from repro.pipeline.replan import (StragglerMonitor, elastic_platform,
                                   elastic_replan, replan_stages)


def _instance():
    wl = make_workload([4.0, 2.0, 6.0, 3.0, 5.0, 2.0],
                       [1.0, 2.0, 1.0, 3.0, 1.0, 2.0, 1.0])
    pf = make_platform([3.0, 2.0, 2.0, 1.0], 10.0)
    return wl, pf


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------

def test_ewma_first_observation_copies():
    mon = StragglerMonitor(num_stages=3)
    out = mon.observe([1.0, 2.0, 3.0])
    np.testing.assert_array_equal(out, [1.0, 2.0, 3.0])


def test_ewma_convergence_to_stationary_times():
    """Repeated identical observations converge the EWMA geometrically."""
    mon = StragglerMonitor(num_stages=2, alpha=0.2)
    mon.observe([1.0, 1.0])
    target = np.array([3.0, 0.5])
    for _ in range(60):
        mon.observe(target)
    np.testing.assert_allclose(mon.ewma, target, rtol=1e-5)


def test_ewma_blend_is_exact():
    mon = StragglerMonitor(num_stages=1, alpha=0.2)
    mon.observe([1.0])
    mon.observe([2.0])
    assert mon.ewma[0] == pytest.approx(0.8 * 1.0 + 0.2 * 2.0)


def test_threshold_flagging():
    """Only stages whose EWMA/predicted ratio exceeds the threshold flag."""
    mon = StragglerMonitor(num_stages=3, threshold=1.3)
    mon.observe([1.0, 1.4, 1.2])
    assert mon.stragglers([1.0, 1.0, 1.0]) == [1]


def test_no_observation_means_no_stragglers():
    mon = StragglerMonitor(num_stages=3)
    assert mon.stragglers([1.0, 1.0, 1.0]) == []


def test_replan_stages_no_straggler_fast_path():
    """Healthy timings: no replan, the platform object passes through."""
    wl, pf = _instance()
    current = plan(wl, pf, Objective("period"))
    mon = StragglerMonitor(num_stages=current.num_stages)
    predicted = interval_cycle_times(wl, pf, current.mapping)
    mon.observe(predicted)   # exactly as predicted
    new_plan, out_pf = replan_stages(wl, pf, current, mon)
    assert new_plan is None
    assert out_pf is pf


def test_replan_stages_degrades_and_replans():
    wl, pf = _instance()
    current = plan(wl, pf, Objective("period"))
    mon = StragglerMonitor(num_stages=current.num_stages)
    predicted = interval_cycle_times(wl, pf, current.mapping)
    slow = predicted.copy()
    slow[0] *= 2.0           # stage 0's pod runs 2x slow
    mon.observe(slow)
    new_plan, degraded = replan_stages(wl, pf, current, mon)
    assert isinstance(new_plan, StagePlan)
    bad_pod = current.mapping.alloc[0]
    assert degraded.s[bad_pod] == pytest.approx(pf.s[bad_pod] / 2.0)
    # the other pods are untouched
    for u in range(pf.p):
        if u != bad_pod:
            assert degraded.s[u] == pf.s[u]


# ---------------------------------------------------------------------------
# Elastic resize
# ---------------------------------------------------------------------------

def test_elastic_platform_preserves_surviving_speeds():
    """Shrink keeps the survivors' observed speeds verbatim."""
    pf = make_platform([3.0, 1.5, 2.0, 0.5], 10.0)
    out = elastic_platform(pf, 3)
    np.testing.assert_array_equal(out.s, [3.0, 1.5, 2.0])
    assert out.b == pf.b


def test_elastic_platform_fills_new_pods_with_median():
    """Growth: survivors keep their speeds, new pods get the median prior."""
    pf = make_platform([3.0, 1.0, 2.0], 10.0)
    out = elastic_platform(pf, 5)
    np.testing.assert_array_equal(out.s[:3], pf.s)
    assert out.s[3] == out.s[4] == pytest.approx(np.median(pf.s))


def test_elastic_platform_explicit_survivors():
    pf = make_platform([3.0, 1.0, 2.0, 4.0], 10.0)
    out = elastic_platform(pf, 2, surviving=[3, 1])
    np.testing.assert_array_equal(out.s, [4.0, 1.0])


def test_elastic_platform_rejects_zero_pods():
    pf = make_platform([1.0, 2.0], 10.0)
    with pytest.raises(ValueError):
        elastic_platform(pf, 0)


def test_elastic_replan_uses_measured_heterogeneity():
    """The resized plan must see the observed speeds: with one pod far
    faster than the rest, a median-rebuilt (homogeneous) platform would
    spread stages evenly, while the true heterogeneous platform loads the
    fast pod — the plan's stage allocation must reflect the latter."""
    wl = make_workload([4.0, 2.0, 6.0, 3.0, 5.0, 2.0, 4.0, 3.0],
                       np.ones(9))
    pf = make_platform([10.0, 1.0, 1.0, 1.0, 1.0], 10.0)
    new = elastic_replan(wl, pf, 4)   # drop the last pod, keep 10.0 + 1.0s
    # the fast surviving pod (index 0) must carry the largest interval
    sizes = {u: e - d + 1 for (d, e), u in
             zip(new.mapping.intervals, new.mapping.alloc)}
    assert 0 in sizes, "fast pod unused: measured speeds were discarded"
    assert sizes[0] == max(sizes.values())
