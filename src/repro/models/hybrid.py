"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* full-attention block
applied every ``cfg.attn_every`` layers.

Execution structure mirrors the paper-planner's padded-interval trick: the
n_layers Mamba blocks are grouped into G = ceil(L / attn_every) groups of
``attn_every`` (last group padded with masked identity layers), and we scan
over groups: [shared attention] -> [inner scan over the group's Mamba layers].
This keeps one compiled group body (bounded HLO) and gives the attention
applications a natural per-group KV cache stack.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .attention import (KVCache, attention, cache_from_prefill,
                        decode_attention_step, init_attention, init_cache)
from .common import ModelConfig
from .layers import embed, init_embed, init_mlp, mlp, rms_norm, shard, unembed
from .ssm import (MambaState, init_mamba2, init_mamba_state, mamba2_decode_step,
                  mamba2_forward, ssm_dims)


def group_shape(cfg: ModelConfig) -> tuple:
    """(n_groups, group_size, n_padded_layers)."""
    g = cfg.attn_every
    ng = math.ceil(cfg.n_layers / g)
    pad = ng * g - cfg.n_layers
    return ng, g, pad


def init_params(key, cfg: ModelConfig) -> dict:
    ke, ka, km, kn = jax.random.split(key, 4)
    ng, g, pad = group_shape(cfg)
    layer_keys = jax.random.split(km, ng * g)
    mamba = jax.vmap(lambda k: init_mamba2(k, cfg))(layer_keys)
    # reshape leading dim to (ng, g)
    mamba = jax.tree.map(lambda a: a.reshape((ng, g) + a.shape[1:]), mamba)
    return {
        "embed": init_embed(ke, cfg),
        "shared_attn": {
            "ln": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
            "attn": init_attention(ka, cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
            "mlp": init_mlp(kn, cfg),
        },
        "mamba_groups": mamba,
        "mamba_ln": jnp.ones((ng, g, cfg.d_model), cfg.jparam_dtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
    }


def _group_forward(shared, group_params, group_ln, group_mask, x, cfg, positions):
    """One group: shared attention application + masked scan over Mamba layers."""
    h = rms_norm(x, shared["ln"], cfg.norm_eps)
    h = attention(shared["attn"], h, cfg, positions=positions, causal=True)
    x = x + h
    h = rms_norm(x, shared["ln2"], cfg.norm_eps)
    x = x + mlp(shared["mlp"], h, cfg)

    def body(x, inp):
        lp, ln, m = inp
        h = rms_norm(x, ln, cfg.norm_eps)
        h = mamba2_forward(lp, h, cfg)
        return x + m.astype(x.dtype) * h, None

    x, _ = jax.lax.scan(body, x, (group_params, group_ln, group_mask))
    return x


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig) -> tuple:
    x = embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    shared = params["shared_attn"]
    ng, g, _ = group_shape(cfg)
    layer_mask = (jnp.arange(ng * g) < cfg.n_layers).reshape(ng, g)

    def gbody(x, inp):
        gp, gln, gm = inp
        x = _group_forward(shared, gp, gln, gm, x, cfg, positions)
        return x, None

    if cfg.remat == "block":
        gbody = jax.checkpoint(gbody)
    x, _ = jax.lax.scan(gbody, x, (params["mamba_groups"], params["mamba_ln"],
                                   layer_mask))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(params["embed"], x, cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class HybridState(NamedTuple):
    caches: KVCache        # stacked (ng, B, C, K, hd) — one per attention application
    mamba: MambaState      # stacked (ng, g, ...) per layer


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int) -> HybridState:
    ng, g, _ = group_shape(cfg)
    d_in, H, P, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * N
    caches = KVCache(
        k=jnp.zeros((ng, batch, capacity, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        v=jnp.zeros((ng, batch, capacity, cfg.n_kv_heads, cfg.head_dim), cfg.jdtype),
        pos=jnp.zeros((ng, batch), jnp.int32),
        positions=jnp.full((ng, batch, capacity), -1, jnp.int32),
    )
    mamba = MambaState(
        conv=jnp.zeros((ng, g, batch, conv_dim, cfg.ssm_conv - 1), jnp.float32),
        ssm=jnp.zeros((ng, g, batch, H, P, N), jnp.float32),
    )
    return HybridState(caches, mamba)


def decode_step(params: dict, state: HybridState, token: jax.Array,
                cfg: ModelConfig) -> tuple:
    x = embed(params["embed"], token, cfg)
    shared = params["shared_attn"]
    ng, g, _ = group_shape(cfg)
    layer_mask = (jnp.arange(ng * g) < cfg.n_layers).reshape(ng, g)

    def gbody(x, inp):
        gp, gln, gm, cache, mstate = inp
        h = rms_norm(x, shared["ln"], cfg.norm_eps)
        h, new_cache = decode_attention_step(shared["attn"], h, cache, cfg)
        x = x + h
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + mlp(shared["mlp"], h, cfg)

        def lbody(x, linp):
            lp, ln, m, ms = linp
            h = rms_norm(x, ln, cfg.norm_eps)
            h, new_ms = mamba2_decode_step(lp, h, ms, cfg)
            return x + m.astype(x.dtype) * h, new_ms

        x, new_mstate = jax.lax.scan(lbody, x, (gp, gln, gm, mstate))
        return x, (new_cache, new_mstate)

    x, (new_caches, new_mamba) = jax.lax.scan(
        gbody, x, (params["mamba_groups"], params["mamba_ln"],
                   layer_mask, state.caches, state.mamba))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return logits, HybridState(new_caches, new_mamba)
