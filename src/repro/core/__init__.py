"""Core paper library: Benoit/Rehn-Sonigo/Robert 2007, bi-criteria pipeline mapping."""

from .workload import Workload, make_workload, uniform_workload
from .platform import Platform, make_platform, homogeneous_platform, tpu_pod_platform
from .metrics import (Mapping, period, latency, evaluate, interval_cycle_times,
                      optimal_latency, single_processor_mapping,
                      intervals_from_cuts, all_interval_partitions)
from .heuristics import (HeuristicResult, run_heuristic, NAMES,
                         FIXED_PERIOD_HEURISTICS, FIXED_LATENCY_HEURISTICS,
                         sp_mono_p, explo3_mono, explo3_bi, sp_bi_p, sp_mono_l, sp_bi_l)
from .exact import (brute_force, exact_min_period, dp_homogeneous_period,
                    dp_speed_ordered, pareto_exact)
from .pareto import pareto_front, tradeoff_curves, sweep_heuristic
from .planner import Objective, StagePlan, plan, replan_for_straggler, InfeasiblePlan
from .deal import DealPlan, plan_with_deal

__all__ = [
    "Workload", "make_workload", "uniform_workload",
    "Platform", "make_platform", "homogeneous_platform", "tpu_pod_platform",
    "Mapping", "period", "latency", "evaluate", "interval_cycle_times",
    "optimal_latency", "single_processor_mapping", "intervals_from_cuts",
    "all_interval_partitions",
    "HeuristicResult", "run_heuristic", "NAMES",
    "FIXED_PERIOD_HEURISTICS", "FIXED_LATENCY_HEURISTICS",
    "sp_mono_p", "explo3_mono", "explo3_bi", "sp_bi_p", "sp_mono_l", "sp_bi_l",
    "brute_force", "exact_min_period", "dp_homogeneous_period", "dp_speed_ordered",
    "pareto_exact", "pareto_front", "tradeoff_curves", "sweep_heuristic",
    "Objective", "StagePlan", "plan", "replan_for_straggler", "InfeasiblePlan",
    "DealPlan", "plan_with_deal",
]
