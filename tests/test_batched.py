"""Batched campaign engine: bit-for-bit equivalence with the per-instance
path, padding/convergence-mask behavior, and the campaign wiring."""

import math

import numpy as np
import pytest

from repro.core import make_platform, make_workload, optimal_latency, period
from repro.core.batched import (batched_fixed_latency, batched_sp_bi_p,
                                batched_trajectories, batched_trajectory_sets,
                                stack_instances)
from repro.core.heuristics import (sp_bi_l, sp_bi_p, sp_mono_l,
                                   split_trajectory)
from repro.core.metrics import single_processor_mapping
from repro.sim import gen_instance_batch
from repro.sim.experiments import (run_campaign, run_experiment,
                                   run_replicated, summarize_experiment,
                                   summarize_replicated)

SEEDS = range(7000, 7006)


def _same_result(a, b):
    return (a.mapping == b.mapping and a.period == b.period
            and a.latency == b.latency and a.feasible == b.feasible
            and a.splits == b.splits)


@pytest.mark.parametrize("exp", ["E1", "E2", "E3", "E4"])
@pytest.mark.parametrize("p", [10, 100])
def test_trajectories_bitwise_equal(exp, p):
    """Batched H1-H4 trajectories == per-instance split_trajectory, EXACTLY
    (float equality, not approx), for every experiment family and both
    paper processor counts."""
    batch = gen_instance_batch(exp, 12, p, SEEDS)
    for code in ("H1", "H2", "H3", "H4"):
        bt = batched_trajectories(code, batch)
        for i, (wl, pf) in enumerate(batch):
            assert bt[i] == split_trajectory(code, wl, pf), (code, i)


def test_trajectory_sets_group_codes():
    """Grouped runs (H1+H4 and H2+H3 share lockstep batches) return the same
    trajectories as separate runs."""
    batch = gen_instance_batch("E2", 15, 10, SEEDS)
    grouped = batched_trajectory_sets(["H1", "H2", "H3", "H4"], batch)
    for code in ("H1", "H2", "H3", "H4"):
        assert grouped[code] == batched_trajectories(code, batch), code


@pytest.mark.parametrize("exp", ["E1", "E2", "E3", "E4"])
@pytest.mark.parametrize("p", [10, 100])
def test_fixed_latency_bitwise_equal(exp, p):
    """Batched H5/H6 == sp_mono_l/sp_bi_l per instance, with per-problem
    bounds spanning infeasible (below L_opt) through exhaustion."""
    batch = gen_instance_batch(exp, 12, p, SEEDS)
    mults = [0.9, 1.0, 1.2, 1.6, 2.2, 3.0]
    bounds = [optimal_latency(wl, pf) * m
              for (wl, pf), m in zip(batch, mults)]
    for code, fn in (("H5", sp_mono_l), ("H6", sp_bi_l)):
        rs = batched_fixed_latency(code, batch, bounds)
        for i, (wl, pf) in enumerate(batch):
            assert _same_result(rs[i], fn(wl, pf, bounds[i])), (code, i)


@pytest.mark.parametrize("exp", ["E2", "E4"])
@pytest.mark.parametrize("p", [10, 100])
def test_h4_binary_search_bitwise_equal(exp, p):
    """The lockstep H4 binary search (all problems probed per bisection step)
    == per-instance sp_bi_p, including infeasible bounds."""
    batch = gen_instance_batch(exp, 10, p, SEEDS)
    fracs = [0.05, 0.2, 0.4, 0.6, 0.8, 1.0]
    bounds = [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
              for (wl, pf), f in zip(batch, fracs)]
    rs = batched_sp_bi_p(batch, bounds, iters=8)
    for i, (wl, pf) in enumerate(batch):
        assert _same_result(rs[i], sp_bi_p(wl, pf, bounds[i], iters=8)), i


def test_padding_mixed_convergence():
    """A batch mixing an instance that converges immediately (no improving
    split: every extra processor is uselessly slow) with one that splits many
    times: per-problem masks must keep trajectories independent and padded
    state must not leak across rows."""
    n = 12
    fast_flat = make_workload([10.0] * n, [0.0] * (n + 1))
    wl2 = make_workload(list(range(1, n + 1)), [5.0] * (n + 1))
    pf_stuck = make_platform([20.0] + [0.001] * 9, b=10.0)   # splitting never helps
    pf_rich = make_platform([20.0, 19.0, 18.0, 17.0, 16.0, 15.0, 14.0, 13.0,
                             12.0, 11.0], b=10.0)
    pairs = [(fast_flat, pf_stuck), (fast_flat, pf_rich), (wl2, pf_stuck),
             (wl2, pf_rich)]
    pb = stack_instances(pairs)
    for code in ("H1", "H2", "H3", "H4"):
        bt = batched_trajectories(code, pb)
        lengths = [len(t) for t in bt]
        # stuck instances record only the initial state; rich ones split
        assert lengths[0] == 1 and lengths[2] == 1, (code, lengths)
        assert lengths[1] > 1 and lengths[3] > 1, (code, lengths)
        for i, (wl, pf) in enumerate(pairs):
            assert bt[i] == split_trajectory(code, wl, pf), (code, i)


def test_stack_instances_validates_shapes():
    wl_a = make_workload([1.0, 2.0], [0.0, 0.0, 0.0])
    wl_b = make_workload([1.0, 2.0, 3.0], [0.0] * 4)
    pf = make_platform([1.0, 2.0], 10.0)
    with pytest.raises(ValueError):
        stack_instances([(wl_a, pf), (wl_b, pf)])
    with pytest.raises(ValueError):
        stack_instances([])


def test_run_experiment_engines_identical():
    """The whole experiment harness (curves + thresholds + feasibility
    fractions) is byte-identical between engines."""
    for exp, n, p in (("E1", 5, 10), ("E2", 10, 10), ("E3", 8, 100)):
        a = run_experiment(exp, n, p, n_pairs=5, n_bounds=5, engine="scalar")
        b = run_experiment(exp, n, p, n_pairs=5, n_bounds=5, engine="batched")
        assert summarize_experiment(a) == summarize_experiment(b), (exp, n, p)


def test_run_campaign_matches_per_exp():
    """Cross-family stacking (the 4 experiment families in one batch) changes
    nothing about per-family results."""
    camp = run_campaign(("E1", "E2", "E3", "E4"), 8, 10, n_pairs=4, n_bounds=4)
    for exp in ("E1", "E2", "E3", "E4"):
        solo = run_experiment(exp, 8, 10, n_pairs=4, n_bounds=4, engine="scalar")
        assert summarize_experiment(solo) == summarize_experiment(camp[exp]), exp


def test_unknown_code_and_engine_raise():
    batch = gen_instance_batch("E1", 5, 5, [1, 2])
    with pytest.raises(KeyError):
        batched_trajectories("H5", batch)
    with pytest.raises(ValueError):
        run_experiment("E1", 5, 5, n_pairs=2, n_bounds=3, engine="bogus")


def test_jax_backend_agrees():
    """The scoring kernels under jax.jit (x64) drive the same splits; with
    the kernels' runtime-zero FMA guard the floats are bit-identical too."""
    jax = pytest.importorskip("jax")
    del jax
    batch = gen_instance_batch("E2", 8, 6, range(3))
    for code in ("H1", "H2", "H3", "H4"):
        a = batched_trajectories(code, batch, backend="numpy")
        b = batched_trajectories(code, batch, backend="jax")
        assert a == b, code


# ---------------------------------------------------------------------------
# Fused device-resident engine (repro.core.fused): the whole lockstep loop
# under one jit'd lax.while_loop, O(1) dispatches per (shape, arity).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("exp", ["E1", "E2", "E3", "E4"])
@pytest.mark.parametrize("p", [10, 100])
def test_fused_trajectories_identical(exp, p):
    """Fused split trajectories == the numpy engine, EXACTLY (same splits AND
    same floats — the FMA guard defeats XLA's contraction drift), for every
    experiment family and both paper processor counts."""
    pytest.importorskip("jax")
    batch = gen_instance_batch(exp, 12, p, SEEDS)
    for code in ("H1", "H2", "H3", "H4"):
        assert (batched_trajectories(code, batch, backend="fused")
                == batched_trajectories(code, batch, backend="numpy")), code


def test_fused_fixed_latency_and_h4_ports():
    """The H4-H6 bound-grid entry points run device-resident too: fused
    batched_fixed_latency / batched_sp_bi_p == the scalar heuristics."""
    pytest.importorskip("jax")
    batch = gen_instance_batch("E2", 10, 10, SEEDS)
    mults = [0.9, 1.0, 1.2, 1.6, 2.2, 3.0]
    lbounds = [optimal_latency(wl, pf) * m for (wl, pf), m in zip(batch, mults)]
    for code, fn in (("H5", sp_mono_l), ("H6", sp_bi_l)):
        rs = batched_fixed_latency(code, batch, lbounds, backend="fused")
        for i, (wl, pf) in enumerate(batch):
            assert _same_result(rs[i], fn(wl, pf, lbounds[i])), (code, i)
    fracs = [0.05, 0.2, 0.4, 0.6, 0.8, 1.0]
    pbounds = [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
               for (wl, pf), f in zip(batch, fracs)]
    rs = batched_sp_bi_p(batch, pbounds, iters=8, backend="fused")
    for i, (wl, pf) in enumerate(batch):
        assert _same_result(rs[i], sp_bi_p(wl, pf, pbounds[i], iters=8)), i


def test_fused_padding_mixed_convergence():
    """Inside the traced loop, converged rows must sit inert (masked) while
    other rows keep splitting: mix an immediately-stuck instance with rich
    ones and require per-row trajectories identical to the scalar path."""
    pytest.importorskip("jax")
    n = 12
    fast_flat = make_workload([10.0] * n, [0.0] * (n + 1))
    wl2 = make_workload(list(range(1, n + 1)), [5.0] * (n + 1))
    pf_stuck = make_platform([20.0] + [0.001] * 9, b=10.0)
    pf_rich = make_platform([20.0, 19.0, 18.0, 17.0, 16.0, 15.0, 14.0, 13.0,
                             12.0, 11.0], b=10.0)
    pairs = [(fast_flat, pf_stuck), (fast_flat, pf_rich), (wl2, pf_stuck),
             (wl2, pf_rich)]
    pb = stack_instances(pairs)
    for code in ("H1", "H2", "H3", "H4"):
        bt = batched_trajectories(code, pb, backend="fused")
        lengths = [len(t) for t in bt]
        assert lengths[0] == 1 and lengths[2] == 1, (code, lengths)
        assert lengths[1] > 1 and lengths[3] > 1, (code, lengths)
        for i, (wl, pf) in enumerate(pairs):
            assert bt[i] == split_trajectory(code, wl, pf), (code, i)


def test_fused_large_grid_smoke():
    """The large-grid follow-up shape (n=80, p=1000) completes under the
    fused engine and matches the numpy engine exactly."""
    pytest.importorskip("jax")
    batch = gen_instance_batch("E3", 80, 1000, range(2))
    got = batched_trajectory_sets(["H1", "H4"], batch, backend="fused")
    ref = batched_trajectory_sets(["H1", "H4"], batch, backend="numpy")
    assert got == ref
    assert all(len(t) > 1 for t in got["H1"])


def test_fused_trace_count_per_campaign():
    """The O(1)-dispatch contract: a whole campaign (trajectories for H1-H4,
    the lockstep H4 bisection, H5/H6 over the bound grid) compiles at most 2
    fused-loop traces — one per split arity — and a rerun of the same shapes
    compiles none."""
    pytest.importorskip("jax")
    from repro.core import fused

    # a shape no other test uses, so the lru-cached loops are cold
    kw = dict(n_pairs=3, n_bounds=5, h4_iters=4, include_h4=True)
    fused.reset_trace_count()
    camp = run_campaign(("E1", "E2"), 9, 7, backend="fused", **kw)
    assert fused.trace_count() <= 2
    fused.reset_trace_count()
    camp2 = run_campaign(("E1", "E2"), 9, 7, backend="fused", **kw)
    assert fused.trace_count() == 0  # warm: dispatches only, no re-trace
    for exp in ("E1", "E2"):
        assert summarize_experiment(camp[exp]) == summarize_experiment(camp2[exp])
        solo = run_experiment(exp, 9, 7, engine="scalar", **kw)
        assert summarize_experiment(solo) == summarize_experiment(camp[exp]), exp


def test_fused_campaign_engine_byte_identical():
    """run_experiment(engine='fused') reproduces the scalar harness output
    byte-for-byte, including curves, thresholds, and feasibility fractions."""
    pytest.importorskip("jax")
    a = run_experiment("E4", 10, 10, n_pairs=5, n_bounds=5, engine="scalar")
    b = run_experiment("E4", 10, 10, n_pairs=5, n_bounds=5, engine="fused")
    assert summarize_experiment(a) == summarize_experiment(b)


def test_replicated_campaign_cis():
    """run_replicated: bank 0 equals the plain campaign; CI half-widths are
    finite where every replication has feasible points; engines agree."""
    rep, first = run_replicated(("E2",), 8, 10, n_pairs=3, replications=4,
                                n_bounds=4)
    camp = run_campaign(("E2",), 8, 10, n_pairs=3, n_bounds=4)
    assert summarize_experiment(first["E2"]) == summarize_experiment(camp["E2"])
    r = rep["E2"]
    assert r.replications == 4
    mean_per, ci_per, mean_lat, ci_lat, frac = r.curves["H5"]
    sel = frac == 1.0
    assert np.isfinite(mean_per[sel]).all() and np.isfinite(ci_per[sel]).all()
    assert (ci_per[sel] >= 0).all() and (ci_lat[sel] >= 0).all()
    m, ci = r.thresholds["H1"]
    assert np.isfinite(m) and np.isfinite(ci) and ci >= 0
    text = summarize_replicated(r)
    assert "period_ci95" in text and "threshold_ci95" in text
    repf, _ = run_replicated(("E2",), 8, 10, n_pairs=3, replications=4,
                             n_bounds=4, engine="fused")
    assert summarize_replicated(repf["E2"]) == text
