"""Assigned architectures (10) + the paper's own pipeline config.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` return ModelConfigs;
``cells()`` enumerates the assigned (arch x shape) grid with applicability
(long_500k needs sub-quadratic attention; see DESIGN.md §Shape-cell skips).
"""

from __future__ import annotations

from ..models.common import SHAPES, ModelConfig, ShapeSpec
from . import (arctic_480b, internvl2_26b, mixtral_8x7b, qwen15_110b,
               qwen25_14b, qwen3_4b, stablelm_12b, whisper_large_v3,
               xlstm_350m, zamba2_7b)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "qwen2.5-14b": qwen25_14b,
    "qwen3-4b": qwen3_4b,
    "qwen1.5-110b": qwen15_110b,
    "stablelm-12b": stablelm_12b,
    "arctic-480b": arctic_480b,
    "mixtral-8x7b": mixtral_8x7b,
    "xlstm-350m": xlstm_350m,
    "internvl2-26b": internvl2_26b,
    "whisper-large-v3": whisper_large_v3,
}

ARCH_IDS = tuple(_MODULES)

# Archs with sub-quadratic attention state growth (eligible for long_500k):
# hybrid (SSM + bounded attn), xlstm (recurrent), mixtral (sliding window).
LONG_CONTEXT_OK = {"zamba2-7b", "xlstm-350m", "mixtral-8x7b"}


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].full()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke()


def supports(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_OK
    return True


def cells(include_skipped: bool = False):
    """Yield (arch_id, ShapeSpec[, skipped]) for the assigned 10x4 grid."""
    for a in ARCH_IDS:
        for sname, sspec in SHAPES.items():
            ok = supports(a, sname)
            if include_skipped:
                yield a, sspec, not ok
            elif ok:
                yield a, sspec
