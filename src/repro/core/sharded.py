"""Multi-device sharded campaign engine: the fused loop under ``shard_map``.

The fused engine (:mod:`repro.core.fused`) runs the entire H1–H6 lockstep
splitting loop as one jitted ``lax.while_loop`` — O(1) host dispatches — but
on a single device.  A campaign over a replication study (seed banks x
families x bound grids) is embarrassingly parallel across stacked instances,
so this module shards the INSTANCE axis of that same loop across every
available device via ``jax.sharding.Mesh`` + ``shard_map``: one SPMD program
where each device runs the identical fused loop over its local rows.

Design:

  - The traced program is literally ``fused._build_loop``'s loop, wrapped in
    ``shard_map`` over a 1-D device mesh along the row axis.  No collectives
    are needed: rows never interact, and the only cross-row expressions in
    the loop — the bucket-routing ``max(need)`` and the ``active.any()``
    exit test — are intentionally evaluated PER SHARD.  Bucket choice cannot
    change results (every bucket covering a row's span scores the same valid
    lanes, and tie-break keys use absolute positions — see fused.py), so a
    shard routing to a smaller bucket than its neighbors is pure savings,
    and a shard whose rows all converge simply exits its while-loop early.
  - Batches are padded to a device multiple with INERT rows: padding rows
    replicate row 0's instance data but start inactive (``active0=False``),
    so ``live`` is False for them in every iteration, they accept no splits,
    and their state is discarded on write-back — the same trick the fused
    engine already uses for its row-chunk padding (property-tested in
    tests/test_engine_properties.py).
  - Per-device rows-per-dispatch reuses :func:`fused.chunk_rows`, so the
    per-shard lane budget matches the single-device engine and the global
    chunk is ``chunk_rows(n, k) * num_devices``.

Equivalence contract: bit-identical (``==``, not approx) to
``backend="fused"`` — and therefore to the numpy/scalar reference — because
each row's floats are produced by the exact same traced expressions on
per-row data, with the same FMA guard and left-associated reductions; the
device mesh only changes WHERE a row is computed, never what is computed.
Asserted across the full differential harness by
tests/test_engine_equivalence.py and on multi-device meshes by the CI job
running under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Use via ``backend="sharded"`` on any :mod:`repro.core.batched` entry point,
``engine="sharded"`` in ``repro.sim.experiments``, or
``ReplanService(backend="sharded")`` in the fleet layer.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import numpy as np

from . import fused
from .fused import chunk_rows

__all__ = ["sharded_available", "device_count", "run_sharded",
           "run_sharded_bisection", "trace_count", "reset_trace_count",
           "dispatch_count", "reset_dispatch_count"]

# traces / dispatches of the SPMD programs, mirroring fused.py's counters
# (the shared bucket branches still count into fused._BUCKET_TRACES).
_TRACES = [0]
_DISPATCHES = [0]


def trace_count() -> int:
    """Traces of the sharded SPMD programs since the last reset."""
    return _TRACES[0]


def reset_trace_count() -> None:
    _TRACES[0] = 0


def dispatch_count() -> int:
    """SPMD-program dispatches since the last reset — one per global
    row-chunk, independent of device count (the O(1)-dispatch contract
    carries over from the fused engine)."""
    return _DISPATCHES[0]


def reset_dispatch_count() -> None:
    _DISPATCHES[0] = 0


def sharded_available() -> bool:
    try:
        import jax  # noqa: F401
        from jax.experimental.shard_map import shard_map  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return False
    return True


def device_count() -> int:
    """Devices in the default mesh (respects
    ``--xla_force_host_platform_device_count`` on CPU)."""
    import jax

    return len(jax.devices())


@functools.lru_cache(maxsize=None)
def _mesh():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("i",))


def _shard_wrap(fn: Callable, n_state_out: int, mesh) -> Callable:
    """Wrap an unjitted per-shard program in ``shard_map`` over the row axis.

    ``fn(*args) -> (*state..., per_rec, lat_rec, acc_rec, t)`` where the
    state outputs are row-leading, the records are (T, S_local), and ``t``
    is a per-shard scalar.  Scalar inputs (0-d) are replicated; every other
    input is sharded along its leading axis.  The per-shard iteration count
    comes back broadcast per-row so the host can take the global max.
    """
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    row = P("i")
    rec = P(None, "i")

    def local(*args):
        out = fn(*args)
        state, trecs, t = out[:n_state_out], out[n_state_out:-1], out[-1]
        t_rows = jnp.full((state[0].shape[0],), t, dtype=jnp.int64)
        return (*state, *trecs, t_rows)

    def specs_for(args):
        return tuple(P() if np.ndim(a) == 0 else row for a in args)

    def wrapped(*args):
        _TRACES[0] += 1  # Python-executes only while tracing
        body = shard_map(local, mesh=mesh, in_specs=specs_for(args),
                         out_specs=(row,) * n_state_out + (rec,) * 3 + (row,),
                         check_rep=False)
        return body(*args)

    return wrapped


@functools.lru_cache(maxsize=None)
def _get_sharded_loop(n: int, p: int, k: int, T: int, S_local: int) -> Callable:
    """The jitted SPMD fused loop for static shape (n, p, k): per-shard rows
    ``S_local``, global rows ``S_local * device_count()``.  SoA state buffers
    donated, exactly like ``fused._get_loop``."""
    import jax

    _init_state, loop = fused._build_loop(n, p, k, T, S_local)
    wrapped = _shard_wrap(loop, n_state_out=5, mesh=_mesh())
    return jax.jit(wrapped, donate_argnums=(10, 11, 12, 13, 14))


@functools.lru_cache(maxsize=None)
def _get_sharded_bisect(n: int, p: int, T: int, S_local: int,
                        iters: int) -> Callable:
    """The jitted SPMD H4 bisection (probe0 + ``lax.scan``) — the per-shard
    program is ``fused._build_bisect``'s, sharded over the row axis."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    fn = fused._build_bisect(n, p, T, S_local, iters)
    mesh = _mesh()
    row = P("i")

    def wrapped(*args):
        _TRACES[0] += 1  # Python-executes only while tracing
        in_specs = tuple(P() if np.ndim(a) == 0 else row for a in args)
        body = shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=(row,) * 11, check_rep=False)
        return body(*args)

    return jax.jit(wrapped)


def run_sharded(state, k: int, bi_mode: np.ndarray, stop: np.ndarray,
                lat_limit: np.ndarray, record: Optional[Callable] = None) -> None:
    """Run the fused loop over ``state`` (a ``batched._BatchState``) as one
    SPMD program per global row-chunk, sharded across all devices.  Drop-in
    replacement for :func:`fused.run_fused` — same write-back, same record
    replay, bit-identical floats on any device count.
    """
    pb = state.pb
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0 or not state.active.any():
        state.active[:] = False
        return
    D = device_count()
    S_local = chunk_rows(n, k)
    S = S_local * D
    fn = _get_sharded_loop(n, p, k, T, S_local)
    b = np.float64(pb.b)
    bi_mode = np.asarray(bi_mode, dtype=bool)
    stop = np.asarray(stop, dtype=np.float64)
    lat_limit = np.asarray(lat_limit, dtype=np.float64)
    chunks = []  # (rows, per_rec, lat_rec, acc_rec, t_used)
    for lo in range(0, B, S):
        rows = np.arange(lo, min(lo + S, B))
        pad = S - rows.size
        # padding rows carry row 0's instance data but start INACTIVE, so
        # they are live in no iteration and their state is never written back
        sel = np.concatenate([rows, np.zeros(pad, dtype=np.int64)]) if pad else rows
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = state.active[rows]
        _DISPATCHES[0] += 1
        # the SoA state slices are fresh fancy-index copies, safe to donate
        out = fn(pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), bi_mode[sel],
                 stop[sel], lat_limit[sel], act,
                 state.arr[sel], state.m[sel], state.next_idx[sel],
                 state.lat_sum[sel], state.splits[sel])
        (arr, m, next_idx, lat_sum, splits,
         per_rec, lat_rec, acc_rec, t_rows) = (np.asarray(o) for o in out)
        r = rows.size
        state.arr[rows] = arr[:r]
        state.m[rows] = m[:r]
        state.next_idx[rows] = next_idx[:r]
        state.lat_sum[rows] = lat_sum[:r]
        state.splits[rows] = splits[:r]
        state.active[rows] = False
        if record is not None:
            chunks.append((rows, per_rec[:, :r], lat_rec[:, :r],
                           acc_rec[:, :r], int(t_rows.max())))
    if record is None:
        return
    # Replay records in global lockstep order (a row's s-th accepted split
    # lands at iteration s on every shard — see fused.run_fused).
    t_max = max((t for *_, t in chunks), default=0)
    for t in range(t_max):
        rsel, pers, lats = [], [], []
        for rows, per_rec, lat_rec, acc_rec, t_used in chunks:
            if t >= t_used:
                continue
            a = acc_rec[t]
            if a.any():
                rsel.append(rows[a])
                pers.append(per_rec[t][a])
                lats.append(lat_rec[t][a])
        if rsel:
            record(np.concatenate(rsel), np.concatenate(pers),
                   np.concatenate(lats))


def run_sharded_bisection(pb, p_fix: np.ndarray, lo: np.ndarray,
                          hi: np.ndarray, iters: int) -> dict:
    """The fused H4 binary search (probe0 + ``lax.scan``) as one SPMD
    program per global row-chunk — :func:`fused.run_fused_bisection` sharded
    across the device mesh, same outputs bit-for-bit."""
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0:
        raise ValueError("unsplittable shape: caller should use the host path")
    D = device_count()
    S_local = chunk_rows(n, 1)
    S = S_local * D
    fn = _get_sharded_bisect(n, p, T, S_local, int(iters))
    b = np.float64(pb.b)
    p_fix = np.asarray(p_fix, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out = {
        "items0": np.zeros((B, n, 3)), "m0": np.zeros(B, dtype=np.int64),
        "sp0": np.zeros(B, dtype=np.int64), "per0": np.zeros(B),
        "lat0": np.zeros(B), "feas0": np.zeros(B, dtype=bool),
        "items": np.zeros((B, n, 3)), "m": np.zeros(B, dtype=np.int64),
        "sp": np.zeros(B, dtype=np.int64), "per": np.zeros(B),
        "lat": np.zeros(B),
    }
    names = ("items0", "m0", "sp0", "per0", "lat0", "feas0",
             "items", "m", "sp", "per", "lat")
    for lo_i in range(0, B, S):
        rows = np.arange(lo_i, min(lo_i + S, B))
        pad = S - rows.size
        sel = (np.concatenate([rows, np.zeros(pad, dtype=np.int64)])
               if pad else rows)
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = True
        _DISPATCHES[0] += 1
        res = fn(pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), p_fix[sel],
                 lo[sel], hi[sel], act)
        for name, val in zip(names, res):
            out[name][rows] = np.asarray(val)[:rows.size]
    return out
