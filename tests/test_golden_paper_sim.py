"""Golden-file regression: the paper_sim CSV pipeline, byte-for-byte.

A small-grid run of the REAL ``benchmarks/paper_sim.run()`` pipeline (all
eight scenario families, n=5, p=10, 3 pairs) is checked in under
``tests/golden/paper_sim/``; every engine must reproduce those files
byte-identically.  Any CSV schema change, tie-break drift, generator stream
change, or cross-engine divergence fails tier-1 here instead of only
surfacing in CI artifact diffs.

Regenerate (after an INTENTIONAL output change — state it in the PR):

    PYTHONPATH=src:benchmarks python - <<'EOF'
    import pathlib, paper_sim
    paper_sim.run(out_dir=pathlib.Path("tests/golden/paper_sim"),
                  engine="scalar", families="all", ns=(5,), ps=(10,),
                  n_pairs=3, n_bounds=4)
    EOF
"""

import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "golden" / "paper_sim"

sys.path.insert(0, str(REPO / "benchmarks"))
import paper_sim  # noqa: E402


def _engines():
    engines = ["scalar", "batched"]
    try:
        import jax  # noqa: F401
        engines.append("fused")
    except Exception:  # pragma: no cover - jax is baked into the image
        pass
    return engines


@pytest.mark.parametrize("engine", _engines())
def test_paper_sim_csvs_match_golden(engine, tmp_path):
    out = tmp_path / engine
    res = paper_sim.run(out_dir=out, engine=engine, families="all",
                        ns=(5,), ps=(10,), n_pairs=3, n_bounds=4)
    assert all(c.startswith("[PASS]") for c in res["claims"]), res["claims"]
    golden_files = sorted(f.name for f in GOLDEN.iterdir())
    assert golden_files, "golden set missing"
    got_files = sorted(f.name for f in out.iterdir())
    assert got_files == golden_files
    for name in golden_files:
        assert (out / name).read_bytes() == (GOLDEN / name).read_bytes(), \
            (engine, name)
