"""Serving driver: continuous-batching decode loop.

A request pool feeds a fixed-width decode batch; finished sequences free
their slot for the next request (continuous batching).  Prefill runs per
request (chunked into the batch), decode is a single fused ``serve_step``.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \\
        --requests 16 --batch 4 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import Objective, PlanRequest, plan_request, tpu_pod_platform
from ..models import get_model
from ..models.transformer import prefill as tf_prefill


def plan_serving(arch: str, pods: int, smoke: bool = True,
                 shape_name: str = "decode_32k") -> dict:
    """Plan the pipeline placement of ``arch`` over ``pods`` pods via the
    solver-registry portfolio; returns a JSON-able digest of the PlanReport
    (chosen mapping + per-solver provenance)."""
    from ..models.common import SHAPES
    from ..models.registry import lm_workload

    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    wl = lm_workload(cfg, SHAPES[shape_name])
    pf = tpu_pod_platform(pods)
    report = plan_request(PlanRequest(wl, pf, Objective("period")))
    digest = {
        "feasible": report.feasible,
        "pareto": [list(pt) for pt in report.pareto],
        "candidates": [
            {"solver": c.solver, "period": c.period, "latency": c.latency,
             "feasible": c.feasible, "wall_ms": c.wall_time * 1e3,
             **({"error": c.error} if c.error else {})}
            for c in report.candidates
        ],
    }
    if report.feasible:
        digest.update(
            planner=report.plan.planner,
            stage_sizes=list(report.plan.stage_sizes),
            pods=[int(u) for u in report.plan.mapping.alloc],
            period=report.plan.period,
            latency=report.plan.latency,
        )
    return digest


def sample_tokens(logits: np.ndarray, rng: Optional[np.random.Generator] = None,
                  greedy: bool = True, temperature: float = 1.0) -> np.ndarray:
    """Next-token choice for a (B, V) logit batch.

    Greedy (or ``temperature <= 0``) takes the argmax.  Otherwise Gumbel-max
    sampling from the seeded generator: ``argmax(logits/T + Gumbel)`` draws
    exactly from ``softmax(logits/T)`` without materializing the softmax.
    """
    if greedy or temperature <= 0:
        return logits.argmax(-1)
    if rng is None:
        raise ValueError("sampling needs a seeded Generator")
    return (logits / temperature + rng.gumbel(size=logits.shape)).argmax(-1)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    generated: Optional[List[int]] = None
    done: bool = False


def serve_pool(arch: str = "qwen3-4b", smoke: bool = True, n_requests: int = 16,
               batch: int = 4, prompt_len: int = 16, max_new: int = 32,
               capacity: int = 128, seed: int = 0, greedy: bool = True,
               temperature: float = 1.0, pods: int = 0, replan: bool = False,
               replan_every: int = 8, inject_straggler: float = 0.0) -> dict:
    """Run a request pool to completion; returns throughput metrics.

    With ``pods > 0`` the metrics include a ``plan`` digest: the pipeline
    placement of the served model across that many pods, computed through the
    PlanRequest portfolio (provenance included).  With ``replan`` the fleet
    service (:mod:`repro.fleet`) shadows the decode loop: every
    ``replan_every`` steps the measured step time feeds a ``StageTimings``
    event (``inject_straggler`` > 1 additionally slows stage 0 — a
    deterministic straggler for smoke tests) and the service republishes the
    placement when the EWMA flags drift."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    reqs = [Request(i, rng.integers(1, cfg.vocab_size, prompt_len).astype(np.int32),
                    max_new, []) for i in range(n_requests)]

    decode = jax.jit(api.decode, donate_argnums=(1,))

    state = api.init_decode_state(batch, capacity)
    slots: List[Optional[Request]] = [None] * batch
    slot_steps = np.zeros(batch, np.int32)
    cur_tokens = np.zeros((batch, 1), np.int32)
    queue = list(reqs)
    sample_rng = np.random.default_rng(seed + 1)

    fleet = None
    if replan and pods > 0:
        from ..core import interval_cycle_times
        from ..fleet import ReplanService, StageTimings
        from ..models.common import SHAPES
        from ..models.registry import lm_workload

        wl = lm_workload(cfg, SHAPES["decode_32k"])
        fleet = ReplanService([(wl, tpu_pod_platform(pods))])
        replans = 0
        baseline_wall = None
        window: List[float] = []

    t0 = time.time()
    tokens_out = 0
    steps = 0

    def admit(state):
        """Fill free slots: run the prompt through decode steps (prefill-as-
        decode keeps the driver model-agnostic across cache/SSM states)."""
        nonlocal cur_tokens
        for s in range(batch):
            if slots[s] is None and queue:
                r = queue.pop(0)
                slots[s] = r
                slot_steps[s] = 0
                # feed the prompt token by token into this slot
                for t in r.prompt[:-1]:
                    tok = cur_tokens.copy()
                    tok[s, 0] = t
                    cur_tokens = tok
                    _, state = decode(params, state, jnp.asarray(cur_tokens))
                cur_tokens[s, 0] = r.prompt[-1]
        return state

    state = admit(state)
    while any(slots) or queue:
        ts = time.perf_counter()
        logits, state = decode(params, state, jnp.asarray(cur_tokens))
        steps += 1
        logits_np = np.asarray(logits[:, 0], np.float32)
        if fleet is not None:
            window.append(time.perf_counter() - ts)
            if len(window) == replan_every:
                mean_wall = float(np.mean(window))
                window.clear()
                if baseline_wall is None:
                    baseline_wall = mean_wall     # warmup window sets the norm
                else:
                    # the fastest window seen is the platform's true speed;
                    # measuring against it keeps the drift ratio robust to a
                    # slow warmup window (compile tails)
                    baseline_wall = min(baseline_wall, mean_wall)
                    st = fleet.states[0]
                    predicted = interval_cycle_times(st.workload, st.platform,
                                                     st.plan.mapping)
                    observed = predicted * (mean_wall / baseline_wall)
                    if inject_straggler > 1.0:
                        observed[0] *= inject_straggler
                    replans += len(fleet.tick([StageTimings(0, tuple(observed))]))
        nxt = sample_tokens(logits_np, sample_rng, greedy, temperature)
        for s in range(batch):
            r = slots[s]
            if r is None:
                continue
            tok = int(nxt[s])
            r.generated.append(tok)
            tokens_out += 1
            slot_steps[s] += 1
            cur_tokens[s, 0] = tok
            if slot_steps[s] >= r.max_new:
                r.done = True
                slots[s] = None
        if any(sl is None for sl in slots) and queue:
            state = admit(state)

    dt = time.time() - t0
    out = {
        "requests": n_requests,
        "decode_steps": steps,
        "tokens_generated": tokens_out,
        "tokens_per_s": tokens_out / max(dt, 1e-9),
        "wall_s": dt,
        "all_done": all(r.done for r in reqs),
    }
    if pods > 0:
        out["plan"] = plan_serving(arch, pods, smoke=smoke)
    if fleet is not None:
        fplan = fleet.states[0].plan
        out["replan"] = {
            "replans": replans,
            "stage_sizes": list(fplan.stage_sizes),
            "pods": [int(u) for u in fplan.mapping.alloc],
            "period": fplan.period,
            "metrics": fleet.metrics.summary(),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy decode")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--pods", type=int, default=0,
                    help="also plan pipeline placement over this many pods")
    ap.add_argument("--replan", action="store_true",
                    help="drive the fleet replanning service from live "
                         "decode-step timings (needs --pods)")
    ap.add_argument("--replan-every", type=int, default=8)
    ap.add_argument("--inject-straggler", type=float, default=0.0,
                    help="slow stage 0 by this factor after warmup "
                         "(deterministic straggler for smoke tests)")
    args = ap.parse_args()
    out = serve_pool(arch=args.arch, smoke=args.smoke, n_requests=args.requests,
                     batch=args.batch, prompt_len=args.prompt_len,
                     max_new=args.max_new, seed=args.seed,
                     greedy=not args.sample, temperature=args.temperature,
                     pods=args.pods, replan=args.replan,
                     replan_every=args.replan_every,
                     inject_straggler=args.inject_straggler)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
