"""Exact and strong-baseline solvers for the mapping problem.

The period-minimization problem is NP-hard (paper Theorem 2), so exact solvers
are exponential in ``p`` — they exist to measure heuristic optimality gaps on
small/medium instances and to power property tests.

 - ``brute_force``          : full enumeration, tiny instances (n<=10, p<=6).
 - ``exact_min_period``     : binary search on K + interval/bitmask DP; exact,
                              practical to p ~ 14, any n (O(2^p n^2) feasibility).
 - ``dp_homogeneous_period``: exact O(n^2 p) DP when all speeds are equal
                              (the classic chains-to-chains with comm terms).
 - ``dp_speed_ordered``     : beyond-paper baseline — exact *under the
                              constraint* that faster processors take earlier
                              intervals; polynomial O(n^2 p^2).
 - ``pareto_exact``         : exact bi-criteria Pareto front, tiny instances.
"""

from __future__ import annotations

import itertools
import math
from typing import Optional

import numpy as np

from .metrics import Mapping, all_interval_partitions, latency
from .platform import Platform
from .workload import Workload


def _cycle_table(workload: Workload, platform: Platform) -> np.ndarray:
    """cyc[d-1, e-1, u] = cycle time of interval [d,e] on processor u."""
    n, p = workload.n, platform.p
    pre = workload.prefix_w()
    cyc = np.full((n, n, p), np.inf)
    for d in range(1, n + 1):
        for e in range(d, n + 1):
            wsum = pre[e] - pre[d - 1]
            comm = workload.delta[d - 1] / platform.b + workload.delta[e] / platform.b
            cyc[d - 1, e - 1, :] = comm + wsum / platform.s
    return cyc


def _latency_table(workload: Workload, platform: Platform,
                   cyc: np.ndarray) -> np.ndarray:
    """lat[d-1, e-1, u] = interval [d,e]'s Eq. (2) term on processor u
    (input comm + compute; the final-output term is added by callers).
    Derived from the cycle table: the cycle just adds the output comm."""
    return cyc - (workload.delta[1:] / platform.b)[None, :, None]


def _enumerated_metrics(workload: Workload, platform: Platform, m: int,
                        cyc_t: np.ndarray, lat_t: np.ndarray) -> tuple:
    """Stack every (partition into m intervals, distinct-processor assignment)
    and evaluate them all at once: returns (parts (C,m,2), procs (P,m),
    per (C,P), lat (C,P)).  Row-major (partition-major) order matches the
    nested loops of the scalar enumeration, so stable argmins agree."""
    n, p = workload.n, platform.p
    parts = np.array(list(all_interval_partitions(n, m)), dtype=np.intp)
    procs = np.array(list(itertools.permutations(range(p), m)), dtype=np.intp)
    if parts.ndim == 2:            # m == 1: (C, 2) -> (C, 1, 2)
        parts = parts[:, None, :]
    D = parts[:, None, :, 0] - 1
    E = parts[:, None, :, 1] - 1
    U = procs[None, :, :]
    per = cyc_t[D, E, U].max(axis=-1)
    lat = lat_t[D, E, U].sum(axis=-1) + workload.delta[n] / platform.b
    return parts, procs, per, lat


# ---------------------------------------------------------------------------
# Brute force (tiny)
# ---------------------------------------------------------------------------

def brute_force(
    workload: Workload,
    platform: Platform,
    *,
    period_cap: float = math.inf,
    latency_cap: float = math.inf,
    objective: str = "period",
) -> Optional[Mapping]:
    """Enumerate all (partition, distinct-processor assignment); return the best
    mapping under the caps, minimizing ``objective`` ('period' or 'latency'),
    breaking ties on the other criterion.  None if infeasible.

    The enumeration is evaluated in stacked numpy batches (one per interval
    count) rather than per-mapping Python loops; tie-breaking order is
    identical to the scalar enumeration."""
    n, p = workload.n, platform.p
    cyc_t = _cycle_table(workload, platform)
    lat_t = _latency_table(workload, platform, cyc_t)
    best: Optional[Mapping] = None
    best_key = (math.inf, math.inf)
    for m in range(1, min(n, p) + 1):
        parts, procs, per, lat = _enumerated_metrics(workload, platform, m, cyc_t, lat_t)
        ok = (per <= period_cap + 1e-12) & (lat <= latency_cap + 1e-12)
        if not ok.any():
            continue
        a, c = (per, lat) if objective == "period" else (lat, per)
        a = np.where(ok, a, np.inf).ravel()
        c = np.where(ok, c, np.inf).ravel()
        first = np.lexsort((c, a))[0]
        key = (float(a[first]), float(c[first]))
        if key < best_key:
            ci, pi = divmod(int(first), procs.shape[0])
            best = Mapping(tuple(map(tuple, parts[ci])), tuple(int(u) for u in procs[pi]))
            best_key = key
    return best


def pareto_exact(workload: Workload, platform: Platform) -> list:
    """All Pareto-optimal (period, latency) points over every mapping (tiny
    instances).  Candidate evaluation is fully vectorized over the stacked
    enumeration."""
    n, p = workload.n, platform.p
    cyc_t = _cycle_table(workload, platform)
    lat_t = _latency_table(workload, platform, cyc_t)
    pts = []
    for m in range(1, min(n, p) + 1):
        _, _, per, lat = _enumerated_metrics(workload, platform, m, cyc_t, lat_t)
        pts.append(np.stack([per.ravel(), lat.ravel()], axis=1))
    from .pareto import pareto_front

    return pareto_front(np.concatenate(pts))


# ---------------------------------------------------------------------------
# Exact min-period via threshold search + bitmask feasibility
# ---------------------------------------------------------------------------

def _feasible(cyc: np.ndarray, n: int, p: int, K: float) -> Optional[list]:
    """Is there a partition + distinct assignment with every cycle <= K?
    DP over (stages consumed, frozenset of used processors) — memoized on
    (e, mask).  Returns the item list [(d,e,u)] or None.

    ok[d-1, e-1, u] = cyc[d,e,u] <= K.  f(e, mask): stages 1..e assignable
    using exactly the processors in mask.
    """
    ok = cyc <= K + 1e-12
    # f[e] = set of masks achievable covering stages 1..e. Use dict e -> set(masks).
    from functools import lru_cache

    procs = range(p)

    @lru_cache(maxsize=None)
    def f(e: int, mask: int) -> Optional[tuple]:
        if e == 0:
            return () if mask == 0 else None
        for u in procs:
            if not (mask >> u) & 1:
                continue
            sub = mask & ~(1 << u)
            for d in range(1, e + 1):
                if ok[d - 1, e - 1, u] and (res := f(d - 1, sub)) is not None:
                    return res + ((d, e, u),)
        return None

    for m in range(1, min(n, p) + 1):
        for combo in itertools.combinations(procs, m):
            mask = sum(1 << u for u in combo)
            if (res := f(n, mask)) is not None:
                return list(res)
    return None


def exact_min_period(
    workload: Workload, platform: Platform, latency_cap: float = math.inf
) -> Optional[Mapping]:
    """Exact minimum-period mapping via binary search over the O(n^2 p) candidate
    cycle values + bitmask feasibility DP.  With ``latency_cap`` the feasibility
    check additionally verifies the latency (making it exact for the bi-criteria
    problem at a given latency bound, at extra cost)."""
    n, p = workload.n, platform.p
    cyc = _cycle_table(workload, platform)
    cands = np.unique(cyc[np.isfinite(cyc)])
    # keep only values achievable as some interval cycle
    mask_valid = np.zeros_like(cyc, dtype=bool)
    for d in range(1, n + 1):
        mask_valid[d - 1, d - 1 :, :] = True
    cands = np.unique(cyc[mask_valid])

    def try_K(K: float) -> Optional[Mapping]:
        items = _feasible(cyc, n, p, K)
        if items is None:
            return None
        mp = Mapping(tuple((d, e) for d, e, _ in items), tuple(u for _, _, u in items))
        if latency(workload, platform, mp) > latency_cap + 1e-12:
            return _feasible_with_latency(cyc, workload, platform, K, latency_cap)
        return mp

    lo, hi = 0, len(cands) - 1
    if try_K(cands[hi]) is None:
        return None
    best = None
    while lo <= hi:
        mid = (lo + hi) // 2
        mp = try_K(float(cands[mid]))
        if mp is not None:
            best = mp
            hi = mid - 1
        else:
            lo = mid + 1
    return best


def _feasible_with_latency(
    cyc: np.ndarray, workload: Workload, platform: Platform, K: float, latency_cap: float
) -> Optional[Mapping]:
    """Feasibility under both cycle<=K and total latency <= cap: DP minimizing
    latency over (e, mask).  Exponential in p; used only when a latency cap is set."""
    n, p = workload.n, platform.p
    ok = cyc <= K + 1e-12
    pre = workload.prefix_w()
    b = platform.b
    from functools import lru_cache

    @lru_cache(maxsize=None)
    def g(e: int, mask: int):
        """min latency contribution for stages 1..e using processor set = mask."""
        if e == 0:
            return (0.0, ()) if mask == 0 else (math.inf, None)
        best = (math.inf, None)
        for u in range(p):
            if not (mask >> u) & 1:
                continue
            sub = mask & ~(1 << u)
            for d in range(1, e + 1):
                if not ok[d - 1, e - 1, u]:
                    continue
                prev_cost, prev_items = g(d - 1, sub)
                if prev_items is None:
                    continue
                cost = prev_cost + workload.delta[d - 1] / b + (pre[e] - pre[d - 1]) / platform.s[u]
                if cost < best[0]:
                    best = (cost, prev_items + ((d, e, u),))
        return best

    tail = workload.delta[n] / b
    overall = (math.inf, None)
    for m in range(1, min(n, p) + 1):
        for combo in itertools.combinations(range(p), m):
            mask = sum(1 << u for u in combo)
            cost, items = g(n, mask)
            if items is not None and cost + tail <= latency_cap + 1e-12 and cost < overall[0]:
                overall = (cost, items)
    if overall[1] is None:
        return None
    items = overall[1]
    return Mapping(tuple((d, e) for d, e, _ in items), tuple(u for _, _, u in items))


def exact_min_latency(
    workload: Workload, platform: Platform, period_cap: float = math.inf
) -> Optional[Mapping]:
    """Exact minimum-latency mapping subject to ``period <= period_cap``.

    DP over (stages consumed, processor mask) minimizing the Eq. (2) sum with
    every interval cycle <= the cap — the same machinery as the latency-capped
    feasibility check of :func:`exact_min_period`, with the roles of the two
    criteria swapped.  Exponential in p; None when the cap is infeasible.
    Without a cap this reduces to Lemma 1 (whole chain on the fastest
    processor)."""
    cyc = _cycle_table(workload, platform)
    return _feasible_with_latency(cyc, workload, platform, float(period_cap), math.inf)


# ---------------------------------------------------------------------------
# Polynomial DPs
# ---------------------------------------------------------------------------

def dp_homogeneous_period(workload: Workload, p: int, s: float, b: float) -> tuple:
    """Exact min period for identical processors (chains-to-chains with comms).
    Returns (period, intervals).  O(n^2 p)."""
    n = workload.n
    pre = workload.prefix_w()

    def cyc(d, e):
        return workload.delta[d - 1] / b + (pre[e] - pre[d - 1]) / s + workload.delta[e] / b

    INF = math.inf
    # f[k][e] = min over partitions of 1..e into k intervals of max cycle
    f = [[INF] * (n + 1) for _ in range(p + 1)]
    cut = [[-1] * (n + 1) for _ in range(p + 1)]
    f[0][0] = 0.0
    for k in range(1, p + 1):
        for e in range(1, n + 1):
            for d in range(1, e + 1):
                v = max(f[k - 1][d - 1], cyc(d, e))
                if v < f[k][e]:
                    f[k][e] = v
                    cut[k][e] = d
    best_k = min(range(1, p + 1), key=lambda k: f[k][n])
    # backtrack
    intervals = []
    e, k = n, best_k
    while e > 0:
        d = cut[k][e]
        intervals.append((d, e))
        e, k = d - 1, k - 1
    intervals.reverse()
    return f[best_k][n], tuple(intervals)


def dp_speed_ordered(workload: Workload, platform: Platform,
                     latency_cap: float = math.inf) -> Optional[Mapping]:
    """Beyond-paper polynomial baseline: exact min-period mapping *under the
    constraint* that processors are assigned to intervals in non-increasing
    speed order (fastest gets the first interval).  O(n^2 p^2) DP over
    (stage e, index into the speed-sorted list).  Ignores the latency cap
    unless set (then applied as a post-check)."""
    n = workload.n
    order = platform.sorted_indices()
    p = len(order)
    pre = workload.prefix_w()
    b = platform.b

    def cyc(d, e, oi):
        u = order[oi]
        return workload.delta[d - 1] / b + (pre[e] - pre[d - 1]) / platform.s[u] + workload.delta[e] / b

    INF = math.inf
    # f[oi][e]: min max-cycle covering stages 1..e where the *last* interval uses
    # speed-order index oi (processors with smaller index may be skipped).
    f = np.full((p, n + 1), INF)
    back = {}
    for oi in range(p):
        for e in range(1, n + 1):
            for d in range(1, e + 1):
                c = cyc(d, e, oi)
                if d == 1:
                    prev = 0.0
                    key = None
                else:
                    prev = INF
                    key = None
                    for oj in range(oi):
                        if f[oj][d - 1] < prev:
                            prev = f[oj][d - 1]
                            key = oj
                    if key is None:
                        continue
                v = max(prev, c)
                if v < f[oi][e]:
                    f[oi][e] = v
                    back[(oi, e)] = (d, key)
    end = min(range(p), key=lambda oi: f[oi][n])
    if not math.isfinite(f[end][n]):
        return None
    items = []
    oi, e = end, n
    while e > 0:
        d, prev_oi = back[(oi, e)]
        items.append((d, e, int(order[oi])))
        e = d - 1
        if prev_oi is None:
            break
        oi = prev_oi
    items.reverse()
    mp = Mapping(tuple((d, e) for d, e, _ in items), tuple(u for _, _, u in items))
    if latency(workload, platform, mp) > latency_cap + 1e-12:
        return None
    return mp
