"""Fused device-resident campaign engine: the whole lockstep loop under jit.

The batched engine (:mod:`repro.core.batched`) runs B problems in lockstep,
but only the inner scoring kernels run under ``jax.jit`` — every iteration
still round-trips through Python for worst-interval selection, candidate-grid
construction, and state updates, so a campaign issues O(iterations) host
dispatches and cannot live on an accelerator.  This module traces the ENTIRE
splitting loop — stop checks, worst-interval argmax, span-padded masked
candidate scoring through the shared ``score_2way_kernel``/``score_3way_kernel``,
exact lexicographic tie-breaks, and structure-of-arrays state updates — into
one ``jax.jit``-compiled ``lax.while_loop``, so a whole campaign run is O(1)
host dispatches per (shape, heuristic-arity) pair.

Design differences from the numpy lockstep loop (same *choices*, fixed shape):

  - Candidate grids are STATIC: 2-way splits score all cuts ``1..n-1`` and
    3-way splits all pairs ``c1 < c2`` in ``1..n-1`` every iteration, with
    validity masks selecting the worst interval's span — no data-dependent
    span compaction (which would retrace).  Masked lanes use clamped gathers
    and are excluded by the same feasibility masks the numpy path uses.
  - The 2-stage 3-way fallback (scalar generator in the numpy engine) is six
    extra static lanes with the scalar path's enumeration-order tie-break.
  - Convergence is a per-row mask; the loop exits when every row is done,
    recording per-iteration (period, latency, accepted) into fixed (T, S)
    buffers (T = max possible splits) for trajectory assembly on the host.
  - Batches are padded to a fixed chunk size S per (n, arity), so EVERY call
    of a campaign — trajectories, H4 bisection probes on shrinking subsets,
    H5/H6 bound-grid runs — reuses one trace per arity.  The module counts
    traces (:func:`trace_count`) so tests can assert the O(1) contract.

Equivalence contract: split trajectories — the accepted splits AND their
(period, latency) floats — are identical to the numpy engine on all tested
instances (asserted by tests/test_batched.py).  This requires defeating two
XLA rewrites that would drift by an ulp and flip exact ties: FMA contraction
of ``a * b + c`` chains (neutralized by the kernels' runtime-``zero`` guard:
``fma(a, b, 0) == round(a * b)``) and reduction reordering (the kernels sum
the 3-part axis with explicit left-associated adds; max/min reductions are
order-exact).  The numpy engine remains the contractual bit-exact reference;
the fused engine is validated against it per test grid.

Use via ``backend="fused"`` on any :mod:`repro.core.batched` entry point (the
lockstep runner dispatches here), or ``engine="fused"`` in
``repro.sim.experiments`` / ``benchmarks/paper_sim.py``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import numpy as np

from .heuristics import _EPS, score_2way_kernel, score_3way_kernel

__all__ = ["fused_available", "run_fused", "run_fused_bisection",
           "trace_count", "reset_trace_count",
           "dispatch_count", "reset_dispatch_count"]

# number of traced (compiled) variants of the fused programs since the last
# reset; incremented from inside the traced wrappers, which Python-execute
# only while jax is tracing — so this counts actual traces, not dispatches.
_TRACES = [0]
# number of jitted-program dispatches (host -> device calls) since the last
# reset: one per row-chunk for the lockstep loop, one per row-chunk for the
# WHOLE H4 bisection (probe-at-hi + the lax.scan over probe iterations).
_DISPATCHES = [0]

# lane budget per jitted call: rows_per_chunk * candidate_lanes is held under
# this so the 3-way pair grid of large n stays cache-/memory-sized.
_LANE_BUDGET = 4_000_000
_MAX_CHUNK = 128

_PERMS3 = np.array([(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1),
                    (2, 1, 0)])
# the scalar 2-stage fallback's candidate order: permutations((j,jp,jpp), 2)
_FB_A = np.array([0, 0, 1, 1, 2, 2])
_FB_B = np.array([1, 2, 0, 2, 0, 1])


def fused_available() -> bool:
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return False
    return True


def trace_count() -> int:
    """Traces of the fused programs since the last :func:`reset_trace_count`."""
    return _TRACES[0]


def reset_trace_count() -> None:
    _TRACES[0] = 0


def dispatch_count() -> int:
    """Jitted-program dispatches since :func:`reset_dispatch_count` — the
    O(1)-dispatch contract is asserted on this counter by the tests."""
    return _DISPATCHES[0]


def reset_dispatch_count() -> None:
    _DISPATCHES[0] = 0


def chunk_rows(n: int, k: int) -> int:
    """Fixed rows-per-call for shape (n, arity k) — deterministic so every
    call of a campaign pads to the same chunk shape and shares one trace."""
    if k == 1:
        lanes = max(2 * (n - 1), 1)
    else:
        lanes = 18 * ((n - 1) * (n - 2) // 2) + 6
    return int(max(1, min(_MAX_CHUNK, _LANE_BUDGET // max(lanes, 1))))


def _lex_argmin_traced(xp, keys, mask):
    """Traced mirror of ``batched._lex_argmin``: per-row first index of the
    lexicographically smallest key tuple among masked lanes (no early exit —
    extra key passes only re-filter ties, so the winner is identical)."""
    has = mask.any(axis=1)
    m = mask
    for key in keys:
        kmin = xp.where(m, key, xp.inf).min(axis=1)
        m = m & (key == kmin[:, None])
    return xp.argmax(m, axis=1), has


def _build_loop(n: int, p: int, k: int, T: int, S: int) -> Callable:
    """Build the UNJITTED fused loop for static shape (n, p, k).

    Returned callable:
        fn(w, delta, s, b, prefix, order, bi_mode, stop, lat_limit, active0)
        -> (arr, m, next_idx, lat_sum, splits, per_rec, lat_rec, acc_rec, t)
    with arr (S, n, 5) in the ``_BatchState`` field layout and the records
    (T, S) per lockstep iteration.  Callers jit it (:func:`_get_loop`) or
    inline it into a larger traced program (:func:`_get_bisect`).
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from jax import lax

    rows = jnp.arange(S)
    col = jnp.arange(n)[None, :]
    # static 2-way cut grid (absolute cuts 1..n-1, both placement orders)
    C2 = np.arange(1, n)
    cutorder = np.concatenate([C2 * 2.0, C2 * 2.0 + 1.0])[None, :]
    # static 3-way pair grid (absolute cuts, c1 < c2 in 1..n-1) + its exact
    # integer tie-break key (c1, c2, perm), matching batched._choose_3way
    if n >= 3:
        o1, o2 = np.triu_indices(n - 1, k=1)
        C31, C32 = o1 + 1, o2 + 1
        K3 = C31.size
        ccp = ((C31 * (n + 1) + C32)[None, :] * 6
               + np.arange(6)[:, None]).astype(float).reshape(1, 6 * K3)
    else:
        C31 = C32 = np.zeros(0, dtype=np.int64)
        K3 = 0
        ccp = np.zeros((1, 0))
    fb_key = np.arange(6, dtype=float)[None, :]

    def take1(A, idx):
        return jnp.take_along_axis(A, idx[:, None], axis=1)[:, 0]

    def choose_2way(prefix, delta, s, b, zero, d, e, j, jp_, bi, old_cycle,
                    cur_lat, lat_lim, live):
        valid = (C2[None, :] >= d[:, None]) & (C2[None, :] < e[:, None])
        pre_d1 = take1(prefix, d - 1)
        pre_e = take1(prefix, e)
        del_d1 = take1(delta, d - 1)
        del_e = take1(delta, e)
        inv_j = 1.0 / take1(s, j)
        inv_p = 1.0 / take1(s, jp_)
        cyc1, cyc2, dlat = score_2way_kernel(
            pre_d1[:, None], prefix[:, 1:n], pre_e[:, None],
            del_d1[:, None], delta[:, 1:n], del_e[:, None], b,
            inv_j[:, None], inv_p[:, None], xp=jnp, zero=zero)
        mx = jnp.maximum(cyc1, cyc2)
        okay = (mx < old_cycle[:, None] - _EPS)
        okay &= cur_lat[:, None] + dlat <= lat_lim[:, None] + _EPS
        okay &= jnp.concatenate([valid, valid], axis=1)
        okay &= live[:, None]
        ratio = jnp.maximum(
            dlat / jnp.maximum(old_cycle[:, None] - cyc1, _EPS),
            dlat / jnp.maximum(old_cycle[:, None] - cyc2, _EPS))
        bc = bi[:, None]
        keys = [jnp.where(bc, ratio, mx), jnp.where(bc, mx, dlat),
                jnp.broadcast_to(cutorder, mx.shape)]
        q, has = _lex_argmin_traced(jnp, keys, okay)
        c = jnp.take(jnp.asarray(C2), q % (n - 1), mode="clip")
        swapped = q >= (n - 1)
        pa = jnp.where(swapped, jp_, j)
        pb2 = jnp.where(swapped, j, jp_)
        pd = jnp.stack([d, c + 1, c + 1], axis=1)
        pe = jnp.stack([c, e, e], axis=1)
        pu = jnp.stack([pa, pb2, pb2], axis=1)
        nparts = jnp.full((S,), 2, dtype=jnp.int64)
        consumed = jnp.ones((S,), dtype=jnp.int64)
        return has, pd, pe, pu, nparts, consumed

    def choose_3way(prefix, delta, s, b, zero, d, e, j, jp_, jpp, bi,
                    old_cycle, cur_lat, lat_lim, live):
        pre_d1 = take1(prefix, d - 1)
        pre_e = take1(prefix, e)
        del_d1 = take1(delta, d - 1)
        del_e = take1(delta, e)
        sj = take1(s, j)
        s3 = jnp.stack([sj, take1(s, jp_), take1(s, jpp)], axis=1)   # (S, 3)
        base_term = del_d1 / b + (pre_e - pre_d1) / sj
        procs3 = jnp.stack([j, jp_, jpp], axis=1)                    # (S, 3)
        span2 = (e - d + 1) == 2

        # --- >=3-stage lanes: all (c1, c2) pairs x 6 permutations ----------
        if K3:
            valid = ((C31[None, :] >= d[:, None])
                     & (C32[None, :] <= (e - 1)[:, None]))
            pre_c1 = prefix[:, C31]
            pre_c2 = prefix[:, C32]
            del_c1 = delta[:, C31]
            del_c2 = delta[:, C32]
            W = jnp.stack([pre_c1 - pre_d1[:, None], pre_c2 - pre_c1,
                           pre_e[:, None] - pre_c2], axis=1)         # (S, 3, K)
            dI = jnp.stack([jnp.broadcast_to(del_d1[:, None], (S, K3)),
                            del_c1, del_c2], axis=1) / b
            dO = jnp.stack([del_c1, del_c2,
                            jnp.broadcast_to(del_e[:, None], (S, K3))],
                           axis=1) / b
            invp = (1.0 / s3)[:, _PERMS3][:, :, :, None]             # (S,6,3,1)
            cyc, dlat, mx = score_3way_kernel(
                dI[:, None], W[:, None], dO[:, None], invp,
                base_term[:, None, None], xp=jnp, zero=zero)
            ratio = (dlat[:, :, None, :]
                     / jnp.maximum(old_cycle[:, None, None, None] - cyc,
                                   _EPS)).max(axis=2)
            mx_f = mx.reshape(S, 6 * K3)
            dlat_f = dlat.reshape(S, 6 * K3)
            ratio_f = ratio.reshape(S, 6 * K3)
            okay3 = mx_f < old_cycle[:, None] - _EPS
            okay3 &= cur_lat[:, None] + dlat_f <= lat_lim[:, None] + _EPS
            okay3 &= jnp.broadcast_to(valid[:, None, :],
                                      (S, 6, K3)).reshape(S, 6 * K3)
            okay3 &= (live & ~span2)[:, None]

        # --- 2-stage fallback lanes: permutations((j,jp,jpp), 2) at cut d ---
        # (division-based like the scalar generator the numpy engine calls)
        pre_dd = take1(prefix, jnp.minimum(d, n))
        del_dd = take1(delta, jnp.minimum(d, n))
        W1 = (pre_dd - pre_d1)[:, None]
        W2 = (pre_e - pre_dd)[:, None]
        spa = s3[:, _FB_A]
        spb = s3[:, _FB_B]
        t1 = del_d1[:, None] / b + W1 / spa
        cyc1_fb = t1 + del_dd[:, None] / b
        t2 = del_dd[:, None] / b + W2 / spb
        cyc2_fb = t2 + del_e[:, None] / b
        dlat_fb = (t1 + t2) - base_term[:, None]
        mx_fb = jnp.maximum(cyc1_fb, cyc2_fb)
        okay_fb = mx_fb < old_cycle[:, None] - _EPS
        okay_fb &= cur_lat[:, None] + dlat_fb <= lat_lim[:, None] + _EPS
        okay_fb &= (live & span2)[:, None]
        ratio_fb = jnp.maximum(
            dlat_fb / jnp.maximum(old_cycle[:, None] - cyc1_fb, _EPS),
            dlat_fb / jnp.maximum(old_cycle[:, None] - cyc2_fb, _EPS))

        # one lex-argmin over the concatenated lanes; per row only one lane
        # family is unmasked, so the key families never compete
        bc = bi[:, None]
        if K3:
            key1 = jnp.concatenate(
                [jnp.where(bc, ratio_f, mx_f), jnp.where(bc, ratio_fb, mx_fb)],
                axis=1)
            key2 = jnp.concatenate(
                [jnp.where(bc, mx_f, dlat_f), jnp.where(bc, mx_fb, dlat_fb)],
                axis=1)
            key3 = jnp.concatenate(
                [jnp.broadcast_to(ccp, (S, 6 * K3)),
                 jnp.broadcast_to(fb_key, (S, 6))], axis=1)
            okay = jnp.concatenate([okay3, okay_fb], axis=1)
        else:
            key1 = jnp.where(bc, ratio_fb, mx_fb)
            key2 = jnp.where(bc, mx_fb, dlat_fb)
            key3 = jnp.broadcast_to(fb_key, (S, 6))
            okay = okay_fb
        q, has = _lex_argmin_traced(jnp, [key1, key2, key3], okay)

        fb = q >= 6 * K3
        # grid winner
        pi = jnp.minimum(q // max(K3, 1), 5)
        kk = q % max(K3, 1)
        c1b = jnp.take(jnp.asarray(C31), kk, mode="clip") if K3 else d
        c2b = jnp.take(jnp.asarray(C32), kk, mode="clip") if K3 else d
        perm = jnp.asarray(_PERMS3)[pi]                              # (S, 3)
        u_grid = jnp.take_along_axis(procs3, perm, axis=1)
        pd_g = jnp.stack([d, c1b + 1, c2b + 1], axis=1)
        pe_g = jnp.stack([c1b, c2b, e], axis=1)
        # fallback winner
        qf = jnp.where(fb, q - 6 * K3, 0)
        ia = jnp.asarray(_FB_A)[qf]
        ib = jnp.asarray(_FB_B)[qf]
        pu0 = jnp.take_along_axis(procs3, ia[:, None], axis=1)[:, 0]
        pu1 = jnp.take_along_axis(procs3, ib[:, None], axis=1)[:, 0]
        pd_f = jnp.stack([d, d + 1, d + 1], axis=1)
        pe_f = jnp.stack([d, e, e], axis=1)
        pu_f = jnp.stack([pu0, pu1, pu1], axis=1)
        cons_f = jnp.where((ia != 0) & (ib != 0), 2, 1).astype(jnp.int64)

        fbc = fb[:, None]
        pd = jnp.where(fbc, pd_f, pd_g)
        pe = jnp.where(fbc, pe_f, pe_g)
        pu = jnp.where(fbc, pu_f, u_grid)
        nparts = jnp.where(fb, 2, 3).astype(jnp.int64)
        consumed = jnp.where(fb, cons_f, 2).astype(jnp.int64)
        return has, pd, pe, pu, nparts, consumed

    def fn(w, delta, s, b, zero, prefix, order, bi_mode, stop, lat_limit,
           active0):
        del w  # stage works enter via their prefix sums
        fastest = order[:, 0]
        term0 = delta[:, 0] / b + (prefix[:, n] - prefix[:, 0]) / take1(s, fastest)
        tail = delta[:, n] / b
        arr = jnp.full((S, n, 5), 0.0).at[:, :, 3].set(-jnp.inf)
        arr = arr.at[:, 0, 0].set(1.0)
        arr = arr.at[:, 0, 1].set(float(n))
        arr = arr.at[:, 0, 2].set(fastest.astype(jnp.float64))
        arr = arr.at[:, 0, 3].set(term0 + tail)
        arr = arr.at[:, 0, 4].set(term0)
        m0 = jnp.ones(S, dtype=jnp.int64)
        nx0 = jnp.ones(S, dtype=jnp.int64)
        sp0 = jnp.zeros(S, dtype=jnp.int64)
        per_rec = jnp.zeros((T, S))
        lat_rec = jnp.zeros((T, S))
        acc_rec = jnp.zeros((T, S), dtype=bool)

        def cond(carry):
            t, active = carry[0], carry[5]
            return (t < T) & active.any()

        def body(carry):
            (t, arr, m, next_idx, lat_sum, active,
             per_rec, lat_rec, acc_rec) = carry[:9]
            splits = carry[9]
            cyc = arr[:, :, 3]
            per = cyc.max(axis=1)
            live = active & (per > stop + _EPS)
            widx = jnp.argmax(cyc, axis=1)
            item = jnp.take_along_axis(arr, widx[:, None, None], axis=1)[:, 0, :]
            d = jnp.clip(item[:, 0].astype(jnp.int64), 1, n)
            e = jnp.clip(item[:, 1].astype(jnp.int64), 1, n)
            j = jnp.clip(item[:, 2].astype(jnp.int64), 0, p - 1)
            live &= (item[:, 1] > item[:, 0]) & (next_idx + k <= p)
            old_cycle = item[:, 3]
            old_term = item[:, 4]
            cur_lat = lat_sum + tail
            jp_ = take1(order, jnp.clip(next_idx, 0, p - 1))
            if k == 1:
                has, pd, pe, pu, nparts, consumed = choose_2way(
                    prefix, delta, s, b, zero, d, e, j, jp_, bi_mode,
                    old_cycle, cur_lat, lat_limit, live)
            else:
                jpp = take1(order, jnp.clip(next_idx + 1, 0, p - 1))
                has, pd, pe, pu, nparts, consumed = choose_3way(
                    prefix, delta, s, b, zero, d, e, j, jp_, jpp, bi_mode,
                    old_cycle, cur_lat, lat_limit, live)
            accept = live & has

            # apply splits (same division-based expressions as _apply_splits)
            pdc = jnp.clip(pd, 1, n)
            pec = jnp.clip(pe, 1, n)
            puc = jnp.clip(pu, 0, p - 1)
            del_pd1 = jnp.take_along_axis(delta, pdc - 1, axis=1)
            pre_pe = jnp.take_along_axis(prefix, pec, axis=1)
            pre_pd1 = jnp.take_along_axis(prefix, pdc - 1, axis=1)
            s_pu = jnp.take_along_axis(s, puc, axis=1)
            del_pe = jnp.take_along_axis(delta, pec, axis=1)
            t_parts = del_pd1 / b + (pre_pe - pre_pd1) / s_pu
            c_parts = t_parts + del_pe / b
            add = t_parts[:, 0] + t_parts[:, 1]
            add = jnp.where(nparts == 3, add + t_parts[:, 2], add)
            new_lat = (lat_sum - old_term) + add
            sh = (nparts - 1)[:, None]
            idxc = widx[:, None]
            src = jnp.where(col <= idxc, col,
                            jnp.where(col <= idxc + sh, idxc, col - sh))
            new_arr = jnp.take_along_axis(arr, src[:, :, None], axis=1)
            parts5 = jnp.stack([pdc.astype(jnp.float64),
                                pec.astype(jnp.float64),
                                puc.astype(jnp.float64), c_parts, t_parts],
                               axis=2)                               # (S, 3, 5)
            m0_ = (col == idxc)[:, :, None]
            m1_ = (col == idxc + 1)[:, :, None]
            m2_ = ((col == idxc + 2) & (nparts == 3)[:, None])[:, :, None]
            new_arr = jnp.where(m0_, parts5[:, 0][:, None, :], new_arr)
            new_arr = jnp.where(m1_, parts5[:, 1][:, None, :], new_arr)
            new_arr = jnp.where(m2_, parts5[:, 2][:, None, :], new_arr)

            acc3 = accept[:, None, None]
            arr = jnp.where(acc3, new_arr, arr)
            m = m + jnp.where(accept, nparts - 1, 0)
            next_idx = next_idx + jnp.where(accept, consumed, 0)
            lat_sum = jnp.where(accept, new_lat, lat_sum)
            splits = splits + accept.astype(jnp.int64)

            per_rec = per_rec.at[t].set(arr[:, :, 3].max(axis=1))
            lat_rec = lat_rec.at[t].set(lat_sum + tail)
            acc_rec = acc_rec.at[t].set(accept)
            return (t + 1, arr, m, next_idx, lat_sum, accept,
                    per_rec, lat_rec, acc_rec, splits)

        init = (jnp.int64(0), arr, m0, nx0, term0, active0,
                per_rec, lat_rec, acc_rec, sp0)
        (t, arr, m, next_idx, lat_sum, active,
         per_rec, lat_rec, acc_rec, splits) = lax.while_loop(cond, body, init)
        return arr, m, next_idx, lat_sum, splits, per_rec, lat_rec, acc_rec, t

    return fn


@functools.lru_cache(maxsize=None)
def _get_loop(n: int, p: int, k: int, T: int, S: int) -> Callable:
    """The jitted fused loop for static shape (n, p, k), cached per shape."""
    import jax

    loop = _build_loop(n, p, k, T, S)

    def counted(*args):
        _TRACES[0] += 1  # Python-executes only while tracing
        return loop(*args)

    return jax.jit(counted)


@functools.lru_cache(maxsize=None)
def _get_bisect(n: int, p: int, T: int, S: int, iters: int) -> Callable:
    """The jitted FUSED H4 bisection for static shape (n, p): the probe at
    the upper latency bound plus a ``lax.scan`` over ``iters`` probe
    iterations — each probe an inline :func:`_build_loop` run — carrying the
    per-row (lo, hi) bound state and the best-so-far probe outcome.  One
    dispatch replaces the ~iters+1 per-probe dispatches of the host-driven
    binary search, with bit-identical updates: ``mid = 0.5 * (lo + hi)``,
    feasibility ``(period <= p_fix + eps) & (latency <= mid + eps)``, and the
    (latency, then period) best-probe tie-break all mirror
    ``batched._sp_bi_p_rowwise`` expression for expression.

    Returned callable:
        fn(w, delta, s, b, zero, prefix, order, p_fix, lo0, hi0, active0)
        -> (items0, m0, sp0, per0, lat0, feas0,
            best_items, best_m, best_sp, best_per, best_lat)
    with items* (S, n, 3) in the ``_BatchState`` (d, e, proc) layout.
    """
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from jax import lax

    loop = _build_loop(n, p, 1, T, S)

    def fn(w, delta, s, b, zero, prefix, order, p_fix, lo0, hi0, active0):
        _TRACES[0] += 1  # Python-executes only while tracing
        all_bi = jnp.ones(S, dtype=bool)
        tail = delta[:, n] / b

        def probe(limits, act):
            arr, m, _nx, lat_sum, splits, *_rest = loop(
                w, delta, s, b, zero, prefix, order, all_bi, p_fix, limits,
                act)
            per = arr[:, :, 3].max(axis=1)
            lat = lat_sum + tail
            feas = (per <= p_fix + _EPS) & (lat <= limits + _EPS)
            return arr, m, splits, per, lat, feas

        # Ensure feasibility at the upper end first (the rowwise path's
        # probe0); its state seeds both the failure outputs and `best`.
        arr0, m0, sp0, per0, lat0, feas0 = probe(hi0, active0)
        alive = feas0 & active0

        def body(carry, _):
            lo, hi, b_it, b_m, b_sp, b_per, b_lat = carry
            mid = 0.5 * (lo + hi)
            arr, m, sp, per, lat, feas = probe(mid, alive)
            good = alive & feas
            hi = jnp.where(good, mid, hi)
            lo = jnp.where(alive & ~feas, mid, lo)
            better = good & ((lat < b_lat - _EPS)
                             | ((jnp.abs(lat - b_lat) <= _EPS)
                                & (per < b_per)))
            bc = better[:, None, None]
            return (lo, hi, jnp.where(bc, arr[:, :, :3], b_it),
                    jnp.where(better, m, b_m), jnp.where(better, sp, b_sp),
                    jnp.where(better, per, b_per),
                    jnp.where(better, lat, b_lat)), None

        init = (lo0, hi0, arr0[:, :, :3], m0, sp0, per0, lat0)
        (_lo, _hi, b_it, b_m, b_sp, b_per, b_lat), _ = lax.scan(
            body, init, None, length=iters)
        return (arr0[:, :, :3], m0, sp0, per0, lat0, feas0,
                b_it, b_m, b_sp, b_per, b_lat)

    return jax.jit(fn)


def run_fused(state, k: int, bi_mode: np.ndarray, stop: np.ndarray,
              lat_limit: np.ndarray, record: Optional[Callable] = None) -> None:
    """Run the fused loop over ``state`` (a ``batched._BatchState``), writing
    final arrays back and replaying per-iteration ``record`` callbacks — a
    drop-in replacement for the numpy ``_run_loop`` body with O(1) dispatches.
    """
    pb = state.pb
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0 or not state.active.any():
        state.active[:] = False
        return
    S = chunk_rows(n, k)
    fn = _get_loop(n, p, k, T, S)
    b = np.float64(pb.b)
    bi_mode = np.asarray(bi_mode, dtype=bool)
    stop = np.asarray(stop, dtype=np.float64)
    lat_limit = np.asarray(lat_limit, dtype=np.float64)
    chunks = []  # (rows, per_rec, lat_rec, acc_rec, t_used)
    for lo in range(0, B, S):
        rows = np.arange(lo, min(lo + S, B))
        pad = S - rows.size
        sel = np.concatenate([rows, np.zeros(pad, dtype=np.int64)]) if pad else rows
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = state.active[rows]
        _DISPATCHES[0] += 1
        out = fn(pb.w[sel], pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), bi_mode[sel],
                 stop[sel], lat_limit[sel], act)
        (arr, m, next_idx, lat_sum, splits,
         per_rec, lat_rec, acc_rec, t_used) = (np.asarray(o) for o in out)
        r = rows.size
        state.arr[rows] = arr[:r]
        state.m[rows] = m[:r]
        state.next_idx[rows] = next_idx[:r]
        state.lat_sum[rows] = lat_sum[:r]
        state.splits[rows] = splits[:r]
        state.active[rows] = False
        if record is not None:
            chunks.append((rows, per_rec[:, :r], lat_rec[:, :r],
                           acc_rec[:, :r], int(t_used)))
    if record is None:
        return
    # Replay records in global lockstep order: a row's s-th accepted split
    # always lands at iteration s regardless of which rows share its chunk,
    # so merging chunk records per iteration reproduces the numpy engine's
    # record sequence exactly.
    t_max = max((t for *_, t in chunks), default=0)
    for t in range(t_max):
        rsel, pers, lats = [], [], []
        for rows, per_rec, lat_rec, acc_rec, t_used in chunks:
            if t >= t_used:
                continue
            a = acc_rec[t]
            if a.any():
                rsel.append(rows[a])
                pers.append(per_rec[t][a])
                lats.append(lat_rec[t][a])
        if rsel:
            record(np.concatenate(rsel), np.concatenate(pers),
                   np.concatenate(lats))


def run_fused_bisection(pb, p_fix: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                        iters: int) -> dict:
    """Run the ENTIRE H4 binary search device-resident: one jitted
    probe0 + ``lax.scan`` program per row-chunk (O(1) host dispatches per
    campaign instead of ~iters+1), bit-identical to the host-driven search.

    ``pb`` is a ``batched.ProblemBatch``; returns per-row numpy arrays:
    ``items0/m0/sp0/per0/lat0/feas0`` (the probe-at-``hi`` state — the
    failure outputs) and ``items/m/sp/per/lat`` (the best feasible probe).
    The caller (``batched._sp_bi_p_fused``) assembles HeuristicResults.
    """
    B, n, p = pb.B, pb.n, pb.p
    T = min(n - 1, p - 1)
    if T <= 0:
        raise ValueError("unsplittable shape: caller should use the host path")
    S = chunk_rows(n, 1)
    fn = _get_bisect(n, p, T, S, int(iters))
    b = np.float64(pb.b)
    p_fix = np.asarray(p_fix, dtype=np.float64)
    lo = np.asarray(lo, dtype=np.float64)
    hi = np.asarray(hi, dtype=np.float64)
    out = {
        "items0": np.zeros((B, n, 3)), "m0": np.zeros(B, dtype=np.int64),
        "sp0": np.zeros(B, dtype=np.int64), "per0": np.zeros(B),
        "lat0": np.zeros(B), "feas0": np.zeros(B, dtype=bool),
        "items": np.zeros((B, n, 3)), "m": np.zeros(B, dtype=np.int64),
        "sp": np.zeros(B, dtype=np.int64), "per": np.zeros(B),
        "lat": np.zeros(B),
    }
    names = ("items0", "m0", "sp0", "per0", "lat0", "feas0",
             "items", "m", "sp", "per", "lat")
    for lo_i in range(0, B, S):
        rows = np.arange(lo_i, min(lo_i + S, B))
        pad = S - rows.size
        sel = (np.concatenate([rows, np.zeros(pad, dtype=np.int64)])
               if pad else rows)
        act = np.zeros(S, dtype=bool)
        act[:rows.size] = True
        _DISPATCHES[0] += 1
        res = fn(pb.w[sel], pb.delta[sel], pb.s[sel], b, np.float64(0.0),
                 pb.prefix[sel], pb.order[sel].astype(np.int64), p_fix[sel],
                 lo[sel], hi[sel], act)
        for name, val in zip(names, res):
            out[name][rows] = np.asarray(val)[:rows.size]
    return out
