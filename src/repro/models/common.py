"""Shared model configuration covering all ten assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def abstract_mesh():
    """``jax.sharding.get_abstract_mesh()`` when the installed jax has it
    (>= 0.5); ``None`` otherwise — older jax has no ambient abstract mesh, so
    every call site's no-mesh path is the correct behavior there."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA window (mixtral)
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"              # swiglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    moe_shard_map: bool = True       # manual-data dispatch (False: pure GSPMD —
                                     # needed for bf16 params on XLA:CPU, see moe.py)
    fsdp_params: bool = False        # 2D weight sharding (model x data), per-layer gather

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): shared full-attention block applied every k layers
    attn_every: int = 0

    # xlstm: every k-th block is an sLSTM block (others mLSTM)
    slstm_every: int = 0
    xlstm_chunk: int = 256

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500              # 30 s of audio frames (stub frontend)

    # vlm (internvl2)
    n_vis_tokens: int = 0            # stub ViT frontend output length

    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "block"             # none | block  (checkpoint each scanned block)
    accum_steps: int = 1             # gradient-accumulation microbatches per step
    use_pallas: bool = False         # use Pallas kernels for hot paths
    attn_chunk: int = 1024           # KV block for chunked attention
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def jparam_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init to within ties/rounding)."""
    d, h, kv, hd, ff, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, cfg.d_ff, cfg.vocab_size, cfg.n_layers)
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    mlp = 3 * d * ff if cfg.act == "swiglu" else 2 * d * ff
    if cfg.family == "moe":
        moe = cfg.n_experts * 3 * d * cfg.expert_d_ff + d * cfg.n_experts
        mlp = moe + (3 * d * cfg.d_ff if cfg.dense_residual else 0)
    per_layer = attn + mlp + 2 * d
    if cfg.family == "ssm":
        per_layer = _mamba2_params(cfg) + 2 * d
    if cfg.family == "hybrid":
        per_layer = _mamba2_params(cfg) + 2 * d
        emb += attn + 2 * d          # one shared attention block
    if cfg.family == "xlstm":
        # rough: mLSTM blocks dominate
        per_layer = _mlstm_params(cfg) + 2 * d
    if cfg.family == "encdec":
        dec = attn + attn + mlp + 3 * d          # self + cross + mlp
        enc = attn + mlp + 2 * d
        return emb + cfg.n_enc_layers * enc + L * dec
    return emb + L * per_layer


def _mamba2_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    G = 1
    proj_in = d * (2 * d_in + 2 * G * cfg.ssm_state + H)
    conv = (d_in + 2 * G * cfg.ssm_state) * cfg.ssm_conv
    return proj_in + conv + H + H + d_in + d_in * d  # A, D, norm-ish, out


def _mlstm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = 2 * d
    return d * 2 * d_in + 3 * d_in * d_in // 1 + d_in * d  # rough


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) parameters: MoE counts only top-k experts."""
    if cfg.family != "moe":
        return param_count(cfg)
    d, ff, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    moe_active = cfg.top_k * 3 * d * cfg.expert_d_ff + d * cfg.n_experts
    dense = 3 * d * cfg.d_ff if cfg.dense_residual else 0
    return emb + L * (attn + moe_active + dense + 2 * d)
