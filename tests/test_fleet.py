"""Fleet replanning service: signature/dedup exactness, warm-start
equivalence, deterministic replay, and the batched portfolio's bit-identity
to scalar solo replans — the subsystem's acceptance contract."""

import dataclasses

import numpy as np
import pytest

from repro.core import (Platform, make_platform, min_period_exhaustive,
                        sample_failures, stack_instances)
from repro.core.batched import ProblemBatch, batched_min_period
from repro.fleet import (ChaosSpec, PodCountChange, PodFailure, ReplanService,
                         StageDrift, StageTimings, canonicalize,
                         gen_burst_trace, inject_chaos, make_fleet,
                         remap_alloc, signature, span_bucket)
from repro.launch.serve import sample_tokens
from repro.sim.generators import gen_instance

SEEDS = range(8100, 8106)


def _plans_equal(a, b):
    return (a.period == b.period and a.latency == b.latency
            and a.mapping.intervals == b.mapping.intervals
            and a.mapping.alloc == b.mapping.alloc)


# ---------------------------------------------------------------------------
# Core: the batched min-period portfolio
# ---------------------------------------------------------------------------

def test_batched_min_period_bit_identical_to_scalar():
    """Every float, mapping, winner name, and split count matches the scalar
    4-strategy exhaustion portfolio."""
    for exp in ("E1", "E2", "E3", "E4"):
        pairs = [gen_instance(exp, 12, 6, s) for s in SEEDS]
        for r, (wl, pf) in zip(batched_min_period(stack_instances(pairs)),
                               pairs):
            ref = min_period_exhaustive(wl, pf)
            assert _plans_equal(r, ref)
            assert r.name == ref.name and r.splits == ref.splits


def test_from_arrays_matches_stack_instances():
    pairs = [gen_instance("E3", 9, 5, s) for s in SEEDS]
    pb1 = stack_instances(pairs)
    pb2 = ProblemBatch.from_arrays(np.stack([wl.w for wl, _ in pairs]),
                                   np.stack([wl.delta for wl, _ in pairs]),
                                   np.stack([pf.s for _, pf in pairs]),
                                   pairs[0][1].b)
    np.testing.assert_array_equal(pb1.prefix, pb2.prefix)
    np.testing.assert_array_equal(pb1.order, pb2.order)
    assert pb1.b == pb2.b


# ---------------------------------------------------------------------------
# Signatures: relabeling theorem
# ---------------------------------------------------------------------------

def test_signature_invariant_under_processor_relabeling():
    wl, pf = gen_instance("E2", 8, 5, 0)
    rng = np.random.default_rng(1)
    perm = rng.permutation(pf.p)
    shuffled = Platform(pf.s[perm], pf.b)
    assert signature(wl, pf).digest == signature(wl, shuffled).digest


def test_signature_sensitive_to_every_field():
    wl, pf = gen_instance("E2", 8, 5, 0)
    base = signature(wl, pf).digest
    assert signature(wl, Platform(pf.s * 1.0000001, pf.b)).digest != base
    assert signature(wl, Platform(pf.s, pf.b * 2)).digest != base
    wl2 = dataclasses.replace(wl, w=wl.w + 1e-9)
    assert signature(wl2, pf).digest != base


def test_canonical_solve_remaps_bit_identically():
    """Solving the speed-sorted canonical platform and remapping the alloc
    through the permutation reproduces the original solve exactly — the
    theorem that makes signature dedup exact, including equal-speed ties."""
    for seed in SEEDS:
        wl, pf = gen_instance("E1", 10, 6, seed)   # E1 has many speed ties
        canon, perm = canonicalize(pf)
        ref = min_period_exhaustive(wl, pf)
        via = min_period_exhaustive(wl, canon)
        assert via.period == ref.period and via.latency == ref.latency
        assert via.mapping.intervals == ref.mapping.intervals
        assert remap_alloc(via.mapping.alloc, perm) == ref.mapping.alloc


def test_span_bucket_powers_of_two():
    assert [span_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    with pytest.raises(ValueError):
        span_bucket(0)


# ---------------------------------------------------------------------------
# Service: dedup exactness, determinism, warm-start
# ---------------------------------------------------------------------------

def _small_fleet():
    pairs, groups = make_fleet(n_groups=3, replicas=4, n=8, p=4, seed=42)
    trace = gen_burst_trace(groups, num_ticks=12, seed=7, n_stages=8,
                            initial_pods=4, burst_prob=0.8)
    return pairs, groups, trace


def test_deduped_replans_bit_identical_to_solo():
    """After a full burst trace, every instance's published plan equals the
    scalar portfolio run solo on that instance's effective platform."""
    pairs, _, trace = _small_fleet()
    svc = ReplanService(pairs)
    svc.run_trace(trace)
    for st in svc.states:
        ref = min_period_exhaustive(st.workload, st.platform)
        assert _plans_equal(st.plan, ref)


def test_dedup_actually_dedups():
    """Replicated groups with correlated events: far fewer solves than
    requests, and at least one replan happened."""
    pairs, _, trace = _small_fleet()
    svc = ReplanService(pairs)
    m = svc.run_trace(trace)
    assert m.requests > 0
    assert m.solves < m.requests
    assert m.dedup_hit_rate() > 0.2


def test_trace_generation_and_replay_deterministic():
    pairs, groups, trace = _small_fleet()
    trace2 = gen_burst_trace(groups, num_ticks=12, seed=7, n_stages=8,
                             initial_pods=4, burst_prob=0.8)
    assert trace == trace2
    a, b = ReplanService(pairs), ReplanService(pairs)
    a.run_trace(trace)
    b.run_trace(trace)
    assert a.fleet_digest() == b.fleet_digest()
    # every counter (not the wall-clock timings) replays identically
    for f in ("ticks", "events", "requests", "solves", "warm_hits"):
        assert getattr(a.metrics, f) == getattr(b.metrics, f)
    assert a.metrics.churns == b.metrics.churns


def test_warm_start_equals_cold_on_stationary_trace():
    """A stationary trace (the same drift repeating) and exact-bytes
    signatures: warm-starting can only skip work, never change plans."""
    pairs, groups, _ = _small_fleet()
    events = tuple(StageDrift(i, 2, 2.0) for g in groups for i in g)
    from repro.fleet.telemetry import Trace
    stationary = Trace(ticks=(events,) * 6)
    warm = ReplanService(pairs, warm_start=True)
    cold = ReplanService(pairs, warm_start=False)
    warm.run_trace(stationary)
    cold.run_trace(stationary)
    assert warm.fleet_digest() == cold.fleet_digest()
    assert warm.metrics.solves <= cold.metrics.solves


def test_warm_start_equals_cold_on_burst_trace():
    pairs, _, trace = _small_fleet()
    warm = ReplanService(pairs, warm_start=True)
    cold = ReplanService(pairs, warm_start=False)
    warm.run_trace(trace)
    cold.run_trace(trace)
    assert warm.fleet_digest() == cold.fleet_digest()


def test_pod_failure_shrinks_platform_and_replans():
    wl, pf = gen_instance("E2", 8, 4, 3)
    svc = ReplanService([(wl, pf)])
    p0 = svc.states[0].platform.p
    published = svc.tick([PodFailure(0, 1)])
    assert svc.states[0].platform.p == p0 - 1
    assert 0 in published
    assert max(svc.states[0].plan.mapping.alloc) < p0 - 1


def test_pod_count_change_preserves_surviving_speeds():
    wl, pf = gen_instance("E2", 8, 4, 3)
    svc = ReplanService([(wl, pf)])
    svc.tick([StageDrift(0, 0, 3.0)])          # degrade someone's speed
    degraded = svc.states[0].platform.s.copy()
    svc.tick([PodCountChange(0, 6)])
    out = svc.states[0].platform.s
    np.testing.assert_array_equal(out[:4], degraded)
    assert len(out) == 6


def test_straggler_fast_path_no_replan():
    """On-prediction timings never dirty an instance."""
    from repro.core import interval_cycle_times
    wl, pf = gen_instance("E2", 8, 4, 3)
    svc = ReplanService([(wl, pf)])
    st = svc.states[0]
    predicted = interval_cycle_times(st.workload, st.platform,
                                     st.plan.mapping)
    before = svc.fleet_digest()
    published = svc.tick([StageTimings(0, tuple(predicted))])
    assert published == {}
    assert svc.fleet_digest() == before
    assert svc.metrics.requests == 0


# ---------------------------------------------------------------------------
# Chaos: fault injection, graceful degradation, reliability floor
# ---------------------------------------------------------------------------

def _chaos_fleet():
    """The small fleet with seeded per-group failure probabilities and a
    chaos-injected burst trace."""
    pairs, groups = make_fleet(n_groups=3, replicas=4, n=8, p=4, seed=42)
    shared, withfail = {}, []
    for wl, pf in pairs:
        if id(pf) not in shared:
            shared[id(pf)] = pf.with_failures(
                sample_failures(pf.p, kind="bimodal", seed=len(shared)))
        withfail.append((wl, shared[id(pf)]))
    trace = gen_burst_trace(groups, num_ticks=12, seed=7, n_stages=8,
                            initial_pods=4, burst_prob=0.8)
    chaos = inject_chaos(trace, groups, ChaosSpec(), seed=13, initial_pods=4)
    return withfail, groups, chaos


def test_chaos_injection_deterministic():
    """Same (trace, groups, spec, seed) -> identical chaos trace; zero
    probabilities -> the input trace unchanged; and a full replay of the
    chaos trace is deterministic (same fleet_digest and counters)."""
    pairs, groups, chaos = _chaos_fleet()
    _, _, chaos2 = _chaos_fleet()
    assert chaos == chaos2
    base = gen_burst_trace(groups, num_ticks=12, seed=7, n_stages=8,
                           initial_pods=4, burst_prob=0.8)
    calm = ChaosSpec(storm_prob=0, flap_prob=0, drop_prob=0, dup_prob=0,
                     reorder_prob=0)
    assert inject_chaos(base, groups, calm, seed=13).ticks == base.ticks
    a, b = ReplanService(pairs), ReplanService(pairs)
    a.run_trace(chaos)
    b.run_trace(chaos)
    assert a.fleet_digest() == b.fleet_digest()
    for f in ("requests", "solves", "dropped_events", "invalid_published"):
        assert getattr(a.metrics, f) == getattr(b.metrics, f)


def test_chaos_never_publishes_invalid_plans():
    """Through storms, flaps, and delivery faults, no instance ever ends a
    tick with a plan addressing dead pods."""
    pairs, _, chaos = _chaos_fleet()
    svc = ReplanService(pairs, reliability_floor=0.9)
    m = svc.run_trace(chaos)
    assert m.invalid_published == 0
    for st in svc.states:
        assert max(st.plan.mapping.alloc) < st.platform.p
        if st.plan.groups is not None:
            assert max(u for g in st.plan.groups for u in g) < st.platform.p


def test_solve_deadline_defers_then_recovers():
    """With a zero solve budget, non-urgent replans are deferred (keeping the
    last valid plan); when the budget returns, the pending retries converge to
    the exact no-deadline outcome."""
    pairs, _, chaos = _chaos_fleet()
    svc = ReplanService(pairs, solve_deadline=0.0)
    svc.run_trace(chaos)
    assert svc.metrics.deferred > 0
    assert svc.metrics.degraded_ticks > 0
    assert svc.metrics.invalid_published == 0
    # lift the deadline: one empty tick drains the pending retries, and every
    # published plan equals the scalar portfolio on the instance's CURRENT
    # effective platform (deferral may have skipped intermediate replans, but
    # it never changes what the final converged answer is)
    svc.solve_deadline = None
    svc.tick([])
    assert not svc._pending
    for st in svc.states:
        assert _plans_equal(st.plan, min_period_exhaustive(st.workload,
                                                           st.platform))


def test_batched_failure_falls_back_to_scalar(monkeypatch):
    """A poisoned batched solve degrades to per-member scalar solves with
    bit-identical published plans."""
    import repro.fleet.service as svc_mod
    pairs, _, chaos = _chaos_fleet()
    ref = ReplanService(pairs)
    ref.run_trace(chaos)

    def boom(pb, backend):
        raise RuntimeError("poisoned batch")

    monkeypatch.setattr(svc_mod, "batched_min_period", boom)
    svc = ReplanService(pairs)
    svc.run_trace(chaos)
    assert svc.metrics.fallback_solves > 0
    assert svc.fleet_digest() == ref.fleet_digest()


def test_reliability_floor_triggers_replication():
    """An instance whose plan reliability sits below the floor gets greedy
    replicas until it clears the floor (pods permitting)."""
    wl, pf = gen_instance("E2", 4, 10, seed=5)
    pf = pf.with_failures(np.full(pf.p, 0.1))
    svc = ReplanService([(wl, pf)], reliability_floor=0.97)
    st = svc.states[0]
    assert st.plan.groups is not None          # replication actually fired
    assert svc._plan_reliability(st) >= 0.97 - 1e-9
    # without the floor the same instance plans below it
    bare = ReplanService([(wl, pf)])
    assert bare._plan_reliability(bare.states[0]) < 0.97


def test_stale_stage_drift_dropped():
    """An out-of-range StageDrift (stale plan shape) is dropped — counted,
    no replan, no wrap-around onto an arbitrary stage."""
    wl, pf = gen_instance("E2", 8, 4, 3)
    svc = ReplanService([(wl, pf)])
    before = svc.fleet_digest()
    published = svc.tick([StageDrift(0, 50, 3.0)])
    assert published == {}
    assert svc.fleet_digest() == before
    assert svc.metrics.dropped_events == 1


def test_platform_names_stay_bounded():
    """Repeated degradation / pod failure appends each suffix at most once —
    names cannot accrete over a long trace — and the name never feeds the
    signature, so dedup is unaffected."""
    wl, pf = gen_instance("E2", 8, 6, 3)
    d = pf.degrade(0, 2.0).degrade(1, 2.0).degrade(0, 1.5)
    assert d.name.count("-degraded") == 1
    f = d.without(0).without(1).without(2)
    assert f.name.count("-failed") == 1
    assert f.name.count("-degraded") == 1
    renamed = Platform(pf.s, pf.b, name="something-else")
    assert signature(wl, renamed).digest == signature(wl, pf).digest


# ---------------------------------------------------------------------------
# Serve satellite: temperature sampling
# ---------------------------------------------------------------------------

def test_sample_tokens_greedy_is_argmax():
    logits = np.array([[0.1, 2.0, -1.0], [3.0, 0.0, 1.0]], np.float32)
    np.testing.assert_array_equal(sample_tokens(logits, greedy=True), [1, 0])
    # temperature <= 0 also short-circuits to argmax
    np.testing.assert_array_equal(
        sample_tokens(logits, np.random.default_rng(0), greedy=False,
                      temperature=0.0), [1, 0])


def test_sample_tokens_seeded_and_distributed():
    """Same seed, same draw; and over many draws the frequencies track
    softmax(logits/T) (Gumbel-max correctness)."""
    logits = np.log(np.array([[0.6, 0.3, 0.1]], np.float32))
    a = sample_tokens(np.tile(logits, (4, 1)), np.random.default_rng(5),
                      greedy=False)
    b = sample_tokens(np.tile(logits, (4, 1)), np.random.default_rng(5),
                      greedy=False)
    np.testing.assert_array_equal(a, b)
    draws = sample_tokens(np.tile(logits, (4000, 1)),
                          np.random.default_rng(11), greedy=False,
                          temperature=1.0)
    freq = np.bincount(draws, minlength=3) / 4000
    np.testing.assert_allclose(freq, [0.6, 0.3, 0.1], atol=0.03)


def test_sample_tokens_low_temperature_approaches_greedy():
    rng = np.random.default_rng(2)
    logits = np.array([[0.0, 1.0, 0.5]], np.float32)
    draws = [int(sample_tokens(logits, rng, greedy=False, temperature=1e-4)[0])
             for _ in range(50)]
    assert all(d == 1 for d in draws)


# ---------------------------------------------------------------------------
# Fail-fast construction + bounded plan cache (PR-8 satellites)
# ---------------------------------------------------------------------------

def test_init_validates_knobs_fail_fast():
    wl, pf = gen_instance("E2", 8, 4, 0)
    with pytest.raises(ValueError, match="unknown backend"):
        ReplanService([(wl, pf)], backend="cuda")
    with pytest.raises(ValueError, match="solve_deadline"):
        ReplanService([(wl, pf)], solve_deadline=-1.0)
    with pytest.raises(ValueError, match="reliability_floor"):
        ReplanService([(wl, pf)], reliability_floor=1.5)
    with pytest.raises(ValueError, match="plan_cache_cap"):
        ReplanService([(wl, pf)], plan_cache_cap=0)
    with pytest.raises(ValueError, match="quarantine_after"):
        ReplanService([(wl, pf)], quarantine_after=0)


def test_plan_cache_default_cap_never_evicts():
    """The default LRU cap sits far above the standard traces' distinct
    problem count: zero evictions, and the hit-rate + published plans are
    identical to an unbounded cache."""
    pairs, _, chaos = _chaos_fleet()
    capped = ReplanService(pairs)                       # default cap
    unbounded = ReplanService(pairs, plan_cache_cap=None)
    capped.run_trace(chaos)
    unbounded.run_trace(chaos)
    assert capped.plan_cache.evictions == 0
    assert capped.metrics.cache_evictions == 0
    assert capped.metrics.dedup_hit_rate() == unbounded.metrics.dedup_hit_rate()
    assert capped.metrics.warm_hits == unbounded.metrics.warm_hits
    assert capped.fleet_digest() == unbounded.fleet_digest()


def test_plan_cache_tiny_cap_evicts_but_stays_bit_identical():
    """Eviction pressure costs re-solves, never correctness: a 2-entry cache
    publishes the same plans as an unbounded one (exact-bytes signatures)."""
    pairs, _, chaos = _chaos_fleet()
    tiny = ReplanService(pairs, plan_cache_cap=2)
    ref = ReplanService(pairs, plan_cache_cap=None)
    tiny.run_trace(chaos)
    ref.run_trace(chaos)
    assert tiny.plan_cache.evictions > 0
    # metrics count per-tick evictions; the cache counter also includes the
    # initial (pre-metrics) fleet planning
    assert 0 < tiny.metrics.cache_evictions <= tiny.plan_cache.evictions
    assert len(tiny.plan_cache) <= 2
    assert tiny.metrics.solves >= ref.metrics.solves   # evictions re-solve
    assert tiny.fleet_digest() == ref.fleet_digest()


def test_plan_cache_lru_order_touches_on_hit():
    from repro.fleet.service import _PlanCache
    c = _PlanCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.lookup("a") == 1        # touch "a": now "b" is oldest
    c.put("c", 3)
    assert c.evictions == 1
    assert "b" not in c and "a" in c and "c" in c
