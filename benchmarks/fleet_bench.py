"""Fleet replanning benchmark: burst-trace replay through the service.

Replays the *standard trace* — a fixed-seed correlated burst trace over a
replicated fleet — through :class:`repro.fleet.ReplanService` and records
ROADMAP item 2's success metrics as ``fleet_replan_*`` rows:

  - ``fleet_replan_throughput`` — replans/sec over the whole replay
  - ``fleet_replan_latency``    — p50/p99 per-request replan latency
  - ``fleet_replan_dedup``      — signature dedup hit-rate (gated floor)
  - ``fleet_replan_churn``      — mean fraction of layers remapped

Unlike ``planner_bench.py`` (which regenerates BENCH_planner.json wholesale),
this script MERGES its rows into the existing file so the two benchmarks can
run independently; ``benchmarks/bench_gate.py`` requires the rows and gates
the dedup and throughput floors.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] [--backend B]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
BENCH_JSON = REPO_ROOT / "BENCH_planner.json"

from repro.fleet import ReplanService, gen_burst_trace, make_fleet  # noqa: E402

# The standard trace: every number fixed so the measured dedup hit-rate and
# throughput are comparable across PRs (bench_gate floors assume this shape).
STANDARD = dict(n_groups=16, replicas=16, n=12, p=6, fleet_seed=2007,
                num_ticks=30, trace_seed=42, burst_prob=0.6)
QUICK = dict(n_groups=6, replicas=8, n=8, p=4, fleet_seed=2007,
             num_ticks=12, trace_seed=42, burst_prob=0.6)


def run(quick: bool = False, backend: str = "numpy") -> list:
    cfg = QUICK if quick else STANDARD
    pairs, groups = make_fleet(cfg["n_groups"], cfg["replicas"], cfg["n"],
                               cfg["p"], seed=cfg["fleet_seed"])
    trace = gen_burst_trace(groups, cfg["num_ticks"], seed=cfg["trace_seed"],
                            n_stages=cfg["n"], initial_pods=cfg["p"],
                            burst_prob=cfg["burst_prob"])
    svc = ReplanService(pairs, backend=backend)
    metrics = svc.run_trace(trace)
    extra = {"backend": backend, "fleet_size": len(pairs),
             "digest": svc.fleet_digest()}
    return metrics.bench_rows(extra=extra)


def merge_bench_json(rows, path: pathlib.Path = BENCH_JSON,
                     mode: str = "full") -> None:
    """Merge rows into the existing BENCH json (planner_bench owns the file
    and overwrites it wholesale; we only add/update our rows)."""
    payload = json.loads(path.read_text()) if path.exists() else {}
    payload.setdefault("_meta", {})["mode"] = mode
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        entry = {"us_per_call": us, "derived": derived}
        if len(row) > 3 and row[3]:
            entry.update(row[3])
        payload[name] = entry
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="numpy")
    args = ap.parse_args()
    rows = run(quick=args.quick, backend=args.backend)
    for name, us, derived, _ in rows:
        print(f"{name},{'' if us is None else f'{us:.1f}'},{derived}")
    merge_bench_json(rows, mode="quick" if args.quick else "full")
    print(f"# merged into {BENCH_JSON}")


if __name__ == "__main__":
    main()
