"""The paper's six heuristics: behavior, faithfulness, and fast-path identity."""

import math

import numpy as np
import pytest

from repro.core import (FIXED_LATENCY_HEURISTICS, FIXED_PERIOD_HEURISTICS,
                        Platform, Workload, brute_force, evaluate,
                        make_platform, make_workload, optimal_latency,
                        run_heuristic, single_processor_mapping, period)
from repro.core.heuristics import reference_mode, split_trajectory


def _rand_instance(rng, n_max=20, p_max=12):
    n = int(rng.integers(2, n_max))
    p = int(rng.integers(2, p_max))
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 101, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    return wl, pf


def test_fast_paths_match_reference():
    rng = np.random.default_rng(42)
    for _ in range(25):
        wl, pf = _rand_instance(rng)
        for code in ["H1", "H2", "H3", "H5", "H6"]:
            bound = (float(rng.uniform(0.1, 50)) if code in ("H1", "H2", "H3")
                     else optimal_latency(wl, pf) * float(rng.uniform(1.0, 3.0)))
            fast = run_heuristic(code, wl, pf, bound)
            with reference_mode():
                ref = run_heuristic(code, wl, pf, bound)
            assert fast.mapping == ref.mapping, (code, bound)
            assert fast.period == pytest.approx(ref.period)
            assert fast.latency == pytest.approx(ref.latency)


def test_feasible_results_respect_constraints():
    rng = np.random.default_rng(7)
    for _ in range(30):
        wl, pf = _rand_instance(rng)
        for code in FIXED_PERIOD_HEURISTICS:
            bound = float(rng.uniform(0.5, 30))
            r = run_heuristic(code, wl, pf, bound)
            if r.feasible:
                assert r.period <= bound + 1e-9
                r.mapping.validate(wl.n, pf.p)
        for code in FIXED_LATENCY_HEURISTICS:
            bound = optimal_latency(wl, pf) * float(rng.uniform(0.8, 2.5))
            r = run_heuristic(code, wl, pf, bound)
            if r.feasible:
                assert r.latency <= bound + 1e-9
                r.mapping.validate(wl.n, pf.p)


def test_fixed_latency_failure_iff_below_optimal():
    """H5/H6 fail exactly when L_fix < L_opt (explains the paper's Table-1
    observation that their failure thresholds coincide)."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        wl, pf = _rand_instance(rng)
        lopt = optimal_latency(wl, pf)
        for code in ("H5", "H6"):
            assert not run_heuristic(code, wl, pf, lopt * 0.999).feasible
            assert run_heuristic(code, wl, pf, lopt * 1.001).feasible


def test_initial_state_is_optimal_latency():
    wl = make_workload([3, 4, 5], [1, 1, 1, 1])
    pf = make_platform([2.0, 8.0, 4.0], b=10.0)
    r = run_heuristic("H5", wl, pf, optimal_latency(wl, pf))
    assert r.feasible
    assert r.latency == pytest.approx(optimal_latency(wl, pf))
    assert r.mapping.alloc == (1,)       # fastest processor


def test_trajectory_matches_direct_runs():
    """result(H, P_fix) == first trajectory state with period <= P_fix."""
    rng = np.random.default_rng(11)
    for _ in range(15):
        wl, pf = _rand_instance(rng)
        for code in ["H1", "H2", "H3"]:
            traj = split_trajectory(code, wl, pf)
            assert traj[0][0] >= traj[-1][0] - 1e-12  # period non-increasing
            for frac in (0.2, 0.5, 0.9):
                bound = traj[0][0] * frac
                direct = run_heuristic(code, wl, pf, bound)
                hit = next(((p, l) for p, l in traj if p <= bound + 1e-12), None)
                if hit is None:
                    assert not direct.feasible
                else:
                    assert direct.feasible
                    assert direct.period == pytest.approx(hit[0])
                    assert direct.latency == pytest.approx(hit[1])


def test_splitting_gives_speedup_on_uniform_chain():
    """Uniform stages on equal-speed processors: H1 run to exhaustion should
    parallelize substantially (period well below single-processor)."""
    wl = make_workload([10.0] * 16, [0.0] * 17)
    pf = make_platform([1.0] * 8, b=1.0)
    r = run_heuristic("H1", wl, pf, 0.0)   # run to exhaustion (infeasible bound)
    single = 160.0
    assert r.period <= single / 4          # at least 4x speedup with 8 procs


def test_h4_beats_or_matches_h1_latency():
    """H4's binary search minimizes latency under the period bound; at equal
    period bounds its latency should not exceed H1's by much (usually less)."""
    rng = np.random.default_rng(5)
    wins = total = 0
    for _ in range(20):
        wl, pf = _rand_instance(rng)
        bound = period(wl, pf, single_processor_mapping(wl, pf.fastest())) * 0.75
        r1 = run_heuristic("H1", wl, pf, bound)
        r4 = run_heuristic("H4", wl, pf, bound)
        if r1.feasible and r4.feasible:
            total += 1
            if r4.latency <= r1.latency + 1e-9:
                wins += 1
    assert total > 5
    assert wins / total >= 0.5
