"""Quickstart: the paper's bi-criteria pipeline mapping, end to end.

1. Build a pipeline workload (here: qwen3-4b's 36 transformer blocks at the
   train_4k shape) and a heterogeneous platform (4 pods, one degraded).
2. Run the paper's heuristics + the auto portfolio planner.
3. Inspect the period/latency trade-off and the resulting stage plan.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import (NAMES, Objective, make_platform, optimal_latency,
                        plan, plan_with_deal, run_heuristic, tradeoff_curves)
from repro.models.common import SHAPES
from repro.models.registry import lm_workload


def main() -> None:
    cfg = get_config("qwen3-4b")
    wl = lm_workload(cfg, SHAPES["train_4k"])
    print(f"workload: {wl.n} stages, {wl.total_work/1e12:.1f} TFLOP per step")

    # 4 pods at 25.2 PF/s effective each; pod 2 is thermally degraded 1.6x
    pf = make_platform([25.2e15, 25.2e15, 25.2e15 / 1.6, 25.2e15], b=25e9)

    print("\n--- paper heuristics, fixed period = 1.5x ideal ---")
    ideal = wl.total_work / pf.s.sum()
    for code in ("H1", "H2", "H3", "H4"):
        r = run_heuristic(code, wl, pf, ideal * 1.5)
        status = "ok " if r.feasible else "FAIL"
        print(f"{code} {NAMES[code]:14s} [{status}] period={r.period*1e3:7.2f}ms "
              f"latency={r.latency*1e3:7.2f}ms splits={r.splits}")

    print("\n--- fixed latency = 1.2x optimal ---")
    lopt = optimal_latency(wl, pf)
    for code in ("H5", "H6"):
        r = run_heuristic(code, wl, pf, lopt * 1.2)
        print(f"{code} {NAMES[code]:14s} period={r.period*1e3:7.2f}ms "
              f"latency={r.latency*1e3:7.2f}ms")

    print("\n--- auto portfolio planner (min period) ---")
    p = plan(wl, pf, Objective("period"), mode="auto")
    print(f"planner={p.planner} stages={p.stage_sizes} on pods {p.mapping.alloc}")
    print(f"period={p.period*1e3:.2f}ms latency={p.latency*1e3:.2f}ms "
          f"padding_overhead={p.padding_overhead:.1%}")
    print("note: the degraded pod receives the smallest interval")

    print("\n--- deal-skeleton extension (the paper's Section-7 future work) ---")
    # A compute-dominated chain (the paper's E3 regime) with one huge stage:
    # interval splitting is stuck (a stage is atomic), dealing replicates it.
    from repro.sim import gen_instance

    wl3, pf3 = gen_instance("E3", n=8, p=10, seed=7)
    base3 = plan(wl3, pf3, Objective("period"), mode="auto")
    dealt = plan_with_deal(wl3, pf3, Objective("period"))
    print(f"base:   m={base3.num_stages} stages, period={base3.period:.2f}")
    print(f"dealt:  groups={dealt.groups}")
    print(f"        period={dealt.period:.2f} "
          f"({(1 - dealt.period/base3.period):.1%} better)")


if __name__ == "__main__":
    main()
