"""Mixture-of-Experts FFN with sort-based token dispatch.

Dispatch is capacity-bounded and sort-based (Megablocks-style, adapted to
XLA/TPU): tokens are argsorted by expert id, ranked within their expert, and
scattered into dense (E, C, d) buffers, so expert compute is plain batched
einsum on MXU-aligned shapes and the compiled FLOPs reflect *active* experts
only (top-k), keeping the roofline's MoE accounting honest.  Tokens beyond
capacity are dropped (standard GShard semantics, capacity_factor 1.25).

Supports Arctic's "dense residual": a standard MLP running in parallel with
the MoE, summed at the output.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, abstract_mesh
from .layers import dense_init, init_mlp, mlp, shard


def init_moe(key, cfg: ModelConfig) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    pdt = cfg.jparam_dtype
    p = {
        "router": dense_init(ks[0], (d, E), pdt),
        "wi": dense_init(ks[1], (E, d, f), pdt, fan_in=d),
        "wg": dense_init(ks[2], (E, d, f), pdt, fan_in=d),
        "wo": dense_init(ks[3], (E, f, d), pdt, fan_in=f),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff)
    return p


def _moe_groups(N: int, E: int, B: int) -> int:
    """Number of dispatch groups: one per data shard when it divides the
    batch (locality by construction — sort/scatter never cross shards),
    clamped so each group still feeds every expert a reasonable slice."""
    am = abstract_mesh()
    dsize = 1
    if am is not None and not am.empty:
        for a in ("pod", "data"):
            if a in am.axis_names:
                dsize *= am.shape[a]
    G = dsize
    while G > 1 and (B % G or (N // G) < 2 * E):
        G //= 2
    return max(G, 1)


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple:
    """x: (B, S, d) -> (y, aux_loss).

    Grouped local dispatch: tokens are split into G groups, one per data
    shard (read off the abstract mesh at trace time), and ALL dispatch
    machinery is per-group — batched argsort rows, searchsorted counts,
    take_along_axis gathers — so nothing crosses shards.  The only scatter is
    the capacity-buffer fill, with group-major *sorted unique* indices.  The
    combine is scatter-free: each (token, choice) pair gathers its expert
    output back through the inverse sort permutation.  Expert einsums carry
    an explicit G dim sharded on 'data' with experts (or the expert FFN dim)
    sharded on 'model': compiled FLOPs are active-only with no data-axis
    redundancy.  Dropping is per-group (standard for dropping MoE)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * S
    dt = x.dtype
    G = _moe_groups(N, E, B)
    n = N // G

    flat = x.reshape(G, n, d)
    flat = shard(flat, "batch", None, "d_model")

    am = abstract_mesh()
    data_axes = tuple(a for a in ("pod", "data")
                      if am is not None and not am.empty and a in am.axis_names)
    dsize = 1
    for a in data_axes:
        dsize *= am.shape[a]
    if cfg.moe_shard_map and data_axes and dsize > 1 and G % dsize == 0:
        # Manual over the data axes: the dispatch below becomes provably
        # shard-local (GSPMD cannot insert conservative collectives around
        # the scatter/gathers); 'model' stays auto for the expert einsums.
        from jax.sharding import PartitionSpec as P

        spec_g = P(data_axes if len(data_axes) > 1 else data_axes[0])
        # NOTE(perf, TPU): doing the boundary grad-psum in bf16 would halve
        # its wire bytes, but XLA:CPU crashes compiling bf16 all-reduce
        # ("Invalid binary instruction opcode copy" in AllReducePromotion).
        # bf16 params are therefore staged through f32 before capture so the
        # psum stays f32 — one extra per-layer cast (~0.4s memory-term for
        # arctic) instead of a 16x collective blowup.  See EXPERIMENTS.md §Perf.
        logical = {"wi": ("experts", None, None), "wg": ("experts", None, None),
                   "wo": ("experts", None, None), "router": (None, None)}
        cap = {}
        for kk, ax in logical.items():
            w = params[kk]
            if cfg.fsdp_params:
                # undo the data-axis shard (per-layer FSDP all-gather); the
                # constraint's transpose reduce-scatters the grads back
                w = shard(w, *ax)
            if w.dtype == jnp.bfloat16:
                w = w.astype(jnp.float32)   # f32 boundary psum (XLA:CPU bug)
            cap[kk] = w

        def _local(fl):
            y, aux = _grouped_dispatch(cap, fl, cfg)
            return y, jax.lax.psum(aux, data_axes) / dsize

        local = jax.shard_map(_local, in_specs=(spec_g,),
                              out_specs=(spec_g, P()),
                              axis_names=set(data_axes), check_vma=False)
        y, aux = local(flat)
    else:
        y, aux = _grouped_dispatch(params, flat, cfg)
    y = shard(y, "batch", None, "d_model")
    y = y.reshape(B, S, d)

    if cfg.dense_residual:
        y = y + mlp(params["dense"], x, cfg)
    return shard(y, "batch", "seq", "d_model"), aux


def _grouped_dispatch(params, flat, cfg: ModelConfig) -> tuple:
    """Dispatch + expert compute for (G_local, n, d) token groups.  All ops
    are row-local; safe to run under data-manual shard_map."""
    G, n, d = flat.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = flat.dtype
    nk = n * k
    C = max(1, int(math.ceil(n * k / E * cfg.capacity_factor)))

    logits = jnp.einsum("gnd,de->gne", flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_logits, top_ids = jax.lax.top_k(logits, k)                  # (G, n, k)
    weights = jax.nn.softmax(top_logits, axis=-1).astype(dt)        # mixtral convention

    # Load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs_full, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_ids[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch (all row-local ops) -----------------
    eids = top_ids.reshape(G, nk)
    token_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)[None], (G, nk))
    order = jnp.argsort(eids, axis=-1, stable=True)                  # (G, nk)
    e_sorted = jnp.take_along_axis(eids, order, axis=-1)
    tok_sorted = jnp.take_along_axis(token_of, order, axis=-1)
    # counts per expert from the sorted rows (no scatter): binary search
    bounds = jnp.arange(E + 1, dtype=jnp.int32)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, bounds, side="left"))(
        e_sorted)                                                    # (G, E+1)
    offsets = starts[:, :-1]                                         # (G, E)
    rank = jnp.arange(nk, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(offsets, e_sorted, axis=-1)              # (G, nk)
    keep = rank < C
    r_idx = jnp.minimum(rank, C - 1)

    gathered = jnp.take_along_axis(flat, tok_sorted[..., None], axis=1)
    gathered = gathered * keep[..., None].astype(dt)                 # (G, nk, d)

    # one scatter: group-major flattened, indices sorted & unique
    tgt = e_sorted * C + r_idx                                       # (G, nk)
    gidx = (jnp.arange(G, dtype=jnp.int32)[:, None] * (E * C) + tgt).reshape(-1)
    buf = jnp.zeros((G * E * C, d), dt)
    buf = buf.at[gidx].add(gathered.reshape(G * nk, d),
                           indices_are_sorted=True)
    buf = buf.reshape(G, E, C, d)
    buf = shard(buf, None, "experts", None, "d_model")

    # ---- expert compute (explicit G dim) -----------------------------------
    h = jnp.einsum("gecd,edf->gecf", buf, params["wi"].astype(dt))
    g = jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    h = shard(h, None, "experts", None, "ff")
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(dt))
    out = shard(out, None, "experts", None, "d_model")

    # ---- scatter-free combine: inverse-permutation gathers ------------------
    inv_order = jnp.argsort(order, axis=-1)                          # (G, nk)
    loc_sorted = e_sorted * C + r_idx                                # (G, nk)
    loc = jnp.take_along_axis(loc_sorted, inv_order, axis=-1)        # pair order
    keep_pair = jnp.take_along_axis(keep, inv_order, axis=-1)
    out_flat = out.reshape(G, E * C, d)
    back = jnp.take_along_axis(out_flat, loc[..., None], axis=1)     # (G, nk, d)
    back = back * (weights.reshape(G, nk) * keep_pair.astype(dt))[..., None]
    y = back.reshape(G, n, k, d).sum(axis=2)                         # (G, n, d)
    return y, aux


def moe_ffn_tokens(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Decode-friendly MoE for small N (B tokens): per-token expert gather.

    For single-token decode, dispatch-sort machinery is overkill; compute the
    k selected experts per token by gathering their weights (N*k small)."""
    B, S, d = x.shape
    N = B * S
    k = cfg.top_k
    dt = x.dtype
    flat = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    top_logits, top_ids = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_logits, axis=-1).astype(dt)        # (N, k)
    wi = params["wi"].astype(dt)[top_ids]                            # (N, k, d, f)
    wg = params["wg"].astype(dt)[top_ids]
    wo = params["wo"].astype(dt)[top_ids]                            # (N, k, f, d)
    h = jnp.einsum("nd,nkdf->nkf", flat, wi)
    g = jnp.einsum("nd,nkdf->nkf", flat, wg)
    h = jax.nn.silu(g) * h
    out = jnp.einsum("nkf,nkfd->nkd", h, wo)
    y = jnp.einsum("nkd,nk->nd", out, weights).reshape(B, S, d)
    if cfg.dense_residual:
        y = y + mlp(params["dense"], x, cfg)
    return y
