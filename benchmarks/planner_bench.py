"""Planner quality + speed: heuristic optimality gap vs the exact solver on
small/medium instances, runtime scaling, the vectorized candidate-evaluation
speedup, and the batched-vs-fused campaign-engine comparison (warm, cold,
and cold-with-persistent-compilation-cache).

Prints ``name,us_per_call,derived`` CSV rows and writes them as
machine-readable ``BENCH_planner.json`` at the repo root so the perf
trajectory is tracked across PRs.  Rows additionally carry STRUCTURED fields
(``speedup``, ``dispatches``, ``cold_us``, ...) next to the human-readable
``derived`` string — ``benchmarks/bench_gate.py`` parses those to fail CI on
perf regressions.  Quality-only rows (optimality gaps) carry no
``us_per_call`` — gaps are reported in ``derived``/``gap`` only.

    PYTHONPATH=src python benchmarks/planner_bench.py [--quick]
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from repro.core import (Objective, PlanRequest, auto_request, evaluate,
                        evaluate_batch, exact_min_period, make_platform,
                        make_workload, pareto_exact, period, plan_request,
                        solve)
from repro.sim.experiments import run_campaign, run_experiment, summarize_experiment
from repro.sim.generators import gen_instance

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = REPO_ROOT / "BENCH_planner.json"


def optimality_gaps(n_inst: int = 20, seed: int = 0) -> dict:
    """Mean period gap (heuristic / exact - 1) on instances small enough for
    the exact bitmask solver (n<=14, p<=9)."""
    rng = np.random.default_rng(seed)
    gaps = {c: [] for c in ("H1", "H2", "H3", "auto")}
    for _ in range(n_inst):
        n = int(rng.integers(4, 14))
        p = int(rng.integers(3, 9))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        opt = period(wl, pf, exact_min_period(wl, pf))
        for code in ("H1", "H2", "H3"):
            # run to exhaustion: an unreachable period bound minimizes period
            c = solve(code, wl, pf, Objective("latency", bound=0.0))
            gaps[code].append(c.period / opt - 1)
        rep = plan_request(auto_request(wl, pf, Objective("period")))
        gaps["auto"].append(rep.plan.period / opt - 1)
    return {c: float(np.mean(v)) for c, v in gaps.items()}


def timing(reps: int = 10) -> list:
    """us_per_call for each solver at the paper's largest size (n=40, p=100),
    plus the full request/report portfolio."""
    rows = []
    wl, pf = gen_instance("E2", 40, 100, seed=1)
    for code in ("H1", "H2", "H3", "H5", "H6"):
        obj = (Objective("latency", bound=0.0) if code in ("H1", "H2", "H3")
               else Objective("period", bound=1e18))
        t0 = time.perf_counter()
        for _ in range(reps):
            solve(code, wl, pf, obj)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"heuristic_{code}_n40_p100", us, ""))
    t0 = time.perf_counter()
    plan_request(auto_request(wl, pf, Objective("period")))
    rows.append(("planner_auto_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    t0 = time.perf_counter()
    plan_request(PlanRequest(wl, pf, Objective("period")))
    rows.append(("plan_request_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    return rows


def vectorized_eval(reps: int = 5, seed: int = 3) -> list:
    """The tentpole perf claim: batch candidate evaluation vs the per-mapping
    Python loop, on the full mapping enumeration of a small instance (the
    workload of portfolio tables, sweeps, and pareto_exact)."""
    import itertools

    from repro.core import Mapping, all_interval_partitions

    rng = np.random.default_rng(seed)
    n, p = 8, 5
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 51, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    mappings = [Mapping(iv, procs)
                for m in range(1, min(n, p) + 1)
                for iv in all_interval_partitions(n, m)
                for procs in itertools.permutations(range(p), m)]

    t0 = time.perf_counter()
    for _ in range(reps):
        loop = np.array([evaluate(wl, pf, mp) for mp in mappings])
    us_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        batch = evaluate_batch(wl, pf, mappings)
    us_batch = (time.perf_counter() - t0) / reps * 1e6
    assert np.allclose(loop, batch)

    t0 = time.perf_counter()
    for _ in range(reps):
        pareto_exact(wl, pf)
    us_pex = (time.perf_counter() - t0) / reps * 1e6

    k = len(mappings)
    return [
        (f"evaluate_loop_{k}_mappings", us_loop, ""),
        (f"evaluate_batch_{k}_mappings", us_batch,
         f"speedup={us_loop / us_batch:.1f}x"),
        (f"pareto_exact_n{n}_p{p}", us_pex, "vectorized enumeration"),
    ]


def _engine_comparison_rows(exps, points, kw, row_prefix) -> list:
    """Time a family set through all three engines (scalar reference, numpy
    lockstep, fused cold + warm), asserting byte-identical outputs, and emit
    ``{row_prefix}{scalar,batched,fused}_<tag>`` rows."""
    t0 = time.perf_counter()
    scal = {(e, n, p): run_experiment(e, n, p, engine="scalar", **kw)
            for n, p in points for e in exps}
    us_scal = (time.perf_counter() - t0) * 1e6

    def run_engine(backend):
        t0 = time.perf_counter()
        out = {}
        for n, p in points:
            camp = run_campaign(exps, n, p, backend=backend, **kw)
            for e in exps:
                out[(e, n, p)] = camp[e]
        return out, (time.perf_counter() - t0) * 1e6

    batc, us_batc = run_engine("numpy")
    fusd, us_cold = run_engine("fused")    # includes jit traces
    _, us_fusd = run_engine("fused")       # warm: traces cached
    for key in scal:
        assert summarize_experiment(scal[key]) == summarize_experiment(batc[key]), key
        assert summarize_experiment(scal[key]) == summarize_experiment(fusd[key]), key
    tag = (f"{exps[0]}-{exps[-1]}_"
           + "_".join(f"n{n}p{p}" for n, p in points))
    return [
        (f"{row_prefix}scalar_{tag}", us_scal, "per-instance reference path"),
        (f"{row_prefix}batched_{tag}", us_batc,
         f"speedup={us_scal / us_batc:.1f}x vs scalar, identical outputs",
         {"speedup_vs_scalar": us_scal / us_batc, "identical_outputs": True}),
        (f"{row_prefix}fused_{tag}", us_fusd,
         f"warm; speedup={us_scal / us_fusd:.1f}x vs scalar, "
         f"cold_with_traces_us={us_cold:.0f}, identical outputs",
         {"speedup_vs_scalar": us_scal / us_fusd, "cold_us": us_cold,
          "vs_batched": us_batc / us_fusd, "identical_outputs": True}),
    ]


def campaign_speedup(quick: bool = False) -> list:
    """The batched and fused campaign engines vs the per-instance reference
    path on a representative Section-5 slice (all four experiment families,
    paper batch size, small and large (n, p) points), asserting identical
    outputs while timing all three.  The fused engine is timed twice: cold
    (including its one-off jit traces) and warm (the steady-state cost every
    further campaign of the same shapes pays)."""
    if quick:
        points = ((10, 10),)
        kw = dict(n_pairs=4, n_bounds=4, h4_iters=4, include_h4=True)
    else:
        points = ((10, 10), (20, 100), (40, 100))
        kw = dict(n_pairs=50, n_bounds=12, h4_iters=10, include_h4=True)
    return _engine_comparison_rows(("E1", "E2", "E3", "E4"), points, kw,
                                   "campaign_")


def fused_large_grid(quick: bool = False) -> list:
    """The n in {80, 160}, p = 1000 follow-up families under the (now
    span-bucketed) fused engine — the campaign shape whose static-grid tax
    was steepest (PR-4 warm: 23.5 s at n=160 vs 2.2 s numpy) — asserting
    byte-identical outputs vs the numpy lockstep path.  Row names are stable
    across PRs so the bucketing win shows on the same rows."""
    from repro.core import fused

    if quick:
        points, n_pairs = ((80, 1000),), 2
    else:
        points, n_pairs = ((80, 1000), (160, 1000)), 4
    exps = ("E1", "E2", "E3", "E4")
    kw = dict(n_pairs=n_pairs, n_bounds=8, h4_iters=6, include_h4=True)
    rows = []
    for n, p in points:
        t0 = time.perf_counter()
        ref = run_campaign(exps, n, p, backend="numpy", **kw)
        us_np = (time.perf_counter() - t0) * 1e6
        fused.reset_bucket_trace_count()
        t0 = time.perf_counter()
        run_campaign(exps, n, p, backend="fused", **kw)   # cold: jit traces
        us_cold = (time.perf_counter() - t0) * 1e6
        buckets = fused.bucket_trace_count()
        t0 = time.perf_counter()
        fus = run_campaign(exps, n, p, backend="fused", **kw)
        us_warm = (time.perf_counter() - t0) * 1e6
        for e in exps:
            assert summarize_experiment(ref[e]) == summarize_experiment(fus[e]), (e, n)
        rows.append((f"campaign_fused_largegrid_E1-E4_n{n}p{p}", us_warm,
                     f"warm, span-bucketed; numpy_batched_us={us_np:.0f}, "
                     f"cold_with_traces_us={us_cold:.0f}, "
                     f"bucket_traces={buckets}, identical outputs",
                     {"numpy_batched_us": us_np, "cold_us": us_cold,
                      "vs_batched": us_np / us_warm, "bucket_traces": buckets,
                      "bucket_trace_budget": fused.trace_budget(n),
                      "identical_outputs": True}))
    return rows


def fused_bucketed_cold_start(quick: bool = False) -> list:
    """The span-bucketed fused engine's cold-start story, measured in FRESH
    subprocesses: cold without the persistent compilation cache, cold with a
    warmed cache (compile replaced by cache load), and the in-process warm
    steady state.  The with/without-cache delta is the satellite claim of
    this PR's cold-start work (``enable_persistent_cache`` + donated SoA
    buffers)."""
    import tempfile

    from repro.core import fused

    n, p, pairs, nb = (9, 7, 3, 4) if quick else (20, 100, 8, 6)
    tag = f"E1-E4_n{n}p{p}"
    exps = ("E1", "E2", "E3", "E4")
    child = (
        "import time, sys\n"
        "from repro.core import fused\n"
        "cache = sys.argv[1]\n"
        "if cache != 'none':\n"
        "    fused.enable_persistent_cache(cache)\n"
        "from repro.sim.experiments import run_campaign\n"
        "t0 = time.perf_counter()\n"
        f"run_campaign({exps!r}, {n}, {p}, n_pairs={pairs}, n_bounds={nb},\n"
        f"             h4_iters=4, backend='fused')\n"
        "print('ELAPSED_US=%.0f' % ((time.perf_counter() - t0) * 1e6))\n"
    )

    def run_child(cache_arg):
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(REPO_ROOT / "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        env.pop("JAX_COMPILATION_CACHE_DIR", None)
        out = subprocess.run([sys.executable, "-c", child, cache_arg],
                             capture_output=True, text=True, env=env,
                             check=True)
        for line in out.stdout.splitlines():
            if line.startswith("ELAPSED_US="):
                return float(line.split("=", 1)[1])
        raise RuntimeError(f"no timing in child output: {out.stdout!r}")

    us_nocache = run_child("none")
    with tempfile.TemporaryDirectory(prefix="repro-jax-cache-") as cachedir:
        run_child(cachedir)                  # populate the cache
        us_cached = run_child(cachedir)      # fresh process, warm cache

    # in-process warm steady state of the same campaign shape
    kw = dict(n_pairs=pairs, n_bounds=nb, h4_iters=4, include_h4=True)
    run_campaign(exps, n, p, backend="fused", **kw)
    t0 = time.perf_counter()
    run_campaign(exps, n, p, backend="fused", **kw)
    us_warm = (time.perf_counter() - t0) * 1e6
    return [
        (f"campaign_fused_bucketed_warm_{tag}", us_warm,
         "in-process warm steady state (traces cached)",
         {"buckets_k1": len(fused.bucket_sizes(n, 1)),
          "buckets_k2": len(fused.bucket_sizes(n, 2))}),
        (f"campaign_fused_bucketed_cold_nocache_{tag}", us_nocache,
         "fresh process, no persistent compilation cache (full jit traces)"),
        (f"campaign_fused_bucketed_cold_cache_{tag}", us_cached,
         f"fresh process, warm persistent compilation cache "
         f"(cache_speedup={us_nocache / us_cached:.1f}x vs no-cache cold)",
         {"cache_speedup": us_nocache / us_cached,
          "nocache_cold_us": us_nocache}),
    ]


def split_score_pallas(quick: bool = False) -> list:
    """The pallas split-scoring kernels vs the shared numpy kernels on a
    lockstep-representative candidate grid (identical floats on every live
    lane, asserted).  On CPU the pallas path runs in interpret mode — the
    honest number here is its overhead factor; the compiled TPU/GPU path is
    what the kernels exist for."""
    from repro.core.heuristics import _PERMS3, score_2way_kernel, score_3way_kernel
    from repro.kernels import split_score

    rng = np.random.default_rng(23)
    A, K = (16, 64) if quick else (64, 160)
    reps = 3 if quick else 20
    pre = np.sort(rng.uniform(0.0, 100.0, (A, K + 2)), axis=1)
    delta = rng.uniform(0.0, 50.0, (A, K + 2))
    args = (pre[:, :1], pre[:, 1:-1], pre[:, -1:],
            delta[:, :1], delta[:, 1:-1], delta[:, -1:], 10.0,
            rng.uniform(0.05, 2.0, (A, 1)), rng.uniform(0.05, 2.0, (A, 1)))
    need = rng.integers(1, K + 1, A)

    t0 = time.perf_counter()
    for _ in range(reps):
        want = score_2way_kernel(*args, xp=np)
    us_np = (time.perf_counter() - t0) / reps * 1e6
    got = split_score.score_2way_pallas(*args, need=need)   # traces
    t0 = time.perf_counter()
    for _ in range(reps):
        got = split_score.score_2way_pallas(*args, need=need)
    us_pl = (time.perf_counter() - t0) / reps * 1e6
    live = np.concatenate([np.arange(K)[None, :] < need[:, None]] * 2, axis=1)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g)[live], w[live])
    mode = "interpret" if split_score._interpret() else "compiled"
    rows = [
        (f"split_score_2way_numpy_A{A}K{K}", us_np, "shared numpy kernel"),
        (f"split_score_2way_pallas_A{A}K{K}", us_pl,
         f"{mode} mode; identical floats on live lanes, "
         f"numpy_us={us_np:.0f}",
         {"vs_numpy": us_np / us_pl, "interpret": split_score._interpret(),
          "identical_live_lanes": True}),
    ]

    span = 12 if quick else 24
    o1, o2 = np.triu_indices(span - 1, k=1)
    Kp = o1.size
    dI = rng.uniform(0.0, 10.0, (A, 3, Kp))
    W3 = rng.uniform(0.1, 100.0, (A, 3, Kp))
    dO = rng.uniform(0.0, 10.0, (A, 3, Kp))
    invp = rng.uniform(0.05, 2.0, (A, 3))[:, np.asarray(_PERMS3)][:, :, :, None]
    base = rng.uniform(1.0, 50.0, (A, 1, 1))
    spans = rng.integers(3, span + 1, A)
    need3 = split_score.pair_need(spans, span)
    t0 = time.perf_counter()
    for _ in range(reps):
        want3 = score_3way_kernel(dI[:, None], W3[:, None], dO[:, None],
                                  invp, base, xp=np)
    us_np3 = (time.perf_counter() - t0) / reps * 1e6
    got3 = split_score.score_3way_pallas(dI[:, None], W3[:, None],
                                         dO[:, None], invp, base, need=need3)
    t0 = time.perf_counter()
    for _ in range(reps):
        got3 = split_score.score_3way_pallas(dI[:, None], W3[:, None],
                                             dO[:, None], invp, base,
                                             need=need3)
    us_pl3 = (time.perf_counter() - t0) / reps * 1e6
    live_l = o2[None, :] <= (spans - 2)[:, None]
    for g, w in zip(got3, want3):
        lv = (np.broadcast_to(live_l[:, None, None, :], w.shape)
              if w.ndim == 4 else np.broadcast_to(live_l[:, None, :], w.shape))
        assert np.array_equal(np.asarray(g)[lv], w[lv])
    rows += [
        (f"split_score_3way_numpy_A{A}span{span}", us_np3,
         "shared numpy kernel"),
        (f"split_score_3way_pallas_A{A}span{span}", us_pl3,
         f"{mode} mode; identical floats on live lanes, "
         f"numpy_us={us_np3:.0f}",
         {"vs_numpy": us_np3 / us_pl3, "interpret": split_score._interpret(),
          "identical_live_lanes": True}),
    ]
    return rows


def image_family_campaign(quick: bool = False) -> list:
    """The image-processing follow-up families (I1-I4: JPEG encoder profile,
    bimodal, correlated comm∝comp, uniform-wide) through the campaign
    engines, asserting byte-identical outputs across scalar/batched/fused."""
    if quick:
        points = ((10, 10),)
        kw = dict(n_pairs=4, n_bounds=4, h4_iters=4, include_h4=True)
    else:
        points = ((10, 10), (20, 100))
        kw = dict(n_pairs=50, n_bounds=12, h4_iters=10, include_h4=True)
    return _engine_comparison_rows(("I1", "I2", "I3", "I4"), points, kw,
                                   "image_family_")


def sharded_campaign(quick: bool = False) -> list:
    """The shard_map SPMD campaign engine (``backend="sharded"``) vs the
    fused single-device engine it wraps, bit-identity asserted both times.

    Two measurements:

    - ``campaign_sharded_1dev_*`` — in-process on the default mesh (usually
      one device): the degenerate mesh must stay bit-identical to fused and
      close to it in warm time.
    - ``campaign_sharded_8dev_*`` — a FRESH subprocess under
      ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the README
      "scaling out" recipe) timing the same campaign warm through both
      engines.  On forced host devices every shard shares the host's
      compute, so IDEAL 8-device scaling is elapsed time equal to the fused
      single-program time; ``scaling_efficiency = fused_warm / sharded_warm``
      is the fraction of that ideal the engine achieves — what it loses to
      SPMD overhead (batch padding, per-shard dispatch, per-shard while-loop
      divergence).  On real multi-chip hardware the same per-shard programs
      run concurrently, so efficiency e here reads as e x D throughput
      scaling.  ``bench_gate.py`` floors the 8-device efficiency at 0.6 and
      requires ``identical_outputs`` on both rows.
    """
    exps = ("E1", "E2", "E3", "E4")

    # in-process, default mesh; warm BOTH engines at this exact campaign
    # signature before timing (the grids campaign_speedup traced use
    # different pair/bound counts, so its cache entries don't apply)
    from repro.core import sharded as sharded_mod

    n1, p1 = 10, 10
    kw1 = dict(n_pairs=4, n_bounds=4, h4_iters=4, include_h4=True)
    run_campaign(exps, n1, p1, backend="fused", **kw1)     # cold: traces
    run_campaign(exps, n1, p1, backend="sharded", **kw1)   # cold: traces
    t0 = time.perf_counter()
    ref = run_campaign(exps, n1, p1, backend="fused", **kw1)
    us_f1 = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    shd = run_campaign(exps, n1, p1, backend="sharded", **kw1)
    us_s1 = (time.perf_counter() - t0) * 1e6
    for e in exps:
        assert summarize_experiment(ref[e]) == summarize_experiment(shd[e]), e
    d1 = sharded_mod.device_count()

    # fresh subprocess with 8 forced host devices; warm best-of-reps both
    # engines back to back so they see the same machine state
    n, p = 20, 100
    pairs, nb, iters, reps = (12, 8, 6, 3) if quick else (24, 8, 6, 5)
    child = (
        "import time\n"
        "import jax\n"
        "from repro.sim.experiments import run_campaign, summarize_experiment\n"
        f"exps = {exps!r}\n"
        f"kw = dict(n_pairs={pairs}, n_bounds={nb}, h4_iters={iters},\n"
        "          include_h4=True)\n"
        f"n, p = {n}, {p}\n"
        "run_campaign(exps, n, p, backend='fused', **kw)\n"
        "run_campaign(exps, n, p, backend='sharded', **kw)\n"
        "tf = ts = float('inf')\n"
        f"for _ in range({reps}):\n"
        "    t0 = time.perf_counter()\n"
        "    f = run_campaign(exps, n, p, backend='fused', **kw)\n"
        "    tf = min(tf, time.perf_counter() - t0)\n"
        "    t0 = time.perf_counter()\n"
        "    s = run_campaign(exps, n, p, backend='sharded', **kw)\n"
        "    ts = min(ts, time.perf_counter() - t0)\n"
        "ident = all(summarize_experiment(f[e]) == summarize_experiment(s[e])\n"
        "            for e in exps)\n"
        "print('DEVICES=%d' % len(jax.devices()))\n"
        "print('FUSED_US=%.0f' % (tf * 1e6))\n"
        "print('SHARDED_US=%.0f' % (ts * 1e6))\n"
        "print('IDENTICAL=%d' % ident)\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO_ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.pop("JAX_COMPILATION_CACHE_DIR", None)
    out = subprocess.run([sys.executable, "-c", child], capture_output=True,
                         text=True, env=env, check=True)
    vals = dict(line.split("=", 1) for line in out.stdout.splitlines()
                if "=" in line)
    devices = int(vals["DEVICES"])
    us_f8, us_s8 = float(vals["FUSED_US"]), float(vals["SHARDED_US"])
    identical = bool(int(vals["IDENTICAL"]))
    eff = us_f8 / us_s8
    return [
        (f"campaign_sharded_1dev_E1-E4_n{n1}p{p1}", us_s1,
         f"warm, {d1}-device mesh; fused_us={us_f1:.0f}, identical outputs",
         {"devices": d1, "fused_us": us_f1, "vs_fused": us_f1 / us_s1,
          "identical_outputs": True}),
        (f"campaign_sharded_8dev_E1-E4_n{n}p{p}", us_s8,
         f"warm, {devices} forced host devices; fused_us={us_f8:.0f}, "
         f"scaling_efficiency={eff:.3f} of ideal (shards share the host), "
         f"identical outputs",
         {"devices": devices, "fused_us": us_f8, "scaling_efficiency": eff,
          "identical_outputs": identical}),
    ]


def fused_h4_bisection(quick: bool = False) -> list:
    """The fused ``lax.scan`` H4 bisection (one dispatch per row-chunk for
    the WHOLE binary search) vs the host-driven probe loop it replaced
    (~iters+1 dispatches), identical outputs — dispatch counts recorded in
    ``derived`` so the O(1) contract is tracked across PRs."""
    from repro.core import batched, fused
    from repro.core.metrics import period, single_processor_mapping
    from repro.sim import gen_instance_batch

    n, p = (10, 10) if quick else (20, 100)
    B = 12 if quick else 48
    iters = 10
    batch = gen_instance_batch("E2", n, p, range(100, 100 + B))
    pb = batched._as_problem_batch(batch)
    fracs = np.tile([0.05, 0.2, 0.4, 0.6, 0.8, 1.0], B)[:B]
    bounds = np.array(
        [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
         for (wl, pf), f in zip(batch, fracs)])
    lo, hi = batched.h4_search_bounds(pb)

    batched.batched_sp_bi_p(pb, bounds, iters=iters,
                            backend="fused")  # cold: traces
    fused.reset_dispatch_count()
    t0 = time.perf_counter()
    rs_scan = batched.batched_sp_bi_p(pb, bounds, iters=iters, backend="fused")
    us_scan = (time.perf_counter() - t0) * 1e6
    d_scan = fused.dispatch_count()

    fused.reset_dispatch_count()
    t0 = time.perf_counter()
    rs_loop = batched._sp_bi_p_rowwise(pb, bounds, iters, "fused",
                                       lo.copy(), hi.copy(), True)
    us_loop = (time.perf_counter() - t0) * 1e6
    d_loop = fused.dispatch_count()

    for a, b in zip(rs_scan, rs_loop):
        assert (a.mapping == b.mapping and a.period == b.period
                and a.latency == b.latency and a.feasible == b.feasible
                and a.splits == b.splits)
    assert d_loop >= 2 * d_scan, (d_loop, d_scan)
    return [
        (f"campaign_fused_h4scan_n{n}p{p}_B{B}", us_scan,
         f"dispatches={d_scan} vs {d_loop} probe-loop "
         f"({d_loop / d_scan:.0f}x fewer), identical outputs",
         {"dispatches": d_scan, "identical_outputs": True}),
        (f"campaign_fused_h4probe_loop_n{n}p{p}_B{B}", us_loop,
         f"PR-3 style host-driven bisection, dispatches={d_loop}",
         {"dispatches": d_loop}),
    ]


def deal_speedup(quick: bool = False) -> list:
    """Satellite before/after: the deal extension's candidate enumeration as
    per-mapping ``_deal_metrics`` Python loops vs the stacked-numpy
    ``_DealState.candidate_metrics`` batch, on identical enumerations."""
    from repro.core import Mapping
    from repro.core.deal import _DealState, _deal_metrics

    rng = np.random.default_rng(7)
    n, p = 24, 64
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 51, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    m = 8
    cuts = sorted(rng.choice(np.arange(2, n), size=m - 1, replace=False))
    iv, prev = [], 1
    for c in list(cuts) + [n]:
        iv.append((prev, int(c)))
        prev = int(c) + 1
    mapping = Mapping(tuple(iv), tuple(range(m)))
    free = list(range(m, p))
    st = _DealState(wl, pf, mapping)
    j = 0
    reps = 20 if quick else 200

    t0 = time.perf_counter()
    for _ in range(reps):
        loop = np.array([
            _deal_metrics(wl, pf, mapping,
                          [[u] if t != j else [u, cand]
                           for t, u in enumerate(mapping.alloc)])
            for cand in free])
    us_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        batch = st.candidate_metrics(j, pf.s[np.asarray(free)])
    us_batch = (time.perf_counter() - t0) / reps * 1e6
    assert np.array_equal(loop, batch)
    k = len(free)
    return [
        (f"deal_enum_loop_{k}_candidates", us_loop,
         "per-candidate _deal_metrics Python loops"),
        (f"deal_enum_batched_{k}_candidates", us_batch,
         f"speedup={us_loop / us_batch:.1f}x, identical metrics"),
    ]


def run(quick: bool = False) -> list:
    # point the persistent compilation cache at a FRESH per-run directory:
    # the in-process cold rows below must measure real trace+compile cost
    # every run (a warm machine-wide cache would silently turn them into
    # cache loads and corrupt the cross-PR perf trajectory); the cache's
    # cross-process win is measured explicitly by fused_bucketed_cold_start
    import tempfile

    from repro.core.fused import enable_persistent_cache

    _cache_tmp = tempfile.TemporaryDirectory(prefix="repro-bench-jax-cache-")
    enable_persistent_cache(_cache_tmp.name)
    rows = timing(reps=2 if quick else 10)
    rows += vectorized_eval(reps=2 if quick else 5)
    rows += campaign_speedup(quick=quick)
    rows += fused_large_grid(quick=quick)
    rows += image_family_campaign(quick=quick)
    rows += sharded_campaign(quick=quick)
    rows += fused_h4_bisection(quick=quick)
    rows += fused_bucketed_cold_start(quick=quick)
    rows += split_score_pallas(quick=quick)
    rows += deal_speedup(quick=quick)
    gaps = optimality_gaps(n_inst=4 if quick else 20)
    for c, g in gaps.items():
        # quality-only rows: no us_per_call, the gap lives in `derived`
        rows.append((f"gap_vs_exact_{c}", None, f"gap={g:.4f}", {"gap": g}))
    return rows


def write_bench_json(rows, path: pathlib.Path = BENCH_JSON,
                     mode: str = "full") -> None:
    """Persist benchmark rows as {name: {us_per_call, derived, ...}} JSON.

    Rows are (name, us, derived) or (name, us, derived, extra): ``extra`` is
    a dict of STRUCTURED fields (numeric speedups, dispatch counts, cache
    deltas) merged into the row object — ``benchmarks/bench_gate.py`` reads
    those, so regressions fail CI on numbers, not string parsing.
    ``_meta.mode`` records quick vs full so cross-PR comparisons never mix
    the two (they use different reps/instance counts under the same names).
    """
    payload = {}
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        entry = {"us_per_call": us, "derived": derived}
        if len(row) > 3 and row[3]:
            entry.update(row[3])
        payload[name] = entry
    payload["_meta"] = {"mode": mode}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def format_row(name, us, derived, extra=None) -> str:
    return f"{name},{'' if us is None else f'{us:.1f}'},{derived}"


def main() -> None:
    quick = "--quick" in sys.argv
    rows = run(quick=quick)
    for row in rows:
        print(format_row(*row))
    write_bench_json(rows, mode="quick" if quick else "full")
    print(f"# wrote {BENCH_JSON}")


if __name__ == "__main__":
    main()
