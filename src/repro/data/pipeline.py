"""Deterministic data pipeline: synthetic LM streams + sharded global batches.

The dataset is a deterministic function of (seed, step) so that restart from a
checkpoint reproduces the exact token stream without persisting cursor state
beyond the step counter — the property the fault-tolerance tests rely on.
A background prefetch thread keeps ``prefetch`` batches ahead of the consumer.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class SyntheticLMDataset:
    """Deterministic synthetic token stream with a learnable structure
    (repeated n-gram motifs) so a ~100M model visibly learns."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, motif_len: int = 16, n_motifs: int = 64):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab_size, (n_motifs, motif_len))

    def batch(self, step: int) -> dict:
        """Batch for ``step`` — pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        n, m = self.motifs.shape
        reps = S // m + 2
        idx = rng.integers(0, n, (B, reps))
        stream = self.motifs[idx].reshape(B, reps * m)[:, : S + 1]
        noise = rng.random((B, S + 1)) < 0.05
        stream = np.where(noise, rng.integers(0, self.vocab_size, (B, S + 1)), stream)
        return {
            "tokens": stream[:, :-1].astype(np.int32),
            "labels": stream[:, 1:].astype(np.int32),
        }


def make_batch_sharding(mesh, batch_size: int):
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    first = (data_axes if len(data_axes) > 1 else data_axes[0]) \
        if data_axes and batch_size % dsize == 0 else None
    return NamedSharding(mesh, P(first))


class ShardedLoader:
    """Prefetching loader that device_puts batches with the data sharding."""

    def __init__(self, dataset: SyntheticLMDataset, mesh=None,
                 start_step: int = 0, prefetch: int = 2):
        self.dataset = dataset
        self.mesh = mesh
        self.step = start_step
        self.prefetch = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _sharding(self):
        if self.mesh is None:
            return None
        return make_batch_sharding(self.mesh, self.dataset.global_batch)

    def _produce(self):
        step = self.step
        sharding = self._sharding()
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            if sharding is not None:
                batch = {k: jax.device_put(v, sharding) for k, v in batch.items()}
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator:
        self._q = queue.Queue(maxsize=self.prefetch)
        self._stop.clear()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()
        try:
            while True:
                step, batch = self._q.get()
                self.step = step + 1
                yield step, batch
        finally:
            self._stop.set()

    def close(self):
        self._stop.set()
        if self._q is not None:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
