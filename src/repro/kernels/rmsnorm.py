"""Pallas TPU fused RMSNorm (optionally with residual add).

Rows stream through VMEM in blocks of ``block_rows``; the reduction runs in
fp32 on the VPU with the full feature dim resident (d_model lanes), one HBM
read + one write per element — the memory-bound ideal for a norm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _kernel_residual(x_ref, res_ref, scale_ref, o_ref, r_out_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    r_out_ref[...] = x.astype(r_out_ref.dtype)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d).  Row-blocked fused RMSNorm."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = 1
    for s in x.shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)
    block_rows = min(block_rows, n)
    if n % block_rows:
        block_rows = 1
    grid = (n // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)


def rmsnorm_residual(x: jax.Array, residual: jax.Array, scale: jax.Array, *,
                     eps: float = 1e-5, block_rows: int = 256,
                     interpret: bool = False) -> tuple:
    """Fused (x + residual) -> RMSNorm.  Returns (normed, new_residual)."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = 1
    for s in x.shape[:-1]:
        n *= s
    x2, r2 = x.reshape(n, d), residual.reshape(n, d)
    block_rows = min(block_rows, n)
    if n % block_rows:
        block_rows = 1
    grid = (n // block_rows,)
    out, res = pl.pallas_call(
        functools.partial(_kernel_residual, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((n, d), x.dtype),
        ],
        interpret=interpret,
    )(x2, r2, scale)
    return out.reshape(orig_shape), res.reshape(orig_shape)
