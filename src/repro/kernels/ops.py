"""Jitted public wrappers for the Pallas kernels.

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes in Python via XLA on CPU) so every call site — models, tests,
benchmarks — exercises the same code path that compiles for TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import mamba2_ssd as _ssd
from . import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def decode_attention(q, k, v, positions, pos, *, window: Optional[int] = None,
                     block_c: int = 512):
    """q: (B,H,hd); cache k,v: (B,C,K,hd); positions: (B,C) absolute positions
    stored per slot (-1 = empty); pos: (B,) current decode position."""
    valid = (positions >= 0) & (positions <= pos[:, None])
    if window is not None:
        valid &= positions > (pos[:, None] - window)
    return _dec.decode_attention(q, k, v, valid, block_c=min(block_c, k.shape[1]),
                                 interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256):
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm_residual(x, residual, scale, *, eps: float = 1e-5, block_rows: int = 256):
    return _rn.rmsnorm_residual(x, residual, scale, eps=eps,
                                block_rows=block_rows, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int = 256):
    return _ssd.ssd_chunked_kernel(x, dt, A, Bmat, Cmat, chunk,
                                   interpret=_interpret())
