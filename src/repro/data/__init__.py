from .pipeline import SyntheticLMDataset, ShardedLoader, make_batch_sharding

__all__ = ["SyntheticLMDataset", "ShardedLoader", "make_batch_sharding"]
