"""Planner quality + speed: heuristic optimality gap vs the exact solver on
small/medium instances, runtime scaling, and the vectorized candidate-
evaluation speedup (name,us_per_call,derived CSV).

    PYTHONPATH=src python benchmarks/planner_bench.py [--quick]
"""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import (Objective, PlanRequest, auto_request, evaluate,
                        evaluate_batch, exact_min_period, make_platform,
                        make_workload, pareto_exact, period, plan_request,
                        solve)
from repro.sim.generators import gen_instance


def optimality_gaps(n_inst: int = 20, seed: int = 0) -> dict:
    """Mean period gap (heuristic / exact - 1) on instances small enough for
    the exact bitmask solver (n<=14, p<=9)."""
    rng = np.random.default_rng(seed)
    gaps = {c: [] for c in ("H1", "H2", "H3", "auto")}
    for _ in range(n_inst):
        n = int(rng.integers(4, 14))
        p = int(rng.integers(3, 9))
        wl = make_workload(rng.integers(1, 21, n).astype(float),
                           rng.integers(1, 51, n + 1).astype(float))
        pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
        opt = period(wl, pf, exact_min_period(wl, pf))
        for code in ("H1", "H2", "H3"):
            # run to exhaustion: an unreachable period bound minimizes period
            c = solve(code, wl, pf, Objective("latency", bound=0.0))
            gaps[code].append(c.period / opt - 1)
        rep = plan_request(auto_request(wl, pf, Objective("period")))
        gaps["auto"].append(rep.plan.period / opt - 1)
    return {c: float(np.mean(v)) for c, v in gaps.items()}


def timing(reps: int = 10) -> list:
    """us_per_call for each solver at the paper's largest size (n=40, p=100),
    plus the full request/report portfolio."""
    rows = []
    wl, pf = gen_instance("E2", 40, 100, seed=1)
    for code in ("H1", "H2", "H3", "H5", "H6"):
        obj = (Objective("latency", bound=0.0) if code in ("H1", "H2", "H3")
               else Objective("period", bound=1e18))
        t0 = time.perf_counter()
        for _ in range(reps):
            solve(code, wl, pf, obj)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"heuristic_{code}_n40_p100", us, ""))
    t0 = time.perf_counter()
    plan_request(auto_request(wl, pf, Objective("period")))
    rows.append(("planner_auto_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    t0 = time.perf_counter()
    plan_request(PlanRequest(wl, pf, Objective("period")))
    rows.append(("plan_request_n40_p100", (time.perf_counter() - t0) * 1e6, ""))
    return rows


def vectorized_eval(reps: int = 5, seed: int = 3) -> list:
    """The tentpole perf claim: batch candidate evaluation vs the per-mapping
    Python loop, on the full mapping enumeration of a small instance (the
    workload of portfolio tables, sweeps, and pareto_exact)."""
    import itertools

    from repro.core import Mapping, all_interval_partitions

    rng = np.random.default_rng(seed)
    n, p = 8, 5
    wl = make_workload(rng.integers(1, 21, n).astype(float),
                       rng.integers(1, 51, n + 1).astype(float))
    pf = make_platform(rng.integers(1, 21, p).astype(float), 10.0)
    mappings = [Mapping(iv, procs)
                for m in range(1, min(n, p) + 1)
                for iv in all_interval_partitions(n, m)
                for procs in itertools.permutations(range(p), m)]

    t0 = time.perf_counter()
    for _ in range(reps):
        loop = np.array([evaluate(wl, pf, mp) for mp in mappings])
    us_loop = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        batch = evaluate_batch(wl, pf, mappings)
    us_batch = (time.perf_counter() - t0) / reps * 1e6
    assert np.allclose(loop, batch)

    t0 = time.perf_counter()
    for _ in range(reps):
        pareto_exact(wl, pf)
    us_pex = (time.perf_counter() - t0) / reps * 1e6

    k = len(mappings)
    return [
        (f"evaluate_loop_{k}_mappings", us_loop, ""),
        (f"evaluate_batch_{k}_mappings", us_batch,
         f"speedup={us_loop / us_batch:.1f}x"),
        (f"pareto_exact_n{n}_p{p}", us_pex, "vectorized enumeration"),
    ]


def run(quick: bool = False) -> list:
    rows = timing(reps=2 if quick else 10)
    rows += vectorized_eval(reps=2 if quick else 5)
    gaps = optimality_gaps(n_inst=4 if quick else 20)
    for c, g in gaps.items():
        rows.append((f"gap_vs_exact_{c}", 0.0, f"{g:.4f}"))
    return rows


def main() -> None:
    for name, us, derived in run(quick="--quick" in sys.argv):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
