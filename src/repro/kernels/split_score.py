"""Pallas split-scoring kernels: candidate-grid evaluation as masked tiles.

The chains-to-chains split scoring at the heart of the H1-H6 heuristics is a
masked-tile reduction: per batch row, a contiguous band of candidate lanes
(cuts of the worst interval) is live and everything beyond it is padding —
exactly the shape the repo's attention kernels handle with ``pl.when``
tile skipping.  This module implements the shared scoring kernels of
:mod:`repro.core.heuristics` as real ``pl.pallas_call`` kernels:

  - :func:`score_2way_pallas` — every 2-way split of the worst interval,
    both placement orders.  Lanes are (row, cut) tiles of ``block_a x
    block_k``; a per-row ``need`` column (the row's live cut count — 2-way
    cut lanes are span-prefix-valid) lets whole tiles beyond every row's
    span skip compute and zero-fill via ``pl.when``, mirroring the fused
    engine's span bucketing at tile granularity.
  - :func:`score_3way_pallas` — all (c1, c2) cut pairs x 6 processor
    permutations.  Pair lanes are laid out r1-major (the caller's triu
    order), so ``need`` carries the per-row last-valid-lane bound
    (:func:`pair_need`) and out-of-band tiles skip the same way.

Equivalence contract: inside the live lanes the kernels evaluate the SAME
expressions as ``score_2way_kernel``/``score_3way_kernel`` — including the
runtime-``zero`` FMA guard and the left-associated 3-part latency sum — so
in interpret mode (CPU; op-by-op float64 execution) outputs are bit-identical
to the numpy kernels on every live lane.  Skipped tiles are zero-filled;
callers mask them out of candidate selection by the same validity masks that
already exclude them on the numpy path, so heuristic outputs are identical
(asserted by the ``pallas`` column of tests/test_engine_equivalence.py).
Out of interpret mode the kernels compile for TPU/GPU, where the float64
contract is out of scope (devices score in their native dtype).

Selected behind ``repro.core.heuristics.score_kernels("pallas")`` —
``repro.core.batched`` exposes it as ``backend="pallas"``.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interpret() -> bool:
    """Interpret (emulate) off-device: CPU runs op-by-op in float64, which is
    what the bit-identity contract is asserted on."""
    return jax.default_backend() not in ("tpu", "gpu")


def _ensure_x64() -> None:
    """The bit-identity contract is float64: callers may invoke these kernels
    before anything else has flipped jax's x64 switch."""
    jax.config.update("jax_enable_x64", True)


def _ceil_to(a: int, m: int) -> int:
    return -(-a // m) * m


def pair_need(span, lanes: int):
    """Last-valid-lane bound (exclusive) per row for the r1-major (c1, c2)
    pair layout of ``lanes``-span grids: a row of span ``s`` has its last
    valid pair (r1, r2) = (s-3, s-2) at index ``(s-3)(L-2) - (s-3)(s-4)/2``
    (pairs are prefix-dense in r1-groups).  Rows with span < 3 need 0 lanes.
    """
    span = np.asarray(span, dtype=np.int64)
    o1 = np.maximum(span - 3, 0)
    need = o1 * (lanes - 2) - o1 * (o1 - 1) // 2 + 1
    return np.where(span >= 3, need, 0)


# ---------------------------------------------------------------------------
# 2-way kernel
# ---------------------------------------------------------------------------

def _score2_kernel(pre_d1_ref, pre_C_ref, pre_e_ref, del_d1_ref, del_C_ref,
                   del_e_ref, inv_j_ref, inv_p_ref, b_ref, zero_ref, need_ref,
                   cyc1a_ref, cyc1b_ref, cyc2a_ref, cyc2b_ref,
                   dlata_ref, dlatb_ref, *, block_k: int):
    lane0 = pl.program_id(1) * block_k
    # live-lane bound of this row tile: cut lanes are span-prefix-valid, so
    # tiles starting at or past every row's span carry only masked lanes
    tile_need = jnp.max(need_ref[...])

    @pl.when(lane0 < tile_need)
    def _compute():
        b = b_ref[0, 0]
        zero = zero_ref[0, 0]
        W1 = pre_C_ref[...] - pre_d1_ref[...]
        W2 = pre_e_ref[...] - pre_C_ref[...]
        dIn = del_d1_ref[...] / b
        dMid = del_C_ref[...] / b
        dOut = del_e_ref[...] / b
        inv_j = inv_j_ref[...]
        inv_p = inv_p_ref[...]
        # order A: first part stays on j; order B: swapped.  Same guarded
        # expressions as heuristics.score_2way_kernel, element for element.
        cyc1a_ref[...] = dIn + (W1 * inv_j + zero) + dMid
        cyc1b_ref[...] = dIn + (W1 * inv_p + zero) + dMid
        cyc2a_ref[...] = dMid + (W2 * inv_p + zero) + dOut
        cyc2b_ref[...] = dMid + (W2 * inv_j + zero) + dOut
        dlata_ref[...] = dMid + (W2 * (inv_p - inv_j) + zero)
        dlatb_ref[...] = dMid + (W1 * (inv_p - inv_j) + zero)

    @pl.when(lane0 >= tile_need)
    def _masked():
        for ref in (cyc1a_ref, cyc1b_ref, cyc2a_ref, cyc2b_ref,
                    dlata_ref, dlatb_ref):
            ref[...] = jnp.zeros_like(ref)


@functools.partial(jax.jit, static_argnames=("interpret", "block_a", "block_k"))
def _score2_call(pre_d1, pre_C, pre_e, del_d1, del_C, del_e, b, inv_j, inv_p,
                 zero, need, interpret, block_a, block_k):
    A, K = pre_C.shape
    Ap, Kp = _ceil_to(A, block_a), _ceil_to(K, block_k)
    pad_l = ((0, Ap - A), (0, Kp - K))
    pad_c = ((0, Ap - A), (0, 0))
    lanes = [jnp.pad(x, pad_l) for x in (pre_C, del_C)]
    cols = [jnp.pad(jnp.broadcast_to(x, (A, 1)), pad_c)
            for x in (pre_d1, pre_e, del_d1, del_e, inv_j, inv_p)]
    need_p = jnp.pad(need.reshape(A, 1), pad_c)
    scal = [jnp.reshape(x, (1, 1)) for x in (b, zero)]
    lanespec = pl.BlockSpec((block_a, block_k), lambda i, j: (i, j))
    colspec = pl.BlockSpec((block_a, 1), lambda i, j: (i, 0))
    scalspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_score2_kernel, block_k=block_k),
        grid=(Ap // block_a, Kp // block_k),
        in_specs=[colspec, lanespec, colspec, colspec, lanespec, colspec,
                  colspec, colspec, scalspec, scalspec, colspec],
        out_specs=[lanespec] * 6,
        out_shape=[jax.ShapeDtypeStruct((Ap, Kp), pre_C.dtype)] * 6,
        interpret=interpret,
    )(cols[0], lanes[0], cols[1], cols[2], lanes[1], cols[3], cols[4],
      cols[5], *scal, need_p)
    cyc1a, cyc1b, cyc2a, cyc2b, dlata, dlatb = (o[:A, :K] for o in outs)
    return (jnp.concatenate([cyc1a, cyc1b], axis=-1),
            jnp.concatenate([cyc2a, cyc2b], axis=-1),
            jnp.concatenate([dlata, dlatb], axis=-1))


def score_2way_pallas(pre_d1, pre_C, pre_e, delta_d1, delta_C, delta_e, b,
                      inv_j, inv_p, *, zero=0.0, need=None, interpret=None,
                      block_a: int = 8, block_k: int = 128):
    """Pallas mirror of ``heuristics.score_2way_kernel`` (batched shapes:
    lanes (A, K), interval-end columns (A, 1)).  ``need`` is the per-row
    live-cut count (``e - d``); lanes at or past it sit in skippable tiles.
    Returns ``(cyc1, cyc2, dlat)`` with both placement orders concatenated
    along the last axis, exactly like the shared kernel."""
    _ensure_x64()
    pre_C = jnp.asarray(pre_C)
    A, K = pre_C.shape
    if interpret is None:
        interpret = _interpret()
    if need is None:
        need = np.full(A, K)
    return _score2_call(pre_d1, pre_C, pre_e, delta_d1, delta_C, delta_e,
                        jnp.asarray(b, pre_C.dtype), inv_j, inv_p,
                        jnp.asarray(zero, pre_C.dtype),
                        jnp.asarray(need, jnp.int64), interpret,
                        int(block_a), int(block_k))


# ---------------------------------------------------------------------------
# 3-way kernel
# ---------------------------------------------------------------------------

def _score3_kernel(dI_ref, W_ref, dO_ref, invp_ref, base_ref, zero_ref,
                   need_ref, cyc_ref, dlat_ref, mx_ref, *, block_k: int):
    lane0 = pl.program_id(1) * block_k
    tile_need = jnp.max(need_ref[...])

    @pl.when(lane0 < tile_need)
    def _compute():
        zero = zero_ref[0, 0]
        dI = dI_ref[...][:, None, :, :]          # (BA, 1, 3, BK)
        W = W_ref[...][:, None, :, :]
        dO = dO_ref[...][:, None, :, :]
        invp = invp_ref[...][:, :, :, None]      # (BA, 6, 3, 1)
        base = base_ref[...][:, :, None]         # (BA, 1, 1)
        # same guarded expressions as heuristics.score_3way_kernel: the part
        # sum is spelled left-associated so traced reductions keep numpy's
        # element order
        comp = dI + (W * invp + zero)
        cyc = comp + dO
        cyc_ref[...] = cyc
        dlat_ref[...] = (comp[..., 0, :] + comp[..., 1, :]
                         + comp[..., 2, :]) - base
        mx_ref[...] = cyc.max(axis=-2)

    @pl.when(lane0 >= tile_need)
    def _masked():
        for ref in (cyc_ref, dlat_ref, mx_ref):
            ref[...] = jnp.zeros_like(ref)


@functools.partial(jax.jit, static_argnames=("interpret", "block_a", "block_k"))
def _score3_call(dI, W, dO, invp, base_term, zero, need, interpret,
                 block_a, block_k):
    A, _, K = dI.shape
    Ap, Kp = _ceil_to(A, block_a), _ceil_to(K, block_k)
    pad_l = ((0, Ap - A), (0, 0), (0, Kp - K))
    lanes = [jnp.pad(x, pad_l) for x in (dI, W, dO)]
    invp_p = jnp.pad(invp, ((0, Ap - A), (0, 0), (0, 0)))
    base_p = jnp.pad(base_term.reshape(A, 1), ((0, Ap - A), (0, 0)))
    need_p = jnp.pad(need.reshape(A, 1), ((0, Ap - A), (0, 0)))
    lanespec = pl.BlockSpec((block_a, 3, block_k), lambda i, j: (i, 0, j))
    permspec = pl.BlockSpec((block_a, 6, 3), lambda i, j: (i, 0, 0))
    colspec = pl.BlockSpec((block_a, 1), lambda i, j: (i, 0))
    scalspec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_score3_kernel, block_k=block_k),
        grid=(Ap // block_a, Kp // block_k),
        in_specs=[lanespec, lanespec, lanespec, permspec, colspec, scalspec,
                  colspec],
        out_specs=[
            pl.BlockSpec((block_a, 6, 3, block_k), lambda i, j: (i, 0, 0, j)),
            pl.BlockSpec((block_a, 6, block_k), lambda i, j: (i, 0, j)),
            pl.BlockSpec((block_a, 6, block_k), lambda i, j: (i, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Ap, 6, 3, Kp), dI.dtype),
            jax.ShapeDtypeStruct((Ap, 6, Kp), dI.dtype),
            jax.ShapeDtypeStruct((Ap, 6, Kp), dI.dtype),
        ],
        interpret=interpret,
    )(*lanes, invp_p, base_p, jnp.reshape(zero, (1, 1)), need_p)
    cyc, dlat, mx = outs
    return cyc[:A, :, :, :K], dlat[:A, :, :K], mx[:A, :, :K]


def score_3way_pallas(dI, W, dO, invp, base_term, *, zero=0.0, need=None,
                      interpret=None, block_a: int = 8, block_k: int = 128):
    """Pallas mirror of ``heuristics.score_3way_kernel`` for the batched
    call shapes: ``dI``/``W``/``dO`` (A, 1, 3, K) carrying the three parts on
    axis -2 and the r1-major (c1, c2) pair lanes on axis -1, ``invp``
    (A, 6, 3, 1), ``base_term`` (A, 1, 1).  ``need`` is the per-row
    last-valid-lane bound (:func:`pair_need`).  Returns ``(cyc, dlat, mx)``
    shaped (A, 6, 3, K) / (A, 6, K) / (A, 6, K) like the shared kernel."""
    _ensure_x64()
    dI = jnp.asarray(dI)
    A = dI.shape[0]
    K = dI.shape[-1]
    if interpret is None:
        interpret = _interpret()
    if need is None:
        need = np.full(A, K)
    return _score3_call(dI.reshape(A, 3, K), jnp.asarray(W).reshape(A, 3, K),
                        jnp.asarray(dO).reshape(A, 3, K),
                        jnp.asarray(invp).reshape(A, 6, 3),
                        jnp.asarray(base_term), jnp.asarray(zero, dI.dtype),
                        jnp.asarray(need, jnp.int64), interpret,
                        int(block_a), int(block_k))
