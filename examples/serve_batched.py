"""Batched serving example: continuous-batching decode over a request pool.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import json

from repro.launch.serve import serve_pool


def main() -> None:
    out = serve_pool(arch="qwen3-4b", smoke=True, n_requests=12, batch=4,
                     prompt_len=16, max_new=24)
    print(json.dumps(out, indent=2))
    assert out["all_done"]


if __name__ == "__main__":
    main()
