"""Bi-criteria sweeps: trace (period, latency) trade-off curves with the
paper's heuristics, and compute Pareto fronts."""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from .heuristics import (FIXED_LATENCY_HEURISTICS, FIXED_PERIOD_HEURISTICS,
                         HeuristicResult, run_heuristic)
from .platform import Platform
from .workload import Workload


def pareto_front(points: Iterable, rtol: float = 1e-9) -> list:
    """Non-dominated subset of (period, latency) points, sorted by period.
    Points whose coordinates differ by less than ``rtol`` (relative) are
    considered equal, so floating-point noise cannot leak dominated points."""
    pts = sorted(set((float(a), float(b)) for a, b in points))
    front = []
    best_lat = float("inf")
    for per, lat in pts:
        if lat < best_lat * (1 - rtol):
            # drop a predecessor with (numerically) equal period but worse latency
            while front and per <= front[-1][0] * (1 + rtol) and lat < front[-1][1]:
                front.pop()
            front.append((per, lat))
            best_lat = lat
    return front


def sweep_heuristic(
    code: str,
    workload: Workload,
    platform: Platform,
    bounds: Sequence[float],
) -> list:
    """Run heuristic ``code`` for every bound; return list of HeuristicResult."""
    return [run_heuristic(code, workload, platform, float(b)) for b in bounds]


def default_period_grid(workload: Workload, platform: Platform, k: int = 20) -> np.ndarray:
    """Geometric grid of fixed-period bounds between the best single-processor
    cycle / p and the single-processor period."""
    from .metrics import period, single_processor_mapping

    hi = period(workload, platform, single_processor_mapping(workload, platform.fastest()))
    lo = max(hi / (2 * platform.p), 1e-9)
    return np.geomspace(lo, hi, k)


def default_latency_grid(workload: Workload, platform: Platform, k: int = 20) -> np.ndarray:
    from .metrics import optimal_latency

    lo = optimal_latency(workload, platform)
    hi = lo * 5.0
    return np.linspace(lo, hi, k)


def tradeoff_curves(workload: Workload, platform: Platform, k: int = 20) -> dict:
    """For each heuristic, the list of achieved (period, latency) points over a
    grid of bounds (the paper's Figures 2-7 are averages of these across
    random instances)."""
    out = {}
    pgrid = default_period_grid(workload, platform, k)
    lgrid = default_latency_grid(workload, platform, k)
    for code in FIXED_PERIOD_HEURISTICS:
        res = sweep_heuristic(code, workload, platform, pgrid)
        out[code] = [(r.period, r.latency) for r in res if r.feasible]
    for code in FIXED_LATENCY_HEURISTICS:
        res = sweep_heuristic(code, workload, platform, lgrid)
        out[code] = [(r.period, r.latency) for r in res if r.feasible]
    return out
