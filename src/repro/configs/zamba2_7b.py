"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        attn_every=6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-7b-smoke", family="hybrid",
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_chunk=32,
        attn_every=2,
    )
