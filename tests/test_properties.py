"""Hypothesis property tests over the core system invariants."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Mapping, Objective, all_interval_partitions,
                        exact_min_period, latency, make_platform,
                        make_workload, optimal_latency, pareto_front, period,
                        plan, run_heuristic, single_processor_mapping)

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@st.composite
def instances(draw, n_max=12, p_max=8):
    n = draw(st.integers(2, n_max))
    p = draw(st.integers(2, p_max))
    w = draw(st.lists(st.floats(0.1, 100), min_size=n, max_size=n))
    delta = draw(st.lists(st.floats(0.0, 100), min_size=n + 1, max_size=n + 1))
    s = draw(st.lists(st.floats(0.5, 20), min_size=p, max_size=p))
    b = draw(st.floats(0.5, 50))
    return make_workload(w, delta), make_platform(s, b)


@given(instances())
def test_latency_lower_bound_is_fastest_processor(inst):
    """Lemma 1: no mapping has latency below all-on-fastest."""
    wl, pf = inst
    lopt = optimal_latency(wl, pf)
    # check several random-ish mappings
    for m in range(1, min(wl.n, pf.p, 4) + 1):
        for intervals in list(all_interval_partitions(wl.n, m))[:5]:
            procs = tuple(np.argsort(-pf.s)[:m])
            mp = Mapping(intervals, procs)
            assert latency(wl, pf, mp) >= lopt - 1e-9


@given(instances())
def test_period_at_most_latency(inst):
    """For any single mapping, the max cycle (period) never exceeds the sum
    (latency) plus output-comm asymmetry allowance."""
    wl, pf = inst
    mp = single_processor_mapping(wl, pf.fastest())
    assert period(wl, pf, mp) <= latency(wl, pf, mp) + 1e-9


@given(instances(n_max=10, p_max=6))
def test_heuristics_feasibility_contract(inst):
    wl, pf = inst
    single_per = period(wl, pf, single_processor_mapping(wl, pf.fastest()))
    for code in ("H1", "H2", "H3"):
        r = run_heuristic(code, wl, pf, single_per)  # always feasible bound
        assert r.feasible
        assert r.period <= single_per + 1e-9
        r.mapping.validate(wl.n, pf.p)
    lopt = optimal_latency(wl, pf)
    for code in ("H5", "H6"):
        r = run_heuristic(code, wl, pf, lopt * 1.5)
        assert r.feasible
        assert r.latency <= lopt * 1.5 + 1e-9


@given(instances(n_max=8, p_max=6))
def test_more_processors_never_hurt_h1(inst):
    """Adding a processor cannot worsen H1's exhaustion-run period."""
    wl, pf = inst
    r_small = run_heuristic("H1", wl, pf, 0.0)
    s2 = np.concatenate([pf.s, [pf.s.max()]])
    pf2 = make_platform(s2, pf.b)
    r_big = run_heuristic("H1", wl, pf2, 0.0)
    assert r_big.period <= r_small.period + 1e-9


@given(st.lists(st.tuples(st.floats(0.1, 100), st.floats(0.1, 100)),
                min_size=1, max_size=50))
def test_pareto_front_nondominated(points):
    front = pareto_front(points)
    # every front point must be non-dominated by any input point
    for fp in front:
        for q in points:
            assert not (q[0] < fp[0] * (1 - 1e-9) and q[1] < fp[1] * (1 - 1e-9))
    # front sorted and strictly improving in latency
    for a, b in zip(front, front[1:]):
        assert a[0] <= b[0] and a[1] >= b[1]


@given(instances(n_max=6, p_max=4))
def test_exact_min_period_dominates_heuristics(inst):
    wl, pf = inst
    opt = exact_min_period(wl, pf)
    assert opt is not None
    opt_per = period(wl, pf, opt)
    for code in ("H1", "H2", "H3"):
        r = run_heuristic(code, wl, pf, 0.0)
        assert r.period >= opt_per - 1e-9


@given(instances(n_max=10, p_max=6))
def test_planner_auto_objective(inst):
    wl, pf = inst
    p = plan(wl, pf, Objective("period"), mode="auto")
    p.mapping.validate(wl.n, pf.p)
    assert sum(p.stage_sizes) == wl.n
    assert p.max_stage_size == max(p.stage_sizes)
    assert 0.0 <= p.padding_overhead < 1.0
    # planner's period is realized by its own mapping
    assert period(wl, pf, p.mapping) == pytest.approx(p.period)
