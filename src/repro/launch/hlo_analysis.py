"""Loop-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` on XLA:CPU counts each ``while`` body ONCE, which
silently undercounts every scan-over-layers model by ~n_layers x.  This module
parses ``compiled.as_text()`` instead:

 1. builds the computation call graph — while bodies with their trip counts
    (from the ``known_trip_count`` backend config, falling back to the loop
    condition's comparison constant), fusion/call/conditional edges;
 2. multiplies per-computation costs by the product of enclosing trip counts;
 3. reports:
      - dot_flops        : MXU flops from `dot` ops (2 * result * contraction)
      - bytes_accessed   : HBM-traffic model — per materializing op, result +
                           resolved operand bytes; dynamic-(update-)slice and
                           slicing fusions charged at slice size (in-place /
                           streaming reads); fusion internals excluded;
      - collective_bytes : summed *operand* bytes of all-reduce / all-gather /
                           reduce-scatter / all-to-all / collective-permute
                           (the spec'd roofline numerator), with a per-kind
                           breakdown and counts.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branches=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops that materialize HBM traffic on TPU.  Deliberately excluded (they fuse
# into neighbors or are layout-only on TPU): broadcast, iota, transpose,
# select, pad, reverse, bitcast, reshape.
_TRAFFIC_OPS = {
    "dot", "fusion", "copy", "reduce", "reduce-window", "sort", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice",
    "concatenate", "slice", "convolution",
    "select-and-scatter", "custom-call", "rng", "cholesky",
    "triangular-solve",
} | set(COLLECTIVES)


def _type_bytes(type_str: str) -> int:
    """Bytes of a result type annotation (array or tuple)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _operands(line: str, op_start: int) -> List[str]:
    """%names inside the op's argument parens."""
    lp = line.find("(", op_start)
    if lp < 0:
        return []
    depth = 0
    for i in range(lp, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_RE.findall(line[lp:i])
    return _OPERAND_RE.findall(line[lp:])


def parse(text: str):
    """-> (comps: name -> [parsed op dicts], sizes: (comp, %name) -> bytes,
    dims: (comp, %name) -> list of per-array dim tuples).

    Symbol tables are PER COMPUTATION: HLO op names (param_0.1, ...) repeat
    across computations, so a global table would corrupt operand lookups."""
    comps: Dict[str, list] = {}
    sizes: Dict[tuple, int] = {}
    dims: Dict[tuple, list] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        m = _COMP_HEADER_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, type_str, kind = om.group(1), om.group(2), om.group(3)
        sizes[(cur, name)] = _type_bytes(type_str)
        dims[(cur, name)] = [
            tuple(int(x) for x in dd.split(",") if x)
            for _, dd in _SHAPE_RE.findall(type_str)
        ]
        comps[cur].append({
            "name": name, "kind": kind, "type_bytes": sizes[(cur, name)],
            "line": line, "op_end": om.end() - 1,
        })
    return comps, sizes, dims


def call_multipliers(comps) -> tuple:
    edges = defaultdict(list)
    unknown = []
    for name, ops in comps.items():
        for op in ops:
            line = op["line"]
            if op["kind"] == "while":
                wm = _WHILE_RE.search(line)
                if not wm:
                    continue
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trips = [int(c) for o in comps.get(cond, ())
                             for c in _CONST_RE.findall(o["line"])]
                    trip = max(trips) if trips else 1
                    if not trips:
                        unknown.append(body)
                edges[name].append((body, trip))
                edges[name].append((cond, trip))
                continue
            if op["kind"] == "conditional":
                b = _BRANCHES_RE.search(line)
                if b:
                    for br in b.group(1).split(","):
                        edges[name].append((br.strip().lstrip("%"), 1))
            for callee in _CALLS_RE.findall(line):
                edges[name].append((callee, 1))

    called = {c for outs in edges.values() for c, _ in outs}
    mult = {}
    for _ in range(len(comps) + 1):
        new = {name: (1.0 if name not in called else 0.0) for name in comps}
        for name, outs in edges.items():
            for callee, factor in outs:
                if callee in new:
                    new[callee] += mult.get(name, 1.0 if name not in called else 0.0) * factor
        if new == mult:
            break
        mult = new
    return mult, unknown


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def analyze(text: str, detail: bool = False) -> dict:
    comps, sizes, dims = parse(text)
    mult, unknown = call_multipliers(comps)

    fusion_comps = set()
    slicing_fusions = set()
    for name, ops in comps.items():
        for op in ops:
            if op["kind"] == "fusion":
                for callee in _CALLS_RE.findall(op["line"]):
                    fusion_comps.add(callee)
    dus_fusions = set()
    for fc in fusion_comps:
        for op in comps.get(fc, ()):
            if op["kind"] in ("dynamic-slice", "slice"):
                slicing_fusions.add(fc)
            if op["kind"] == "dynamic-update-slice":
                dus_fusions.add(fc)

    flops = 0.0
    bytes_accessed = 0.0
    bytes_by_kind = defaultdict(float)
    coll = defaultdict(float)
    coll_count = defaultdict(int)
    detail_rows: list = []

    for name_comp, ops in comps.items():
        k = mult.get(name_comp, 0.0)
        if k == 0.0:
            continue
        in_fusion = name_comp in fusion_comps
        for op in ops:
            kind = op["kind"]
            line = op["line"]
            name = op["name"]
            if kind == "dot":
                shapes = _SHAPE_RE.findall(line)
                res_elems = 1
                if shapes:
                    for d in shapes[0][1].split(","):
                        if d:
                            res_elems *= int(d)
                opnds = _operands(line, op["op_end"])
                cm = _DOT_CONTRACT_RE.search(line)
                contract = 1
                lhs_dims = None
                if len(shapes) > 1:            # operand annotated inline
                    lhs_dims = tuple(int(x) for x in shapes[1][1].split(",") if x)
                elif opnds:                     # resolve in this computation
                    dl = dims.get((name_comp, opnds[0]))
                    if dl and len(dl) == 1:
                        lhs_dims = dl[0]
                if cm and lhs_dims is not None:
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                flops += k * 2.0 * res_elems * max(contract, 1)
            if in_fusion:
                continue
            if kind.endswith("-done"):
                continue
            base = kind[:-6] if kind.endswith("-start") else kind
            opnd_bytes = [sizes.get((name_comp, o), 0)
                          for o in _operands(line, op["op_end"])]
            if base in COLLECTIVES:
                ob = sum(opnd_bytes) if opnd_bytes else op["type_bytes"]
                coll[base] += k * ob
                coll_count[base] += max(int(k), 1)
                bytes_accessed += k * ob
                continue
            if base not in _TRAFFIC_OPS:
                continue
            # Traffic model: every materialized tensor is written once and
            # read ~once downstream => 2 x result bytes; in-place updates
            # (DUS and DUS-rooted fusions) cost 2 x the update slice; dots
            # additionally stream their operands (weights re-read per use).
            res_b = op["type_bytes"]
            if base == "dynamic-update-slice" and len(opnd_bytes) >= 2:
                contrib = k * 2 * opnd_bytes[1]
            elif base in ("dynamic-slice", "slice"):
                contrib = k * 2 * res_b
            elif base == "dot":
                contrib = k * (res_b + sum(opnd_bytes))
            elif base == "fusion":
                callee = next(iter(_CALLS_RE.findall(line)), None)
                if callee in dus_fusions:
                    small = sum(b for b in opnd_bytes if b < res_b)
                    contrib = k * 2 * small
                elif callee in slicing_fusions:
                    contrib = k * 2 * res_b
                else:
                    contrib = k * 2 * res_b
            else:
                contrib = k * 2 * res_b
            bytes_accessed += contrib
            bytes_by_kind[base] += contrib
            if detail and contrib > 0:
                import re as _re

                mm = _re.search(r'op_name="([^"]*)"', line)
                detail_rows.append((contrib, k, base, res_b,
                                    (mm.group(1) if mm else "?")[-85:]))

    return {
        "dot_flops": flops,
        "bytes_accessed": bytes_accessed,
        "bytes_by_kind": dict(bytes_by_kind),
        "collective_bytes": float(sum(coll.values())),
        "collectives": dict(coll),
        "collective_counts": dict(coll_count),
        "unknown_loops": unknown,
        "n_computations": len(comps),
        "detail": sorted(detail_rows, reverse=True) if detail else None,
    }
